#!/usr/bin/env bash
# Budget smoke: the closed-loop bit budget and the quantized downlink
# on a real TCP run, contrasted against the same run with both knobs
# effectively off.
#
# Runs `feddq serve` twice with the same seed, two workers each on the
# built-in native manifest (FEDDQ_NATIVE_CLIENTS=2), under a fixed
# 8-bit uplink policy with error feedback: once with `--downlink-bits
# 32` (fp32 broadcast, ledger only — the baseline costs) and once with
# a ~2-bit/element round cap (`--bit-budget`) plus a 4-bit quantized
# downlink.  The budgeted run must complete every round, ship strictly
# fewer uplink bits than the free 8-bit run, pay the full fp32 frame
# only for the round-0 init, and undercut the baseline's broadcast
# ledger overall — while both runs remain plain, loss-finite sessions.
#
# CI runs this in the budget-smoke job; it also works locally:
#
#     scripts/budget_smoke.sh
set -euo pipefail
cd "$(dirname "$0")/.."

FREE_ADDR="${BUDGET_FREE_ADDR:-127.0.0.1:17885}"
CAPPED_ADDR="${BUDGET_CAPPED_ADDR:-127.0.0.1:17887}"
ROUNDS="${BUDGET_ROUNDS:-6}"
# mlp is d = 101770; 2 clients at ~2 bits/element per round
CAP=$((2 * 101770 * 2))
FREE_REPORT="$(mktemp -t budget_free.XXXXXX.json)"
CAPPED_REPORT="$(mktemp -t budget_capped.XXXXXX.json)"
export FEDDQ_NATIVE_CLIENTS=2

cargo build --release --locked

cleanup() {
    kill -9 "${SERVE_PID:-}" "${W0_PID:-}" "${W1_PID:-}" 2>/dev/null || true
}
trap cleanup EXIT

# one_run <addr> <report> <extra flags...>: serve + 2 workers to completion
one_run() {
    local addr="$1" report="$2"
    shift 2
    echo "== serve on $addr ($ROUNDS rounds, fixed:8 + EF, $*) =="
    target/release/feddq serve --addr "$addr" --rounds "$ROUNDS" \
        --train-size 2000 --test-size 500 \
        --policy fixed:8 --error-feedback \
        "$@" --out "$report" &
    SERVE_PID=$!
    target/release/feddq worker --addr "$addr" --id 0 &
    W0_PID=$!
    target/release/feddq worker --addr "$addr" --id 1 &
    W1_PID=$!
    wait "$SERVE_PID"
    wait "$W0_PID"
    wait "$W1_PID"
}

one_run "$FREE_ADDR" "$FREE_REPORT" --downlink-bits 32
one_run "$CAPPED_ADDR" "$CAPPED_REPORT" --bit-budget "$CAP" --downlink-bits 4

echo "== verifying the budgeted run undercuts the free run on both ledgers =="
python3 - "$FREE_REPORT" "$CAPPED_REPORT" "$ROUNDS" "$CAP" <<'EOF'
import json
import sys

with open(sys.argv[1]) as f:
    free = json.load(f)["rounds"]
with open(sys.argv[2]) as f:
    capped = json.load(f)["rounds"]
want = int(sys.argv[3])
cap = int(sys.argv[4])
D = 101770
free_up = int(free[-1]["cum_uplink_bits"])
capped_up = int(capped[-1]["cum_uplink_bits"])
free_down = int(free[-1]["cum_downlink_bits"])
capped_down = int(capped[-1]["cum_downlink_bits"])
print(f"  rounds {len(capped)}/{want}; uplink free {free_up} vs capped {capped_up}; "
      f"downlink fp32 {free_down} vs 4-bit {capped_down}")
ok = True
if len(free) != want or len(capped) != want:
    print("  FAIL: both runs must complete every round")
    ok = False
if int(capped[0]["downlink_bits"]) != 2 * D * 32:
    print("  FAIL: round 0 must be the full fp32 init broadcast")
    ok = False
# header + byte-padding slack: 4 segments x 2 clients
slack = 2 * 4 * (88 + 7)
over = [r["round"] for r in capped if int(r["uplink_bits"]) > cap + slack]
if over:
    print(f"  FAIL: rounds {over} exceed the {cap}-bit budget (+{slack} slack)")
    ok = False
if not capped_up < free_up:
    print("  FAIL: the bit budget must shrink the uplink ledger")
    ok = False
if not capped_down < free_down:
    print("  FAIL: the 4-bit downlink must undercut the fp32 broadcast ledger")
    ok = False
if any(float(r["train_loss"]) != float(r["train_loss"]) for r in capped):
    print("  FAIL: budgeted training must stay finite")
    ok = False
sys.exit(0 if ok else 1)
EOF
echo "budget smoke passed"
