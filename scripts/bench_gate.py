#!/usr/bin/env python3
"""CI perf-regression gate over the flat BENCH_*.json maps.

Compares a freshly generated bench JSON against the committed baseline
and fails (exit 1) when any tracked *throughput* row regresses more
than the tolerance (default 30%, override with BENCH_GATE_TOLERANCE,
e.g. 0.30).

Gating policy, chosen to keep CI signal high on shared runners:

* ``*_gbps`` keys (higher is better) are **gated**: fresh must be at
  least ``baseline * (1 - tolerance)``.
* ``*_secs`` and ``*_speedup*`` keys are **informational only** — raw
  wall times on shared CI hardware are too noisy to fail a build on,
  and speedups divide two noisy numbers.
* Baseline values that are zero or negative are treated as *unseeded*:
  reported, never failed.  This bootstraps the gate on a machine class
  that has not produced a calibrated baseline yet; commit a real bench
  run's JSON to arm it.
* A **gated** (``*_gbps``) row with an armed baseline that is missing
  from the fresh run **fails**: renaming or deleting a bench must come
  with a baseline update, otherwise coverage would silently disappear.
  Missing informational rows only warn.
* Keys present only in the fresh run are new rows — reported, passing.
* ``--require-armed`` turns the unseeded-baseline warning into a hard
  failure: if every gated row's baseline is still zero-seeded the gate
  exits 1.  CI passes this flag so a repo whose committed baselines were
  never calibrated fails loudly instead of green-lighting regressions
  forever.  Arm it with ``scripts/calibrate_bench.sh`` on a
  toolchain-equipped host and commit the regenerated ``BENCH_*.json``.

Usage:
    bench_gate.py --baseline path/to/committed.json --fresh path/to/new.json [--require-armed]
"""

import argparse
import json
import os
import sys


def load(path):
    with open(path) as f:
        data = json.load(f)
    if not isinstance(data, dict):
        sys.exit(f"{path}: expected a flat JSON object of name -> number")
    return {k: v for k, v in data.items() if isinstance(v, (int, float))}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True, help="committed baseline JSON")
    ap.add_argument("--fresh", required=True, help="freshly generated JSON")
    ap.add_argument(
        "--require-armed",
        action="store_true",
        help="fail (exit 1) when every gated baseline row is still zero-seeded",
    )
    args = ap.parse_args()

    tolerance = float(os.environ.get("BENCH_GATE_TOLERANCE", "0.30"))
    baseline = load(args.baseline)
    fresh = load(args.fresh)

    failures = []
    print(f"== bench gate: {args.fresh} vs {args.baseline} (tolerance {tolerance:.0%}) ==")
    for key in sorted(set(baseline) | set(fresh)):
        b = baseline.get(key)
        f = fresh.get(key)
        if f is None:
            if key.endswith("_gbps") and b is not None and b > 0:
                failures.append((key, b, None))
                print(f"  FAIL     {key}: armed baseline row missing from fresh run (update the baseline if the bench was renamed/removed)")
            else:
                print(f"  MISSING  {key}: in baseline but not regenerated")
            continue
        if b is None:
            print(f"  NEW      {key}: {f:.4g}")
            continue
        if not key.endswith("_gbps"):
            print(f"  INFO     {key}: {b:.4g} -> {f:.4g}")
            continue
        if b <= 0:
            print(f"  UNSEEDED {key}: baseline {b:.4g}, fresh {f:.4g} (commit a calibrated baseline to arm)")
            continue
        floor = b * (1.0 - tolerance)
        if f < floor:
            failures.append((key, b, f))
            print(f"  FAIL     {key}: {f:.4g} GB/s < {floor:.4g} (baseline {b:.4g}, -{(1 - f / b):.0%})")
        else:
            print(f"  OK       {key}: {b:.4g} -> {f:.4g} GB/s ({(f / b - 1):+.0%})")

    if failures:
        print(f"\n{len(failures)} throughput row(s) regressed more than {tolerance:.0%} or went missing")
        sys.exit(1)
    gated = [k for k in baseline if k.endswith("_gbps")]
    if gated and all(baseline[k] <= 0 for k in gated):
        print("\nWARNING: every gated row is unseeded — the regression gate is UNARMED.")
        print("Run scripts/calibrate_bench.sh on a toolchain-equipped host and commit")
        print("the regenerated BENCH_*.json as the baseline to arm it.")
        if args.require_armed:
            print("--require-armed: refusing to pass with an unarmed gate.")
            sys.exit(1)
    print("\nbench gate passed")


if __name__ == "__main__":
    main()
