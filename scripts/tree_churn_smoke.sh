#!/usr/bin/env bash
# Tree churn smoke: a real TCP tree run that survives its aggregator
# being killed.
#
# Runs `feddq serve --fanout 2` with quorum aggregation enabled, one
# `feddq aggregate` process owning the only subtree, and two leaf
# workers on the built-in native manifest (FEDDQ_NATIVE_CLIENTS=2).
# Mid-run the aggregator is `kill -9`'d and restarted: the restarted
# process must rejoin upstream (two-step handshake through the tree
# rejoin accept loop), re-accept its leaves (which retry their
# aggregator with bounded backoff), and be adopted mid-round by the
# server's failover poll.  The run must finish every round (exit 0),
# and the written report must record at least one `subtree_failed`
# round (the kill) and at least one `rejoined` aggregator (the
# restart).
#
# CI runs this in the churn-smoke job; it also works locally:
#
#     scripts/tree_churn_smoke.sh
set -euo pipefail
cd "$(dirname "$0")/.."

ADDR="${CHURN_ADDR:-127.0.0.1:17883}"
AGG_ADDR="${CHURN_AGG_ADDR:-127.0.0.1:17884}"
ROUNDS="${CHURN_ROUNDS:-40}"
REPORT="$(mktemp -t tree_churn_report.XXXXXX.json)"
SERVE_LOG="$(mktemp -t tree_churn_serve.XXXXXX.log)"
export FEDDQ_NATIVE_CLIENTS=2

cargo build --release --locked

cleanup() {
    kill -9 "${SERVE_PID:-}" "${AGG_PID:-}" "${W0_PID:-}" "${W1_PID:-}" 2>/dev/null || true
}
trap cleanup EXIT

echo "== serve on $ADDR ($ROUNDS rounds, fanout 2, quorum 0.5, round-timeout 20s) =="
target/release/feddq serve --addr "$ADDR" --rounds "$ROUNDS" \
    --train-size 2000 --test-size 500 --fanout 2 \
    --quorum 0.5 --round-timeout 20 --out "$REPORT" >"$SERVE_LOG" 2>&1 &
SERVE_PID=$!
target/release/feddq aggregate --upstream "$ADDR" --addr "$AGG_ADDR" --id 0 --fanout 2 &
AGG_PID=$!
target/release/feddq worker --addr "$AGG_ADDR" --id 0 &
W0_PID=$!
target/release/feddq worker --addr "$AGG_ADDR" --id 1 &
W1_PID=$!

# Wait for the first round record before pulling the plug: killing the
# aggregator during the initial handshake would (correctly) abort serve.
for _ in $(seq 1 100); do
    if grep -q "round " "$SERVE_LOG"; then break; fi
    if ! kill -0 "$SERVE_PID" 2>/dev/null; then
        echo "serve exited before round 0:"; cat "$SERVE_LOG"; exit 1
    fi
    sleep 0.2
done
grep -q "round " "$SERVE_LOG" || { echo "no round completed in 20s:"; cat "$SERVE_LOG"; exit 1; }

echo "== kill -9 the aggregator mid-run =="
kill -9 "$AGG_PID"
sleep 1

echo "== restart the aggregator (rejoins the run in progress) =="
target/release/feddq aggregate --upstream "$ADDR" --addr "$AGG_ADDR" --id 0 --fanout 2 &
AGG_PID=$!

if ! wait "$SERVE_PID"; then
    echo "serve failed:"; cat "$SERVE_LOG"; exit 1
fi
wait "$AGG_PID"
wait "$W0_PID"
wait "$W1_PID"

echo "== verifying the report recorded the aggregator churn =="
python3 - "$REPORT" "$ROUNDS" <<'EOF'
import json
import sys

with open(sys.argv[1]) as f:
    report = json.load(f)
rounds = report["rounds"]
want = int(sys.argv[2])
subtree_failed = sum(int(r["subtree_failed"]) for r in rounds)
rejoined = sum(int(r["rejoined"]) for r in rounds)
depths = {int(r["agg_depth"]) for r in rounds}
print(f"  rounds {len(rounds)}/{want}, subtree_failed {subtree_failed}, "
      f"rejoined {rejoined}, agg_depth {sorted(depths)}")
ok = True
if len(rounds) != want:
    print("  FAIL: the tree run must complete every round")
    ok = False
if subtree_failed < 1:
    print("  FAIL: the killed aggregator must be recorded as subtree_failed")
    ok = False
if rejoined < 1:
    print("  FAIL: the restarted aggregator must be recorded as rejoined")
    ok = False
if depths != {2}:
    print("  FAIL: every round must fold through the aggregator tier")
    ok = False
sys.exit(0 if ok else 1)
EOF
echo "tree churn smoke passed"
