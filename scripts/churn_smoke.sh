#!/usr/bin/env bash
# Churn smoke: a real TCP run that survives a worker being killed.
#
# Runs `feddq serve` with quorum aggregation enabled, two workers on
# the built-in native manifest (FEDDQ_NATIVE_CLIENTS=2), then
# `kill -9`s one worker mid-run and restarts it.  The run must finish
# every round (exit 0), and the written report must record at least one
# `failed` client-round (the kill) and at least one `rejoined` worker
# (the restart re-attaching through the server's rejoin accept loop).
#
# CI runs this in the churn-smoke job; it also works locally:
#
#     scripts/churn_smoke.sh
set -euo pipefail
cd "$(dirname "$0")/.."

ADDR="${CHURN_ADDR:-127.0.0.1:17879}"
ROUNDS="${CHURN_ROUNDS:-40}"
REPORT="$(mktemp -t churn_report.XXXXXX.json)"
SERVE_LOG="$(mktemp -t churn_serve.XXXXXX.log)"
export FEDDQ_NATIVE_CLIENTS=2

cargo build --release --locked

cleanup() {
    kill -9 "${SERVE_PID:-}" "${W0_PID:-}" "${W1_PID:-}" 2>/dev/null || true
}
trap cleanup EXIT

echo "== serve on $ADDR ($ROUNDS rounds, quorum 0.5, round-timeout 10s) =="
target/release/feddq serve --addr "$ADDR" --rounds "$ROUNDS" \
    --train-size 2000 --test-size 500 \
    --quorum 0.5 --round-timeout 10 --out "$REPORT" >"$SERVE_LOG" 2>&1 &
SERVE_PID=$!
target/release/feddq worker --addr "$ADDR" --id 0 &
W0_PID=$!
target/release/feddq worker --addr "$ADDR" --id 1 &
W1_PID=$!

# Wait for the first round record before pulling the plug: killing a
# worker during the initial handshake would (correctly) abort serve.
for _ in $(seq 1 100); do
    if grep -q "round " "$SERVE_LOG"; then break; fi
    if ! kill -0 "$SERVE_PID" 2>/dev/null; then
        echo "serve exited before round 0:"; cat "$SERVE_LOG"; exit 1
    fi
    sleep 0.2
done
grep -q "round " "$SERVE_LOG" || { echo "no round completed in 20s:"; cat "$SERVE_LOG"; exit 1; }

echo "== kill -9 worker 1 mid-run =="
kill -9 "$W1_PID"
sleep 1

echo "== restart worker 1 (rejoins the run in progress) =="
target/release/feddq worker --addr "$ADDR" --id 1 &
W1_PID=$!

if ! wait "$SERVE_PID"; then
    echo "serve failed:"; cat "$SERVE_LOG"; exit 1
fi
wait "$W0_PID"
wait "$W1_PID"

echo "== verifying the report recorded the churn =="
python3 - "$REPORT" "$ROUNDS" <<'EOF'
import json
import sys

with open(sys.argv[1]) as f:
    report = json.load(f)
rounds = report["rounds"]
want = int(sys.argv[2])
failed = sum(int(r["failed"]) for r in rounds)
rejoined = sum(int(r["rejoined"]) for r in rounds)
print(f"  rounds {len(rounds)}/{want}, failed {failed}, rejoined {rejoined}")
ok = True
if len(rounds) != want:
    print("  FAIL: the quorum run must complete every round")
    ok = False
if failed < 1:
    print("  FAIL: the killed worker must be recorded as failed")
    ok = False
if rejoined < 1:
    print("  FAIL: the restarted worker must be recorded as rejoined")
    ok = False
sys.exit(0 if ok else 1)
EOF
echo "churn smoke passed"
