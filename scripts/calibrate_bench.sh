#!/usr/bin/env bash
# Calibrate the committed perf-gate baselines from a real bench run.
#
# The CI bench gate (scripts/bench_gate.py, run with --require-armed)
# refuses to pass while the committed BENCH_*.json baselines are
# zero-seeded, because an all-zero baseline can never catch a
# regression.  Run this on a rust-toolchain-equipped host that is
# representative of the CI machine class, then commit the regenerated
# JSON files:
#
#     scripts/calibrate_bench.sh
#     git add BENCH_hotpath.json BENCH_kernels.json
#     git commit -m "Arm the bench gate with calibrated baselines"
#
# Full (non-quick) mode is used deliberately: the baselines should come
# from stable measurements, not the smoke-mode settings CI uses for the
# relative comparison.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== running perf benches (full mode) =="
cargo bench --bench perf_hotpath --locked
cargo bench --bench perf_kernels --locked

echo
echo "== verifying the regenerated baselines are armed =="
python3 - <<'EOF'
import json
import sys

ok = True
for path in ("BENCH_hotpath.json", "BENCH_kernels.json"):
    with open(path) as f:
        data = json.load(f)
    gated = {k: v for k, v in data.items() if k.endswith("_gbps")}
    zero = [k for k, v in gated.items() if not (isinstance(v, (int, float)) and v > 0)]
    if not gated:
        print(f"  {path}: no gated (_gbps) rows?!")
        ok = False
    elif zero:
        print(f"  {path}: still zero-seeded rows: {', '.join(sorted(zero))}")
        ok = False
    else:
        print(f"  {path}: {len(gated)} gated rows armed")
if not ok:
    print("calibration produced unusable baselines — investigate before committing")
    sys.exit(1)
EOF

echo
echo "calibrated — commit BENCH_hotpath.json and BENCH_kernels.json to arm the CI gate"
