#!/usr/bin/env bash
# Semi-sync smoke: bounded staleness on a real TCP run, contrasted
# against strict synchronous rounds.
#
# Runs `feddq serve` twice with the same seed, two workers each on the
# built-in native manifest (FEDDQ_NATIVE_CLIENTS=2), under a simulated
# stall model whose overshoot is one round-length (stall 35s against a
# 30s budget): once with `--staleness 2` (stalled updates are banked
# and folded, discounted, a round late) and once with `--staleness 0`
# (stalled updates are dropped at the timeout).  The semi-sync run must
# record at least one `stale_folded` update and finish with a strictly
# smaller summed simulated makespan — a straggler that is banked costs
# its round nothing, while strict sync charges the full timeout.
#
# CI runs this in the churn-smoke job; it also works locally:
#
#     scripts/semisync_smoke.sh
set -euo pipefail
cd "$(dirname "$0")/.."

STRICT_ADDR="${SEMISYNC_STRICT_ADDR:-127.0.0.1:17881}"
SEMI_ADDR="${SEMISYNC_ADDR:-127.0.0.1:17883}"
ROUNDS="${SEMISYNC_ROUNDS:-40}"
FAULTS="stall:0.25:35"
STRICT_REPORT="$(mktemp -t semisync_strict.XXXXXX.json)"
SEMI_REPORT="$(mktemp -t semisync_semi.XXXXXX.json)"
export FEDDQ_NATIVE_CLIENTS=2

cargo build --release --locked

cleanup() {
    kill -9 "${SERVE_PID:-}" "${W0_PID:-}" "${W1_PID:-}" 2>/dev/null || true
}
trap cleanup EXIT

# one_run <addr> <staleness> <report>: serve + 2 workers to completion
one_run() {
    local addr="$1" k="$2" report="$3"
    echo "== serve on $addr ($ROUNDS rounds, $FAULTS, timeout 30s, staleness $k) =="
    target/release/feddq serve --addr "$addr" --rounds "$ROUNDS" \
        --train-size 2000 --test-size 500 \
        --sim-faults "$FAULTS" --round-timeout 30 --quorum 0.5 \
        --staleness "$k" --out "$report" &
    SERVE_PID=$!
    target/release/feddq worker --addr "$addr" --id 0 &
    W0_PID=$!
    target/release/feddq worker --addr "$addr" --id 1 &
    W1_PID=$!
    wait "$SERVE_PID"
    wait "$W0_PID"
    wait "$W1_PID"
}

one_run "$STRICT_ADDR" 0 "$STRICT_REPORT"
one_run "$SEMI_ADDR" 2 "$SEMI_REPORT"

echo "== verifying the semi-sync run folded stragglers and won on makespan =="
python3 - "$STRICT_REPORT" "$SEMI_REPORT" "$ROUNDS" <<'EOF'
import json
import sys

with open(sys.argv[1]) as f:
    strict = json.load(f)["rounds"]
with open(sys.argv[2]) as f:
    semi = json.load(f)["rounds"]
want = int(sys.argv[3])
folded = sum(int(r["stale_folded"]) for r in semi)
strict_folded = sum(int(r["stale_folded"]) for r in strict)
strict_span = sum(float(r["sim_makespan_secs"]) for r in strict)
semi_span = sum(float(r["sim_makespan_secs"]) for r in semi)
print(f"  rounds {len(semi)}/{want}, stale_folded {folded}, "
      f"makespan strict {strict_span:.1f}s vs semi-sync {semi_span:.1f}s")
ok = True
if len(strict) != want or len(semi) != want:
    print("  FAIL: both runs must complete every round")
    ok = False
if strict_folded != 0:
    print("  FAIL: strict sync must never fold a stale update")
    ok = False
if folded < 1:
    print("  FAIL: the semi-sync run must fold at least one banked straggler")
    ok = False
if not semi_span < strict_span:
    print("  FAIL: bounded staleness must beat strict sync on simulated makespan")
    ok = False
sys.exit(0 if ok else 1)
EOF
echo "semisync smoke passed"
