#!/usr/bin/env python3
"""Render the codec width -> GB/s table and the round-scheduler rows
from BENCH_hotpath.json as GitHub-flavored markdown (for the
bench-smoke job summary).

Shows, per wire width, the SWAR pack/unpack kernels next to the generic
get_slice/put_slice baselines and the unpack speedup, the fused encode
and narrow-fold rows, and the scheduler's sampled-cohort /
slowest-first-dispatch timings.  Zero values mean the row was not
produced by this run (or the bench is unarmed) and are rendered as "-".

Usage:
    bench_summary.py BENCH_hotpath.json >> "$GITHUB_STEP_SUMMARY"
"""

import json
import sys

WIDTHS = (1, 2, 4, 8, 16)


def fmt(v):
    return f"{v:.3f}" if isinstance(v, (int, float)) and v > 0 else "-"


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_hotpath.json"
    with open(path) as f:
        data = json.load(f)

    print("### Codec kernels: width -> GB/s")
    print()
    print("| width (bits) | unpack SWAR | unpack generic | unpack speedup | pack SWAR | pack generic |")
    print("|---:|---:|---:|---:|---:|---:|")
    for w in WIDTHS:
        us = data.get(f"unpack_w{w}_gbps", 0.0)
        ug = data.get(f"unpack_{w}bit_gbps", 0.0)
        ps = data.get(f"pack_w{w}_gbps", 0.0)
        pg = data.get(f"pack_{w}bit_gbps", 0.0)
        speed = f"{us / ug:.2f}x" if us and ug else "-"
        print(f"| {w} | {fmt(us)} | {fmt(ug)} | {speed} | {fmt(ps)} | {fmt(pg)} |")
    print()
    print("| fused pipeline row | GB/s |")
    print("|---|---:|")
    for key, label in (
        ("encode_fused_gbps", "client encode, fused quantize-pack"),
        ("encode_split_gbps", "client encode, split quantize + pack"),
        ("fold_narrow_gbps", "server fold, narrow u16 rows"),
        ("fold_f32rows_gbps", "server fold, f32 reference rows"),
    ):
        print(f"| {label} | {fmt(data.get(key, 0.0))} |")
    print()
    print("### Round scheduler")
    print()
    print("| scheduler row | value |")
    print("|---|---:|")
    for key, label, unit in (
        ("e2e_round_secs_threads4", "s/round, full cohort (threads=4)", "s"),
        ("sched_sampled_round_secs", "s/round, participation=0.5", "s"),
        ("sched_full_vs_sampled_secs", "s/round saved by sampling half", "s"),
        ("straggler_idorder_secs", "dispatch makespan, id-order", "s"),
        ("straggler_slowfirst_secs", "dispatch makespan, slowest-first", "s"),
        ("straggler_slowfirst_speedup", "slowest-first speedup", "x"),
    ):
        v = data.get(key, 0.0)
        # 0 is the zero-seeded "not produced" sentinel; any other value
        # (including a negative seconds-saved regression) is shown.
        shown = f"{v:.3f} {unit}" if isinstance(v, (int, float)) and v != 0 else "-"
        print(f"| {label} | {shown} |")


if __name__ == "__main__":
    main()
