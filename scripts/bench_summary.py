#!/usr/bin/env python3
"""Render the codec width -> GB/s table from BENCH_hotpath.json as
GitHub-flavored markdown (for the bench-smoke job summary).

Shows, per wire width, the SWAR pack/unpack kernels next to the generic
get_slice/put_slice baselines and the unpack speedup, plus the fused
encode and narrow-fold rows.  Zero values mean the row was not produced
by this run (or the bench is unarmed) and are rendered as "-".

Usage:
    bench_summary.py BENCH_hotpath.json >> "$GITHUB_STEP_SUMMARY"
"""

import json
import sys

WIDTHS = (1, 2, 4, 8, 16)


def fmt(v):
    return f"{v:.3f}" if isinstance(v, (int, float)) and v > 0 else "-"


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_hotpath.json"
    with open(path) as f:
        data = json.load(f)

    print("### Codec kernels: width -> GB/s")
    print()
    print("| width (bits) | unpack SWAR | unpack generic | unpack speedup | pack SWAR | pack generic |")
    print("|---:|---:|---:|---:|---:|---:|")
    for w in WIDTHS:
        us = data.get(f"unpack_w{w}_gbps", 0.0)
        ug = data.get(f"unpack_{w}bit_gbps", 0.0)
        ps = data.get(f"pack_w{w}_gbps", 0.0)
        pg = data.get(f"pack_{w}bit_gbps", 0.0)
        speed = f"{us / ug:.2f}x" if us and ug else "-"
        print(f"| {w} | {fmt(us)} | {fmt(ug)} | {speed} | {fmt(ps)} | {fmt(pg)} |")
    print()
    print("| fused pipeline row | GB/s |")
    print("|---|---:|")
    for key, label in (
        ("encode_fused_gbps", "client encode, fused quantize-pack"),
        ("encode_split_gbps", "client encode, split quantize + pack"),
        ("fold_narrow_gbps", "server fold, narrow u16 rows"),
        ("fold_f32rows_gbps", "server fold, f32 reference rows"),
    ):
        print(f"| {label} | {fmt(data.get(key, 0.0))} |")


if __name__ == "__main__":
    main()
