//! End-to-end driver: trains the paper's benchmark-1 model (Vanilla CNN,
//! Fashion-MNIST-shaped data) federated across 10 clients for a few
//! hundred rounds with FedDQ, logging the full loss curve and writing the
//! per-round report — the workload that proves all three layers compose:
//! Rust coordinator -> AOT JAX round executable -> Pallas quantizer ->
//! bit-packed wire -> fused dequantize-aggregate.
//!
//!     cargo run --release --example e2e_train [-- rounds]
//!
//! The run is recorded in EXPERIMENTS.md §E2E.

use feddq::config::RunConfig;
use feddq::coordinator::Session;
use feddq::metrics::gbits;
use feddq::quant::PolicyConfig;

fn main() -> anyhow::Result<()> {
    let rounds: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(200);

    let mut cfg = RunConfig::default_for("vanilla_cnn");
    cfg.policy = PolicyConfig::FedDq { resolution: 0.005 };
    cfg.rounds = rounds;
    cfg.train_size = 4000;
    cfg.test_size = 1000;
    cfg.eval_every = 5;
    cfg.target_accuracy = Some(0.97);

    let mut session = Session::new(cfg)?;
    println!(
        "e2e: vanilla_cnn d={} ({} segments), {} clients, tau={}, B={}, data={}",
        session.manifest().d,
        session.manifest().num_segments(),
        session.manifest().n_clients,
        session.manifest().tau,
        session.manifest().batch,
        session.data_source
    );

    let t0 = std::time::Instant::now();
    let report = session.run_with(|m, rec| {
        if rec.evaluated() {
            println!(
                "round {m:>4}  loss {:.4}  test_loss {:.4}  acc {:.4}  bits {:.2}  range {:.4}  cum {:.4} Gb",
                rec.train_loss, rec.test_loss, rec.test_accuracy,
                rec.mean_bits, rec.mean_range, gbits(rec.cum_uplink_bits)
            );
        } else {
            println!("round {m:>4}  loss {:.4}  bits {:.2}", rec.train_loss, rec.mean_bits);
        }
    })?;
    let secs = t0.elapsed().as_secs_f64();

    std::fs::create_dir_all("reports").ok();
    report.write_csv("reports/e2e_train.csv")?;
    report.write_json("reports/e2e_train.json")?;
    println!(
        "\ne2e done: {} rounds in {:.1}s ({:.2} s/round), best acc {:.4}, uplink {:.4} Gb",
        report.rounds.len(),
        secs,
        secs / report.rounds.len() as f64,
        report.best_accuracy(),
        gbits(report.total_uplink_bits())
    );
    println!("loss curve written to reports/e2e_train.csv");
    Ok(())
}
