//! Distributed mode demo: a real multi-endpoint federation over TCP in
//! one process — the server and its workers each own a model runtime
//! and speak the framed wire protocol on localhost sockets, exactly
//! what `feddq serve` / `feddq worker` do across machines.
//!
//!     cargo run --release --example distributed -- [train flags]
//!
//! The artifacts directory is routed through the backend seam
//! (`--artifacts` / `FEDDQ_ARTIFACTS`, default `artifacts`), so with no
//! AOT artifacts present everything runs on the built-in native MLP
//! backend — no `make artifacts` required.  One worker is spawned per
//! manifest client; CI smokes the topology with
//! `FEDDQ_NATIVE_CLIENTS=2` and `--rounds 2`.
//!
//! All scheduler knobs flow through: `--agg-shards`, `--eval-threads`,
//! `--decode-buffers` (bounded decode pool), `--fold-overlap`
//! (per-shard prefix folds overlapping straggler arrivals — active
//! over TCP from round 0, since each worker's ready `Join` carries its
//! shard size) and `--codec` (narrow SWAR path vs scalar reference).

use feddq::cli::{run_config_from_args, Args};
use feddq::coordinator::topology;
use feddq::metrics::gbits;
use feddq::quant::PolicyConfig;
use feddq::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    let addr = args.get_or("addr", "127.0.0.1:17878").to_string();
    let mut cfg = run_config_from_args(&args, "mlp")?;
    // Demo-sized defaults for anything the caller didn't pin down.
    if args.get("policy").is_none() {
        cfg.policy = PolicyConfig::FedDq { resolution: 0.005 };
    }
    if args.get("rounds").is_none() {
        cfg.rounds = 5;
    }
    if args.get("train-size").is_none() {
        cfg.train_size = 2000;
    }
    if args.get("test-size").is_none() {
        cfg.test_size = 500;
    }
    args.finish()?;

    // Worker count comes from the manifest the backend seam resolves
    // (built-in native manifest when the artifacts dir has none), never
    // from a hardcoded artifacts path.
    let n = {
        let rt = Runtime::new(&cfg.artifacts_dir)?;
        rt.load_model(&cfg.model)?.mm.n_clients as u32
    };

    println!(
        "spawning {n} TCP workers + server on {addr} (fold_overlap={}, decode_buffers={})",
        cfg.round.pipeline.fold_overlap, cfg.round.pipeline.decode_buffers
    );
    let workers: Vec<_> = (0..n)
        .map(|id| {
            let addr = addr.clone();
            let artifacts = cfg.artifacts_dir.clone();
            // workers retry the connect internally (bounded backoff), so
            // racing the server's bind() needs no loop here
            std::thread::spawn(move || topology::worker(&addr, id, &artifacts))
        })
        .collect();

    let report = topology::serve(&cfg, &addr, |m, rec| {
        println!(
            "round {m}: loss {:.4} acc {:.3} bits/elem {:.2} cum {:.4} Gb (recv+decode {:.3}s agg {:.3}s eval {:.3}s)",
            rec.train_loss,
            rec.test_accuracy,
            rec.mean_bits,
            gbits(rec.cum_uplink_bits),
            rec.recv_decode_secs,
            rec.agg_secs,
            rec.eval_secs,
        );
    })?;
    for w in workers {
        w.join().unwrap()?;
    }
    println!(
        "distributed run done: best acc {:.3}, uplink {:.4} Gb over real sockets",
        report.best_accuracy(),
        gbits(report.total_uplink_bits())
    );
    Ok(())
}
