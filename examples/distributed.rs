//! Distributed mode demo: a real multi-endpoint federation over TCP in
//! one process — the server and ten worker clients each own a PJRT
//! runtime and speak the framed wire protocol on localhost sockets,
//! exactly what `feddq serve` / `feddq worker` do across machines.
//!
//!     cargo run --release --example distributed

use feddq::config::RunConfig;
use feddq::coordinator::topology;
use feddq::metrics::gbits;
use feddq::quant::PolicyConfig;

fn main() -> anyhow::Result<()> {
    let addr = "127.0.0.1:17878";
    let mut cfg = RunConfig::default_for("mlp");
    cfg.policy = PolicyConfig::FedDq { resolution: 0.005 };
    cfg.rounds = 5;
    cfg.train_size = 2000;
    cfg.test_size = 500;
    let n = 10u32;

    println!("spawning {n} TCP workers + server on {addr}");
    let workers: Vec<_> = (0..n)
        .map(|id| {
            let addr = addr.to_string();
            std::thread::spawn(move || {
                for _ in 0..100 {
                    match topology::worker(&addr, id, "artifacts") {
                        Ok(()) => return Ok(()),
                        Err(e) if format!("{e:#}").contains("Connection refused") => {
                            std::thread::sleep(std::time::Duration::from_millis(100));
                        }
                        Err(e) => return Err(e),
                    }
                }
                anyhow::bail!("server never came up")
            })
        })
        .collect();

    let report = topology::serve(&cfg, addr, |m, rec| {
        println!(
            "round {m}: loss {:.4} acc {:.3} bits/elem {:.2} cum {:.4} Gb",
            rec.train_loss, rec.test_accuracy, rec.mean_bits, gbits(rec.cum_uplink_bits)
        );
    })?;
    for w in workers {
        w.join().unwrap()?;
    }
    println!(
        "distributed run done: best acc {:.3}, uplink {:.4} Gb over real sockets",
        report.best_accuracy(),
        gbits(report.total_uplink_bits())
    );
    Ok(())
}
