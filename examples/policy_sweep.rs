//! Policy sweep: compare every quantization policy (FedDQ at several
//! resolutions, AdaQuantFL, fixed 2/4/8-bit, fp32) on the same federated
//! workload and print a ranking by bits-to-target-accuracy.
//!
//!     cargo run --release --example policy_sweep [-- rounds target_acc]

use feddq::config::RunConfig;
use feddq::coordinator::Session;
use feddq::metrics::gbits;
use feddq::quant::PolicyConfig;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let rounds: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(25);
    let target: f32 = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(0.85);

    let policies = vec![
        PolicyConfig::FedDq { resolution: 0.0025 },
        PolicyConfig::FedDq { resolution: 0.005 },
        PolicyConfig::FedDq { resolution: 0.01 },
        PolicyConfig::AdaQuantFl { s0: 2 },
        PolicyConfig::Fixed { bits: 2 },
        PolicyConfig::Fixed { bits: 4 },
        PolicyConfig::Fixed { bits: 8 },
        PolicyConfig::Fp32,
    ];

    println!("sweep: mlp, {rounds} rounds, target acc {target}");
    println!(
        "{:<16} {:>9} {:>12} {:>14} {:>12}",
        "policy", "best acc", "rounds@tgt", "Gb@tgt", "total Gb"
    );
    let mut rows = Vec::new();
    for p in policies {
        let mut cfg = RunConfig::default_for("mlp");
        cfg.policy = p.clone();
        cfg.rounds = rounds;
        cfg.train_size = 2000;
        cfg.test_size = 500;
        let report = Session::new(cfg)?.run()?;
        let hit = report.rounds_to_accuracy(target);
        let (r_s, g_s) = match hit {
            Some((r, bits)) => (r.to_string(), format!("{:.4}", gbits(bits))),
            None => ("-".into(), "-".into()),
        };
        println!(
            "{:<16} {:>9.4} {:>12} {:>14} {:>12.4}",
            p.label(),
            report.best_accuracy(),
            r_s,
            g_s,
            gbits(report.total_uplink_bits())
        );
        rows.push((p.label(), hit));
    }

    // ranking by bits to target
    let mut ranked: Vec<_> = rows.iter().filter_map(|(l, h)| h.map(|(_, b)| (l, b))).collect();
    ranked.sort_by_key(|&(_, b)| b);
    println!("\nranking by uplink bits to reach acc {target}:");
    for (i, (l, b)) in ranked.iter().enumerate() {
        println!("  {}. {:<16} {:.4} Gb", i + 1, l, gbits(*b));
    }
    Ok(())
}
