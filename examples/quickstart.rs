//! Quickstart: a 15-round federated run with FedDQ on the MLP benchmark.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Prints per-round loss / accuracy / bit-width and the final
//! communication tally, then repeats the run with AdaQuantFL so you can
//! see the descending-vs-ascending bit schedules side by side.

use feddq::config::RunConfig;
use feddq::coordinator::Session;
use feddq::metrics::gbits;
use feddq::quant::PolicyConfig;

fn run(policy: PolicyConfig) -> anyhow::Result<()> {
    let mut cfg = RunConfig::default_for("mlp");
    cfg.policy = policy;
    cfg.rounds = 15;
    cfg.train_size = 2000;
    cfg.test_size = 500;
    println!("\n=== policy {} ===", cfg.policy.label());
    let mut session = Session::new(cfg)?;
    println!(
        "model mlp: d={} params, {} clients, data={}",
        session.manifest().d,
        session.manifest().n_clients,
        session.data_source
    );
    let report = session.run_with(|m, rec| {
        println!(
            "round {m:>3}: loss {:.4}  acc {:.3}  bits/elem {:>5.2}  cum {:.4} Gb",
            rec.train_loss, rec.test_accuracy, rec.mean_bits,
            gbits(rec.cum_uplink_bits)
        );
    })?;
    println!(
        "--> best acc {:.3} with {:.4} Gb uplink",
        report.best_accuracy(),
        gbits(report.total_uplink_bits())
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    run(PolicyConfig::FedDq { resolution: 0.005 })?;
    run(PolicyConfig::AdaQuantFl { s0: 2 })?;
    Ok(())
}
