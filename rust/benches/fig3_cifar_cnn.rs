//! Fig. 3 — benchmark 2: 4conv+3fc CNN on CIFAR-10(-shaped) data.
//! Same axes as Fig. 2: (a) vs bit volume, (b) vs rounds.

use feddq::bench_support as bs;
use feddq::quant::PolicyConfig;

fn main() -> anyhow::Result<()> {
    println!("=== Fig 3: cnn4 / CIFAR-10 — FedDQ vs AdaQuantFL ===");
    let setup = bs::setup_for("cnn4");
    let feddq = bs::run_policy(&setup, PolicyConfig::FedDq { resolution: 0.005 })?;
    let ada = bs::run_policy(&setup, PolicyConfig::AdaQuantFl { s0: 2 })?;

    for rep in [&feddq, &ada] {
        println!();
        bs::print_series(rep);
        bs::save(rep, &format!("fig3_{}", rep.label.replace([':', '.'], "_")));
    }

    println!("\n-- crossover summary --");
    for target in [0.6f32, 0.7, 0.8] {
        bs::print_table1_row("fig3", target, &feddq, "AdaQuantFL", &ada);
    }
    Ok(())
}
