//! Table I — performance improvement summary: communicated bits (Gb) and
//! communication rounds needed to hit a target test accuracy, FedDQ vs
//! AdaQuantFL, across the three paper benchmarks, with reduction ratios.
//!
//! Paper values (their testbed): −65.2%/−20.0%/−60.9% bits and
//! −57%/−41.5%/−68% rounds.  Our substrate differs (CPU XLA, synthetic
//! data, CPU-scaled widths), so the *sign and rough magnitude* of the
//! reductions is the reproduction target, not the absolute numbers.

use feddq::bench_support as bs;
use feddq::metrics::gbits;
use feddq::quant::PolicyConfig;

struct Row {
    bench: &'static str,
    model: &'static str,
    /// Target ladder: the row reports the highest accuracy level that
    /// BOTH policies reach within the round budget (robust on a scaled
    /// substrate where the paper's absolute accuracies don't transfer).
    targets: &'static [f32],
}

fn main() -> anyhow::Result<()> {
    println!("=== Table I: FedDQ vs AdaQuantFL — bits & rounds to target accuracy ===");
    // Accuracy targets chosen near each benchmark's convergence plateau on
    // this substrate (paper used 91% / 62% / 72% on the real datasets).
    let rows = [
        Row { bench: "1: FMNIST/CNN", model: "vanilla_cnn", targets: &[0.92, 0.90, 0.85, 0.80] },
        Row { bench: "2: CIFAR/cnn4", model: "cnn4", targets: &[0.80, 0.75, 0.70, 0.60] },
        Row { bench: "3: CIFAR/rn18", model: "resnet18", targets: &[0.70, 0.60, 0.50, 0.40] },
    ];

    println!(
        "{:<16} {:>7} | {:>12} {:>8} | {:>12} {:>8} | {:>9} {:>9}",
        "benchmark", "target", "AdaQ Gb", "rounds", "FedDQ Gb", "rounds", "bits red", "rnds red"
    );
    for row in rows {
        let mut setup = bs::setup_for(row.model);
        // table budgets slightly below the figure budgets: the ladder
        // reports the milestone both policies reach within them
        setup.rounds = match row.model {
            "vanilla_cnn" => setup.rounds.min(30),
            "cnn4" => setup.rounds.min(20),
            _ => setup.rounds.min(10),
        };
        let feddq = bs::run_policy(&setup, PolicyConfig::FedDq { resolution: 0.005 })?;
        let ada = bs::run_policy(&setup, PolicyConfig::AdaQuantFl { s0: 2 })?;
        let hit = row.targets.iter().find_map(|&t| {
            match (feddq.rounds_to_accuracy(t), ada.rounds_to_accuracy(t)) {
                (Some(f), Some(a)) => Some((t, f, a)),
                _ => None,
            }
        });
        match hit {
            Some((target, (fr, fb), (ar, ab))) => {
                println!(
                    "{:<16} {:>6.0}% | {:>12.4} {:>8} | {:>12.4} {:>8} | {:>8.1}% {:>8.1}%",
                    row.bench,
                    target * 100.0,
                    gbits(ab),
                    ar,
                    gbits(fb),
                    fr,
                    100.0 * (1.0 - fb as f64 / ab as f64),
                    100.0 * (1.0 - fr as f64 / ar as f64),
                );
            }
            None => {
                println!(
                    "{:<16}        | no common target reached (feddq best {:.3}, ada best {:.3}) — raise FEDDQ_BENCH_ROUNDS",
                    row.bench,
                    feddq.best_accuracy(),
                    ada.best_accuracy()
                );
            }
        }
    }
    println!("\npaper (real datasets): bits −65.2% / −20.0% / −60.9%; rounds −57% / −41.5% / −68%");
    Ok(())
}
