//! L3 hot-path micro-benchmarks: the pure-Rust wire work (bit packing,
//! unpacking, message encode/decode, CRC framing) plus end-to-end
//! federated rounds at threads=1 vs threads=4 — the parallel round
//! engine's headline number.  §Perf targets: pack/unpack >= 1 GB/s per
//! core; >= 2x s/round at threads=4 on a multi-core host.
//!
//! Emits `BENCH_hotpath.json` (name -> GB/s and s/round) so the perf
//! trajectory is tracked across PRs.

use feddq::bench_support as bs;
use feddq::config::RunConfig;
use feddq::coordinator::Session;
use feddq::quant::PolicyConfig;
use feddq::util::bench::{bench_header, black_box, Bencher};
use feddq::util::rng::Rng;
use feddq::wire::bitpack::{BitReader, BitWriter};
use feddq::wire::frame;
use feddq::wire::messages::{Message, SegmentHeader, Update};

/// One e2e run at `threads` workers; returns s/round.
fn e2e_round_secs(threads: usize, rounds: usize) -> anyhow::Result<f64> {
    let setup = bs::setup_for("mlp");
    let mut cfg = RunConfig::default_for("mlp");
    cfg.policy = PolicyConfig::FedDq { resolution: 0.005 };
    cfg.rounds = rounds;
    cfg.train_size = setup.train_size.min(1500);
    cfg.test_size = 500;
    cfg.eval_every = 1000; // isolate the round path from eval
    cfg.threads = threads;
    let t0 = std::time::Instant::now();
    let mut session = Session::new(cfg)?;
    let setup_secs = t0.elapsed().as_secs_f64();
    let t1 = std::time::Instant::now();
    let report = session.run()?;
    let run_secs = t1.elapsed().as_secs_f64();
    let per_round = run_secs / report.rounds.len() as f64;
    println!(
        "threads={threads}: setup {:.2}s; {} rounds in {:.2}s = {:.3} s/round ({} clients x tau={} local steps + quantize + pack + aggregate)",
        setup_secs,
        report.rounds.len(),
        run_secs,
        per_round,
        session.manifest().n_clients,
        session.manifest().tau,
    );
    Ok(per_round)
}

fn main() -> anyhow::Result<()> {
    let mut b = Bencher::default();
    let mut rng = Rng::new(7);
    let mut json: Vec<(String, f64)> = Vec::new();

    bench_header("bit packing / unpacking (1M codes)");
    let n = 1_000_000usize;
    for bits in [1u32, 4, 8, 12, 16] {
        let max = (1u64 << bits) - 1;
        let codes: Vec<u32> = (0..n).map(|_| (rng.next_u64() % (max + 1)) as u32).collect();
        let in_bytes = (n * 4) as u64; // source f32/u32 stream
        let r = b.bench_bytes(&format!("pack {bits}-bit"), Some(in_bytes), &mut || {
            let mut w = BitWriter::with_capacity(n * bits as usize / 8 + 8);
            w.put_slice(&codes, bits);
            black_box(w.finish())
        });
        json.push((format!("pack_{bits}bit_gbps"), r.throughput_gbps().unwrap_or(0.0)));
        let mut w = BitWriter::new();
        w.put_slice(&codes, bits);
        let packed = w.finish();
        let r = b.bench_bytes(&format!("unpack {bits}-bit"), Some(in_bytes), &mut || {
            let mut r = BitReader::new(&packed);
            let mut out = Vec::new();
            r.get_slice(&mut out, n, bits).unwrap();
            black_box(out)
        });
        json.push((format!("unpack_{bits}bit_gbps"), r.throughput_gbps().unwrap_or(0.0)));
    }

    bench_header("message encode/decode (100k-element update, 8-bit)");
    let d = 100_000usize;
    let mut w = BitWriter::new();
    let codes: Vec<u32> = (0..d).map(|_| (rng.next_u64() % 256) as u32).collect();
    w.put_slice(&codes, 8);
    let update = Update {
        round: 3,
        client_id: 2,
        num_samples: 600,
        train_loss: 0.42,
        segments: vec![
            SegmentHeader { bits: 8, level: 255, min: -0.1, step: 0.001 };
            12
        ],
        payload: w.finish(),
    };
    let msg = Message::Update(update);
    let encoded = msg.encode();
    let bytes = encoded.len() as u64;
    let r = b.bench_bytes("encode Update", Some(bytes), &mut || black_box(msg.encode()));
    json.push(("encode_update_gbps".into(), r.throughput_gbps().unwrap_or(0.0)));
    let r = b.bench_bytes("decode Update", Some(bytes), &mut || {
        black_box(Message::decode(&encoded).unwrap())
    });
    json.push(("decode_update_gbps".into(), r.throughput_gbps().unwrap_or(0.0)));
    let r = b.bench_bytes("crc32 frame", Some(bytes), &mut || {
        black_box(frame::crc32(&encoded))
    });
    json.push(("crc32_gbps".into(), r.throughput_gbps().unwrap_or(0.0)));

    bench_header("end-to-end federated rounds (mlp, 10 clients, in-proc)");
    let rounds = if std::env::var("FEDDQ_BENCH_FAST").is_ok() { 3 } else { 6 };
    let t1 = e2e_round_secs(1, rounds)?;
    let t4 = e2e_round_secs(4, rounds)?;
    let speedup = t1 / t4;
    println!(
        "round engine speedup threads=4 vs threads=1: {speedup:.2}x ({} cores available)",
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    );
    json.push(("e2e_round_secs_threads1".into(), t1));
    json.push(("e2e_round_secs_threads4".into(), t4));
    json.push(("e2e_round_speedup_t4_vs_t1".into(), speedup));

    bs::write_bench_json("hotpath", &json);
    Ok(())
}
