//! L3 hot-path micro-benchmarks: the pure-Rust wire work (bit packing,
//! unpacking, message encode/decode, CRC framing), the server's sharded
//! accumulator fold and parallel eval, the two-lane scheduler's
//! in-process decode overlap (priority lane vs single-FIFO), plus
//! end-to-end federated rounds at threads=1 vs threads=4 and fold
//! overlap on vs off — the parallel round engine's headline numbers.
//! §Perf targets: pack/unpack >= 1 GB/s per core; >= 2x s/round at
//! threads=4 on a multi-core host; priority-lane decode completion
//! beating the FIFO baseline whenever round jobs are queued.
//!
//! Emits `BENCH_hotpath.json` (name -> GB/s and s/round) so the perf
//! trajectory is tracked across PRs; CI's `bench-smoke` job gates on
//! the `_gbps` rows regressing vs the committed baseline.

use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Instant;

use feddq::bench_support as bs;
use feddq::config::{AggregateMode, CodecMode, RunConfig};
use feddq::coordinator::codec::{self, QuantPlan};
use feddq::coordinator::pool::{self, Task, TaskFn, WorkerPool};
use feddq::coordinator::{Server, ServerOpts, Session};
use feddq::data::{self, DatasetKind};
use feddq::quant::PolicyConfig;
use feddq::runtime::{ModelRuntime, Runtime};
use feddq::util::bench::{bench_header, black_box, Bencher};
use feddq::util::rng::Rng;
use feddq::wire::bitpack::{BitReader, BitWriter};
use feddq::wire::frame;
use feddq::wire::messages::{Message, SegmentHeader, Update};
use feddq::wire::swar;

/// One e2e run at `threads` workers and `participation` sampling;
/// returns s/round.
fn e2e_round_secs(
    threads: usize,
    rounds: usize,
    fold_overlap: bool,
    participation: f32,
) -> anyhow::Result<f64> {
    let setup = bs::setup_for("mlp");
    let mut cfg = RunConfig::default_for("mlp");
    cfg.policy = PolicyConfig::FedDq { resolution: 0.005 };
    cfg.rounds = rounds;
    cfg.train_size = setup.train_size.min(1500);
    cfg.test_size = 500;
    cfg.eval_every = 1000; // isolate the round path from eval
    cfg.threads = threads;
    cfg.round.pipeline.fold_overlap = fold_overlap;
    cfg.round.cohort.participation = participation;
    let t0 = std::time::Instant::now();
    let mut session = Session::new(cfg)?;
    let setup_secs = t0.elapsed().as_secs_f64();
    let t1 = std::time::Instant::now();
    let report = session.run()?;
    let run_secs = t1.elapsed().as_secs_f64();
    let per_round = run_secs / report.rounds.len() as f64;
    println!(
        "threads={threads} fold_overlap={fold_overlap} participation={participation}: setup {:.2}s; {} rounds in {:.2}s = {:.3} s/round ({} clients x tau={} local steps + quantize + pack + aggregate)",
        setup_secs,
        report.rounds.len(),
        run_secs,
        per_round,
        session.manifest().n_clients,
        session.manifest().tau,
    );
    Ok(per_round)
}

/// Makespan of dispatching `durs[id]`-long busy-wait jobs in `order`
/// onto the pool's round lane (median over `reps`).  Measures what
/// dispatch order alone buys when jobs outnumber workers — the
/// straggler-aware scheduler's win.
fn dispatch_makespan_secs(
    tasks: &feddq::coordinator::pool::TaskSender,
    order: &[u32],
    durs: &[f64],
    reps: usize,
) -> f64 {
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let (tx, rx) = channel::<()>();
        let t0 = Instant::now();
        for &id in order {
            let dur = durs[id as usize];
            let tx = tx.clone();
            tasks
                .send(Task::RoundExec(Box::new(move || {
                    let t = Instant::now();
                    while t.elapsed().as_secs_f64() < dur {
                        std::hint::spin_loop();
                    }
                    let _ = tx.send(());
                })))
                .unwrap();
        }
        drop(tx);
        for _ in 0..order.len() {
            rx.recv().unwrap();
        }
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[samples.len() / 2]
}

/// In-process recv/decode overlap: median time until the last of
/// `n_dec` decode tasks finishes when they arrive *behind* `n_round`
/// already-queued round jobs.  `priority = true` is the two-lane
/// scheduler (decodes jump the queue on the server lane); `false`
/// replays the old single-FIFO behavior by queueing the decodes on the
/// round lane, where they wait for every round job to start first.
fn decode_overlap_secs(
    pool: &WorkerPool,
    model: &Arc<ModelRuntime>,
    update: &Arc<Update>,
    priority: bool,
    reps: usize,
) -> f64 {
    let tasks = pool.sender();
    let n_round = 8usize;
    let n_dec = 4usize;
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let (rtx, rrx) = channel::<()>();
        let (dtx, drx) = channel::<()>();
        let t0 = Instant::now();
        for _ in 0..n_round {
            let model = Arc::clone(model);
            let u = Arc::clone(update);
            let rtx = rtx.clone();
            // A round-job stand-in: ~4 decode-equivalents of compute.
            tasks
                .send(Task::RoundExec(Box::new(move || {
                    let mut buf = codec::DecodedUpdate::new();
                    for _ in 0..4 {
                        codec::decode_update_into(&model.mm, &u, &mut buf).unwrap();
                    }
                    let _ = rtx.send(());
                })))
                .unwrap();
        }
        for _ in 0..n_dec {
            let model = Arc::clone(model);
            let u = Arc::clone(update);
            let dtx = dtx.clone();
            let f: TaskFn = Box::new(move || {
                let mut buf = codec::DecodedUpdate::new();
                codec::decode_update_into(&model.mm, &u, &mut buf).unwrap();
                let _ = dtx.send(());
            });
            tasks
                .send(if priority { Task::Exec(f) } else { Task::RoundExec(f) })
                .unwrap();
        }
        for _ in 0..n_dec {
            drx.recv().unwrap();
        }
        samples.push(t0.elapsed().as_secs_f64());
        // Drain the round jobs before the next repetition.
        for _ in 0..n_round {
            rrx.recv().unwrap();
        }
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[samples.len() / 2]
}

fn main() -> anyhow::Result<()> {
    let mut b = Bencher::default();
    let mut rng = Rng::new(7);
    let mut json: Vec<(String, f64)> = Vec::new();

    bench_header("bit packing / unpacking — generic get_slice/put_slice baseline (1M codes)");
    let n = 1_000_000usize;
    // Covers every SWAR-specialized width (1/2/4/8/16) so each has a
    // generic baseline row, plus an odd width (12) for the fallback.
    for bits in [1u32, 2, 4, 8, 12, 16] {
        let max = (1u64 << bits) - 1;
        let codes: Vec<u32> = (0..n).map(|_| (rng.next_u64() % (max + 1)) as u32).collect();
        let in_bytes = (n * 4) as u64; // source f32/u32 stream
        let r = b.bench_bytes(&format!("pack {bits}-bit"), Some(in_bytes), &mut || {
            let mut w = BitWriter::with_capacity(n * bits as usize / 8 + 8);
            w.put_slice(&codes, bits);
            black_box(w.finish())
        });
        json.push((format!("pack_{bits}bit_gbps"), r.throughput_gbps().unwrap_or(0.0)));
        let mut w = BitWriter::new();
        w.put_slice(&codes, bits);
        let packed = w.finish();
        let r = b.bench_bytes(&format!("unpack {bits}-bit"), Some(in_bytes), &mut || {
            let mut r = BitReader::new(&packed);
            let mut out = Vec::new();
            r.get_slice(&mut out, n, bits).unwrap();
            black_box(out)
        });
        json.push((format!("unpack_{bits}bit_gbps"), r.throughput_gbps().unwrap_or(0.0)));
    }

    bench_header("SWAR width-specialized kernels (1M codes; same 4-byte/code basis)");
    // Byte basis matches the generic rows above (4 bytes per code), so
    // unpack_w4_gbps vs unpack_4bit_gbps is a direct speedup ratio —
    // the acceptance gate for the narrow-codec rewrite.
    for bits in [1u32, 2, 4, 8, 16] {
        let max = (1u64 << bits) - 1;
        let codes16: Vec<u16> =
            (0..n).map(|_| (rng.next_u64() % (max + 1)) as u16).collect();
        let in_bytes = (n * 4) as u64;
        let r = b.bench_bytes(&format!("pack w{bits} (SWAR)"), Some(in_bytes), &mut || {
            let mut w = BitWriter::with_capacity(n * bits as usize / 8 + 8);
            swar::pack_u16(&mut w, &codes16, bits);
            black_box(w.finish())
        });
        json.push((format!("pack_w{bits}_gbps"), r.throughput_gbps().unwrap_or(0.0)));
        let mut w = BitWriter::new();
        swar::pack_u16(&mut w, &codes16, bits);
        let packed = w.finish();
        let r = b.bench_bytes(&format!("unpack w{bits} (SWAR)"), Some(in_bytes), &mut || {
            let mut r = BitReader::new(&packed);
            let mut out: Vec<u16> = Vec::new();
            swar::unpack_u16(&mut r, &mut out, n, bits).unwrap();
            black_box(out)
        });
        json.push((format!("unpack_w{bits}_gbps"), r.throughput_gbps().unwrap_or(0.0)));
    }
    // Headline ratio: 4-bit SWAR unpack vs the generic loop (>= 2x is
    // the PR's acceptance bar; both rows land in BENCH_hotpath.json).
    let row = |k: &str| json.iter().find(|(n, _)| n == k).map(|&(_, v)| v).unwrap_or(0.0);
    let w4_speedup = row("unpack_w4_gbps") / row("unpack_4bit_gbps").max(1e-12);
    println!(
        "4-bit unpack: SWAR {:.3} GB/s vs generic {:.3} GB/s = {w4_speedup:.2}x",
        row("unpack_w4_gbps"),
        row("unpack_4bit_gbps"),
    );
    json.push(("unpack_w4_speedup_vs_generic".into(), w4_speedup));

    bench_header("message encode/decode (100k-element update, 8-bit)");
    let d = 100_000usize;
    let mut w = BitWriter::new();
    let codes: Vec<u32> = (0..d).map(|_| (rng.next_u64() % 256) as u32).collect();
    w.put_slice(&codes, 8);
    let update = Update {
        round: 3,
        client_id: 2,
        num_samples: 600,
        train_loss: 0.42,
        segments: vec![
            SegmentHeader { bits: 8, level: 255, min: -0.1, step: 0.001 };
            12
        ],
        payload: w.finish(),
    };
    let msg = Message::Update(update);
    let encoded = msg.encode();
    let bytes = encoded.len() as u64;
    let r = b.bench_bytes("encode Update", Some(bytes), &mut || black_box(msg.encode()));
    json.push(("encode_update_gbps".into(), r.throughput_gbps().unwrap_or(0.0)));
    let r = b.bench_bytes("decode Update", Some(bytes), &mut || {
        black_box(Message::decode(&encoded).unwrap())
    });
    json.push(("decode_update_gbps".into(), r.throughput_gbps().unwrap_or(0.0)));
    let r = b.bench_bytes("crc32 frame", Some(bytes), &mut || {
        black_box(frame::crc32(&encoded))
    });
    json.push(("crc32_gbps".into(), r.throughput_gbps().unwrap_or(0.0)));

    bench_header("client encode: fused quantize→pack vs split (mlp delta, 8-bit)");
    let rt = Runtime::new("artifacts")?;
    let model = Arc::new(rt.load_model("mlp")?);
    let mm = Arc::new(model.mm.clone());
    let delta: Vec<f32> = (0..mm.d)
        .map(|i| -1.0 + 2.0 * i as f32 / (mm.d - 1) as f32)
        .collect();
    let (mins_e, ranges_e) = model.ranges(&delta)?;
    let levels_e = vec![255u32; mm.num_segments()];
    let plan_e = QuantPlan::new(&levels_e, &ranges_e);
    let dbytes = (mm.d * 4) as u64;
    let r = b.bench_bytes("encode split (quantize + pack)", Some(dbytes), &mut || {
        let codes = model
            .quantize(&delta, &mins_e, &plan_e.sinv, &plan_e.maxcode, 7)
            .unwrap();
        black_box(codec::encode_quantized(&mm, &plan_e, &mins_e, &codes))
    });
    json.push(("encode_split_gbps".into(), r.throughput_gbps().unwrap_or(0.0)));
    let r = b.bench_bytes("encode fused (clamp-round-pack)", Some(dbytes), &mut || {
        black_box(codec::encode_quantized_fused(&mm, &plan_e, &mins_e, &delta, 7, None))
    });
    json.push(("encode_fused_gbps".into(), r.throughput_gbps().unwrap_or(0.0)));

    bench_header("server downlink: fused quantized broadcast encode (mlp delta, 4-bit)");
    // The per-round broadcast cost with --downlink-bits on: envelope +
    // fused quantize→pack of the (params - replica) + residual vector,
    // with the EF residual updated in place.  Same 4-byte/element basis
    // as the client encode rows above.
    {
        let replica = vec![0.0f32; mm.d];
        let mut residual = vec![0.0f32; mm.d];
        let r = b.bench_bytes("encode downlink (4-bit fused)", Some(dbytes), &mut || {
            // reset the residual so every rep encodes the same vector
            residual.iter_mut().for_each(|v| *v = 0.0);
            black_box(
                codec::encode_downlink(&mm, 4, &delta, &replica, &mut residual, 7).unwrap(),
            )
        });
        json.push(("downlink_encode_gbps".into(), r.throughput_gbps().unwrap_or(0.0)));
    }

    bench_header("bit-budget controller: per-round allocation (1000-member cohort)");
    // The closed loop's control-plane cost: one plan() over a sampled
    // 1000-client cohort against the mlp segment layout — must stay
    // far below a round's compute cost (microseconds, not millis).
    {
        use feddq::quant::budget::BitBudgetController;
        let seg_sizes: Vec<u64> = mm.segments.iter().map(|s| s.size as u64).collect();
        let k = 1000u32;
        let cap = k as u64 * mm.d as u64 * 4; // ~4 bits/element/member
        let cohort: Vec<(u32, bool)> = (0..k).map(|id| (id, id % 7 == 0)).collect();
        let mut ctl = BitBudgetController::new(cap, seg_sizes);
        let r = b.bench(&format!("budget plan k={k}"), || black_box(ctl.plan(&cohort)));
        let plan_secs = r.median.as_secs_f64();
        println!("budget plan over {k} members: {:.3} ms", plan_secs * 1e3);
        json.push(("budget_plan_secs".into(), plan_secs));
    }

    bench_header("server hot path: sharded aggregation (mlp layout)");
    // Fixture: n decoded 8-bit updates produced through the real codec,
    // decoded both ways (narrow u16 rows = production, f32 reference
    // rows = the pre-SWAR representation) so the fold bandwidth win is
    // a tracked row.
    let n_agg = 32usize;
    let mut updates: Vec<Update> = Vec::with_capacity(n_agg);
    for i in 0..n_agg {
        let levels = vec![255u32; mm.num_segments()];
        let ranges = vec![1.0f32; mm.num_segments()];
        let plan = QuantPlan::new(&levels, &ranges);
        let codes: Vec<f32> = (0..mm.d).map(|j| ((i + j) % 256) as f32).collect();
        let mins = vec![-0.5f32; mm.num_segments()];
        let (headers, payload) = codec::encode_quantized(&mm, &plan, &mins, &codes);
        updates.push(Update {
            round: 0,
            client_id: i as u32,
            num_samples: 100,
            train_loss: 0.0,
            segments: headers,
            payload,
        });
    }
    let mut decs: Vec<codec::DecodedUpdate> = Vec::with_capacity(n_agg);
    let mut decs_ref: Vec<codec::DecodedUpdate> = Vec::with_capacity(n_agg);
    for u in &updates {
        decs.push(codec::decode_update(&mm, u)?);
        let mut d = codec::DecodedUpdate::new();
        codec::decode_update_into_mode(&mm, u, &mut d, CodecMode::Reference)?;
        decs_ref.push(d);
    }
    let w = 1.0f32 / n_agg as f32;
    let fold_bytes = (n_agg * mm.d * 4) as u64;
    let narrow_name = format!("fold narrow u16 rows (n={n_agg})");
    let r = b.bench_bytes(&narrow_name, Some(fold_bytes), &mut || {
        let mut acc = vec![0.0f32; mm.d];
        for dec in &decs {
            codec::fold_range(&mm, dec, w, 0, mm.d, &mut acc);
        }
        black_box(acc)
    });
    json.push(("fold_narrow_gbps".into(), r.throughput_gbps().unwrap_or(0.0)));
    json.push(("agg_fold_serial_gbps".into(), r.throughput_gbps().unwrap_or(0.0)));
    let ref_name = format!("fold f32 reference rows (n={n_agg})");
    let r = b.bench_bytes(&ref_name, Some(fold_bytes), &mut || {
        let mut acc = vec![0.0f32; mm.d];
        for dec in &decs_ref {
            codec::fold_range(&mm, dec, w, 0, mm.d, &mut acc);
        }
        black_box(acc)
    });
    json.push(("fold_f32rows_gbps".into(), r.throughput_gbps().unwrap_or(0.0)));
    drop(decs_ref);
    let pool = WorkerPool::new(4, Arc::clone(&model));
    let tasks = pool.sender();
    let shards = 4usize;
    let shared: Arc<Vec<codec::DecodedUpdate>> = Arc::new(std::mem::take(&mut decs));
    let ws: Arc<Vec<f32>> = Arc::new(vec![w; n_agg]);
    // drives pool::sharded_fold — the exact production aggregation path
    let r = b.bench_bytes(
        &format!("agg fold sharded x{shards} (n={n_agg})"),
        Some(fold_bytes),
        &mut || {
            black_box(
                pool::sharded_fold(&tasks, &model, &shared, &ws, shards, Vec::new()).unwrap(),
            )
        },
    );
    json.push(("agg_sharded_gbps".into(), r.throughput_gbps().unwrap_or(0.0)));

    bench_header("server hot path: parallel eval (mlp, 4 eval batches)");
    // Server eval over a 4-batch synthetic test set, serial vs sliced
    // across the same pool (timing rows — CI gates only on throughput).
    let (_, test, _) = data::load_or_synthesize(DatasetKind::FashionMnist, "data", 64, 4 * 500, 17)?;
    let test = Arc::new(test);
    let server_serial = Server::new(
        Arc::clone(&model),
        Arc::clone(&test),
        17,
        ServerOpts::serial(AggregateMode::Streaming),
    )?;
    let r = b.bench("eval serial (4 batches)", || server_serial.evaluate().unwrap());
    let eval_serial = r.median.as_secs_f64();
    json.push(("eval_serial_secs".into(), eval_serial));
    let server_par = Server::new(
        Arc::clone(&model),
        Arc::clone(&test),
        17,
        ServerOpts {
            aggregate: AggregateMode::Streaming,
            agg_shards: 1,
            eval_threads: 4,
            round: {
                let mut r = feddq::config::RoundPolicy::strict_sync();
                r.pipeline.fold_overlap = false;
                r
            },
            tasks: Some(pool.sender()),
        },
    )?;
    let r = b.bench("eval parallel x4 (4 batches)", || server_par.evaluate().unwrap());
    let eval_par = r.median.as_secs_f64();
    json.push(("eval_parallel_secs".into(), eval_par));
    json.push(("eval_parallel_speedup".into(), eval_serial / eval_par.max(1e-12)));
    drop(server_par);
    drop(server_serial);
    drop(tasks);

    bench_header("two-lane scheduler: in-process decode overlap (priority vs FIFO)");
    // Decode tasks landing behind 8 queued round jobs: the priority
    // lane must finish them well before the single-FIFO baseline, which
    // makes them wait for every round job to start first.
    let ov_update = {
        let levels = vec![255u32; mm.num_segments()];
        let ranges = vec![1.0f32; mm.num_segments()];
        let plan = QuantPlan::new(&levels, &ranges);
        let codes: Vec<f32> = (0..mm.d).map(|j| (j % 256) as f32).collect();
        let mins = vec![-0.5f32; mm.num_segments()];
        let (headers, payload) = codec::encode_quantized(&mm, &plan, &mins, &codes);
        Arc::new(Update {
            round: 0,
            client_id: 0,
            num_samples: 100,
            train_loss: 0.0,
            segments: headers,
            payload,
        })
    };
    let reps = if std::env::var("FEDDQ_BENCH_FAST").is_ok() { 5 } else { 15 };
    let fifo = decode_overlap_secs(&pool, &model, &ov_update, false, reps);
    let prio = decode_overlap_secs(&pool, &model, &ov_update, true, reps);
    let overlap_speedup = fifo / prio.max(1e-12);
    println!(
        "last-decode latency behind 8 round jobs: FIFO {:.2} ms vs priority lane {:.2} ms = {overlap_speedup:.2}x",
        fifo * 1e3,
        prio * 1e3,
    );
    json.push(("inproc_decode_fifo_secs".into(), fifo));
    json.push(("inproc_decode_priority_secs".into(), prio));
    json.push(("inproc_decode_overlap_speedup".into(), overlap_speedup));

    bench_header("round scheduler: slowest-first dispatch vs id-order (synthetic stragglers)");
    // 6 jobs on 2 workers, one 10x straggler with the highest id: in
    // id-order dispatch the straggler starts last and runs alone at the
    // tail; the production scheduler's slowest-first plan starts it
    // first so the fast jobs pack around it.  Uses the real
    // RoundScheduler (EWMA-fed) so the bench exercises the production
    // ordering code, not a reimplementation.
    {
        use feddq::coordinator::sched::RoundScheduler;
        use feddq::sim::latency::{LatencyModel, LatencyProfile};
        let fast = if std::env::var("FEDDQ_BENCH_FAST").is_ok() { 0.004 } else { 0.01 };
        let n_jobs = 6usize;
        let mut durs = vec![fast; n_jobs];
        durs[n_jobs - 1] = fast * 10.0; // the straggler
        let mut sched =
            RoundScheduler::new(n_jobs, 1.0, None, LatencyModel::new(LatencyProfile::Off, 7), 7)?;
        for (id, &d) in durs.iter().enumerate() {
            sched.observe(id as u32, d);
        }
        let plan = sched.plan_round(0);
        assert_eq!(plan.dispatch[0] as usize, n_jobs - 1, "slowest must dispatch first");
        let id_order: Vec<u32> = (0..n_jobs as u32).collect();
        let pool2 = WorkerPool::new(2, Arc::clone(&model));
        let tasks2 = pool2.sender();
        let reps = if std::env::var("FEDDQ_BENCH_FAST").is_ok() { 5 } else { 11 };
        let t_id = dispatch_makespan_secs(&tasks2, &id_order, &durs, reps);
        let t_slow = dispatch_makespan_secs(&tasks2, &plan.dispatch, &durs, reps);
        let slowfirst_speedup = t_id / t_slow.max(1e-12);
        println!(
            "makespan, 6 jobs (one 10x straggler) on 2 workers: id-order {:.2} ms vs slowest-first {:.2} ms = {slowfirst_speedup:.2}x",
            t_id * 1e3,
            t_slow * 1e3,
        );
        json.push(("straggler_idorder_secs".into(), t_id));
        json.push(("straggler_slowfirst_secs".into(), t_slow));
        json.push(("straggler_slowfirst_speedup".into(), slowfirst_speedup));
        drop(tasks2);
    }

    bench_header("end-to-end federated rounds (mlp, 10 clients, in-proc)");
    let rounds = if std::env::var("FEDDQ_BENCH_FAST").is_ok() { 3 } else { 6 };
    let t1 = e2e_round_secs(1, rounds, true, 1.0)?;
    let t4 = e2e_round_secs(4, rounds, true, 1.0)?;
    let t4_no_overlap = e2e_round_secs(4, rounds, false, 1.0)?;
    let speedup = t1 / t4;
    println!(
        "round engine speedup threads=4 vs threads=1: {speedup:.2}x ({} cores available)",
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    );
    json.push(("e2e_round_secs_threads1".into(), t1));
    json.push(("e2e_round_secs_threads4".into(), t4));
    json.push(("e2e_round_speedup_t4_vs_t1".into(), speedup));
    json.push(("e2e_round_secs_threads4_no_fold_overlap".into(), t4_no_overlap));
    json.push(("fold_overlap_speedup".into(), t4_no_overlap / t4.max(1e-12)));

    bench_header("round scheduler: full cohort vs sampled cohort (participation 0.5)");
    // Same engine, half the cohort per round: the round cost should
    // drop roughly with the sampled fraction once threads < clients.
    let t4_sampled = e2e_round_secs(4, rounds, true, 0.5)?;
    println!(
        "s/round threads=4: full {t4:.3} vs participation=0.5 {t4_sampled:.3} ({:.3}s saved/round)",
        t4 - t4_sampled
    );
    json.push(("sched_sampled_round_secs".into(), t4_sampled));
    json.push(("sched_full_vs_sampled_secs".into(), t4 - t4_sampled));

    bench_header("million-client control plane: sparse sampling + compact resident state");
    // The scale-out rows: planning a cohort of ~1000 out of a million
    // registered clients must cost O(k), and the per-client resident
    // state (arena row + banked EF residual) must undercut the fp32
    // baselines it replaced.  Companion assertions live in
    // rust/tests/scale_smoke.rs; these rows track the trajectory.
    {
        use feddq::coordinator::sched::RoundScheduler;
        use feddq::coordinator::{ClientArena, ResidualBank};
        use feddq::sim::latency::{LatencyModel, LatencyProfile};
        let n_reg = 1_000_000usize;
        let sched =
            RoundScheduler::new(n_reg, 0.001, None, LatencyModel::new(LatencyProfile::Off, 7), 7)?;
        let k = sched.cohort_target();
        let r = b.bench(&format!("plan_round n=1M k={k} (sparse draw)"), || {
            black_box(sched.plan_round(3))
        });
        let plan_secs = r.median.as_secs_f64();
        println!("1M-client round plan: {:.3} ms for k={k}", plan_secs * 1e3);
        json.push(("sched_sample_1m_k1000_secs".into(), plan_secs));

        let mut arena = ClientArena::new();
        for id in 0..n_reg as u32 {
            arena.set_samples(id, 60);
        }
        let arena_bpc = arena.resident_bytes() as f64 / n_reg as f64;
        println!("arena resident state: {arena_bpc:.1} B/client across {n_reg} clients");
        json.push(("client_arena_bytes_per_client".into(), arena_bpc));

        let d_res = 100_000usize;
        let spans = [(0usize, 60_000usize), (60_000, 40_000)];
        let vals: Vec<f32> = (0..d_res).map(|i| (i as f32 * 0.37).sin()).collect();
        let bank = ResidualBank::bank(&spans, &vals, 8);
        println!(
            "banked EF residual (d={d_res}, 8-bit): {} B vs {} B fp32",
            bank.resident_bytes(),
            d_res * 4
        );
        json.push(("ef_bank_bytes_per_client".into(), bank.resident_bytes() as f64));
    }

    bs::write_bench_json("hotpath", &json);
    Ok(())
}
