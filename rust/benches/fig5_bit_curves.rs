//! Fig. 5 — the quantization bit-length over training rounds for each
//! experiment: FedDQ descends while AdaQuantFL ascends.  Collates the
//! bit curves from fresh runs of the three benchmarks (small round
//! budgets; the figure is about the *trend*, which appears immediately).

use feddq::bench_support as bs;
use feddq::quant::PolicyConfig;

fn main() -> anyhow::Result<()> {
    println!("=== Fig 5: average quantization bits vs round ===");
    for model in ["vanilla_cnn", "cnn4", "resnet18"] {
        let mut setup = bs::setup_for(model);
        setup.rounds = setup.rounds.min(10);
        let feddq = bs::run_policy(&setup, PolicyConfig::FedDq { resolution: 0.005 })?;
        let ada = bs::run_policy(&setup, PolicyConfig::AdaQuantFl { s0: 2 })?;
        println!("\n-- {model} — columns: round feddq_bits adaquantfl_bits --");
        for (f, a) in feddq.rounds.iter().zip(&ada.rounds) {
            println!("{:>4} {:>6.2} {:>6.2}", f.round, f.mean_bits, a.mean_bits);
        }
        let f_first = feddq.rounds.first().unwrap().mean_bits;
        let f_last = feddq.rounds.last().unwrap().mean_bits;
        let a_first = ada.rounds.first().unwrap().mean_bits;
        let a_last = ada.rounds.last().unwrap().mean_bits;
        println!(
            "# trend: FedDQ {f_first:.2} -> {f_last:.2} ({}), AdaQuantFL {a_first:.2} -> {a_last:.2} ({})",
            if f_last < f_first { "DESCENDING ✓" } else { "not descending ✗" },
            if a_last > a_first { "ascending ✓" } else { "not ascending ✗" },
        );
    }
    Ok(())
}
