//! Fig. 1 — the training characteristics that motivate FedDQ:
//! (a) training loss drops fastest in the earliest rounds;
//! (b) the per-layer range of the model update *descends* with rounds.
//!
//! Run with an unquantized (fp32) uplink so the measured ranges are the
//! raw training dynamics, as in the paper's motivating figure.

use feddq::bench_support as bs;
use feddq::quant::PolicyConfig;

fn main() -> anyhow::Result<()> {
    println!("=== Fig 1: training characteristics (vanilla_cnn, fp32 uplink) ===");
    let setup = bs::setup_for("vanilla_cnn");
    let report = bs::run_policy(&setup, PolicyConfig::Fp32)?;

    println!("\n-- Fig 1(a): training loss vs round --");
    println!("# round train_loss");
    for r in &report.rounds {
        println!("{:>4} {:.5}", r.round, r.train_loss);
    }
    // headline check: the first quarter of training does most of the work
    let q = report.rounds.len() / 4;
    let first_drop = report.rounds[0].train_loss - report.rounds[q.max(1) - 1].train_loss;
    let total_drop =
        report.rounds[0].train_loss - report.rounds.last().unwrap().train_loss;
    println!(
        "# first-quarter loss drop = {:.3} of total {:.3} ({:.0}%)",
        first_drop,
        total_drop,
        100.0 * first_drop / total_drop.max(1e-9)
    );

    println!("\n-- Fig 1(b): per-layer update range vs round --");
    let nseg = report.rounds[0].seg_ranges.len();
    print!("# round");
    for l in 0..nseg {
        print!(" seg{l}");
    }
    println!();
    for r in &report.rounds {
        print!("{:>4}", r.round);
        for v in &r.seg_ranges {
            print!(" {v:.5}");
        }
        println!();
    }
    let early = report.rounds[1].mean_range;
    let late = report.rounds.last().unwrap().mean_range;
    println!(
        "# mean range: round1 {early:.5} -> final {late:.5} ({}x smaller) — paper: descending",
        (early / late.max(1e-9)).round()
    );
    bs::save(&report, "fig1_characteristics");
    Ok(())
}
