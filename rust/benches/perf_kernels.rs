//! L1/L2 micro-benchmarks: latency of each model executable in
//! isolation (the coordinator's entire compute budget), across every
//! model the active backend can load, plus the coordinator's sharded
//! decode-fold over the same layout.  Used by the §Perf pass in
//! EXPERIMENTS.md.  Emits `BENCH_kernels.json` (name -> GB/s or secs)
//! for cross-PR tracking; CI's `bench-smoke` job gates the `_gbps`
//! rows against the committed baseline.

use std::sync::Arc;

use feddq::bench_support as bs;
use feddq::coordinator::codec::{self, QuantPlan};
use feddq::coordinator::pool::{self, WorkerPool};
use feddq::runtime::Runtime;
use feddq::util::bench::{bench_header, Bencher};
use feddq::util::rng::Rng;
use feddq::wire::messages::Update;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new("artifacts")?;
    let mut b = Bencher::quick();
    let mut json: Vec<(String, f64)> = Vec::new();
    let models: Vec<String> = if std::env::var("FEDDQ_BENCH_FAST").is_ok() {
        vec!["mlp".into()]
    } else {
        rt.manifest.models.keys().cloned().collect()
    };

    for name in models {
        let model = match rt.load_model(&name) {
            Ok(m) => Arc::new(m),
            Err(e) => {
                // conv models need AOT artifacts + the pjrt feature
                println!("skipping {name}: {e:#}");
                continue;
            }
        };
        let mm = model.mm.clone();
        bench_header(&format!(
            "{name}: d={} segments={} tau={} B={}",
            mm.d, mm.num_segments(), mm.tau, mm.batch
        ));
        let mut rng = Rng::new(1);
        let params = model.init(0)?;
        let xs: Vec<f32> = (0..mm.tau * mm.batch * mm.input_len())
            .map(|_| rng.next_normal() * 0.5)
            .collect();
        let ys: Vec<i32> = (0..mm.tau * mm.batch).map(|_| rng.below(10) as i32).collect();
        let exs: Vec<f32> = (0..mm.eval_batch * mm.input_len())
            .map(|_| rng.next_normal() * 0.5)
            .collect();
        let eys: Vec<i32> = (0..mm.eval_batch).map(|_| rng.below(10) as i32).collect();

        let (delta, _) = model.local_round(&params, &xs, &ys, 0.1)?;
        let (mins, ranges) = model.ranges(&delta)?;
        let levels = vec![255u32; mm.num_segments()];
        let plan = QuantPlan::new(&levels, &ranges);
        let codes = model.quantize(&delta, &mins, &plan.sinv, &plan.maxcode, 1)?;
        let n = mm.n_clients;
        let codes_n: Vec<f32> = (0..n).flat_map(|_| codes.iter().copied()).collect();
        let mins_n: Vec<f32> = (0..n).flat_map(|_| mins.iter().copied()).collect();
        let steps_n: Vec<f32> = (0..n).flat_map(|_| plan.step.iter().copied()).collect();
        let w = vec![1.0 / n as f32; n];

        // round/evaluate are seconds-long on the conv models (1-core CPU):
        // a single timed execution is the honest, affordable measurement.
        let t0 = std::time::Instant::now();
        model.local_round(&params, &xs, &ys, 0.1)?;
        let round_secs = t0.elapsed().as_secs_f64();
        println!("{:<44} {:>12.3?} single-shot", format!("{name}/round (tau={} SGD steps)", mm.tau), t0.elapsed());
        json.push((format!("{name}_round_secs"), round_secs));
        let t0 = std::time::Instant::now();
        model.evaluate(&params, &exs, &eys)?;
        let eval_secs = t0.elapsed().as_secs_f64();
        println!("{:<44} {:>12.3?} single-shot", format!("{name}/evaluate (E={})", mm.eval_batch), t0.elapsed());
        json.push((format!("{name}_evaluate_secs"), eval_secs));
        let dbytes = (mm.d * 4) as u64;
        let r = b.bench_bytes(&format!("{name}/ranges"), Some(dbytes), &mut || {
            model.ranges(&delta).unwrap()
        });
        json.push((format!("{name}_ranges_gbps"), r.throughput_gbps().unwrap_or(0.0)));
        let r = b.bench_bytes(&format!("{name}/quantize"), Some(dbytes), &mut || {
            model
                .quantize(&delta, &mins, &plan.sinv, &plan.maxcode, 2)
                .unwrap()
        });
        json.push((format!("{name}_quantize_gbps"), r.throughput_gbps().unwrap_or(0.0)));
        let r = b.bench_bytes(
            &format!("{name}/aggregate (n={n})"),
            Some(dbytes * n as u64),
            &mut || model.aggregate(&codes_n, &mins_n, &steps_n, &w).unwrap(),
        );
        json.push((format!("{name}_aggregate_gbps"), r.throughput_gbps().unwrap_or(0.0)));

        // Coordinator-level sharded decode-fold over this layout: the
        // streaming aggregation path's parallel fold (4 shards on a
        // 4-worker pool), byte-equivalent work to the fused aggregate.
        let (headers, payload) = codec::encode_quantized(&mm, &plan, &mins, &codes);
        let u = Update {
            round: 0,
            client_id: 0,
            num_samples: 1,
            train_loss: 0.0,
            segments: headers,
            payload,
        };
        let decs: Arc<Vec<codec::DecodedUpdate>> = Arc::new(
            (0..n).map(|_| codec::decode_update(&mm, &u).unwrap()).collect(),
        );
        let ws: Arc<Vec<f32>> = Arc::new(vec![1.0f32 / n as f32; n]);
        let pool = WorkerPool::new(4, Arc::clone(&model));
        let tasks = pool.sender();
        let shards = 4usize;
        // drives pool::sharded_fold — the exact production aggregation path
        let r = b.bench_bytes(
            &format!("{name}/agg fold sharded x{shards} (n={n})"),
            Some(dbytes * n as u64),
            &mut || pool::sharded_fold(&tasks, &model, &decs, &ws, shards, Vec::new()).unwrap(),
        );
        json.push((format!("{name}_agg_sharded_gbps"), r.throughput_gbps().unwrap_or(0.0)));
        drop(tasks);
    }

    bs::write_bench_json("kernels", &json);
    Ok(())
}
