//! Ablations over FedDQ's design choices (DESIGN.md §4):
//!   1. resolution hyper-parameter sweep (paper §IV: trade-off knob);
//!   2. per-segment vs whole-model range granularity;
//!   3. non-IID severity (Dirichlet alpha) — robustness of the
//!      descending-trend schedule under heterogeneity.

use feddq::bench_support as bs;
use feddq::config::RunConfig;
use feddq::coordinator::Session;
use feddq::data::shard::Sharding;
use feddq::metrics::gbits;
use feddq::quant::PolicyConfig;

fn main() -> anyhow::Result<()> {
    let mut setup = bs::setup_for("mlp");
    setup.rounds = setup.rounds.min(25);

    println!("=== Ablation 1: resolution sweep (mlp, {} rounds) ===", setup.rounds);
    println!("{:<12} {:>9} {:>11} {:>10}", "resolution", "best acc", "total Gb", "end bits");
    for res in [0.001f32, 0.0025, 0.005, 0.01, 0.02] {
        let rep = bs::run_policy(&setup, PolicyConfig::FedDq { resolution: res })?;
        println!(
            "{:<12} {:>9.4} {:>11.4} {:>10.2}",
            res,
            rep.best_accuracy(),
            gbits(rep.total_uplink_bits()),
            rep.rounds.last().unwrap().mean_bits
        );
    }

    println!("\n=== Ablation 2: range granularity (per-segment vs whole-model) ===");
    // The whole-model variant applies Eq. 10 to the global update range —
    // exercised via a custom run loop: emulate by computing with the max
    // segment range, which the FedDq policy exposes as Granularity::Whole.
    // (Session builds policies from PolicyConfig, so we run per-segment
    // here and quantify the headroom from the recorded ranges.)
    let rep = bs::run_policy(&setup, PolicyConfig::FedDq { resolution: 0.005 })?;
    let mut per_seg_bits = 0.0f64;
    let mut whole_bits = 0.0f64;
    for r in &rep.rounds {
        per_seg_bits += r.mean_bits as f64;
        // whole-model bits/elem = bits(max range) for every segment
        let max_range = r.seg_ranges.iter().copied().fold(0.0f32, f32::max);
        whole_bits += feddq::quant::math::feddq_bits(max_range, 0.005, 16) as f64;
    }
    let n = rep.rounds.len() as f64;
    println!(
        "mean bits/elem: per-segment {:.2} vs whole-model {:.2} ({:.0}% saved by per-layer ranges)",
        per_seg_bits / n,
        whole_bits / n,
        100.0 * (1.0 - per_seg_bits / whole_bits)
    );

    println!("\n=== Ablation 3: non-IID severity (Dirichlet alpha) ===");
    println!("{:<10} {:>9} {:>11} {:>10}", "alpha", "best acc", "total Gb", "end bits");
    for alpha in [100.0f64, 1.0, 0.3, 0.1] {
        let mut cfg = RunConfig::default_for("mlp");
        cfg.policy = PolicyConfig::FedDq { resolution: 0.005 };
        cfg.rounds = setup.rounds;
        cfg.train_size = setup.train_size;
        cfg.test_size = setup.test_size;
        cfg.sharding = Sharding::Dirichlet { alpha };
        let rep = Session::new(cfg)?.run()?;
        println!(
            "{:<10} {:>9.4} {:>11.4} {:>10.2}",
            alpha,
            rep.best_accuracy(),
            gbits(rep.total_uplink_bits()),
            rep.rounds.last().unwrap().mean_bits
        );
    }
    Ok(())
}
