//! Fig. 4 — benchmark 3: ResNet-18 on CIFAR-10(-shaped) data, 4 clients.
//! Same axes as Fig. 2: (a) vs bit volume, (b) vs rounds.

use feddq::bench_support as bs;
use feddq::quant::PolicyConfig;

fn main() -> anyhow::Result<()> {
    println!("=== Fig 4: resnet18 / CIFAR-10 — FedDQ vs AdaQuantFL ===");
    let setup = bs::setup_for("resnet18");
    let feddq = bs::run_policy(&setup, PolicyConfig::FedDq { resolution: 0.005 })?;
    let ada = bs::run_policy(&setup, PolicyConfig::AdaQuantFl { s0: 2 })?;

    for rep in [&feddq, &ada] {
        println!();
        bs::print_series(rep);
        bs::save(rep, &format!("fig4_{}", rep.label.replace([':', '.'], "_")));
    }

    println!("\n-- crossover summary --");
    for target in [0.5f32, 0.6, 0.7] {
        bs::print_table1_row("fig4", target, &feddq, "AdaQuantFL", &ada);
    }
    Ok(())
}
