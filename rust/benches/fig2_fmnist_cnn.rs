//! Fig. 2 — benchmark 1: Vanilla CNN on Fashion-MNIST(-shaped) data.
//! (a) accuracy & loss vs communicated bit volume;
//! (b) accuracy & loss vs communication rounds.
//! FedDQ (descending) vs AdaQuantFL (ascending).

use feddq::bench_support as bs;
use feddq::quant::PolicyConfig;

fn main() -> anyhow::Result<()> {
    println!("=== Fig 2: vanilla_cnn / Fashion-MNIST — FedDQ vs AdaQuantFL ===");
    let setup = bs::setup_for("vanilla_cnn");
    let feddq = bs::run_policy(&setup, PolicyConfig::FedDq { resolution: 0.005 })?;
    let ada = bs::run_policy(&setup, PolicyConfig::AdaQuantFl { s0: 2 })?;

    for rep in [&feddq, &ada] {
        println!();
        bs::print_series(rep);
        bs::save(rep, &format!("fig2_{}", rep.label.replace([':', '.'], "_")));
    }

    println!("\n-- crossover summary (who reaches accuracy milestones cheaper) --");
    for target in [0.7f32, 0.8, 0.85, 0.9] {
        bs::print_table1_row("fig2", target, &feddq, "AdaQuantFL", &ada);
    }
    Ok(())
}
