//! `feddq` — the FedDQ federated-learning launcher.
//!
//! Subcommands:
//!   train      single-process federated run (simulated clients)
//!   serve      federated server, accepts TCP workers or aggregators
//!   worker     one federated client process
//!   aggregate  one intermediate aggregator (tree topology)
//!   info       inspect the artifact manifest
//!
//! Run `feddq <cmd> --help` (or no args) for flags.

use anyhow::Result;

use feddq::cli::{run_config_from_args, Args, USAGE};
use feddq::coordinator::{topology, Session};
use feddq::metrics::gbits;
use feddq::runtime::Runtime;
use feddq::util::log::{set_level, Level};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "help" {
        print!("{USAGE}");
        return;
    }
    if let Err(e) = dispatch(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(argv: &[String]) -> Result<()> {
    let cmd = argv[0].as_str();
    let args = Args::parse(&argv[1..])?;
    if args.flag("verbose") {
        set_level(Level::Debug);
    }
    match cmd {
        "train" => cmd_train(&args),
        "serve" => cmd_serve(&args),
        "worker" => cmd_worker(&args),
        "aggregate" => cmd_aggregate(&args),
        "info" => cmd_info(&args),
        other => anyhow::bail!("unknown command {other:?}\n{USAGE}"),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = run_config_from_args(args, "mlp")?;
    let out = args.get("out").map(String::from);
    let quiet = args.flag("quiet");
    let _ = args.flag("verbose");
    args.finish()?;

    let mut session = Session::new(cfg)?;
    println!(
        "model={} d={} clients={} data={} policy={}",
        session.config().model,
        session.manifest().d,
        session.manifest().n_clients,
        session.data_source,
        session.config().policy.label()
    );
    let report = session.run_with(|m, rec| {
        if !quiet {
            println!(
                "round {m:>4}  train_loss {:.4}  test_acc {}  bits/elem {:.2}  cum {:.4} Gb",
                rec.train_loss,
                if rec.evaluated() {
                    format!("{:.4}", rec.test_accuracy)
                } else {
                    "  -   ".to_string()
                },
                rec.mean_bits,
                gbits(rec.cum_uplink_bits),
            );
        }
    })?;
    let best = report.best_accuracy();
    println!(
        "done: {} rounds, best test acc {:.4}, total uplink {:.4} Gb",
        report.rounds.len(),
        best,
        gbits(report.total_uplink_bits())
    );
    if let Some(path) = out {
        if path.ends_with(".csv") {
            report.write_csv(&path)?;
        } else {
            report.write_json(&path)?;
        }
        println!("report written to {path}");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = run_config_from_args(args, "mlp")?;
    let addr = args.get_or("addr", "127.0.0.1:7177").to_string();
    let out = args.get("out").map(String::from);
    let quiet = args.flag("quiet");
    args.finish()?;
    let report = topology::serve(&cfg, &addr, |m, rec| {
        if !quiet {
            println!(
                "round {m:>4}  train_loss {:.4}  test_acc {:.4}  cum {:.4} Gb",
                rec.train_loss, rec.test_accuracy, gbits(rec.cum_uplink_bits)
            );
        }
    })?;
    println!(
        "done: {} rounds, best acc {:.4}, total uplink {:.4} Gb",
        report.rounds.len(),
        report.best_accuracy(),
        gbits(report.total_uplink_bits())
    );
    if let Some(path) = out {
        if path.ends_with(".csv") {
            report.write_csv(&path)?;
        } else {
            report.write_json(&path)?;
        }
    }
    Ok(())
}

fn cmd_worker(args: &Args) -> Result<()> {
    let addr = args.get_or("addr", "127.0.0.1:7177").to_string();
    let id: u32 = args
        .get_parse("id")?
        .ok_or_else(|| anyhow::anyhow!("worker needs --id"))?;
    let artifacts = args
        .get_or("artifacts", &Runtime::default_artifacts_dir())
        .to_string();
    args.finish()?;
    topology::worker(&addr, id, &artifacts)
}

fn cmd_aggregate(args: &Args) -> Result<()> {
    let upstream = args.get_or("upstream", "127.0.0.1:7177").to_string();
    let addr = args.get_or("addr", "127.0.0.1:7178").to_string();
    let id: u32 = args
        .get_parse("id")?
        .ok_or_else(|| anyhow::anyhow!("aggregate needs --id (the subtree's lowest leaf id)"))?;
    let fanout: u32 = args
        .get_parse("fanout")?
        .ok_or_else(|| anyhow::anyhow!("aggregate needs --fanout (must match the run's)"))?;
    let artifacts = args
        .get_or("artifacts", &Runtime::default_artifacts_dir())
        .to_string();
    args.finish()?;
    topology::aggregate(&upstream, &addr, id, fanout, &artifacts)
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = args
        .get_or("artifacts", &Runtime::default_artifacts_dir())
        .to_string();
    args.finish()?;
    let rt = Runtime::new(&dir)?;
    println!("platform: {}", rt.platform());
    println!("artifacts: {dir}");
    for (name, mm) in &rt.manifest.models {
        println!(
            "  {name}: d={} segments={} tau={} batch={} eval_batch={} clients={} input={:?}",
            mm.d,
            mm.num_segments(),
            mm.tau,
            mm.batch,
            mm.eval_batch,
            mm.n_clients,
            mm.input_shape
        );
    }
    Ok(())
}
