//! # FedDQ — communication-efficient federated learning with descending quantization
//!
//! Full-system reproduction of *FedDQ* (Qu, Song, Tsui, 2021) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the federated-learning coordinator: round loop,
//!   client workers, the paper's adaptive quantization policies
//!   ([`quant`]), a bit-exact wire format ([`wire`]), data pipeline
//!   ([`data`]) and metrics ([`metrics`]).
//! * **L2/L1 (build-time python, `python/compile/`)** — JAX model zoo and
//!   Pallas codec kernels, AOT-lowered to HLO text under `artifacts/` and
//!   executed from Rust through the PJRT CPU client ([`runtime`]).
//!
//! Python never runs on the request path: `make artifacts` once, then the
//! `feddq` binary is self-contained.
//!
//! ## Quick tour
//!
//! ```no_run
//! use feddq::config::RunConfig;
//! use feddq::coordinator::Session;
//!
//! let mut cfg = RunConfig::default_for("mlp");
//! cfg.rounds = 20;
//! cfg.policy = feddq::quant::PolicyConfig::FedDq { resolution: 0.005 };
//! let mut session = Session::new(cfg).unwrap();
//! let report = session.run().unwrap();
//! println!("final acc {:.3}", report.rounds.last().unwrap().test_accuracy);
//! ```

pub mod bench_support;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod metrics;
pub mod quant;
pub mod runtime;
pub mod sim;
pub mod util;
pub mod wire;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
