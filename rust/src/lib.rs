//! # FedDQ — communication-efficient federated learning with descending quantization
//!
//! **Start with `ARCHITECTURE.md` at the repo root** — the single
//! authoritative map of this codebase: module layout, the life of a
//! round, the two-lane pool contract, the bytes-moved codec model, the
//! round scheduler and the determinism contract.  `docs/CLI.md` is the
//! complete `feddq` flag reference (held honest by a test).  This page
//! keeps the API-facing summary.
//!
//! Full-system reproduction of *FedDQ* (Qu, Song, Tsui, 2021) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the federated-learning coordinator: round loop,
//!   client workers, the paper's adaptive quantization policies
//!   ([`quant`]), a bit-exact wire format ([`wire`]), data pipeline
//!   ([`data`]) and metrics ([`metrics`]).
//! * **L2/L1 (build-time python, `python/compile/`)** — JAX model zoo and
//!   Pallas codec kernels, AOT-lowered to HLO text under `artifacts/` and
//!   executed from Rust through the PJRT CPU client ([`runtime`]).
//!
//! Python never runs on the request path: `make artifacts` once, then the
//! `feddq` binary is self-contained.  Without artifacts, the pure-Rust
//! native backend ([`runtime::native`]) runs the MLP benchmark out of the
//! box; the PJRT path is behind the `pjrt` cargo feature.
//!
//! ## Parallel round engine
//!
//! The in-process [`coordinator::Session`] runs client local rounds on a
//! persistent worker pool ([`coordinator::pool`]); the thread count is
//! the `threads` knob in [`config::RunConfig`] (default: min(n_clients,
//! cores)).  The broadcast is zero-copy — global parameters live in an
//! `Arc<[f32]>`, the `Broadcast` message is encoded once per round.
//!
//! The **server's** three hot stages scale on the same pool (both
//! in-process and under `feddq serve`), scheduled on a **two-lane
//! queue** ([`coordinator::pool`]): server tasks (decode, shard folds,
//! eval slices) go to a *priority lane* that workers drain before
//! pulling client round jobs from the *round lane*, so an in-process
//! decode overlaps the remaining receives instead of queueing FIFO
//! behind not-yet-started rounds.  The lanes cannot starve or deadlock
//! each other: running tasks are never preempted, priority tasks are
//! self-contained compute that never blocks on round results, and the
//! server only produces priority work in response to *completed* round
//! work (at most one decode plus a bounded number of fold/eval tasks
//! per client reply), so the priority lane drains between arrivals.
//!
//! * **recv/decode pipeline** — each arriving `ClientUpdate` is handed
//!   to a worker the moment it lands, decoding into recycled scratch
//!   buffers while the server blocks on the next reply.  With
//!   `decode_buffers = k > 0` (and fold overlap active) the pipeline's
//!   live memory is **O(workers + k)** buffers instead of one per
//!   client; 0 keeps the historical one-per-client behavior;
//! * **sharded accumulator** — the `d`-length streaming fold splits
//!   into contiguous per-worker chunk ranges (`agg_shards`; 0 = follow
//!   the pool), each shard folding clients in sorted order, so no
//!   `n x d` matrix is needed and the fold scales with cores.  With
//!   `fold_overlap` (on by default) each shard folds the next client
//!   in sorted order *as its decode lands* — per-shard prefix folds
//!   that overlap straggler arrivals — and a client's decode buffer is
//!   recycled the moment every shard has folded it;
//! * **parallel eval** — test batches split into per-worker slices
//!   (`eval_threads`; 0 = follow the pool), reduced in fixed batch
//!   order.
//!
//! Per-stage wall times land in every `RoundRecord`
//! (`recv_decode_secs` / `agg_secs` / `eval_secs`; under fold overlap
//! the fold work shifts into the receive window by design).  The fused
//! XLA aggregate executable remains available as
//! [`config::AggregateMode::Fused`] — prefer it when a hardware
//! backend makes the single fused dispatch cheaper than the streaming
//! fold.
//!
//! Worker threads survive panicking tasks (`catch_unwind` around every
//! task): the panic payload surfaces as a task-level `Err` at the
//! submitter instead of silently shrinking the pool.
//!
//! ## Codec kernel layer
//!
//! FedDQ's bit width *descends* as training converges (Eq. 10), so the
//! hot path's steady state is narrow codes — 1/2/4/8 bits.  The codec
//! is built around that ([`wire::swar`], [`coordinator::codec`]):
//!
//! * **Bytes moved per wire byte.**  A `b`-bit code occupies `b/8`
//!   payload bytes on the wire, but the pre-rewrite server expanded
//!   every code to an f32 (4 bytes) at decode and re-read that row per
//!   accumulator shard: at 4-bit codes that is `4 / 0.5 = 8x` the wire
//!   bytes through memory on decode, again on every fold pass.  Narrow
//!   `u16` rows halve both (2 bytes/code), and the width-specialized
//!   unpack/pack kernels remove the per-code refill logic that
//!   dominated the generic loops.
//! * **Why `u16` rows stay bit-exact.**  Wire widths are at most 16
//!   bits, so codes are integers below 2^16 — exactly representable in
//!   `u16` *and* in `f32`.  The fold widens each code back with
//!   `c as f32` and applies the unchanged expression
//!   `acc += w * (code * step + min)` in the unchanged client order,
//!   so every aggregate — and hence every `RunReport`, including
//!   `params_hash` — is bit-identical to the f32-row path.  The scalar
//!   path survives as [`config::CodecMode::Reference`], and
//!   `rust/tests/parallel_determinism.rs` crosses the two over the
//!   full scheduler knob matrix.
//! * **SWAR width table.**  The specialized kernels splat one `u64`
//!   into 64 / 32 / 16 / 8 / 4 codes at widths 1 / 2 / 4 / 8 / 16 via
//!   shift-mask; odd widths fall back to the generic `get_slice` loop
//!   (they only appear transiently as FedDQ's bit curve descends).
//!   The client's encode is **fused**: one clamp-round-pack pass over
//!   the raw delta ([`coordinator::codec::encode_quantized_fused`]) —
//!   no `d`-length codes vector, no `u32` scratch — drawing the same
//!   stochastic-rounding stream as the quantize executable, so the
//!   payload is byte-identical.  Per-width throughput lands in
//!   `BENCH_hotpath.json` (`unpack_w{1,2,4,8,16}_gbps`,
//!   `pack_w*_gbps`, `encode_fused_gbps`, `fold_narrow_gbps`) and is
//!   gated by CI's `bench-smoke`.
//!
//! ## Round scheduler: partial participation & stragglers
//!
//! Above the pool sits the **round scheduler**
//! ([`coordinator::sched`]): real deployments sample a cohort per
//! round and contend with stragglers, so every round now runs over a
//! scheduled subset:
//!
//! * **`--participation f`** draws `ceil(f * n)` clients per round
//!   from a seeded, *round-keyed* RNG — the cohort is a pure function
//!   of `(seed, round, n, f)`, independent of thread count or any
//!   observation.  Unselected clients run nothing: batch cursors,
//!   quantizer streams and error-feedback residuals stay banked until
//!   their next selected round.  Weights, loss averages and the
//!   `uplink_bits` ledger range over the cohort only.
//! * **`--round-deadline T`** (simulated seconds) over-samples `2x`
//!   candidates, prices them with the **latency model**
//!   ([`sim::latency`], `--sim-latency`), and keeps the deterministic
//!   fastest `ceil(f * n)` finishing by `T` (ties by id); cut
//!   candidates land in the round's `dropped` metric and the cohort's
//!   slowest simulated finisher in `sim_makespan_secs`.
//! * **Straggler-aware dispatch**: the broadcast order is
//!   longest-first — never-observed clients first (unknown cost =
//!   assume long, ranked by simulated latency), then observed clients
//!   slowest-first by an EWMA of worker-measured round times — so
//!   likely-long jobs hit the round lane first and the round's
//!   makespan shrinks when clients outnumber workers.  Dispatch order
//!   is a pure performance heuristic — results fold in sorted client
//!   order regardless.
//! * **`--staleness k`** (semi-synchronous rounds): an update that
//!   answers an already-closed round is banked keyed by
//!   `(round, client id)` and folded into a later round's aggregation
//!   with weight `num_samples / (1 + s)` (`s` rounds late,
//!   renormalized over the fold set) instead of being discarded;
//!   updates more than `k` rounds late drop into the report's
//!   `stale_dropped` column, folded ones into `stale_folded`.
//!   `k = 0` (default) is strict synchronous operation, bit-for-bit.
//!   The whole round-behavior surface is one typed value,
//!   [`config::RoundPolicy`] (cohort / tolerance / pipeline groups
//!   with a validating builder), composed into `RunConfig`.
//!
//! ### Determinism contract
//!
//! A run is a pure function of its [`config::RunConfig`]: for any
//! `threads`, `agg_shards`, `eval_threads`, `decode_buffers`,
//! `fold_overlap` or `codec` value — crossed with any `participation`
//! / `round_deadline` / `sim_latency` / `sim_faults` / `staleness`
//! setting — the engine produces a
//! bit-identical [`metrics::RunReport`] (per-round records, bit
//! ledger, cohort fields, and the final parameter hash).  This holds
//! because client states own independently derived RNG streams, jobs
//! move client state to exactly one worker at a time, cohort selection
//! is seed-pure and observation-blind, the server folds updates in
//! sorted `client_id` order within every accumulator shard (the
//! overlap path serializes each shard's prefix folds in that same
//! order, with the same up-front weights), and eval reduces per-batch
//! partials in batch order.  `rust/tests/parallel_determinism.rs`
//! enforces the contract, including participation in {1.0, 0.5, 0.2}
//! against the full knob matrix.
//!
//! ## Quick tour
//!
//! ```no_run
//! use feddq::config::RunConfig;
//! use feddq::coordinator::Session;
//!
//! let mut cfg = RunConfig::default_for("mlp");
//! cfg.rounds = 20;
//! cfg.policy = feddq::quant::PolicyConfig::FedDq { resolution: 0.005 };
//! let mut session = Session::new(cfg).unwrap();
//! let report = session.run().unwrap();
//! println!("final acc {:.3}", report.rounds.last().unwrap().test_accuracy);
//! ```

#![warn(missing_docs)]

pub mod bench_support;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod metrics;
pub mod quant;
pub mod runtime;
pub mod sim;
pub mod util;
pub mod wire;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
