//! Pure-Rust execution backend: the six model executables implemented
//! directly over flat `f32` slices, no PJRT and no AOT artifacts.
//!
//! This is the default backend.  It implements the same executable
//! contract as `python/compile/model.py` (init / round / evaluate /
//! ranges / quantize / aggregate) for the MLP layout — 784 → hidden →
//! classes with ReLU and softmax cross-entropy — which is the model the
//! integration tests, the quickstart and the perf benches drive.  The
//! conv benchmarks still require the AOT artifacts and the `pjrt`
//! feature (see [`super::pjrt`]).
//!
//! Numerics are deliberately plain: fixed-order f32 accumulation,
//! per-client sequential loops.  A given (seed, input) pair therefore
//! produces bit-identical outputs no matter which thread of the round
//! engine's worker pool executes the call — the determinism contract the
//! parallel `Session` relies on (see `coordinator::pool`).

use anyhow::{ensure, Result};

use super::manifest::ModelManifest;
use crate::util::rng::Rng;

/// Native executor for the two-layer MLP layout.
///
/// Stateless: all methods take `&self` plus plain slices, so one
/// instance can be shared across worker threads.
pub struct NativeMlp {
    din: usize,
    hidden: usize,
    classes: usize,
    /// Flat offsets of (fc1.w, fc1.b, fc2.w, fc2.b).
    off: [usize; 4],
}

impl NativeMlp {
    /// Build from a manifest whose segment table matches the MLP layout
    /// `[w1 [din,h], b1 [h], w2 [h,c], b2 [c]]`.
    pub fn from_manifest(mm: &ModelManifest) -> Result<NativeMlp> {
        let unsupported = || {
            anyhow::anyhow!(
                "model {}: layout not supported by the native backend (MLP only); \
                 conv models need `make artifacts` plus a build with the `pjrt` \
                 feature AND the `xla` bindings dependency added to Cargo.toml \
                 (see rust/src/runtime/pjrt.rs — the offline registry lacks it)",
                mm.name
            )
        };
        if mm.segments.len() != 4 {
            return Err(unsupported());
        }
        let (s0, s1, s2, s3) = (&mm.segments[0], &mm.segments[1], &mm.segments[2], &mm.segments[3]);
        if s0.shape.len() != 2 || s1.shape.len() != 1 || s2.shape.len() != 2 || s3.shape.len() != 1 {
            return Err(unsupported());
        }
        let (din, hidden) = (s0.shape[0], s0.shape[1]);
        let classes = s3.shape[0];
        if s1.shape[0] != hidden || s2.shape != vec![hidden, classes] {
            return Err(unsupported());
        }
        ensure!(mm.input_len() == din, "model {}: input_len != fc1 fan-in", mm.name);
        ensure!(mm.classes == classes, "model {}: classes mismatch", mm.name);
        Ok(NativeMlp {
            din,
            hidden,
            classes,
            off: [s0.offset, s1.offset, s2.offset, s3.offset],
        })
    }

    fn split<'a>(&self, p: &'a [f32]) -> (&'a [f32], &'a [f32], &'a [f32], &'a [f32]) {
        let (din, h, c) = (self.din, self.hidden, self.classes);
        (
            &p[self.off[0]..self.off[0] + din * h],
            &p[self.off[1]..self.off[1] + h],
            &p[self.off[2]..self.off[2] + h * c],
            &p[self.off[3]..self.off[3] + c],
        )
    }

    /// Deterministic parameter init: He for fc1.w, Glorot for fc2.w,
    /// zeros for biases — mirroring `python/compile/models/common.py`,
    /// with this crate's PRNG in place of JAX's.
    pub fn init(&self, mm: &ModelManifest, seed: u32) -> Result<Vec<f32>> {
        let mut params = vec![0.0f32; mm.d];
        let root = Rng::new(seed as u64);
        let he = (2.0 / self.din as f32).sqrt();
        let glorot = (2.0 / (self.hidden + self.classes) as f32).sqrt();
        for (l, seg) in mm.segments.iter().enumerate() {
            let std = match l {
                0 => he,
                2 => glorot,
                _ => continue, // biases stay zero
            };
            let mut rng = root.derive(&format!("init.{}", seg.name));
            for x in &mut params[seg.offset..seg.offset + seg.size] {
                *x = rng.next_normal() * std;
            }
        }
        Ok(params)
    }

    /// Forward pass for a batch: fills `hact` `[b, hidden]` (post-ReLU)
    /// and `logits` `[b, classes]`.
    fn forward(&self, p: &[f32], xs: &[f32], bsz: usize, hact: &mut [f32], logits: &mut [f32]) {
        let (w1, b1, w2, b2) = self.split(p);
        let (din, h, c) = (self.din, self.hidden, self.classes);
        for b in 0..bsz {
            hact[b * h..(b + 1) * h].copy_from_slice(b1);
        }
        for b in 0..bsz {
            let x = &xs[b * din..(b + 1) * din];
            let z = &mut hact[b * h..(b + 1) * h];
            for (i, &xv) in x.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let row = &w1[i * h..(i + 1) * h];
                for (zj, &wj) in z.iter_mut().zip(row) {
                    *zj += xv * wj;
                }
            }
            for zj in z.iter_mut() {
                if *zj < 0.0 {
                    *zj = 0.0;
                }
            }
        }
        for b in 0..bsz {
            logits[b * c..(b + 1) * c].copy_from_slice(b2);
            let hrow = &hact[b * h..(b + 1) * h];
            let lrow = &mut logits[b * c..(b + 1) * c];
            for (j, &hv) in hrow.iter().enumerate() {
                if hv == 0.0 {
                    continue;
                }
                let wrow = &w2[j * c..(j + 1) * c];
                for (lk, &wk) in lrow.iter_mut().zip(wrow) {
                    *lk += hv * wk;
                }
            }
        }
    }

    /// Softmax cross-entropy over `logits` in place: returns the loss sum
    /// and overwrites `logits` with `softmax - onehot` (the logit grad
    /// *before* the 1/B batch-mean scale).
    fn loss_and_dlogits(&self, logits: &mut [f32], ys: &[i32], bsz: usize) -> f32 {
        let c = self.classes;
        let mut loss_sum = 0.0f32;
        for b in 0..bsz {
            let row = &mut logits[b * c..(b + 1) * c];
            let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0f32;
            for v in row.iter_mut() {
                *v = (*v - m).exp();
                sum += *v;
            }
            let y = ys[b] as usize;
            loss_sum += -(row[y] / sum).ln();
            for v in row.iter_mut() {
                *v /= sum;
            }
            row[y] -= 1.0;
        }
        loss_sum
    }

    /// One SGD step on `p` in place; returns the mean batch loss.
    #[allow(clippy::too_many_arguments)]
    fn sgd_step(
        &self,
        p: &mut [f32],
        xs: &[f32],
        ys: &[i32],
        lr: f32,
        bsz: usize,
        hact: &mut [f32],
        logits: &mut [f32],
        grad: &mut [f32],
    ) -> f32 {
        let (din, h, c) = (self.din, self.hidden, self.classes);
        self.forward(p, xs, bsz, hact, logits);
        let loss_sum = self.loss_and_dlogits(logits, ys, bsz);
        let scale = 1.0 / bsz as f32;

        grad.iter_mut().for_each(|g| *g = 0.0);
        let (go1, gb1o, go2, gb2o) = (self.off[0], self.off[1], self.off[2], self.off[3]);
        // fc2 grads + dz1 (reusing one hidden-width scratch row per sample)
        let w2 = self.off[2];
        let mut dz1 = vec![0.0f32; h];
        for b in 0..bsz {
            let dl = &logits[b * c..(b + 1) * c]; // softmax - onehot
            let hrow = &hact[b * h..(b + 1) * h];
            // gb2 += dl ; gW2[j,k] += h[j] * dl[k] ; dh[j] = sum_k dl[k] W2[j,k]
            for (g, &d) in grad[gb2o..gb2o + c].iter_mut().zip(dl) {
                *g += d * scale;
            }
            for j in 0..h {
                let hv = hrow[j];
                let wrow = &p[w2 + j * c..w2 + (j + 1) * c];
                let grow = &mut grad[go2 + j * c..go2 + (j + 1) * c];
                let mut dh = 0.0f32;
                for k in 0..c {
                    dh += dl[k] * wrow[k];
                    if hv != 0.0 {
                        grow[k] += hv * dl[k] * scale;
                    }
                }
                // ReLU mask: hact == 0 ⇔ pre-activation <= 0
                dz1[j] = if hv > 0.0 { dh * scale } else { 0.0 };
            }
            // gb1 += dz1 ; gW1[i,j] += x[i] * dz1[j]
            for (g, &d) in grad[gb1o..gb1o + h].iter_mut().zip(&dz1) {
                *g += d;
            }
            let x = &xs[b * din..(b + 1) * din];
            for (i, &xv) in x.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let grow = &mut grad[go1 + i * h..go1 + (i + 1) * h];
                for (g, &d) in grow.iter_mut().zip(&dz1[..]) {
                    *g += xv * d;
                }
            }
        }
        for (pv, &g) in p.iter_mut().zip(&grad[..]) {
            *pv -= lr * g;
        }
        loss_sum * scale
    }

    /// tau local SGD steps: returns `(p_final - params, mean step loss)`.
    pub fn local_round(
        &self,
        mm: &ModelManifest,
        params: &[f32],
        xs: &[f32],
        ys: &[i32],
        lr: f32,
    ) -> Result<(Vec<f32>, f32)> {
        let (tau, bsz) = (mm.tau, mm.batch);
        let mut p = params.to_vec();
        let mut hact = vec![0.0f32; bsz * self.hidden];
        let mut logits = vec![0.0f32; bsz * self.classes];
        let mut grad = vec![0.0f32; mm.d];
        let mut loss_acc = 0.0f32;
        let step_x = bsz * self.din;
        for t in 0..tau {
            loss_acc += self.sgd_step(
                &mut p,
                &xs[t * step_x..(t + 1) * step_x],
                &ys[t * bsz..(t + 1) * bsz],
                lr,
                bsz,
                &mut hact,
                &mut logits,
                &mut grad,
            );
        }
        for (dv, &pv) in p.iter_mut().zip(params) {
            *dv -= pv;
        }
        Ok((p, loss_acc / tau as f32))
    }

    /// Full-batch evaluation: `(sum of NLL, correct count)`.
    pub fn evaluate(&self, mm: &ModelManifest, params: &[f32], xs: &[f32], ys: &[i32]) -> Result<(f32, i32)> {
        let e = mm.eval_batch;
        let c = self.classes;
        let mut hact = vec![0.0f32; e * self.hidden];
        let mut logits = vec![0.0f32; e * c];
        self.forward(params, xs, e, &mut hact, &mut logits);
        let mut loss_sum = 0.0f32;
        let mut correct = 0i32;
        for b in 0..e {
            let row = &logits[b * c..(b + 1) * c];
            let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let lse = m + row.iter().map(|&v| (v - m).exp()).sum::<f32>().ln();
            let y = ys[b] as usize;
            ensure!(y < c, "label {y} out of range");
            loss_sum += lse - row[y];
            // first-max argmax (matches jnp.argmax tie-breaking)
            let mut best = 0usize;
            for (k, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = k;
                }
            }
            if best == y {
                correct += 1;
            }
        }
        Ok((loss_sum, correct))
    }
}

// ---------------------------------------------------------------------------
// architecture-independent kernels (segment-wise over the manifest)
// ---------------------------------------------------------------------------

/// Per-segment `(min, range)` of an update vector.
pub fn segment_ranges(mm: &ModelManifest, delta: &[f32]) -> (Vec<f32>, Vec<f32>) {
    let l = mm.num_segments();
    let mut mins = Vec::with_capacity(l);
    let mut ranges = Vec::with_capacity(l);
    for seg in &mm.segments {
        let s = &delta[seg.offset..seg.offset + seg.size];
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &v in s {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        mins.push(lo);
        ranges.push(hi - lo);
    }
    (mins, ranges)
}

/// Elementwise stochastic rounding with per-segment `(min, sinv, maxcode)`:
/// `code = clip(floor((x - min) * sinv + u), 0, maxcode)`, `u ~ U[0,1)`
/// drawn deterministically from `seed` in flat element order — the same
/// contract as the quantize executable (`kernels/ref.py`).
pub fn stochastic_quantize(
    mm: &ModelManifest,
    delta: &[f32],
    mins: &[f32],
    sinv: &[f32],
    maxcode: &[f32],
    seed: u32,
) -> Vec<f32> {
    let mut rng = Rng::new(seed as u64);
    let mut codes = vec![0.0f32; mm.d];
    for (l, seg) in mm.segments.iter().enumerate() {
        let (mn, si, mc) = (mins[l], sinv[l], maxcode[l]);
        for j in seg.offset..seg.offset + seg.size {
            let u = rng.next_f32();
            let y = ((delta[j] - mn) * si + u).floor();
            codes[j] = y.clamp(0.0, mc);
        }
    }
    codes
}

/// Weighted sum of per-client dequantized updates (`kernels/ref.py`
/// semantics): `out[j] = Σ_i w[i] * (codes[i,j] * step[i,seg] + min[i,seg])`.
pub fn dequant_aggregate(
    mm: &ModelManifest,
    codes: &[f32],
    mins: &[f32],
    steps: &[f32],
    weights: &[f32],
) -> Vec<f32> {
    let (d, l) = (mm.d, mm.num_segments());
    let n = weights.len();
    let mut out = vec![0.0f32; d];
    for i in 0..n {
        let w = weights[i];
        let row = &codes[i * d..(i + 1) * d];
        for (sl, seg) in mm.segments.iter().enumerate() {
            let (mn, st) = (mins[i * l + sl], steps[i * l + sl]);
            for j in seg.offset..seg.offset + seg.size {
                out[j] += w * (row[j] * st + mn);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;

    fn mlp() -> (ModelManifest, NativeMlp) {
        let mm = Manifest::builtin().models["mlp"].clone();
        let nat = NativeMlp::from_manifest(&mm).unwrap();
        (mm, nat)
    }

    #[test]
    fn builtin_mlp_layout_accepted() {
        let (mm, nat) = mlp();
        assert_eq!(mm.d, 101_770);
        assert_eq!(nat.din, 784);
        assert_eq!(nat.hidden, 128);
        assert_eq!(nat.classes, 10);
    }

    #[test]
    fn init_is_deterministic_and_scaled() {
        let (mm, nat) = mlp();
        let a = nat.init(&mm, 7).unwrap();
        let b = nat.init(&mm, 7).unwrap();
        assert_eq!(a, b);
        let c = nat.init(&mm, 8).unwrap();
        assert_ne!(a, c);
        // biases zero
        let s1 = &mm.segments[1];
        assert!(a[s1.offset..s1.offset + s1.size].iter().all(|&x| x == 0.0));
        // He std ~ sqrt(2/784)
        let s0 = &mm.segments[0];
        let w = &a[s0.offset..s0.offset + s0.size];
        let var = w.iter().map(|&x| (x as f64).powi(2)).sum::<f64>() / w.len() as f64;
        let want = 2.0 / 784.0;
        assert!((var - want).abs() < want * 0.1, "var {var} vs {want}");
    }

    #[test]
    fn local_round_reduces_loss_on_learnable_data() {
        let (mm, nat) = mlp();
        let params = nat.init(&mm, 3).unwrap();
        // one-hot-ish synthetic batch: class = brightest quadrant
        let mut rng = Rng::new(11);
        let n = mm.tau * mm.batch;
        let mut xs = vec![0.0f32; n * mm.input_len()];
        let mut ys = vec![0i32; n];
        for s in 0..n {
            let y = (s % mm.classes) as i32;
            ys[s] = y;
            for j in 0..mm.input_len() {
                let base = if j % mm.classes == y as usize { 0.9 } else { 0.1 };
                xs[s * mm.input_len() + j] = base + 0.05 * rng.next_f32();
            }
        }
        let (delta, loss0) = nat.local_round(&mm, &params, &xs, &ys, 0.1).unwrap();
        assert_eq!(delta.len(), mm.d);
        assert!(loss0.is_finite() && loss0 > 0.0);
        // apply the update and re-run: training loss must drop
        let p2: Vec<f32> = params.iter().zip(&delta).map(|(p, d)| p + d).collect();
        let (_, loss1) = nat.local_round(&mm, &p2, &xs, &ys, 0.1).unwrap();
        assert!(loss1 < loss0, "loss did not drop: {loss0} -> {loss1}");
    }

    #[test]
    fn quantize_codes_bounded_and_close() {
        let (mm, _nat) = mlp();
        let delta: Vec<f32> = (0..mm.d)
            .map(|i| -1.0 + 2.0 * i as f32 / (mm.d - 1) as f32)
            .collect();
        let (mins, ranges) = segment_ranges(&mm, &delta);
        let levels = vec![15u32; mm.num_segments()];
        let plan = crate::coordinator::codec::QuantPlan::new(&levels, &ranges);
        let codes = stochastic_quantize(&mm, &delta, &mins, &plan.sinv, &plan.maxcode, 5);
        for (l, seg) in mm.segments.iter().enumerate() {
            for j in seg.offset..seg.offset + seg.size {
                let c = codes[j];
                assert_eq!(c, c.round());
                assert!((0.0..=15.0).contains(&c));
                let deq = mins[l] + c * plan.step[l];
                assert!((deq - delta[j]).abs() <= plan.step[l] * 1.001 + 1e-6);
            }
        }
        // deterministic in the seed
        let again = stochastic_quantize(&mm, &delta, &mins, &plan.sinv, &plan.maxcode, 5);
        assert_eq!(codes, again);
    }
}
