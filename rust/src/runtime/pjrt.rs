//! PJRT/XLA execution backend (feature `pjrt`).
//!
//! Loads the AOT artifacts emitted by `python/compile/aot.py` and
//! exposes them as typed executables.  Interchange is HLO **text**
//! (`HloModuleProto::from_text_file`), never a serialized proto: jax >=
//! 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids.  See DESIGN.md §2.
//!
//! This module compiles in two modes:
//!
//! * **Stub (default under `--features pjrt`)** — the in-tree [`xla`]
//!   shim below mirrors exactly the bindings-crate API surface this
//!   glue consumes, so `cargo check --features pjrt` keeps the whole
//!   PJRT path type-checking in CI without the external crate.  Every
//!   entry point fails loudly at runtime ("xla bindings are not
//!   linked"), so a stub build can never silently masquerade as a real
//!   accelerator backend.
//! * **Real bindings** — add the crate and swap the shim for a
//!   re-export:
//!
//! ```toml
//! [dependencies]
//! xla = { version = "0.1", optional = true }
//! [features]
//! pjrt = ["dep:xla"]
//! ```
//!
//! then replace the `pub mod xla { ... }` below with
//! `pub(crate) use ::xla;`.

// The stub mirrors a third-party crate's API one-for-one; documenting
// every mirrored signature would just duplicate that crate's docs, so
// the crate-wide `missing_docs` warning is silenced for this
// feature-gated module (keeps `cargo check --features pjrt` and a
// `--features pjrt` rustdoc build warning-free).
#![allow(missing_docs)]

use anyhow::{ensure, Context, Result};

use self::xla::{Literal, PjRtClient, PjRtLoadedExecutable};
use super::manifest::ModelManifest;
use super::Runtime;

/// Offline-checkable stand-in for the `xla` bindings crate (see the
/// module docs).  Method signatures match the call sites in this file
/// one-for-one; constructors that would touch PJRT return errors.
pub mod xla {
    use anyhow::{bail, Result};

    const UNAVAILABLE: &str = "xla bindings are not linked into this build (stub PJRT \
         backend); declare the `xla` crate and re-export it in \
         rust/src/runtime/pjrt.rs to enable real execution";

    pub struct Literal;
    pub struct HloModuleProto;
    pub struct XlaComputation;
    pub struct PjRtClient;
    pub struct PjRtLoadedExecutable;

    impl Literal {
        pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
            Literal
        }
        pub fn scalar<T: Copy>(_v: T) -> Literal {
            Literal
        }
        pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
            bail!(UNAVAILABLE)
        }
        pub fn to_literal_sync(&self) -> Result<Literal> {
            bail!(UNAVAILABLE)
        }
        pub fn to_tuple1(self) -> Result<Literal> {
            bail!(UNAVAILABLE)
        }
        pub fn to_tuple2(self) -> Result<(Literal, Literal)> {
            bail!(UNAVAILABLE)
        }
        pub fn to_vec<T>(&self) -> Result<Vec<T>> {
            bail!(UNAVAILABLE)
        }
        pub fn get_first_element<T>(&self) -> Result<T> {
            bail!(UNAVAILABLE)
        }
    }

    impl HloModuleProto {
        pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
            bail!(UNAVAILABLE)
        }
    }

    impl XlaComputation {
        pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
            XlaComputation
        }
    }

    impl PjRtClient {
        pub fn cpu() -> Result<PjRtClient> {
            bail!(UNAVAILABLE)
        }
        pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
            bail!(UNAVAILABLE)
        }
        pub fn platform_name(&self) -> String {
            "xla-stub".to_string()
        }
    }

    impl PjRtLoadedExecutable {
        pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<Literal>>> {
            bail!(UNAVAILABLE)
        }
    }
}

/// Compile one HLO-text artifact against `client`.
pub fn compile(client: &PjRtClient, artifacts_dir: &str, file: &str) -> Result<PjRtLoadedExecutable> {
    let path = format!("{artifacts_dir}/{file}");
    let proto = xla::HloModuleProto::from_text_file(&path)
        .with_context(|| format!("parsing HLO text {path}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .with_context(|| format!("compiling {path}"))
}

/// One model's six compiled executables.
pub struct PjrtModel {
    init: PjRtLoadedExecutable,
    round: PjRtLoadedExecutable,
    evaluate: PjRtLoadedExecutable,
    ranges: PjRtLoadedExecutable,
    quantize: PjRtLoadedExecutable,
    aggregate: PjRtLoadedExecutable,
}

// PJRT CPU executables are immutable after compilation and `Execute` is
// documented thread-safe (the CPU client dispatches each execution onto
// its own thread pool); the round engine's workers share one model.
unsafe impl Send for PjrtModel {}
unsafe impl Sync for PjrtModel {}

fn vec_literal_f32(data: &[f32], dims: &[i64]) -> Result<Literal> {
    let lit = Literal::vec1(data);
    if dims.len() == 1 {
        return Ok(lit);
    }
    lit.reshape(dims).context("reshape f32 literal")
}

fn vec_literal_i32(data: &[i32], dims: &[i64]) -> Result<Literal> {
    let lit = Literal::vec1(data);
    if dims.len() == 1 {
        return Ok(lit);
    }
    lit.reshape(dims).context("reshape i32 literal")
}

fn run(exe: &PjRtLoadedExecutable, args: &[Literal]) -> Result<Literal> {
    let result = exe.execute::<Literal>(args).context("PJRT execute")?;
    result[0][0].to_literal_sync().context("fetch result literal")
}

impl PjrtModel {
    pub fn load(rt: &Runtime, mm: &ModelManifest) -> Result<Self> {
        Ok(PjrtModel {
            init: rt.compile(&mm.files["init"])?,
            round: rt.compile(&mm.files["round"])?,
            evaluate: rt.compile(&mm.files["evaluate"])?,
            ranges: rt.compile(&mm.files["ranges"])?,
            quantize: rt.compile(&mm.files["quantize"])?,
            aggregate: rt.compile(&mm.files["aggregate"])?,
        })
    }

    pub fn init(&self, mm: &ModelManifest, seed: u32) -> Result<Vec<f32>> {
        let out = run(&self.init, &[Literal::scalar(seed)])?;
        let params = out.to_tuple1()?.to_vec::<f32>()?;
        ensure!(params.len() == mm.d, "init returned wrong length");
        Ok(params)
    }

    pub fn local_round(
        &self,
        mm: &ModelManifest,
        params: &[f32],
        xs: &[f32],
        ys: &[i32],
        lr: f32,
    ) -> Result<(Vec<f32>, f32)> {
        let (tau, b) = (mm.tau as i64, mm.batch as i64);
        let mut xdims = vec![tau, b];
        xdims.extend(mm.input_shape.iter().map(|&v| v as i64));
        let args = [
            Literal::vec1(params),
            vec_literal_f32(xs, &xdims)?,
            vec_literal_i32(ys, &[tau, b])?,
            Literal::scalar(lr),
        ];
        let (delta, loss) = run(&self.round, &args)?.to_tuple2()?;
        Ok((delta.to_vec::<f32>()?, loss.get_first_element::<f32>()?))
    }

    pub fn evaluate(&self, mm: &ModelManifest, params: &[f32], xs: &[f32], ys: &[i32]) -> Result<(f32, i32)> {
        let e = mm.eval_batch as i64;
        let mut xdims = vec![e];
        xdims.extend(mm.input_shape.iter().map(|&v| v as i64));
        let args = [
            Literal::vec1(params),
            vec_literal_f32(xs, &xdims)?,
            Literal::vec1(ys),
        ];
        let (loss, correct) = run(&self.evaluate, &args)?.to_tuple2()?;
        Ok((
            loss.get_first_element::<f32>()?,
            correct.get_first_element::<i32>()?,
        ))
    }

    pub fn ranges(&self, delta: &[f32]) -> Result<(Vec<f32>, Vec<f32>)> {
        let (mins, ranges) = run(&self.ranges, &[Literal::vec1(delta)])?.to_tuple2()?;
        Ok((mins.to_vec::<f32>()?, ranges.to_vec::<f32>()?))
    }

    pub fn quantize(
        &self,
        delta: &[f32],
        mins: &[f32],
        sinv: &[f32],
        maxcode: &[f32],
        seed: u32,
    ) -> Result<Vec<f32>> {
        let args = [
            Literal::vec1(delta),
            Literal::vec1(mins),
            Literal::vec1(sinv),
            Literal::vec1(maxcode),
            Literal::scalar(seed),
        ];
        let codes = run(&self.quantize, &args)?.to_tuple1()?;
        Ok(codes.to_vec::<f32>()?)
    }

    pub fn aggregate(
        &self,
        mm: &ModelManifest,
        codes: &[f32],
        mins: &[f32],
        steps: &[f32],
        weights: &[f32],
    ) -> Result<Vec<f32>> {
        let n = weights.len();
        let l = mm.num_segments();
        let args = [
            vec_literal_f32(codes, &[n as i64, mm.d as i64])?,
            vec_literal_f32(mins, &[n as i64, l as i64])?,
            vec_literal_f32(steps, &[n as i64, l as i64])?,
            Literal::vec1(weights),
        ];
        let delta = run(&self.aggregate, &args)?.to_tuple1()?;
        Ok(delta.to_vec::<f32>()?)
    }
}
