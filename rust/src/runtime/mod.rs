//! Model runtime: the six per-model executables behind one typed facade.
//!
//! Two backends implement the executable contract of
//! `python/compile/model.py`:
//!
//! * **native** (default, [`native`]) — pure-Rust implementations over
//!   flat `f32` slices; no artifacts and no external libraries.  Covers
//!   the MLP layout, which drives the tests, the quickstart and the
//!   hot-path benches.  All methods are deterministic and `Sync`, so the
//!   parallel round engine shares one [`ModelRuntime`] across worker
//!   threads.
//! * **pjrt** (`--features pjrt`, [`pjrt`]) — loads the AOT artifacts
//!   emitted by `python/compile/aot.py` (HLO **text**, see DESIGN.md §2)
//!   and executes them through the PJRT CPU client.  Required for the
//!   conv/resnet benchmarks.
//!
//! [`Runtime::new`] picks the backend by inspecting the artifacts dir:
//! a `manifest.json` selects the artifact manifest (and PJRT when the
//! feature is compiled in); otherwise the built-in native manifest is
//! used so a fresh checkout runs without any build-time Python step.

pub mod manifest;
pub mod model_exec;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

use anyhow::{Context, Result};

pub use manifest::{Manifest, ModelManifest, Segment};
pub use model_exec::ModelRuntime;

/// Backend-owning runtime.  One per process; models loaded from it can
/// be executed from any thread.
pub struct Runtime {
    /// The loaded (or built-in) model manifest.
    pub manifest: Manifest,
    /// Only the PJRT backend reads artifacts after construction.
    #[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
    artifacts_dir: String,
    from_artifacts: bool,
    #[cfg(feature = "pjrt")]
    client: Option<pjrt::xla::PjRtClient>,
}

impl Runtime {
    /// Create a runtime over `artifacts_dir`: uses `manifest.json` when
    /// present, else falls back to the built-in native manifest.
    pub fn new(artifacts_dir: &str) -> Result<Self> {
        let manifest_path = format!("{artifacts_dir}/manifest.json");
        let from_artifacts = std::path::Path::new(&manifest_path).exists();
        let manifest = if from_artifacts {
            Manifest::load(artifacts_dir)
                .with_context(|| format!("loading manifest from {artifacts_dir}"))?
        } else {
            // Loud, so a typo'd --artifacts dir can't silently switch an
            // experiment onto the native backend's different numerics.
            crate::info!(
                "runtime",
                "no manifest.json under {artifacts_dir:?} — using the built-in \
                 native manifest (pure-Rust MLP backend)"
            );
            Manifest::builtin()
        };
        Ok(Runtime {
            manifest,
            artifacts_dir: artifacts_dir.to_string(),
            from_artifacts,
            #[cfg(feature = "pjrt")]
            client: if from_artifacts {
                Some(pjrt::xla::PjRtClient::cpu().context("creating PJRT CPU client")?)
            } else {
                None
            },
        })
    }

    /// True when running on the built-in native manifest (no artifacts).
    pub fn is_builtin(&self) -> bool {
        !self.from_artifacts
    }

    /// Execution platform name (`native-cpu`, or PJRT's platform).
    pub fn platform(&self) -> String {
        #[cfg(feature = "pjrt")]
        if let Some(c) = &self.client {
            return c.platform_name();
        }
        "native-cpu".to_string()
    }

    /// Compile one HLO-text artifact (PJRT backend only).
    #[cfg(feature = "pjrt")]
    pub fn compile(&self, file: &str) -> Result<pjrt::xla::PjRtLoadedExecutable> {
        let client = self
            .client
            .as_ref()
            .context("PJRT client unavailable (running on the builtin manifest)")?;
        pjrt::compile(client, &self.artifacts_dir, file)
    }

    /// Load every executable of `model` into a [`ModelRuntime`].
    pub fn load_model(&self, model: &str) -> Result<ModelRuntime> {
        let mm = self
            .manifest
            .models
            .get(model)
            .with_context(|| format!("model {model:?} not in manifest"))?
            .clone();
        #[cfg(feature = "pjrt")]
        if self.from_artifacts {
            return ModelRuntime::load_pjrt(self, mm);
        }
        ModelRuntime::load_native(mm)
    }

    /// Default artifacts directory: `$FEDDQ_ARTIFACTS` or `artifacts`.
    pub fn default_artifacts_dir() -> String {
        std::env::var("FEDDQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string())
    }
}
