//! PJRT runtime: loads the AOT artifacts emitted by `python/compile/aot.py`
//! and exposes them as typed executables.
//!
//! Interchange is HLO **text** (`HloModuleProto::from_text_file`), never a
//! serialized proto: jax >= 0.5 emits 64-bit instruction ids that the
//! crate's xla_extension 0.5.1 rejects; the text parser reassigns ids.
//! See /opt/xla-example/README.md and DESIGN.md §2.

pub mod manifest;
pub mod model_exec;

use anyhow::{Context, Result};

pub use manifest::{Manifest, ModelManifest, Segment};
pub use model_exec::ModelRuntime;

/// Shared PJRT CPU client.  One per process; executables are compiled
/// against it and can be executed from any thread.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    artifacts_dir: String,
}

impl Runtime {
    /// Create a runtime over `artifacts_dir` (must contain manifest.json).
    pub fn new(artifacts_dir: &str) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)
            .with_context(|| format!("loading manifest from {artifacts_dir}"))?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            manifest,
            artifacts_dir: artifacts_dir.to_string(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile one HLO-text artifact.
    pub fn compile(&self, file: &str) -> Result<xla::PjRtLoadedExecutable> {
        let path = format!("{}/{}", self.artifacts_dir, file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling {path}"))
    }

    /// Load every executable of `model` into a [`ModelRuntime`].
    pub fn load_model(&self, model: &str) -> Result<ModelRuntime> {
        let mm = self
            .manifest
            .models
            .get(model)
            .with_context(|| format!("model {model:?} not in manifest"))?
            .clone();
        ModelRuntime::load(self, mm)
    }

    /// Default artifacts directory: `$FEDDQ_ARTIFACTS` or `artifacts`.
    pub fn default_artifacts_dir() -> String {
        std::env::var("FEDDQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string())
    }
}
