//! The artifact manifest: single source of truth about every AOT-lowered
//! executable, written by `python/compile/aot.py` and parsed here with the
//! in-tree JSON parser.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// One quantization segment (= one parameter tensor / layer).
#[derive(Clone, Debug, PartialEq)]
pub struct Segment {
    /// Tensor name (e.g. `dense/kernel`).
    pub name: String,
    /// Start offset into the flat parameter vector.
    pub offset: usize,
    /// Element count.
    pub size: usize,
    /// Original tensor shape (telemetry; the flat view drives compute).
    pub shape: Vec<usize>,
}

/// Everything Rust needs to drive one model's executables.
#[derive(Clone, Debug)]
pub struct ModelManifest {
    /// Model name (manifest key).
    pub name: String,
    /// Flat parameter dimension.
    pub d: usize,
    /// Quantization segments in offset order, covering `[0, d)`.
    pub segments: Vec<Segment>,
    /// Input image shape `(h, w, c)` as a list.
    pub input_shape: Vec<usize>,
    /// Number of output classes.
    pub classes: usize,
    /// Local SGD steps per round.
    pub tau: usize,
    /// Local minibatch size.
    pub batch: usize,
    /// Server-side evaluation batch size (AOT-static).
    pub eval_batch: usize,
    /// Cohort registry size the benchmark trains with.
    pub n_clients: usize,
    /// executable name -> HLO file name.
    pub files: BTreeMap<String, String>,
}

impl ModelManifest {
    /// Number of quantization segments `L`.
    pub fn num_segments(&self) -> usize {
        self.segments.len()
    }

    /// Elements of one input image.
    pub fn input_len(&self) -> usize {
        self.input_shape.iter().product()
    }

    /// Segment sizes in order (the quantizer's unit of work).
    pub fn segment_sizes(&self) -> Vec<usize> {
        self.segments.iter().map(|s| s.size).collect()
    }

    fn from_json(name: &str, j: &Json) -> Result<Self> {
        let usize_at = |key: &str| -> Result<usize> {
            j.get(key)
                .and_then(Json::as_usize)
                .with_context(|| format!("manifest: model {name}: missing/bad {key}"))
        };
        let mut segments = Vec::new();
        for (i, s) in j
            .get("segments")
            .and_then(Json::as_arr)
            .context("manifest: segments missing")?
            .iter()
            .enumerate()
        {
            let seg = Segment {
                name: s
                    .get("name")
                    .and_then(Json::as_str)
                    .with_context(|| format!("segment {i} name"))?
                    .to_string(),
                offset: s.get("offset").and_then(Json::as_usize).context("offset")?,
                size: s.get("size").and_then(Json::as_usize).context("size")?,
                shape: s
                    .get("shape")
                    .and_then(Json::as_arr)
                    .context("shape")?
                    .iter()
                    .map(|x| x.as_usize().context("shape elem"))
                    .collect::<Result<_>>()?,
            };
            segments.push(seg);
        }
        let mut files = BTreeMap::new();
        for (ename, e) in j
            .get("executables")
            .and_then(Json::as_obj)
            .context("manifest: executables missing")?
        {
            files.insert(
                ename.clone(),
                e.get("file")
                    .and_then(Json::as_str)
                    .with_context(|| format!("executable {ename} file"))?
                    .to_string(),
            );
        }
        let mm = ModelManifest {
            name: name.to_string(),
            d: usize_at("d")?,
            segments,
            input_shape: j
                .get("input_shape")
                .and_then(Json::as_arr)
                .context("input_shape")?
                .iter()
                .map(|x| x.as_usize().context("input_shape elem"))
                .collect::<Result<_>>()?,
            classes: usize_at("classes")?,
            tau: usize_at("tau")?,
            batch: usize_at("batch")?,
            eval_batch: usize_at("eval_batch")?,
            n_clients: usize_at("n_clients")?,
            files,
        };
        mm.validate()?;
        Ok(mm)
    }

    /// Structural invariants every well-formed manifest satisfies.
    pub fn validate(&self) -> Result<()> {
        let mut expect_off = 0usize;
        for s in &self.segments {
            if s.offset != expect_off {
                bail!(
                    "model {}: segment {} offset {} != running total {}",
                    self.name, s.name, s.offset, expect_off
                );
            }
            let prod: usize = s.shape.iter().product();
            if prod != s.size {
                bail!("model {}: segment {} shape/size mismatch", self.name, s.name);
            }
            expect_off += s.size;
        }
        if expect_off != self.d {
            bail!("model {}: segments sum {} != d {}", self.name, expect_off, self.d);
        }
        for required in ["init", "round", "evaluate", "ranges", "quantize", "aggregate"] {
            if !self.files.contains_key(required) {
                bail!("model {}: executable {required} missing", self.name);
            }
        }
        Ok(())
    }
}

/// The parsed manifest.json.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Manifest schema version.
    pub version: usize,
    /// Per-model manifests, keyed by model name.
    pub models: BTreeMap<String, ModelManifest>,
}

impl Manifest {
    /// The manifest the native backend ships with when no AOT artifacts
    /// are present: the MLP benchmark at the exact shapes
    /// `python/compile/configs.py` bakes (784 → 128 → 10, d = 101770,
    /// tau = 5, B = 32, E = 500, 10 clients).  The `files` entries are
    /// placeholders — the native executor needs no HLO.
    ///
    /// `FEDDQ_NATIVE_CLIENTS` overrides the cohort size (>= 1); it
    /// exists for smoke tests that spawn one real process/thread per
    /// manifest client (e.g. CI runs the TCP example with 2 workers)
    /// and must be set identically on server and workers, which share
    /// all other shapes regardless.
    pub fn builtin() -> Manifest {
        let n_clients = std::env::var("FEDDQ_NATIVE_CLIENTS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or(10);
        if n_clients != 10 {
            // Loud: a forgotten export changes sharding (and thus every
            // native-backend result) for all later runs in this shell.
            crate::warn_!(
                "manifest",
                "FEDDQ_NATIVE_CLIENTS={n_clients} overrides the built-in cohort of 10 \
                 (smoke-test knob — unset it for normal runs)"
            );
        }
        let (din, hidden, classes) = (28 * 28, 128, 10);
        let segments = vec![
            Segment {
                name: "fc1.w".into(),
                offset: 0,
                size: din * hidden,
                shape: vec![din, hidden],
            },
            Segment {
                name: "fc1.b".into(),
                offset: din * hidden,
                size: hidden,
                shape: vec![hidden],
            },
            Segment {
                name: "fc2.w".into(),
                offset: din * hidden + hidden,
                size: hidden * classes,
                shape: vec![hidden, classes],
            },
            Segment {
                name: "fc2.b".into(),
                offset: din * hidden + hidden + hidden * classes,
                size: classes,
                shape: vec![classes],
            },
        ];
        let d = din * hidden + hidden + hidden * classes + classes;
        let files: BTreeMap<String, String> =
            ["init", "round", "evaluate", "ranges", "quantize", "aggregate"]
                .iter()
                .map(|&k| (k.to_string(), "<native>".to_string()))
                .collect();
        let mlp = ModelManifest {
            name: "mlp".into(),
            d,
            segments,
            input_shape: vec![28, 28, 1],
            classes,
            tau: 5,
            batch: 32,
            eval_batch: 500,
            n_clients,
            files,
        };
        mlp.validate().expect("builtin manifest is well-formed");
        let mut models = BTreeMap::new();
        models.insert("mlp".to_string(), mlp);
        Manifest { version: 2, models }
    }

    /// Load and parse `<dir>/manifest.json`.
    pub fn load(dir: &str) -> Result<Manifest> {
        let path = format!("{dir}/manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path} — run `make artifacts` first"))?;
        Self::parse(&text)
    }

    /// Parse manifest JSON text (validates every model).
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).context("manifest json")?;
        let version = j
            .get("version")
            .and_then(Json::as_usize)
            .context("manifest version")?;
        let mut models = BTreeMap::new();
        for (name, mj) in j
            .get("models")
            .and_then(Json::as_obj)
            .context("manifest models")?
        {
            models.insert(name.clone(), ModelManifest::from_json(name, mj)?);
        }
        Ok(Manifest { version, models })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> String {
        r#"{
          "version": 2,
          "models": {
            "tiny": {
              "d": 6, "padded": 2048, "tile": 1024, "tiles": 2,
              "num_segments": 2,
              "segments": [
                {"name": "w", "offset": 0, "size": 4, "shape": [2, 2]},
                {"name": "b", "offset": 4, "size": 2, "shape": [2]}
              ],
              "input_shape": [2, 1, 1], "classes": 2,
              "tau": 3, "batch": 4, "eval_batch": 8, "n_clients": 2,
              "executables": {
                "init": {"file": "tiny_init.hlo.txt", "args": []},
                "round": {"file": "tiny_round.hlo.txt", "args": []},
                "evaluate": {"file": "tiny_evaluate.hlo.txt", "args": []},
                "ranges": {"file": "tiny_ranges.hlo.txt", "args": []},
                "quantize": {"file": "tiny_quantize.hlo.txt", "args": []},
                "aggregate": {"file": "tiny_aggregate.hlo.txt", "args": []}
              }
            }
          }
        }"#
        .to_string()
    }

    #[test]
    fn parses_valid_manifest() {
        let m = Manifest::parse(&sample()).unwrap();
        assert_eq!(m.version, 2);
        let t = &m.models["tiny"];
        assert_eq!(t.d, 6);
        assert_eq!(t.num_segments(), 2);
        assert_eq!(t.segment_sizes(), vec![4, 2]);
        assert_eq!(t.input_len(), 2);
        assert_eq!(t.files["round"], "tiny_round.hlo.txt");
    }

    #[test]
    fn rejects_gapped_segments() {
        let bad = sample().replace(r#""offset": 4"#, r#""offset": 5"#);
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_missing_executable() {
        let bad = sample().replace(r#""quantize": {"file": "tiny_quantize.hlo.txt", "args": []},"#, "");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_shape_size_mismatch() {
        let bad = sample().replace(r#""shape": [2, 2]"#, r#""shape": [3, 2]"#);
        assert!(Manifest::parse(&bad).is_err());
    }
}
