//! Typed facade over one model's six executables, backend-dispatched.
//!
//! Every call validates its input shapes once here, so both backends
//! see identical contracts.  A `ModelRuntime` is `Send + Sync` and all
//! methods take `&self`: the parallel round engine shares one instance
//! across its worker threads (`coordinator::pool`).

use anyhow::{ensure, Result};

use super::manifest::ModelManifest;
use super::native;

/// One model's executables plus its manifest.
pub struct ModelRuntime {
    /// The model's manifest (shapes, segments, cohort size).
    pub mm: ModelManifest,
    exec: Exec,
}

enum Exec {
    Native(native::NativeMlp),
    #[cfg(feature = "pjrt")]
    Pjrt(super::pjrt::PjrtModel),
}

impl ModelRuntime {
    /// Load on the pure-Rust native backend.
    pub fn load_native(mm: ModelManifest) -> Result<Self> {
        let exec = Exec::Native(native::NativeMlp::from_manifest(&mm)?);
        Ok(ModelRuntime { mm, exec })
    }

    /// Load compiled AOT executables on the PJRT backend.
    #[cfg(feature = "pjrt")]
    pub fn load_pjrt(rt: &super::Runtime, mm: ModelManifest) -> Result<Self> {
        let exec = Exec::Pjrt(super::pjrt::PjrtModel::load(rt, &mm)?);
        Ok(ModelRuntime { mm, exec })
    }

    /// True when running on the native backend.
    pub fn is_native(&self) -> bool {
        matches!(self.exec, Exec::Native(_))
    }

    /// Initialize a fresh flat parameter vector.
    pub fn init(&self, seed: u32) -> Result<Vec<f32>> {
        match &self.exec {
            Exec::Native(n) => n.init(&self.mm, seed),
            #[cfg(feature = "pjrt")]
            Exec::Pjrt(p) => p.init(&self.mm, seed),
        }
    }

    /// Run tau local SGD steps; returns (delta, mean train loss).
    ///
    /// `xs` is `[tau * batch * input_len]` flat NHWC, `ys` is `[tau * batch]`.
    pub fn local_round(
        &self,
        params: &[f32],
        xs: &[f32],
        ys: &[i32],
        lr: f32,
    ) -> Result<(Vec<f32>, f32)> {
        let mm = &self.mm;
        ensure!(params.len() == mm.d, "params length");
        ensure!(
            xs.len() == mm.tau * mm.batch * mm.input_len(),
            "xs length {} != tau*B*input",
            xs.len()
        );
        ensure!(ys.len() == mm.tau * mm.batch, "ys length");
        match &self.exec {
            Exec::Native(n) => n.local_round(mm, params, xs, ys, lr),
            #[cfg(feature = "pjrt")]
            Exec::Pjrt(p) => p.local_round(mm, params, xs, ys, lr),
        }
    }

    /// Evaluate on one test batch; returns (loss_sum, correct_count).
    pub fn evaluate(&self, params: &[f32], xs: &[f32], ys: &[i32]) -> Result<(f32, i32)> {
        let mm = &self.mm;
        ensure!(params.len() == mm.d, "params length");
        ensure!(xs.len() == mm.eval_batch * mm.input_len(), "eval xs length");
        ensure!(ys.len() == mm.eval_batch, "eval ys length");
        match &self.exec {
            Exec::Native(n) => n.evaluate(mm, params, xs, ys),
            #[cfg(feature = "pjrt")]
            Exec::Pjrt(p) => p.evaluate(mm, params, xs, ys),
        }
    }

    /// Per-segment (min, range) of a model update.
    pub fn ranges(&self, delta: &[f32]) -> Result<(Vec<f32>, Vec<f32>)> {
        ensure!(delta.len() == self.mm.d, "delta length");
        match &self.exec {
            Exec::Native(_) => Ok(native::segment_ranges(&self.mm, delta)),
            #[cfg(feature = "pjrt")]
            Exec::Pjrt(p) => p.ranges(delta),
        }
    }

    /// Stochastic quantization -> integer-valued codes (as f32).
    ///
    /// `sinv[l] = s_l / range_l` (0 collapses the segment), `maxcode[l] = s_l`.
    pub fn quantize(
        &self,
        delta: &[f32],
        mins: &[f32],
        sinv: &[f32],
        maxcode: &[f32],
        seed: u32,
    ) -> Result<Vec<f32>> {
        let l = self.mm.num_segments();
        ensure!(delta.len() == self.mm.d, "delta length");
        ensure!(mins.len() == l && sinv.len() == l && maxcode.len() == l, "segment params");
        match &self.exec {
            Exec::Native(_) => Ok(native::stochastic_quantize(
                &self.mm, delta, mins, sinv, maxcode, seed,
            )),
            #[cfg(feature = "pjrt")]
            Exec::Pjrt(p) => p.quantize(delta, mins, sinv, maxcode, seed),
        }
    }

    /// Fused dequantize + weighted aggregate over all n clients.
    ///
    /// `codes` is `[n * d]` row-major, `mins`/`steps` are `[n * L]`,
    /// `weights` is `[n]` (the paper's `p_i`, summing to 1).
    pub fn aggregate(
        &self,
        codes: &[f32],
        mins: &[f32],
        steps: &[f32],
        weights: &[f32],
    ) -> Result<Vec<f32>> {
        let n = self.mm.n_clients;
        let l = self.mm.num_segments();
        ensure!(codes.len() == n * self.mm.d, "codes shape");
        ensure!(mins.len() == n * l && steps.len() == n * l, "headers shape");
        ensure!(weights.len() == n, "weights shape");
        match &self.exec {
            Exec::Native(_) => Ok(native::dequant_aggregate(
                &self.mm, codes, mins, steps, weights,
            )),
            #[cfg(feature = "pjrt")]
            Exec::Pjrt(p) => p.aggregate(&self.mm, codes, mins, steps, weights),
        }
    }
}
