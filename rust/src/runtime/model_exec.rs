//! Typed wrappers over one model's six AOT executables.
//!
//! Each wrapper builds input literals from plain slices, executes on the
//! PJRT CPU client and unpacks the tuple outputs (everything is lowered
//! with `return_tuple=True`).  These calls are the *entire* compute hot
//! path of the coordinator — Python is never involved at runtime.

use anyhow::{ensure, Context, Result};
use xla::{Literal, PjRtLoadedExecutable};

use super::manifest::ModelManifest;
use super::Runtime;

/// One model's compiled executables plus its manifest.
pub struct ModelRuntime {
    pub mm: ModelManifest,
    init: PjRtLoadedExecutable,
    round: PjRtLoadedExecutable,
    evaluate: PjRtLoadedExecutable,
    ranges: PjRtLoadedExecutable,
    quantize: PjRtLoadedExecutable,
    aggregate: PjRtLoadedExecutable,
}

fn vec_literal_f32(data: &[f32], dims: &[i64]) -> Result<Literal> {
    let lit = Literal::vec1(data);
    if dims.len() == 1 {
        return Ok(lit);
    }
    lit.reshape(dims).context("reshape f32 literal")
}

fn vec_literal_i32(data: &[i32], dims: &[i64]) -> Result<Literal> {
    let lit = Literal::vec1(data);
    if dims.len() == 1 {
        return Ok(lit);
    }
    lit.reshape(dims).context("reshape i32 literal")
}

fn run(exe: &PjRtLoadedExecutable, args: &[Literal]) -> Result<Literal> {
    let result = exe.execute::<Literal>(args).context("PJRT execute")?;
    result[0][0].to_literal_sync().context("fetch result literal")
}

impl ModelRuntime {
    pub fn load(rt: &Runtime, mm: ModelManifest) -> Result<Self> {
        Ok(ModelRuntime {
            init: rt.compile(&mm.files["init"])?,
            round: rt.compile(&mm.files["round"])?,
            evaluate: rt.compile(&mm.files["evaluate"])?,
            ranges: rt.compile(&mm.files["ranges"])?,
            quantize: rt.compile(&mm.files["quantize"])?,
            aggregate: rt.compile(&mm.files["aggregate"])?,
            mm,
        })
    }

    /// Initialize a fresh flat parameter vector.
    pub fn init(&self, seed: u32) -> Result<Vec<f32>> {
        let out = run(&self.init, &[Literal::scalar(seed)])?;
        let params = out.to_tuple1()?.to_vec::<f32>()?;
        ensure!(params.len() == self.mm.d, "init returned wrong length");
        Ok(params)
    }

    /// Run tau local SGD steps; returns (delta, mean train loss).
    ///
    /// `xs` is `[tau * batch * input_len]` flat NHWC, `ys` is `[tau * batch]`.
    pub fn local_round(
        &self,
        params: &[f32],
        xs: &[f32],
        ys: &[i32],
        lr: f32,
    ) -> Result<(Vec<f32>, f32)> {
        let (tau, b) = (self.mm.tau as i64, self.mm.batch as i64);
        ensure!(params.len() == self.mm.d, "params length");
        ensure!(
            xs.len() == (tau * b) as usize * self.mm.input_len(),
            "xs length {} != tau*B*input", xs.len()
        );
        ensure!(ys.len() == (tau * b) as usize, "ys length");
        let mut xdims = vec![tau, b];
        xdims.extend(self.mm.input_shape.iter().map(|&v| v as i64));
        let args = [
            Literal::vec1(params),
            vec_literal_f32(xs, &xdims)?,
            vec_literal_i32(ys, &[tau, b])?,
            Literal::scalar(lr),
        ];
        let (delta, loss) = run(&self.round, &args)?.to_tuple2()?;
        Ok((
            delta.to_vec::<f32>()?,
            loss.get_first_element::<f32>()?,
        ))
    }

    /// Evaluate on one test batch; returns (loss_sum, correct_count).
    pub fn evaluate(&self, params: &[f32], xs: &[f32], ys: &[i32]) -> Result<(f32, i32)> {
        let e = self.mm.eval_batch as i64;
        ensure!(xs.len() == e as usize * self.mm.input_len(), "eval xs length");
        ensure!(ys.len() == e as usize, "eval ys length");
        let mut xdims = vec![e];
        xdims.extend(self.mm.input_shape.iter().map(|&v| v as i64));
        let args = [
            Literal::vec1(params),
            vec_literal_f32(xs, &xdims)?,
            Literal::vec1(ys),
        ];
        let (loss, correct) = run(&self.evaluate, &args)?.to_tuple2()?;
        Ok((
            loss.get_first_element::<f32>()?,
            correct.get_first_element::<i32>()?,
        ))
    }

    /// Per-segment (min, range) of a model update.
    pub fn ranges(&self, delta: &[f32]) -> Result<(Vec<f32>, Vec<f32>)> {
        ensure!(delta.len() == self.mm.d, "delta length");
        let (mins, ranges) = run(&self.ranges, &[Literal::vec1(delta)])?.to_tuple2()?;
        Ok((mins.to_vec::<f32>()?, ranges.to_vec::<f32>()?))
    }

    /// Stochastic quantization -> integer-valued codes (as f32).
    ///
    /// `sinv[l] = s_l / range_l` (0 collapses the segment), `maxcode[l] = s_l`.
    pub fn quantize(
        &self,
        delta: &[f32],
        mins: &[f32],
        sinv: &[f32],
        maxcode: &[f32],
        seed: u32,
    ) -> Result<Vec<f32>> {
        let l = self.mm.num_segments();
        ensure!(delta.len() == self.mm.d, "delta length");
        ensure!(mins.len() == l && sinv.len() == l && maxcode.len() == l, "segment params");
        let args = [
            Literal::vec1(delta),
            Literal::vec1(mins),
            Literal::vec1(sinv),
            Literal::vec1(maxcode),
            Literal::scalar(seed),
        ];
        let codes = run(&self.quantize, &args)?.to_tuple1()?;
        Ok(codes.to_vec::<f32>()?)
    }

    /// Fused dequantize + weighted aggregate over all n clients.
    ///
    /// `codes` is `[n * d]` row-major, `mins`/`steps` are `[n * L]`,
    /// `weights` is `[n]` (the paper's `p_i`, summing to 1).
    pub fn aggregate(
        &self,
        codes: &[f32],
        mins: &[f32],
        steps: &[f32],
        weights: &[f32],
    ) -> Result<Vec<f32>> {
        let n = self.mm.n_clients;
        let l = self.mm.num_segments();
        ensure!(codes.len() == n * self.mm.d, "codes shape");
        ensure!(mins.len() == n * l && steps.len() == n * l, "headers shape");
        ensure!(weights.len() == n, "weights shape");
        let args = [
            vec_literal_f32(codes, &[n as i64, self.mm.d as i64])?,
            vec_literal_f32(mins, &[n as i64, l as i64])?,
            vec_literal_f32(steps, &[n as i64, l as i64])?,
            Literal::vec1(weights),
        ];
        let delta = run(&self.aggregate, &args)?.to_tuple1()?;
        Ok(delta.to_vec::<f32>()?)
    }
}
