//! Run configuration: every knob of a federated training run, with JSON
//! (de)serialization so runs are reproducible and remote workers can be
//! configured over the wire (`Welcome` message).

use anyhow::{Context, Result};

use crate::data::{shard::Sharding, DatasetKind};
use crate::quant::PolicyConfig;
use crate::sim::faults::FaultProfile;
use crate::sim::latency::LatencyProfile;
use crate::util::json::Json;

/// How the server folds decoded client updates into the global delta.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggregateMode {
    /// Stream each update into a single `d`-length accumulator as it is
    /// decoded — allocation-free, no `n x d` materialization (default).
    Streaming,
    /// Materialize all `n` decoded updates and run the fused
    /// dequantize-aggregate executable (the XLA/Pallas kernel path).
    Fused,
}

impl AggregateMode {
    /// Parse `streaming` or `fused`.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "streaming" => Ok(AggregateMode::Streaming),
            "fused" => Ok(AggregateMode::Fused),
            _ => anyhow::bail!("unknown aggregate mode {s:?} (want streaming|fused)"),
        }
    }

    /// Canonical string form (parseable by [`Self::parse`]).
    pub fn label(&self) -> &'static str {
        match self {
            AggregateMode::Streaming => "streaming",
            AggregateMode::Fused => "fused",
        }
    }
}

/// Which codec data path runs the per-byte hot loops.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CodecMode {
    /// Narrow code rows (`u16`) + width-specialized SWAR kernels + the
    /// client's fused quantize→pack pass (default).  Bit-identical to
    /// [`CodecMode::Reference`] — enforced by the determinism suite.
    Narrow,
    /// The scalar reference path: f32 code rows, generic
    /// `get_slice`/`put_slice` loops, unfused quantize-then-pack.
    /// Kept as the cross-check oracle for the SWAR kernels.
    Reference,
}

impl CodecMode {
    /// Parse `narrow` or `reference`.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "narrow" => Ok(CodecMode::Narrow),
            "reference" => Ok(CodecMode::Reference),
            _ => anyhow::bail!("unknown codec mode {s:?} (want narrow|reference)"),
        }
    }

    /// Canonical string form (parseable by [`Self::parse`]).
    pub fn label(&self) -> &'static str {
        match self {
            CodecMode::Narrow => "narrow",
            CodecMode::Reference => "reference",
        }
    }
}

/// Full configuration of one federated run.
#[derive(Clone, Debug, PartialEq)]
pub struct RunConfig {
    /// Model name in the artifact manifest (mlp | vanilla_cnn | cnn4 | resnet18).
    pub model: String,
    /// Dataset benchmark; must match the model's input shape.
    pub dataset: DatasetKind,
    /// Quantization policy for the uplink.
    pub policy: PolicyConfig,
    /// Number of communication rounds.
    pub rounds: usize,
    /// Local SGD step size (paper: 0.1).
    pub lr: f32,
    /// Client sharding.
    pub sharding: Sharding,
    /// Root seed for everything (data, init, quantizer streams).
    pub seed: u64,
    /// Evaluate every k rounds (1 = every round).
    pub eval_every: usize,
    /// Train set size when synthesizing data.
    pub train_size: usize,
    /// Test set size when synthesizing data.
    pub test_size: usize,
    /// Directory with AOT artifacts.
    pub artifacts_dir: String,
    /// Directory with real datasets (falls back to synthetic if absent).
    pub data_dir: String,
    /// Stop early once this test accuracy is reached (None = run all rounds).
    pub target_accuracy: Option<f32>,
    /// Error-feedback compensation: clients accumulate their quantization
    /// residual and fold it into the next round's update (EF-SGD family;
    /// an extension beyond the paper, off by default).
    pub error_feedback: bool,
    /// Worker threads for in-process client rounds; 0 = auto
    /// (min(n_clients, available cores)).  Any value yields the same
    /// `RunReport` bit-for-bit — see the determinism contract in lib.rs.
    pub threads: usize,
    /// Server-side aggregation strategy (streaming by default; the fused
    /// executable only when configured).
    pub aggregate: AggregateMode,
    /// Accumulator shards for the server's parallel decode-fold; 0 =
    /// auto (match the worker pool), 1 = serial fold.  Sharding splits
    /// the `d`-length accumulator into contiguous element ranges and
    /// never reorders per-element arithmetic, so any value yields a
    /// bit-identical `RunReport`.
    pub agg_shards: usize,
    /// Worker slices for server-side evaluation batches; 0 = auto
    /// (match the worker pool), 1 = serial.  The reduction walks
    /// batches in a fixed order, so any value yields a bit-identical
    /// `RunReport`.
    pub eval_threads: usize,
    /// Decode-buffer bound for the recv/decode pipeline; 0 = unbounded
    /// (one buffer per client, the historical behavior).  With fold
    /// overlap active this is a hard cap on live `DecodedUpdate`
    /// buffers — the pipeline's memory becomes O(workers + k) instead
    /// of O(n_clients) — otherwise it caps buffers retained between
    /// rounds.  Any value yields a bit-identical `RunReport`.
    pub decode_buffers: usize,
    /// Overlap the sharded accumulator fold with still-arriving updates
    /// (per-shard prefix folds in sorted client order; on by default).
    /// Requires the streaming aggregate and a pool; falls back to the
    /// after-barrier fold otherwise.  Per-element arithmetic and fold
    /// order are unchanged, so either setting yields a bit-identical
    /// `RunReport`.
    pub fold_overlap: bool,
    /// Codec data path: narrow `u16` rows + SWAR kernels + fused client
    /// encode (default), or the scalar f32 reference path.  Payloads,
    /// codes and folds are bit-identical either way (determinism suite);
    /// `reference` exists as the cross-check oracle and escape hatch.
    pub codec: CodecMode,
    /// Fraction of clients sampled per round, in (0, 1]; each round's
    /// cohort is `ceil(participation * n)` clients drawn by a seeded,
    /// round-keyed RNG (`coordinator::sched`) — bit-reproducible for a
    /// fixed seed regardless of any other knob.  1.0 = every client
    /// every round (the historical behavior).
    pub participation: f32,
    /// Optional round deadline in *simulated* seconds: over-sample
    /// `2 * ceil(participation * n)` candidates, price them with the
    /// latency model and keep the deterministic fastest
    /// `ceil(participation * n)` that finish by the deadline (ties by
    /// client id).  Candidates cut land in the round's `dropped` count.
    /// `None` = no deadline.
    pub round_deadline: Option<f64>,
    /// Simulated per-client latency distribution feeding cohort pricing
    /// and the per-round `sim_makespan_secs` metric (`off` = all costs
    /// zero).  Purely a model: it never delays real execution.
    pub sim_latency: LatencyProfile,
    /// Simulated per-client fault distribution (`off` = no faults).
    /// Faulted clients are decided by seeded per-`(client, round)` draws
    /// *before* dispatch, so runs stay bit-reproducible; their updates
    /// count into the round's `failed` metric and aggregation weights
    /// renormalize over the survivors.
    pub sim_faults: FaultProfile,
    /// Give up waiting for a cohort member's update after this many
    /// seconds (real seconds on the TCP path; simulated completion time
    /// under `--sim-faults` in-process).  `None` = wait forever.
    pub round_timeout: Option<f64>,
    /// Fraction of the dispatched cohort whose updates must arrive for a
    /// round to complete, in (0, 1]; the absolute floor is always at
    /// least one update.  1.0 = every dispatched client must answer
    /// (the historical behavior — any failure aborts the run).
    pub quorum: f32,
}

impl RunConfig {
    /// Sensible defaults per model, matching the paper's §V-A setup.
    pub fn default_for(model: &str) -> RunConfig {
        let dataset = match model {
            "mlp" | "vanilla_cnn" => DatasetKind::FashionMnist,
            _ => DatasetKind::Cifar10,
        };
        // Paper §V-A: eta = 0.1.  The CPU-scaled ResNet-18 (base width 8,
        // soft-Fixup affine) needs 0.2 to train at the paper's round
        // budgets — documented substitution, see DESIGN.md §3.
        let lr = if model == "resnet18" { 0.2 } else { 0.1 };
        RunConfig {
            model: model.to_string(),
            dataset,
            policy: PolicyConfig::FedDq { resolution: 0.005 },
            rounds: 50,
            lr,
            sharding: Sharding::Iid,
            seed: 17,
            eval_every: 1,
            train_size: 4000,
            test_size: 1000,
            artifacts_dir: crate::runtime::Runtime::default_artifacts_dir(),
            data_dir: "data".to_string(),
            target_accuracy: None,
            error_feedback: false,
            threads: 0,
            aggregate: AggregateMode::Streaming,
            agg_shards: 0,
            eval_threads: 0,
            decode_buffers: 0,
            fold_overlap: true,
            codec: CodecMode::Narrow,
            participation: 1.0,
            round_deadline: None,
            sim_latency: LatencyProfile::Off,
            sim_faults: FaultProfile::Off,
            round_timeout: None,
            quorum: 1.0,
        }
    }

    /// Resolve the worker-thread count for `n_clients` in-process
    /// clients: explicit value, or min(n_clients, cores) when 0 — and
    /// never more threads than clients.
    pub fn resolved_threads(&self, n_clients: usize) -> usize {
        let t = if self.threads == 0 {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
        } else {
            self.threads
        };
        t.clamp(1, n_clients.max(1))
    }

    /// Resolve the thread count for a **server-only** pool (`feddq
    /// serve`): the remote workers own the round compute, so unlike
    /// [`Self::resolved_threads`] the cohort size is no cap here —
    /// explicit `threads` value, or available cores when 0.
    pub fn resolved_server_threads(&self) -> usize {
        let t = if self.threads == 0 {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
        } else {
            self.threads
        };
        t.clamp(1, 256)
    }

    /// Resolve the accumulator shard count for the server's parallel
    /// fold: explicit value, or the server pool's thread count when 0
    /// (capped so degenerate configs can't explode into thousands of
    /// tiny chunk tasks).
    pub fn resolved_agg_shards(&self, pool_threads: usize) -> usize {
        let s = if self.agg_shards == 0 { pool_threads } else { self.agg_shards };
        s.clamp(1, 256)
    }

    /// Resolve server-side eval parallelism: explicit value, or the
    /// server pool's thread count when 0 — slicing finer than the pool
    /// that executes the slices is pure dispatch overhead.  The eval
    /// path additionally clamps to the number of eval batches.
    pub fn resolved_eval_threads(&self, pool_threads: usize) -> usize {
        let t = if self.eval_threads == 0 { pool_threads } else { self.eval_threads };
        t.clamp(1, 256)
    }

    /// Human-readable run label (used in report files).
    pub fn label(&self) -> String {
        format!("{}-{}", self.model, self.policy.label())
    }

    /// The full config as JSON (crosses the wire in `Welcome`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::from(self.model.clone())),
            (
                "dataset",
                Json::from(match self.dataset {
                    DatasetKind::FashionMnist => "fashion_mnist",
                    DatasetKind::Cifar10 => "cifar10",
                }),
            ),
            ("policy", Json::from(self.policy.label())),
            ("rounds", Json::from(self.rounds)),
            ("lr", Json::from(self.lr as f64)),
            (
                "sharding",
                Json::from(match self.sharding {
                    Sharding::Iid => "iid".to_string(),
                    Sharding::Dirichlet { alpha } => format!("dirichlet:{alpha}"),
                }),
            ),
            ("seed", Json::from(self.seed as f64)),
            ("eval_every", Json::from(self.eval_every)),
            ("train_size", Json::from(self.train_size)),
            ("test_size", Json::from(self.test_size)),
            ("artifacts_dir", Json::from(self.artifacts_dir.clone())),
            ("data_dir", Json::from(self.data_dir.clone())),
            (
                "target_accuracy",
                match self.target_accuracy {
                    Some(a) => Json::from(a as f64),
                    None => Json::Null,
                },
            ),
            ("error_feedback", Json::from(self.error_feedback)),
            ("threads", Json::from(self.threads)),
            ("aggregate", Json::from(self.aggregate.label())),
            ("agg_shards", Json::from(self.agg_shards)),
            ("eval_threads", Json::from(self.eval_threads)),
            ("decode_buffers", Json::from(self.decode_buffers)),
            ("fold_overlap", Json::from(self.fold_overlap)),
            ("codec", Json::from(self.codec.label())),
            ("participation", Json::from(self.participation as f64)),
            (
                "round_deadline",
                match self.round_deadline {
                    Some(d) => Json::from(d),
                    None => Json::Null,
                },
            ),
            ("sim_latency", Json::from(self.sim_latency.label())),
            ("sim_faults", Json::from(self.sim_faults.label())),
            (
                "round_timeout",
                match self.round_timeout {
                    Some(t) => Json::from(t),
                    None => Json::Null,
                },
            ),
            ("quorum", Json::from(self.quorum as f64)),
        ])
    }

    /// Parse a config written by [`Self::to_json`]; fields introduced
    /// after a serializer's build default compatibly.
    pub fn from_json(j: &Json) -> Result<RunConfig> {
        let str_at = |k: &str| -> Result<&str> {
            j.get(k).and_then(Json::as_str).with_context(|| format!("config: {k}"))
        };
        let usize_at = |k: &str| -> Result<usize> {
            j.get(k).and_then(Json::as_usize).with_context(|| format!("config: {k}"))
        };
        let f64_at = |k: &str| -> Result<f64> {
            j.get(k).and_then(Json::as_f64).with_context(|| format!("config: {k}"))
        };
        let cfg = RunConfig {
            model: str_at("model")?.to_string(),
            dataset: DatasetKind::parse(str_at("dataset")?)?,
            policy: PolicyConfig::parse(str_at("policy")?)?,
            rounds: usize_at("rounds")?,
            lr: f64_at("lr")? as f32,
            sharding: Sharding::parse(str_at("sharding")?)?,
            seed: f64_at("seed")? as u64,
            eval_every: usize_at("eval_every")?,
            train_size: usize_at("train_size")?,
            test_size: usize_at("test_size")?,
            artifacts_dir: str_at("artifacts_dir")?.to_string(),
            data_dir: str_at("data_dir")?.to_string(),
            target_accuracy: match j.get("target_accuracy") {
                Some(Json::Null) | None => None,
                Some(v) => Some(v.as_f64().context("config: target_accuracy")? as f32),
            },
            error_feedback: j
                .get("error_feedback")
                .and_then(Json::as_bool)
                .unwrap_or(false),
            // both absent in pre-threading configs: default sequentially
            // compatible values (auto threads, streaming aggregation)
            threads: j.get("threads").and_then(Json::as_usize).unwrap_or(0),
            aggregate: match j.get("aggregate").and_then(Json::as_str) {
                Some(s) => AggregateMode::parse(s)?,
                None => AggregateMode::Streaming,
            },
            // absent in pre-sharding configs: auto everywhere
            agg_shards: j.get("agg_shards").and_then(Json::as_usize).unwrap_or(0),
            eval_threads: j.get("eval_threads").and_then(Json::as_usize).unwrap_or(0),
            // absent in pre-scheduler configs: unbounded buffers,
            // overlap on (bit-identical to the old after-barrier fold)
            decode_buffers: j.get("decode_buffers").and_then(Json::as_usize).unwrap_or(0),
            fold_overlap: j.get("fold_overlap").and_then(Json::as_bool).unwrap_or(true),
            // absent in pre-SWAR configs: the narrow path is
            // bit-identical to what those configs produced
            codec: match j.get("codec").and_then(Json::as_str) {
                Some(s) => CodecMode::parse(s)?,
                None => CodecMode::Narrow,
            },
            // absent in pre-scheduler configs: full participation, no
            // deadline, no simulated latency — exactly the old behavior
            participation: match j.get("participation") {
                Some(Json::Null) | None => 1.0,
                Some(v) => v.as_f64().context("config: participation")? as f32,
            },
            round_deadline: match j.get("round_deadline") {
                Some(Json::Null) | None => None,
                Some(v) => Some(v.as_f64().context("config: round_deadline")?),
            },
            sim_latency: match j.get("sim_latency").and_then(Json::as_str) {
                Some(s) => LatencyProfile::parse(s)?,
                None => LatencyProfile::Off,
            },
            // absent in pre-churn configs: no faults, no timeout, full
            // quorum — exactly the old all-must-answer behavior
            sim_faults: match j.get("sim_faults").and_then(Json::as_str) {
                Some(s) => FaultProfile::parse(s)?,
                None => FaultProfile::Off,
            },
            round_timeout: match j.get("round_timeout") {
                Some(Json::Null) | None => None,
                Some(v) => Some(v.as_f64().context("config: round_timeout")?),
            },
            quorum: match j.get("quorum") {
                Some(Json::Null) | None => 1.0,
                Some(v) => v.as_f64().context("config: quorum")? as f32,
            },
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// [`Self::from_json`] over JSON text.
    pub fn from_json_str(s: &str) -> Result<RunConfig> {
        Self::from_json(&Json::parse(s)?)
    }

    /// Reject configurations no run could execute.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.rounds > 0, "rounds must be positive");
        anyhow::ensure!(self.lr > 0.0 && self.lr.is_finite(), "lr must be positive");
        anyhow::ensure!(self.eval_every > 0, "eval_every must be positive");
        anyhow::ensure!(self.train_size > 0 && self.test_size > 0, "dataset sizes");
        if let Some(a) = self.target_accuracy {
            anyhow::ensure!((0.0..=1.0).contains(&a), "target accuracy in [0,1]");
        }
        anyhow::ensure!(
            self.participation > 0.0 && self.participation <= 1.0,
            "participation must be in (0, 1]"
        );
        if let Some(d) = self.round_deadline {
            anyhow::ensure!(d.is_finite() && d > 0.0, "round deadline must be positive");
            // Constant simulated costs would make the deadline policy's
            // id tie-break permanently exclude high-id clients.
            anyhow::ensure!(
                !self.sim_latency.is_constant(),
                "round_deadline requires a spreading sim_latency model \
                 (uniform:..|lognormal:.. with non-zero spread)"
            );
        }
        if let Some(t) = self.round_timeout {
            anyhow::ensure!(t.is_finite() && t > 0.0, "round timeout must be positive");
        }
        anyhow::ensure!(
            self.quorum > 0.0 && self.quorum <= 1.0,
            "quorum must be in (0, 1]"
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        for m in ["mlp", "vanilla_cnn", "cnn4", "resnet18"] {
            let c = RunConfig::default_for(m);
            c.validate().unwrap();
            let want = if m == "resnet18" { 0.2 } else { 0.1 };
            assert_eq!(c.lr, want); // paper §V-A (+ documented resnet substitution)
        }
    }

    #[test]
    fn json_roundtrip() {
        let mut c = RunConfig::default_for("cnn4");
        c.policy = PolicyConfig::AdaQuantFl { s0: 4 };
        c.sharding = Sharding::Dirichlet { alpha: 0.5 };
        c.target_accuracy = Some(0.8);
        c.error_feedback = true;
        c.threads = 6;
        c.aggregate = AggregateMode::Fused;
        c.agg_shards = 8;
        c.eval_threads = 3;
        c.decode_buffers = 4;
        c.fold_overlap = false;
        c.codec = CodecMode::Reference;
        c.participation = 0.25;
        c.round_deadline = Some(3.5);
        c.sim_latency = LatencyProfile::LogNormal { median: 1.5, sigma: 0.75 };
        c.sim_faults = FaultProfile::Stall { p: 0.125, secs: 2.5 };
        c.round_timeout = Some(7.5);
        c.quorum = 0.5;
        let j = c.to_json();
        let back = RunConfig::from_json(&j).unwrap();
        assert_eq!(c, back);
        // and through text
        let back2 = RunConfig::from_json_str(&j.to_string_pretty()).unwrap();
        assert_eq!(c, back2);
    }

    #[test]
    fn rejects_invalid() {
        let mut c = RunConfig::default_for("mlp");
        c.rounds = 0;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default_for("mlp");
        c.lr = -0.5;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default_for("mlp");
        c.target_accuracy = Some(2.0);
        assert!(c.validate().is_err());
        let mut c = RunConfig::default_for("mlp");
        c.participation = 0.0;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default_for("mlp");
        c.participation = 1.5;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default_for("mlp");
        c.round_deadline = Some(-1.0);
        assert!(c.validate().is_err());
        // a deadline without a latency model would bias cohorts to low
        // ids (all candidates tie) — rejected
        let mut c = RunConfig::default_for("mlp");
        c.round_deadline = Some(2.0);
        assert!(c.validate().is_err());
        c.sim_latency = LatencyProfile::LogNormal { median: 1.0, sigma: 0.0 };
        assert!(c.validate().is_err(), "sigma 0 is constant — same bias as off");
        c.sim_latency = LatencyProfile::Uniform { lo: 0.5, hi: 1.5 };
        assert!(c.validate().is_ok());
        let mut c = RunConfig::default_for("mlp");
        c.round_timeout = Some(0.0);
        assert!(c.validate().is_err());
        let mut c = RunConfig::default_for("mlp");
        c.quorum = 0.0;
        assert!(c.validate().is_err());
        c.quorum = 1.5;
        assert!(c.validate().is_err());
        c.quorum = 0.5;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn missing_threading_fields_default_compatibly() {
        // configs serialized before the parallel round engine existed
        let c = RunConfig::default_for("mlp");
        let mut j = c.to_json();
        if let Json::Obj(o) = &mut j {
            o.remove("threads");
            o.remove("aggregate");
            o.remove("agg_shards");
            o.remove("eval_threads");
            o.remove("decode_buffers");
            o.remove("fold_overlap");
            o.remove("codec");
            o.remove("participation");
            o.remove("round_deadline");
            o.remove("sim_latency");
            o.remove("sim_faults");
            o.remove("round_timeout");
            o.remove("quorum");
        }
        let back = RunConfig::from_json(&j).unwrap();
        assert_eq!(back.threads, 0);
        assert_eq!(back.aggregate, AggregateMode::Streaming);
        assert_eq!(back.agg_shards, 0);
        assert_eq!(back.eval_threads, 0);
        assert_eq!(back.decode_buffers, 0);
        assert!(back.fold_overlap);
        assert_eq!(back.codec, CodecMode::Narrow);
        assert_eq!(back.participation, 1.0);
        assert_eq!(back.round_deadline, None);
        assert_eq!(back.sim_latency, LatencyProfile::Off);
        assert_eq!(back.sim_faults, FaultProfile::Off);
        assert_eq!(back.round_timeout, None);
        assert_eq!(back.quorum, 1.0);
    }

    #[test]
    fn resolved_threads_clamps() {
        let mut c = RunConfig::default_for("mlp");
        c.threads = 64;
        assert_eq!(c.resolved_threads(10), 10);
        c.threads = 3;
        assert_eq!(c.resolved_threads(10), 3);
        c.threads = 0;
        let auto = c.resolved_threads(10);
        assert!((1..=10).contains(&auto));
    }

    #[test]
    fn resolved_server_knobs_follow_pool_and_clamp() {
        let mut c = RunConfig::default_for("mlp");
        // auto: both server knobs follow the pool
        assert_eq!(c.resolved_agg_shards(4), 4);
        assert_eq!(c.resolved_eval_threads(4), 4);
        // explicit values win, degenerate ones clamp
        c.agg_shards = 7;
        assert_eq!(c.resolved_agg_shards(4), 7);
        c.agg_shards = 100_000;
        assert_eq!(c.resolved_agg_shards(4), 256);
        c.eval_threads = 5;
        assert_eq!(c.resolved_eval_threads(4), 5);
        c.eval_threads = 100_000;
        assert_eq!(c.resolved_eval_threads(4), 256);
    }
}
