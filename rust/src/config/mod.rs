//! Run configuration: every knob of a federated training run, with JSON
//! (de)serialization so runs are reproducible and remote workers can be
//! configured over the wire (`Welcome` message).
//!
//! Round behavior — who is dispatched, when a round may complete
//! without everyone, and how the server's hot path is shaped — is one
//! typed value, [`RoundPolicy`], built through a validating builder
//! ([`RoundPolicy::builder`]) instead of loose fields checked at
//! scattered call sites.  [`RunConfig`] composes it; so does
//! `coordinator::ServerOpts`.

use anyhow::{Context, Result};

use crate::data::{shard::Sharding, DatasetKind};
use crate::quant::PolicyConfig;
use crate::sim::faults::FaultProfile;
use crate::sim::latency::LatencyProfile;
use crate::util::json::Json;

/// How the server folds decoded client updates into the global delta.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggregateMode {
    /// Stream each update into a single `d`-length accumulator as it is
    /// decoded — allocation-free, no `n x d` materialization (default).
    Streaming,
    /// Materialize all `n` decoded updates and run the fused
    /// dequantize-aggregate executable (the XLA/Pallas kernel path).
    Fused,
}

impl AggregateMode {
    /// Parse `streaming` or `fused`.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "streaming" => Ok(AggregateMode::Streaming),
            "fused" => Ok(AggregateMode::Fused),
            _ => anyhow::bail!("unknown aggregate mode {s:?} (want streaming|fused)"),
        }
    }

    /// Canonical string form (parseable by [`Self::parse`]).
    pub fn label(&self) -> &'static str {
        match self {
            AggregateMode::Streaming => "streaming",
            AggregateMode::Fused => "fused",
        }
    }
}

impl std::str::FromStr for AggregateMode {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Self::parse(s)
    }
}

impl std::fmt::Display for AggregateMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// Which codec data path runs the per-byte hot loops.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CodecMode {
    /// Narrow code rows (`u16`) + width-specialized SWAR kernels + the
    /// client's fused quantize→pack pass (default).  Bit-identical to
    /// [`CodecMode::Reference`] — enforced by the determinism suite.
    Narrow,
    /// The scalar reference path: f32 code rows, generic
    /// `get_slice`/`put_slice` loops, unfused quantize-then-pack.
    /// Kept as the cross-check oracle for the SWAR kernels.
    Reference,
}

impl CodecMode {
    /// Parse `narrow` or `reference`.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "narrow" => Ok(CodecMode::Narrow),
            "reference" => Ok(CodecMode::Reference),
            _ => anyhow::bail!("unknown codec mode {s:?} (want narrow|reference)"),
        }
    }

    /// Canonical string form (parseable by [`Self::parse`]).
    pub fn label(&self) -> &'static str {
        match self {
            CodecMode::Narrow => "narrow",
            CodecMode::Reference => "reference",
        }
    }
}

impl std::str::FromStr for CodecMode {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Self::parse(s)
    }
}

impl std::fmt::Display for CodecMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// Cohort selection: who is dispatched each round.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Cohort {
    /// Fraction of clients sampled per round, in (0, 1]; each round's
    /// cohort is `ceil(participation * n)` clients drawn by a seeded,
    /// round-keyed RNG (`coordinator::sched`) — bit-reproducible for a
    /// fixed seed regardless of any other knob.  1.0 = every client
    /// every round (the historical behavior).
    pub participation: f32,
    /// Optional round deadline in *simulated* seconds: over-sample
    /// `2 * ceil(participation * n)` candidates, price them with the
    /// latency model and keep the deterministic fastest
    /// `ceil(participation * n)` that finish by the deadline (ties by
    /// client id).  Candidates cut land in the round's `dropped` count.
    /// `None` = no deadline.  Requires a non-constant latency profile.
    pub deadline: Option<f64>,
}

/// Straggler tolerance: when a round may complete without everyone, and
/// how far behind a late update may trail before it is discarded.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Tolerance {
    /// Fraction of the dispatched cohort whose updates must arrive for a
    /// round to complete, in (0, 1]; the absolute floor is always at
    /// least one update.  1.0 = every dispatched client must answer
    /// (the historical behavior — any failure aborts the run).
    pub quorum: f32,
    /// Give up waiting for a cohort member's update after this many
    /// seconds (real seconds on the TCP path; simulated completion time
    /// under `--sim-faults` in-process).  `None` = wait forever.
    pub round_timeout: Option<f64>,
    /// Bounded staleness `k` for semi-synchronous rounds: round `m+1`
    /// may begin once round `m` reaches quorum, and an update answering
    /// round `m` is still accepted up to `k` rounds later, folded with
    /// a staleness-discounted weight `w / (1 + s)` renormalized over
    /// the round's fold set (`s` = rounds late).  Updates older than
    /// `k` are dropped and counted in `RoundRecord::stale_dropped`.
    /// `0` = strict synchronous rounds (the historical behavior,
    /// bit-for-bit).  `k > 0` requires quorum mode (`quorum < 1` or a
    /// `round_timeout`), since a round that must wait for everyone can
    /// never leave a straggler behind.
    pub staleness: u32,
}

/// Aggregation topology: how updates travel from leaves to the server.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Topology {
    /// Aggregation-tree fanout.  `0` = flat topology (every client sends
    /// straight to the server — the historical behavior, bit-for-bit).
    /// `f >= 2` groups clients into subtrees of `f` consecutive ids
    /// rooted at `id / f * f`; each subtree folds locally and forwards
    /// one `PartialAggregate` upstream.  The grouping *defines* the
    /// canonical fold order, so the in-process engine applies the same
    /// virtual grouping and a TCP tree run is bit-identical to it
    /// (including `params_hash`) for the same seed and cohort.
    pub fanout: u32,
}

/// Server hot-path shape: never changes results, only speed and memory.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Pipeline {
    /// Overlap the sharded accumulator fold with still-arriving updates
    /// (per-shard prefix folds in sorted client order; on by default).
    /// Requires the streaming aggregate and a pool; falls back to the
    /// after-barrier fold otherwise.  Per-element arithmetic and fold
    /// order are unchanged, so either setting yields a bit-identical
    /// `RunReport`.
    pub fold_overlap: bool,
    /// Decode-buffer bound for the recv/decode pipeline; 0 = unbounded
    /// (one buffer per client, the historical behavior).  With fold
    /// overlap active this is a hard cap on live `DecodedUpdate`
    /// buffers — the pipeline's memory becomes O(workers + k) instead
    /// of O(n_clients) — otherwise it caps buffers retained between
    /// rounds.  Any value yields a bit-identical `RunReport`.
    pub decode_buffers: usize,
    /// Codec data path: narrow `u16` rows + SWAR kernels + fused client
    /// encode (default), or the scalar f32 reference path.  Payloads,
    /// codes and folds are bit-identical either way (determinism suite);
    /// `reference` exists as the cross-check oracle and escape hatch.
    pub codec: CodecMode,
}

/// Wire-budget knobs: the round-level uplink bit budget and the
/// quantized downlink broadcast.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Budget {
    /// Round-level uplink *payload* bit budget, split per client per
    /// segment by the server's `BitBudgetController` (slow clients get
    /// narrower widths instead of getting dropped).  `0` = off (the
    /// historical behavior, bit-for-bit).  Budgets clamp the policy's
    /// decision, so they compose with any quantization policy —
    /// including `fp32`, which a budget forces onto the quantized
    /// path.  Requires `error_feedback` (clamping is lossy; the
    /// residual loop compensates).
    pub bit_budget: u64,
    /// Quantize the server's broadcast delta to this many bits per
    /// element (`1..=16`), with a server-side error-feedback residual;
    /// clients train on their replica of the quantized stream.  `32` =
    /// ledger-only mode: the broadcast stays raw fp32 (bit-identical
    /// wire bytes to off) but the downlink ledger columns report the
    /// fp32 cost.  `0` = off (no ledger, the historical behavior).
    /// `1..=16` requires `error_feedback`.
    pub downlink_bits: u32,
}

/// Everything that governs one round's behavior, as one typed value:
/// [`Cohort`] (who is dispatched), [`Tolerance`] (when the round may
/// complete without everyone), [`Pipeline`] (how the server's hot
/// path is shaped) and [`Budget`] (the two-direction wire budget).
/// Construct through [`RoundPolicy::builder`], which
/// cross-validates the fields at build time, or take
/// [`RoundPolicy::strict_sync`] / `Default` for the historical strict
/// synchronous behavior.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RoundPolicy {
    /// Cohort selection knobs.
    pub cohort: Cohort,
    /// Straggler-tolerance knobs.
    pub tolerance: Tolerance,
    /// Server hot-path shape knobs.
    pub pipeline: Pipeline,
    /// Aggregation-topology knobs.
    pub topology: Topology,
    /// Wire-budget knobs.
    pub budget: Budget,
}

impl Default for RoundPolicy {
    fn default() -> Self {
        Self::strict_sync()
    }
}

impl RoundPolicy {
    /// The historical strict synchronous policy: full participation, no
    /// deadline, full quorum, no timeout, no staleness, default
    /// pipeline shape.
    pub fn strict_sync() -> RoundPolicy {
        RoundPolicy {
            cohort: Cohort { participation: 1.0, deadline: None },
            tolerance: Tolerance { quorum: 1.0, round_timeout: None, staleness: 0 },
            pipeline: Pipeline {
                fold_overlap: true,
                decode_buffers: 0,
                codec: CodecMode::Narrow,
            },
            topology: Topology { fanout: 0 },
            budget: Budget { bit_budget: 0, downlink_bits: 0 },
        }
    }

    /// A builder starting from [`Self::strict_sync`]; call
    /// [`RoundPolicyBuilder::build`] to validate and construct.
    pub fn builder() -> RoundPolicyBuilder {
        RoundPolicyBuilder { policy: Self::strict_sync(), latency: LatencyProfile::Off }
    }

    /// Does this policy put the server in tolerant (quorum) mode, where
    /// a round may complete without every dispatched update?
    pub fn is_tolerant(&self) -> bool {
        self.tolerance.quorum < 1.0
            || self.tolerance.round_timeout.is_some()
            || self.tolerance.staleness > 0
    }

    /// Reject policies no run could execute.  `sim_latency` is the
    /// cross-field context: the deadline policy prices candidates with
    /// it, so a constant profile (where the id tie-break alone would
    /// pick the cohort) is rejected.
    pub fn validate(&self, sim_latency: &LatencyProfile) -> Result<()> {
        anyhow::ensure!(
            self.cohort.participation > 0.0 && self.cohort.participation <= 1.0,
            "participation must be in (0, 1]"
        );
        if let Some(d) = self.cohort.deadline {
            anyhow::ensure!(d.is_finite() && d > 0.0, "round deadline must be positive");
            // Constant simulated costs would make the deadline policy's
            // id tie-break permanently exclude high-id clients.
            anyhow::ensure!(
                !sim_latency.is_constant(),
                "round_deadline requires a spreading sim_latency model \
                 (uniform:..|lognormal:.. with non-zero spread)"
            );
        }
        if let Some(t) = self.tolerance.round_timeout {
            anyhow::ensure!(t.is_finite() && t > 0.0, "round timeout must be positive");
        }
        anyhow::ensure!(
            self.tolerance.quorum > 0.0 && self.tolerance.quorum <= 1.0,
            "quorum must be in (0, 1]"
        );
        if self.tolerance.staleness > 0 {
            anyhow::ensure!(
                self.tolerance.quorum < 1.0 || self.tolerance.round_timeout.is_some(),
                "staleness requires quorum mode (quorum < 1 and/or round_timeout): \
                 a round that must wait for every update never leaves a straggler behind"
            );
        }
        anyhow::ensure!(
            self.topology.fanout == 0 || self.topology.fanout >= 2,
            "fanout must be 0 (flat topology) or >= 2 (aggregation tree)"
        );
        anyhow::ensure!(
            self.budget.downlink_bits == 0
                || (1..=16).contains(&self.budget.downlink_bits)
                || self.budget.downlink_bits == 32,
            "downlink_bits must be 0 (off), 1..=16 (quantized broadcast) \
             or 32 (fp32 ledger only)"
        );
        Ok(())
    }

    /// This policy as a nested JSON object (cohort/tolerance/pipeline).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "cohort",
                Json::obj(vec![
                    ("participation", Json::from(self.cohort.participation as f64)),
                    (
                        "deadline",
                        match self.cohort.deadline {
                            Some(d) => Json::from(d),
                            None => Json::Null,
                        },
                    ),
                ]),
            ),
            (
                "tolerance",
                Json::obj(vec![
                    ("quorum", Json::from(self.tolerance.quorum as f64)),
                    (
                        "round_timeout",
                        match self.tolerance.round_timeout {
                            Some(t) => Json::from(t),
                            None => Json::Null,
                        },
                    ),
                    ("staleness", Json::from(self.tolerance.staleness as usize)),
                ]),
            ),
            (
                "pipeline",
                Json::obj(vec![
                    ("fold_overlap", Json::from(self.pipeline.fold_overlap)),
                    ("decode_buffers", Json::from(self.pipeline.decode_buffers)),
                    ("codec", Json::from(self.pipeline.codec.label())),
                ]),
            ),
            (
                "topology",
                Json::obj(vec![(
                    "fanout",
                    Json::from(self.topology.fanout as usize),
                )]),
            ),
            (
                "budget",
                Json::obj(vec![
                    // decimal string: u64-exact (f64 JSON numbers lose
                    // precision past 2^53), like the report's counters
                    ("bit_budget", crate::metrics::u64_json(self.budget.bit_budget)),
                    ("downlink_bits", Json::from(self.budget.downlink_bits as usize)),
                ]),
            ),
        ])
    }

    /// Parse the nested object written by [`Self::to_json`].  Absent
    /// sub-fields default to [`Self::strict_sync`]'s values; mistyped
    /// present fields are errors.
    pub fn from_json(j: &Json) -> Result<RoundPolicy> {
        let mut p = Self::strict_sync();
        if let Some(c) = j.get("cohort") {
            if let Some(v) = c.get("participation") {
                p.cohort.participation = v.as_f64().context("round.cohort.participation")? as f32;
            }
            p.cohort.deadline = match c.get("deadline") {
                Some(Json::Null) | None => None,
                Some(v) => Some(v.as_f64().context("round.cohort.deadline")?),
            };
        }
        if let Some(t) = j.get("tolerance") {
            if let Some(v) = t.get("quorum") {
                p.tolerance.quorum = v.as_f64().context("round.tolerance.quorum")? as f32;
            }
            p.tolerance.round_timeout = match t.get("round_timeout") {
                Some(Json::Null) | None => None,
                Some(v) => Some(v.as_f64().context("round.tolerance.round_timeout")?),
            };
            if let Some(v) = t.get("staleness") {
                p.tolerance.staleness = v.as_usize().context("round.tolerance.staleness")? as u32;
            }
        }
        if let Some(pl) = j.get("pipeline") {
            if let Some(v) = pl.get("fold_overlap") {
                p.pipeline.fold_overlap =
                    v.as_bool().context("round.pipeline.fold_overlap")?;
            }
            if let Some(v) = pl.get("decode_buffers") {
                p.pipeline.decode_buffers =
                    v.as_usize().context("round.pipeline.decode_buffers")?;
            }
            if let Some(v) = pl.get("codec") {
                p.pipeline.codec =
                    CodecMode::parse(v.as_str().context("round.pipeline.codec")?)?;
            }
        }
        // absent in pre-tree configs: flat topology
        if let Some(t) = j.get("topology") {
            if let Some(v) = t.get("fanout") {
                p.topology.fanout = v.as_usize().context("round.topology.fanout")? as u32;
            }
        }
        // absent in pre-budget configs: both knobs off
        if let Some(b) = j.get("budget") {
            if let Some(v) = b.get("bit_budget") {
                p.budget.bit_budget =
                    crate::metrics::json_u64(v).context("round.budget.bit_budget")?;
            }
            if let Some(v) = b.get("downlink_bits") {
                p.budget.downlink_bits =
                    v.as_usize().context("round.budget.downlink_bits")? as u32;
            }
        }
        Ok(p)
    }
}

/// Builder for [`RoundPolicy`] with cross-field validation at
/// construction: invalid combinations (deadline without a spreading
/// latency profile, staleness without quorum mode, out-of-range
/// fractions) fail in [`Self::build`] instead of deep inside a run.
#[derive(Clone, Debug)]
pub struct RoundPolicyBuilder {
    policy: RoundPolicy,
    latency: LatencyProfile,
}

impl RoundPolicyBuilder {
    /// Set the per-round participation fraction, in (0, 1].
    pub fn participation(mut self, f: f32) -> Self {
        self.policy.cohort.participation = f;
        self
    }

    /// Set the simulated round deadline in seconds.
    pub fn deadline(mut self, secs: f64) -> Self {
        self.policy.cohort.deadline = Some(secs);
        self
    }

    /// Set the quorum fraction, in (0, 1].
    pub fn quorum(mut self, f: f32) -> Self {
        self.policy.tolerance.quorum = f;
        self
    }

    /// Set the per-round receive timeout in seconds.
    pub fn round_timeout(mut self, secs: f64) -> Self {
        self.policy.tolerance.round_timeout = Some(secs);
        self
    }

    /// Set the bounded staleness `k` (0 = strict synchronous).
    pub fn staleness(mut self, k: u32) -> Self {
        self.policy.tolerance.staleness = k;
        self
    }

    /// Enable/disable the overlapped shard fold.
    pub fn fold_overlap(mut self, on: bool) -> Self {
        self.policy.pipeline.fold_overlap = on;
        self
    }

    /// Set the decode-buffer bound (0 = unbounded).
    pub fn decode_buffers(mut self, k: usize) -> Self {
        self.policy.pipeline.decode_buffers = k;
        self
    }

    /// Select the codec data path.
    pub fn codec(mut self, c: CodecMode) -> Self {
        self.policy.pipeline.codec = c;
        self
    }

    /// Set the aggregation-tree fanout (0 = flat topology).
    pub fn fanout(mut self, f: u32) -> Self {
        self.policy.topology.fanout = f;
        self
    }

    /// Set the round-level uplink payload bit budget (0 = off).
    pub fn bit_budget(mut self, bits: u64) -> Self {
        self.policy.budget.bit_budget = bits;
        self
    }

    /// Set the downlink broadcast width (0 = off, 1..=16 = quantized,
    /// 32 = fp32 ledger only).
    pub fn downlink_bits(mut self, b: u32) -> Self {
        self.policy.budget.downlink_bits = b;
        self
    }

    /// Provide the simulated-latency profile the policy will run
    /// against; [`Self::build`]'s deadline validation needs it.
    pub fn latency_context(mut self, l: LatencyProfile) -> Self {
        self.latency = l;
        self
    }

    /// Validate the assembled policy and return it.
    pub fn build(self) -> Result<RoundPolicy> {
        self.policy.validate(&self.latency)?;
        Ok(self.policy)
    }
}

/// Full configuration of one federated run.
#[derive(Clone, Debug, PartialEq)]
pub struct RunConfig {
    /// Model name in the artifact manifest (mlp | vanilla_cnn | cnn4 | resnet18).
    pub model: String,
    /// Dataset benchmark; must match the model's input shape.
    pub dataset: DatasetKind,
    /// Quantization policy for the uplink.
    pub policy: PolicyConfig,
    /// Number of communication rounds.
    pub rounds: usize,
    /// Local SGD step size (paper: 0.1).
    pub lr: f32,
    /// Client sharding.
    pub sharding: Sharding,
    /// Root seed for everything (data, init, quantizer streams).
    pub seed: u64,
    /// Evaluate every k rounds (1 = every round).
    pub eval_every: usize,
    /// Train set size when synthesizing data.
    pub train_size: usize,
    /// Test set size when synthesizing data.
    pub test_size: usize,
    /// Directory with AOT artifacts.
    pub artifacts_dir: String,
    /// Directory with real datasets (falls back to synthetic if absent).
    pub data_dir: String,
    /// Stop early once this test accuracy is reached (None = run all rounds).
    pub target_accuracy: Option<f32>,
    /// Error-feedback compensation: clients accumulate their quantization
    /// residual and fold it into the next round's update (EF-SGD family;
    /// an extension beyond the paper, off by default).
    pub error_feedback: bool,
    /// Bit width for the client's *banked* error-feedback residual:
    /// between rounds the residual is stored re-quantized to this many
    /// bits per element (per-segment affine grid) instead of fp32,
    /// shrinking resident client state by `32 / ef_bits`x.  `0` = bank
    /// in fp32 (the historical behavior, bit-for-bit).  Requires
    /// `error_feedback`; the added banking error is bounded by half a
    /// grid step per element and is itself compensated by EF on the
    /// next round.
    pub ef_bits: u32,
    /// Worker threads for in-process client rounds; 0 = auto
    /// (min(n_clients, available cores)).  Any value yields the same
    /// `RunReport` bit-for-bit — see the determinism contract in lib.rs.
    pub threads: usize,
    /// Server-side aggregation strategy (streaming by default; the fused
    /// executable only when configured).
    pub aggregate: AggregateMode,
    /// Accumulator shards for the server's parallel decode-fold; 0 =
    /// auto (match the worker pool), 1 = serial fold.  Sharding splits
    /// the `d`-length accumulator into contiguous element ranges and
    /// never reorders per-element arithmetic, so any value yields a
    /// bit-identical `RunReport`.
    pub agg_shards: usize,
    /// Worker slices for server-side evaluation batches; 0 = auto
    /// (match the worker pool), 1 = serial.  The reduction walks
    /// batches in a fixed order, so any value yields a bit-identical
    /// `RunReport`.
    pub eval_threads: usize,
    /// The round behavior policy: cohort selection, straggler
    /// tolerance (quorum / timeout / bounded staleness) and the server
    /// pipeline shape, as one validated value.
    pub round: RoundPolicy,
    /// Simulated per-client latency distribution feeding cohort pricing
    /// and the per-round `sim_makespan_secs` metric (`off` = all costs
    /// zero).  Purely a model: it never delays real execution.
    pub sim_latency: LatencyProfile,
    /// Simulated per-client fault distribution (`off` = no faults).
    /// Faulted clients are decided by seeded per-`(client, round)` draws
    /// *before* dispatch, so runs stay bit-reproducible; their updates
    /// count into the round's `failed` metric and aggregation weights
    /// renormalize over the survivors.
    pub sim_faults: FaultProfile,
}

impl RunConfig {
    /// Sensible defaults per model, matching the paper's §V-A setup.
    pub fn default_for(model: &str) -> RunConfig {
        let dataset = match model {
            "mlp" | "vanilla_cnn" => DatasetKind::FashionMnist,
            _ => DatasetKind::Cifar10,
        };
        // Paper §V-A: eta = 0.1.  The CPU-scaled ResNet-18 (base width 8,
        // soft-Fixup affine) needs 0.2 to train at the paper's round
        // budgets — documented substitution, see DESIGN.md §3.
        let lr = if model == "resnet18" { 0.2 } else { 0.1 };
        RunConfig {
            model: model.to_string(),
            dataset,
            policy: PolicyConfig::FedDq { resolution: 0.005 },
            rounds: 50,
            lr,
            sharding: Sharding::Iid,
            seed: 17,
            eval_every: 1,
            train_size: 4000,
            test_size: 1000,
            artifacts_dir: crate::runtime::Runtime::default_artifacts_dir(),
            data_dir: "data".to_string(),
            target_accuracy: None,
            error_feedback: false,
            ef_bits: 0,
            threads: 0,
            aggregate: AggregateMode::Streaming,
            agg_shards: 0,
            eval_threads: 0,
            round: RoundPolicy::strict_sync(),
            sim_latency: LatencyProfile::Off,
            sim_faults: FaultProfile::Off,
        }
    }

    /// Resolve the worker-thread count for `n_clients` in-process
    /// clients: explicit value, or min(n_clients, cores) when 0 — and
    /// never more threads than clients.
    pub fn resolved_threads(&self, n_clients: usize) -> usize {
        let t = if self.threads == 0 {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
        } else {
            self.threads
        };
        t.clamp(1, n_clients.max(1))
    }

    /// Resolve the thread count for a **server-only** pool (`feddq
    /// serve`): the remote workers own the round compute, so unlike
    /// [`Self::resolved_threads`] the cohort size is no cap here —
    /// explicit `threads` value, or available cores when 0.
    pub fn resolved_server_threads(&self) -> usize {
        let t = if self.threads == 0 {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
        } else {
            self.threads
        };
        t.clamp(1, 256)
    }

    /// Resolve the accumulator shard count for the server's parallel
    /// fold: explicit value, or the server pool's thread count when 0
    /// (capped so degenerate configs can't explode into thousands of
    /// tiny chunk tasks).
    pub fn resolved_agg_shards(&self, pool_threads: usize) -> usize {
        let s = if self.agg_shards == 0 { pool_threads } else { self.agg_shards };
        s.clamp(1, 256)
    }

    /// Resolve server-side eval parallelism: explicit value, or the
    /// server pool's thread count when 0 — slicing finer than the pool
    /// that executes the slices is pure dispatch overhead.  The eval
    /// path additionally clamps to the number of eval batches.
    pub fn resolved_eval_threads(&self, pool_threads: usize) -> usize {
        let t = if self.eval_threads == 0 { pool_threads } else { self.eval_threads };
        t.clamp(1, 256)
    }

    /// Human-readable run label (used in report files).
    pub fn label(&self) -> String {
        format!("{}-{}", self.model, self.policy.label())
    }

    /// The full config as JSON (crosses the wire in `Welcome`).  The
    /// round policy is the nested `"round"` object; see
    /// [`Self::from_json`] for the legacy flat-key fallback.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::from(self.model.clone())),
            (
                "dataset",
                Json::from(match self.dataset {
                    DatasetKind::FashionMnist => "fashion_mnist",
                    DatasetKind::Cifar10 => "cifar10",
                }),
            ),
            ("policy", Json::from(self.policy.label())),
            ("rounds", Json::from(self.rounds)),
            ("lr", Json::from(self.lr as f64)),
            (
                "sharding",
                Json::from(match self.sharding {
                    Sharding::Iid => "iid".to_string(),
                    Sharding::Dirichlet { alpha } => format!("dirichlet:{alpha}"),
                }),
            ),
            ("seed", Json::from(self.seed as f64)),
            ("eval_every", Json::from(self.eval_every)),
            ("train_size", Json::from(self.train_size)),
            ("test_size", Json::from(self.test_size)),
            ("artifacts_dir", Json::from(self.artifacts_dir.clone())),
            ("data_dir", Json::from(self.data_dir.clone())),
            (
                "target_accuracy",
                match self.target_accuracy {
                    Some(a) => Json::from(a as f64),
                    None => Json::Null,
                },
            ),
            ("error_feedback", Json::from(self.error_feedback)),
            ("ef_bits", Json::from(self.ef_bits as usize)),
            ("threads", Json::from(self.threads)),
            ("aggregate", Json::from(self.aggregate.label())),
            ("agg_shards", Json::from(self.agg_shards)),
            ("eval_threads", Json::from(self.eval_threads)),
            ("round", self.round.to_json()),
            ("sim_latency", Json::from(self.sim_latency.label())),
            ("sim_faults", Json::from(self.sim_faults.label())),
        ])
    }

    /// Parse a config written by [`Self::to_json`]; fields introduced
    /// after a serializer's build default compatibly.  Round behavior
    /// is read from the nested `"round"` object when present; configs
    /// serialized by older builds (flat `participation` /
    /// `round_deadline` / `quorum` / `round_timeout` / `fold_overlap` /
    /// `decode_buffers` / `codec` keys) still deserialize, absent keys
    /// defaulting to the strict synchronous policy.
    pub fn from_json(j: &Json) -> Result<RunConfig> {
        let str_at = |k: &str| -> Result<&str> {
            j.get(k).and_then(Json::as_str).with_context(|| format!("config: {k}"))
        };
        let usize_at = |k: &str| -> Result<usize> {
            j.get(k).and_then(Json::as_usize).with_context(|| format!("config: {k}"))
        };
        let f64_at = |k: &str| -> Result<f64> {
            j.get(k).and_then(Json::as_f64).with_context(|| format!("config: {k}"))
        };
        let round = match j.get("round") {
            Some(r) => RoundPolicy::from_json(r)?,
            // legacy flat layout (and pre-scheduler configs, where the
            // absent keys mean exactly the strict synchronous policy)
            None => {
                let mut p = RoundPolicy::strict_sync();
                p.cohort.participation = match j.get("participation") {
                    Some(Json::Null) | None => 1.0,
                    Some(v) => v.as_f64().context("config: participation")? as f32,
                };
                p.cohort.deadline = match j.get("round_deadline") {
                    Some(Json::Null) | None => None,
                    Some(v) => Some(v.as_f64().context("config: round_deadline")?),
                };
                p.tolerance.quorum = match j.get("quorum") {
                    Some(Json::Null) | None => 1.0,
                    Some(v) => v.as_f64().context("config: quorum")? as f32,
                };
                p.tolerance.round_timeout = match j.get("round_timeout") {
                    Some(Json::Null) | None => None,
                    Some(v) => Some(v.as_f64().context("config: round_timeout")?),
                };
                p.tolerance.staleness = match j.get("staleness") {
                    Some(Json::Null) | None => 0,
                    Some(v) => v.as_usize().context("config: staleness")? as u32,
                };
                p.pipeline.fold_overlap =
                    j.get("fold_overlap").and_then(Json::as_bool).unwrap_or(true);
                p.pipeline.decode_buffers =
                    j.get("decode_buffers").and_then(Json::as_usize).unwrap_or(0);
                p.pipeline.codec = match j.get("codec").and_then(Json::as_str) {
                    Some(s) => CodecMode::parse(s)?,
                    None => CodecMode::Narrow,
                };
                p
            }
        };
        let cfg = RunConfig {
            model: str_at("model")?.to_string(),
            dataset: DatasetKind::parse(str_at("dataset")?)?,
            policy: PolicyConfig::parse(str_at("policy")?)?,
            rounds: usize_at("rounds")?,
            lr: f64_at("lr")? as f32,
            sharding: Sharding::parse(str_at("sharding")?)?,
            seed: f64_at("seed")? as u64,
            eval_every: usize_at("eval_every")?,
            train_size: usize_at("train_size")?,
            test_size: usize_at("test_size")?,
            artifacts_dir: str_at("artifacts_dir")?.to_string(),
            data_dir: str_at("data_dir")?.to_string(),
            target_accuracy: match j.get("target_accuracy") {
                Some(Json::Null) | None => None,
                Some(v) => Some(v.as_f64().context("config: target_accuracy")? as f32),
            },
            error_feedback: j
                .get("error_feedback")
                .and_then(Json::as_bool)
                .unwrap_or(false),
            // absent in pre-banking configs: fp32 residuals
            ef_bits: j.get("ef_bits").and_then(Json::as_usize).unwrap_or(0) as u32,
            // both absent in pre-threading configs: default sequentially
            // compatible values (auto threads, streaming aggregation)
            threads: j.get("threads").and_then(Json::as_usize).unwrap_or(0),
            aggregate: match j.get("aggregate").and_then(Json::as_str) {
                Some(s) => AggregateMode::parse(s)?,
                None => AggregateMode::Streaming,
            },
            // absent in pre-sharding configs: auto everywhere
            agg_shards: j.get("agg_shards").and_then(Json::as_usize).unwrap_or(0),
            eval_threads: j.get("eval_threads").and_then(Json::as_usize).unwrap_or(0),
            round,
            sim_latency: match j.get("sim_latency").and_then(Json::as_str) {
                Some(s) => LatencyProfile::parse(s)?,
                None => LatencyProfile::Off,
            },
            sim_faults: match j.get("sim_faults").and_then(Json::as_str) {
                Some(s) => FaultProfile::parse(s)?,
                None => FaultProfile::Off,
            },
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// [`Self::from_json`] over JSON text.
    pub fn from_json_str(s: &str) -> Result<RunConfig> {
        Self::from_json(&Json::parse(s)?)
    }

    /// Reject configurations no run could execute.  Round-behavior
    /// checks live in [`RoundPolicy::validate`] (one place, whether the
    /// policy arrived via the builder, JSON, or direct construction).
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.rounds > 0, "rounds must be positive");
        anyhow::ensure!(self.lr > 0.0 && self.lr.is_finite(), "lr must be positive");
        anyhow::ensure!(self.eval_every > 0, "eval_every must be positive");
        anyhow::ensure!(self.train_size > 0 && self.test_size > 0, "dataset sizes");
        if let Some(a) = self.target_accuracy {
            anyhow::ensure!((0.0..=1.0).contains(&a), "target accuracy in [0,1]");
        }
        anyhow::ensure!(self.ef_bits <= 8, "ef_bits must be in 0..=8");
        if self.ef_bits > 0 {
            anyhow::ensure!(
                self.error_feedback,
                "ef_bits > 0 banks the error-feedback residual and so \
                 requires --error-feedback"
            );
        }
        if (1..=16).contains(&self.round.budget.downlink_bits) {
            anyhow::ensure!(
                self.error_feedback,
                "a quantized downlink (--downlink-bits 1..=16) is lossy and \
                 requires the error-feedback residual loop (--error-feedback); \
                 use 32 for a lossless fp32 ledger"
            );
        }
        if self.round.budget.bit_budget > 0 {
            anyhow::ensure!(
                self.error_feedback,
                "--bit-budget clamps client bit widths below the policy's \
                 choice and requires --error-feedback to compensate"
            );
        }
        self.round.validate(&self.sim_latency)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        for m in ["mlp", "vanilla_cnn", "cnn4", "resnet18"] {
            let c = RunConfig::default_for(m);
            c.validate().unwrap();
            let want = if m == "resnet18" { 0.2 } else { 0.1 };
            assert_eq!(c.lr, want); // paper §V-A (+ documented resnet substitution)
        }
    }

    #[test]
    fn json_roundtrip() {
        let mut c = RunConfig::default_for("cnn4");
        c.policy = PolicyConfig::AdaQuantFl { s0: 4 };
        c.sharding = Sharding::Dirichlet { alpha: 0.5 };
        c.target_accuracy = Some(0.8);
        c.error_feedback = true;
        c.ef_bits = 6;
        c.threads = 6;
        c.aggregate = AggregateMode::Fused;
        c.agg_shards = 8;
        c.eval_threads = 3;
        c.round = RoundPolicy::builder()
            .participation(0.25)
            .deadline(3.5)
            .quorum(0.5)
            .round_timeout(7.5)
            .staleness(2)
            .fold_overlap(false)
            .decode_buffers(4)
            .codec(CodecMode::Reference)
            .bit_budget((1u64 << 60) + 3) // past 2^53: the string codec is load-bearing
            .downlink_bits(6)
            .latency_context(LatencyProfile::LogNormal { median: 1.5, sigma: 0.75 })
            .build()
            .unwrap();
        c.sim_latency = LatencyProfile::LogNormal { median: 1.5, sigma: 0.75 };
        c.sim_faults = FaultProfile::Stall { p: 0.125, secs: 2.5 };
        let j = c.to_json();
        let back = RunConfig::from_json(&j).unwrap();
        assert_eq!(c, back);
        // and through text
        let back2 = RunConfig::from_json_str(&j.to_string_pretty()).unwrap();
        assert_eq!(c, back2);
        // and a tree-topology config
        let mut c = RunConfig::default_for("mlp");
        c.round = RoundPolicy::builder().fanout(4).build().unwrap();
        let back = RunConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c, back);
        assert_eq!(back.round.topology.fanout, 4);
    }

    #[test]
    fn builder_cross_validates_at_construction() {
        // staleness without quorum mode: the semi-sync window cannot
        // open if every round must wait for everyone
        let e = RoundPolicy::builder().staleness(2).build();
        assert!(e.is_err(), "staleness requires quorum mode");
        assert!(e.unwrap_err().to_string().contains("quorum mode"));
        // either quorum < 1 or a timeout turns quorum mode on
        assert!(RoundPolicy::builder().staleness(2).quorum(0.5).build().is_ok());
        assert!(RoundPolicy::builder().staleness(2).round_timeout(10.0).build().is_ok());
        // deadline needs a spreading latency profile as build context
        assert!(RoundPolicy::builder().deadline(2.0).build().is_err());
        assert!(RoundPolicy::builder()
            .deadline(2.0)
            .latency_context(LatencyProfile::LogNormal { median: 1.0, sigma: 0.0 })
            .build()
            .is_err());
        assert!(RoundPolicy::builder()
            .deadline(2.0)
            .latency_context(LatencyProfile::Uniform { lo: 0.5, hi: 1.5 })
            .build()
            .is_ok());
        // range checks moved out of scattered call sites
        assert!(RoundPolicy::builder().participation(0.0).build().is_err());
        assert!(RoundPolicy::builder().participation(1.5).build().is_err());
        assert!(RoundPolicy::builder().quorum(0.0).build().is_err());
        assert!(RoundPolicy::builder().quorum(1.5).build().is_err());
        assert!(RoundPolicy::builder().round_timeout(0.0).build().is_err());
        assert!(RoundPolicy::builder().deadline(-1.0).build().is_err());
    }

    #[test]
    fn rejects_invalid() {
        let mut c = RunConfig::default_for("mlp");
        c.rounds = 0;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default_for("mlp");
        c.lr = -0.5;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default_for("mlp");
        c.target_accuracy = Some(2.0);
        assert!(c.validate().is_err());
        let mut c = RunConfig::default_for("mlp");
        c.round.cohort.participation = 0.0;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default_for("mlp");
        c.round.cohort.participation = 1.5;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default_for("mlp");
        c.round.cohort.deadline = Some(-1.0);
        assert!(c.validate().is_err());
        // a deadline without a latency model would bias cohorts to low
        // ids (all candidates tie) — rejected
        let mut c = RunConfig::default_for("mlp");
        c.round.cohort.deadline = Some(2.0);
        assert!(c.validate().is_err());
        c.sim_latency = LatencyProfile::LogNormal { median: 1.0, sigma: 0.0 };
        assert!(c.validate().is_err(), "sigma 0 is constant — same bias as off");
        c.sim_latency = LatencyProfile::Uniform { lo: 0.5, hi: 1.5 };
        assert!(c.validate().is_ok());
        let mut c = RunConfig::default_for("mlp");
        c.round.tolerance.round_timeout = Some(0.0);
        assert!(c.validate().is_err());
        let mut c = RunConfig::default_for("mlp");
        c.round.tolerance.quorum = 0.0;
        assert!(c.validate().is_err());
        c.round.tolerance.quorum = 1.5;
        assert!(c.validate().is_err());
        c.round.tolerance.quorum = 0.5;
        assert!(c.validate().is_ok());
        // a directly-mutated policy (no builder) is still caught
        let mut c = RunConfig::default_for("mlp");
        c.round.tolerance.staleness = 3;
        assert!(c.validate().is_err(), "staleness without quorum mode");
        c.round.tolerance.quorum = 0.5;
        assert!(c.validate().is_ok());
        // fanout: 0 or >= 2 (a 1-ary tree is just the flat topology with
        // extra hops)
        assert!(RoundPolicy::builder().fanout(1).build().is_err());
        assert!(RoundPolicy::builder().fanout(2).build().is_ok());
        let mut c = RunConfig::default_for("mlp");
        c.round.topology.fanout = 1;
        assert!(c.validate().is_err());
        // tree topology composes with simulated leaf faults: draws are
        // per (seed, client, round) and failed leaves are excluded at
        // their aggregator, so the two knobs are independent
        let mut c = RunConfig::default_for("mlp");
        c.round.topology.fanout = 2;
        assert!(c.validate().is_ok());
        c.sim_faults = FaultProfile::Stall { p: 0.1, secs: 1.0 };
        c.round.tolerance.round_timeout = Some(2.0);
        assert!(c.validate().is_ok(), "fanout > 0 composes with sim_faults");
        // ef_bits: bounded and gated on error feedback
        let mut c = RunConfig::default_for("mlp");
        c.ef_bits = 4;
        assert!(c.validate().is_err(), "ef_bits without error_feedback");
        c.error_feedback = true;
        assert!(c.validate().is_ok());
        c.ef_bits = 9;
        assert!(c.validate().is_err(), "ef_bits out of range");
        // downlink_bits: 0 | 1..=16 | 32, and a lossy width needs EF
        assert!(RoundPolicy::builder().downlink_bits(17).build().is_err());
        assert!(RoundPolicy::builder().downlink_bits(40).build().is_err());
        assert!(RoundPolicy::builder().downlink_bits(16).build().is_ok());
        assert!(RoundPolicy::builder().downlink_bits(32).build().is_ok());
        let mut c = RunConfig::default_for("mlp");
        c.round.budget.downlink_bits = 4;
        assert!(c.validate().is_err(), "quantized downlink without error_feedback");
        c.error_feedback = true;
        assert!(c.validate().is_ok());
        // 32 is the lossless ledger mode: no EF requirement
        let mut c = RunConfig::default_for("mlp");
        c.round.budget.downlink_bits = 32;
        assert!(c.validate().is_ok());
        // bit_budget clamps below the policy and so also needs EF
        let mut c = RunConfig::default_for("mlp");
        c.round.budget.bit_budget = 100_000;
        assert!(c.validate().is_err(), "bit budget without error_feedback");
        c.error_feedback = true;
        assert!(c.validate().is_ok());
        // and it composes with banked EF residuals
        c.ef_bits = 4;
        assert!(c.validate().is_ok(), "bit budget composes with --ef-bits");
    }

    #[test]
    fn missing_threading_fields_default_compatibly() {
        // configs serialized before the parallel round engine existed
        let c = RunConfig::default_for("mlp");
        let mut j = c.to_json();
        if let Json::Obj(o) = &mut j {
            o.remove("threads");
            o.remove("aggregate");
            o.remove("agg_shards");
            o.remove("eval_threads");
            o.remove("round");
            o.remove("sim_latency");
            o.remove("sim_faults");
        }
        let back = RunConfig::from_json(&j).unwrap();
        assert_eq!(back.threads, 0);
        assert_eq!(back.aggregate, AggregateMode::Streaming);
        assert_eq!(back.agg_shards, 0);
        assert_eq!(back.eval_threads, 0);
        assert_eq!(back.round, RoundPolicy::strict_sync());
        assert_eq!(back.sim_latency, LatencyProfile::Off);
        assert_eq!(back.sim_faults, FaultProfile::Off);
        assert_eq!(back.ef_bits, 0, "pre-banking configs bank in fp32");
        // a nested round object without the topology group (pre-tree
        // serializers) defaults to the flat topology
        let c = RunConfig::default_for("mlp");
        let mut j = c.to_json();
        if let Json::Obj(o) = &mut j {
            o.remove("ef_bits");
            if let Some(Json::Obj(r)) = o.get_mut("round") {
                r.remove("topology");
            }
        }
        let back = RunConfig::from_json(&j).unwrap();
        assert_eq!(back.round.topology.fanout, 0);
        // a round object without the budget group (pre-budget
        // serializers) defaults both knobs off
        let c = RunConfig::default_for("mlp");
        let mut j = c.to_json();
        if let Json::Obj(o) = &mut j {
            if let Some(Json::Obj(r)) = o.get_mut("round") {
                r.remove("budget");
            }
        }
        let back = RunConfig::from_json(&j).unwrap();
        assert_eq!(back.round.budget.bit_budget, 0);
        assert_eq!(back.round.budget.downlink_bits, 0);
    }

    #[test]
    fn legacy_flat_round_fields_still_deserialize() {
        // A config serialized before RoundPolicy existed spelled the
        // round knobs as flat top-level keys; the parser must map them
        // into the nested policy unchanged.
        let legacy = r#"{
            "model": "mlp", "dataset": "fashion_mnist", "policy": "feddq:0.005",
            "rounds": 8, "lr": 0.1, "sharding": "iid", "seed": 17,
            "eval_every": 1, "train_size": 600, "test_size": 500,
            "artifacts_dir": "artifacts", "data_dir": "data",
            "target_accuracy": null, "error_feedback": false,
            "threads": 0, "aggregate": "streaming", "agg_shards": 0,
            "eval_threads": 0,
            "decode_buffers": 3, "fold_overlap": false, "codec": "reference",
            "participation": 0.5, "round_deadline": null,
            "sim_latency": "off", "sim_faults": "stall:0.25:2.5",
            "round_timeout": 12.5, "quorum": 0.5
        }"#;
        let cfg = RunConfig::from_json_str(legacy).unwrap();
        assert_eq!(cfg.round.cohort.participation, 0.5);
        assert_eq!(cfg.round.cohort.deadline, None);
        assert_eq!(cfg.round.tolerance.quorum, 0.5);
        assert_eq!(cfg.round.tolerance.round_timeout, Some(12.5));
        assert_eq!(cfg.round.tolerance.staleness, 0, "legacy configs are strict-sync");
        assert!(!cfg.round.pipeline.fold_overlap);
        assert_eq!(cfg.round.pipeline.decode_buffers, 3);
        assert_eq!(cfg.round.pipeline.codec, CodecMode::Reference);
        assert_eq!(cfg.sim_faults, FaultProfile::Stall { p: 0.25, secs: 2.5 });
    }

    #[test]
    fn resolved_threads_clamps() {
        let mut c = RunConfig::default_for("mlp");
        c.threads = 64;
        assert_eq!(c.resolved_threads(10), 10);
        c.threads = 3;
        assert_eq!(c.resolved_threads(10), 3);
        c.threads = 0;
        let auto = c.resolved_threads(10);
        assert!((1..=10).contains(&auto));
    }

    #[test]
    fn resolved_server_knobs_follow_pool_and_clamp() {
        let mut c = RunConfig::default_for("mlp");
        // auto: both server knobs follow the pool
        assert_eq!(c.resolved_agg_shards(4), 4);
        assert_eq!(c.resolved_eval_threads(4), 4);
        // explicit values win, degenerate ones clamp
        c.agg_shards = 7;
        assert_eq!(c.resolved_agg_shards(4), 7);
        c.agg_shards = 100_000;
        assert_eq!(c.resolved_agg_shards(4), 256);
        c.eval_threads = 5;
        assert_eq!(c.resolved_eval_threads(4), 5);
        c.eval_threads = 100_000;
        assert_eq!(c.resolved_eval_threads(4), 256);
    }
}
