//! Deterministic PRNG: SplitMix64 for seeding, Xoshiro256** as the
//! workhorse generator (Blackman & Vigna).  Every stochastic choice in the
//! coordinator (data synthesis, sharding, shuffling, seeds handed to the
//! XLA executables) flows from one root seed so entire federated runs are
//! bit-reproducible.

/// SplitMix64 step — used to expand a single `u64` seed into generator state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Xoshiro256** — fast, high-quality, 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed from a single `u64` via SplitMix64 (the reference procedure).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream for a labeled subsystem.
    ///
    /// Streams derived with different labels are statistically independent;
    /// the same (seed, label) always yields the same stream.
    pub fn derive(&self, label: &str) -> Rng {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325; // FNV-1a
        for b in label.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
        let mut sm = self.s[0] ^ h;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next 64 random bits (the core xoshiro256** step).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next 32 random bits (the high half of [`Self::next_u64`]).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)` with 53 bits of mantissa.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, n)` (Lemire's unbiased method).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity — data synthesis is not on the hot path).
    pub fn next_normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
        }
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample from Gamma(alpha, 1), alpha > 0 (Marsaglia–Tsang, with the
    /// alpha < 1 boost).  Used for Dirichlet non-IID sharding.
    pub fn next_gamma(&mut self, alpha: f64) -> f64 {
        if alpha < 1.0 {
            let u = self.next_f64().max(f64::MIN_POSITIVE);
            return self.next_gamma(alpha + 1.0) * u.powf(1.0 / alpha);
        }
        let d = alpha - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.next_normal() as f64;
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.next_f64().max(f64::MIN_POSITIVE);
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v3 + v3.ln())
            {
                return d * v3;
            }
        }
    }

    /// Dirichlet(alpha * 1_k) sample of length `k`.
    pub fn next_dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let gs: Vec<f64> = (0..k).map(|_| self.next_gamma(alpha)).collect();
        let sum: f64 = gs.iter().sum::<f64>().max(f64::MIN_POSITIVE);
        gs.into_iter().map(|g| g / sum).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn derive_is_stable_and_distinct() {
        let root = Rng::new(7);
        let mut a1 = root.derive("data");
        let mut a2 = root.derive("data");
        let mut b = root.derive("shard");
        let x = a1.next_u64();
        assert_eq!(x, a2.next_u64());
        assert_ne!(x, b.next_u64());
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x), "{x}");
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(4);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            let x = r.below(7) as usize;
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_normal() as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(6);
        for &alpha in &[0.1, 0.5, 1.0, 10.0] {
            let d = r.next_dirichlet(alpha, 10);
            let s: f64 = d.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(d.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(8);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
