//! Minimal JSON: a recursive-descent parser and a writer over a dynamic
//! [`Json`] value.  Used for the artifact manifest, run configs and metric
//! dumps (serde is unavailable offline — DESIGN.md §5).
//!
//! Supports the full JSON grammar except unicode escapes beyond the BMP
//! surrogate-pair handling (accepted, decoded) and arbitrary-precision
//! numbers (parsed as f64, which is exact for every integer the manifest
//! contains).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.  Objects use a BTreeMap so serialization is
/// deterministic (useful for golden tests and config fingerprints).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (f64-backed; see module docs on exactness).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys — deterministic serialization).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// The boolean value, if this is a `Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The numeric value, if this is a `Num`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The value as a usize, if it is a non-negative whole number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 && n <= usize::MAX as f64 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    /// The string slice, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The element slice, if this is an `Arr`.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// The key map, if this is an `Obj`.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `None` on non-objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Path lookup: `j.at(&["models", "mlp", "d"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Render compactly (no whitespace).
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        write_value(self, &mut s, None, 0);
        s
    }

    /// Render with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        write_value(self, &mut s, Some(2), 0);
        s
    }

    /// Parse one complete JSON document (rejects trailing characters).
    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after value"));
        }
        Ok(v)
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Self {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// A JSON parse failure with its byte position.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    /// Byte offset of the failure in the input.
    pub pos: usize,
    /// Human-readable description.
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for ParseError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while let Some(b) = self.peek() {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected character {:?}", c as char))),
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => return Ok(out),
                b'\\' => {
                    match self.bump().ok_or_else(|| self.err("unterminated escape"))? {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                    return Err(self.err("lone high surrogate"));
                                }
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err(self.err("invalid low surrogate"));
                                }
                                0x10000 + (((hi - 0xD800) as u32) << 10) + (lo - 0xDC00) as u32
                            } else {
                                hi as u32
                            };
                            out.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("invalid codepoint"))?,
                            );
                        }
                        c => {
                            return Err(
                                self.err(&format!("invalid escape \\{:?}", c as char))
                            )
                        }
                    }
                }
                b if b < 0x20 => return Err(self.err("control character in string")),
                b if b < 0x80 => out.push(b as char),
                b => {
                    // multi-byte UTF-8: copy continuation bytes verbatim
                    let len = if b >= 0xF0 {
                        4
                    } else if b >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump().ok_or_else(|| self.err("truncated utf-8"))?;
                    }
                    let s = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u16, ParseError> {
        let mut v: u16 = 0;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("invalid hex digit"))?;
            v = (v << 4) | d as u16;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn write_value(v: &Json, out: &mut String, indent: Option<usize>, depth: usize) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(true) => out.push_str("true"),
        Json::Bool(false) => out.push_str("false"),
        Json::Num(n) => write_number(*n, out),
        Json::Str(s) => write_string(s, out),
        Json::Arr(a) => {
            if a.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_value(item, out, indent, depth + 1);
            }
            newline_indent(out, indent, depth);
            out.push(']');
        }
        Json::Obj(o) => {
            if o.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_string(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_value(val, out, indent, depth + 1);
            }
            newline_indent(out, indent, depth);
            out.push('}');
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_number(n: f64, out: &mut String) {
    if n.is_finite() && n.fract() == 0.0 && n.abs() < 9.0e15 {
        out.push_str(&format!("{}", n as i64));
    } else if n.is_finite() {
        out.push_str(&format!("{n}"));
    } else {
        out.push_str("null"); // JSON has no NaN/Inf
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(j.at(&["c"]).unwrap().as_str(), Some("x"));
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"models":{"mlp":{"d":101770,"segs":[{"n":"fc1.w","s":100352}],"f":0.005,"ok":true,"x":null}}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(j, j2);
        let j3 = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, j3);
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(j.as_str(), Some("é😀"));
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,]", "{\"a\":}", "tru", "1.2.3", "\"\\x\"", "{}x"] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::Num(101770.0).to_string_compact(), "101770");
        assert_eq!(Json::Num(0.005).to_string_compact(), "0.005");
    }
}
