//! Small statistics helpers shared by metrics, benches and tests.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation; 0.0 for fewer than two samples.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Linear-interpolated percentile, `p` in `[0, 100]`.  Sorts a copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Minimum value; +inf for empty input.
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

/// Maximum value; -inf for empty input.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Simple linear regression `y = a + b x`; returns `(a, b)`.
pub fn linreg(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        den += (x - mx) * (x - mx);
    }
    if den == 0.0 || n < 2.0 {
        (my, 0.0)
    } else {
        let b = num / den;
        (my - b * mx, b)
    }
}

/// Exponential moving average over a series (smoothing for loss curves).
pub fn ema(xs: &[f64], alpha: f64) -> Vec<f64> {
    let mut out = Vec::with_capacity(xs.len());
    let mut acc = None;
    for &x in xs {
        let next = match acc {
            None => x,
            Some(prev) => alpha * x + (1.0 - alpha) * prev,
        };
        out.push(next);
        acc = Some(next);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn linreg_exact_line() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [1.0, 3.0, 5.0, 7.0];
        let (a, b) = linreg(&xs, &ys);
        assert!((a - 1.0).abs() < 1e-12);
        assert!((b - 2.0).abs() < 1e-12);
    }

    #[test]
    fn ema_monotone_smoothing() {
        let xs = [10.0, 0.0, 10.0, 0.0];
        let sm = ema(&xs, 0.5);
        assert_eq!(sm[0], 10.0);
        assert!(sm.windows(2).all(|w| (w[0] - w[1]).abs() <= 10.0));
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
