//! From-scratch substrates: the build environment has no crate registry
//! access, so JSON, PRNG, CLI parsing, stats, logging, the micro-bench
//! harness and the property-testing harness are all implemented here
//! (DESIGN.md §5).

pub mod bench;
pub mod json;
pub mod log;
pub mod prop;
pub mod rng;
pub mod stats;
