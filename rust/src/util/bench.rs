//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup, timed iterations, outlier-robust statistics and a
//! stable one-line report format that the `cargo bench` targets print and
//! `bench_output.txt` archives.  Deliberately minimal: monotonic clock,
//! median/p5/p95, and a throughput helper.

use std::time::{Duration, Instant};

use super::stats;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Case name as printed.
    pub name: String,
    /// Iterations measured (after warmup).
    pub iters: usize,
    /// Median iteration time.
    pub median: Duration,
    /// 5th-percentile iteration time.
    pub p05: Duration,
    /// 95th-percentile iteration time.
    pub p95: Duration,
    /// Mean iteration time.
    pub mean: Duration,
    /// Optional bytes processed per iteration (enables GB/s reporting).
    pub bytes_per_iter: Option<u64>,
}

impl BenchResult {
    /// Median throughput in GB/s, when `bytes_per_iter` is set.
    pub fn throughput_gbps(&self) -> Option<f64> {
        self.bytes_per_iter.map(|b| {
            b as f64 / self.median.as_secs_f64() / 1.0e9
        })
    }

    /// The human-readable one-line summary benches print.
    pub fn report_line(&self) -> String {
        let thr = match self.throughput_gbps() {
            Some(gbps) => format!("  {gbps:8.3} GB/s"),
            None => String::new(),
        };
        format!(
            "{:<44} {:>12} median  [{:>12} .. {:>12}]  {} iters{}",
            self.name,
            fmt_dur(self.median),
            fmt_dur(self.p05),
            fmt_dur(self.p95),
            self.iters,
            thr
        )
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

/// Benchmark runner with a global time budget per case.
pub struct Bencher {
    /// Warmup time before measurement starts.
    pub warmup: Duration,
    /// Measurement time budget per case.
    pub budget: Duration,
    /// Lower bound on measured iterations.
    pub min_iters: usize,
    /// Upper bound on measured iterations.
    pub max_iters: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(2),
            min_iters: 5,
            max_iters: 10_000,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    /// Short warmup/budget preset for smoke runs.
    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(50),
            budget: Duration::from_millis(500),
            min_iters: 3,
            max_iters: 2_000,
            ..Default::default()
        }
    }

    /// Time `f` repeatedly; `f` returns a value that is black-boxed.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &BenchResult {
        self.bench_bytes(name, None, &mut f)
    }

    /// Like [`Self::bench`] but annotates bytes/iter for GB/s reporting.
    pub fn bench_bytes<T, F: FnMut() -> T>(
        &mut self,
        name: &str,
        bytes_per_iter: Option<u64>,
        f: &mut F,
    ) -> &BenchResult {
        // Warmup.
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            black_box(f());
        }
        // Timed iterations.
        let mut samples: Vec<f64> = Vec::new();
        let b0 = Instant::now();
        while (b0.elapsed() < self.budget || samples.len() < self.min_iters)
            && samples.len() < self.max_iters
        {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        let result = BenchResult {
            name: name.to_string(),
            iters: samples.len(),
            median: Duration::from_secs_f64(stats::percentile(&samples, 50.0)),
            p05: Duration::from_secs_f64(stats::percentile(&samples, 5.0)),
            p95: Duration::from_secs_f64(stats::percentile(&samples, 95.0)),
            mean: Duration::from_secs_f64(stats::mean(&samples)),
            bytes_per_iter,
        };
        println!("{}", result.report_line());
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Every result measured so far, in run order.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// Prevent the optimizer from eliding benchmark bodies.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Shared header printed by every bench binary.
pub fn bench_header(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn produces_sane_stats() {
        let mut b = Bencher {
            warmup: Duration::from_millis(1),
            budget: Duration::from_millis(20),
            min_iters: 3,
            max_iters: 1000,
            results: Vec::new(),
        };
        let r = b.bench("spin", || {
            let mut acc = 0u64;
            for i in 0..1_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(r.iters >= 3);
        assert!(r.p05 <= r.median && r.median <= r.p95);
    }

    #[test]
    fn throughput_math() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            median: Duration::from_secs(1),
            p05: Duration::from_secs(1),
            p95: Duration::from_secs(1),
            mean: Duration::from_secs(1),
            bytes_per_iter: Some(2_000_000_000),
        };
        assert!((r.throughput_gbps().unwrap() - 2.0).abs() < 1e-9);
    }
}
