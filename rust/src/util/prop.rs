//! Property-testing mini-framework (proptest is unavailable offline).
//!
//! A property is a closure over a [`Gen`] (seeded value source); the
//! runner executes it across many seeds and reports the first failing seed
//! so failures are reproducible.  There is no automatic shrinking — cases
//! are kept small by construction instead (sizes drawn from bounded
//! ranges), which in practice localizes failures well enough for this
//! codebase.

use super::rng::Rng;

/// Seeded generator handed to each property case.
pub struct Gen {
    /// The case's seeded RNG (draw directly for raw bits).
    pub rng: Rng,
    /// Case index (0..cases); useful for size ramping.
    pub case: usize,
}

impl Gen {
    /// Integer in `[lo, hi]` inclusive.
    pub fn int(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.rng.below((hi - lo + 1) as u64) as i64
    }

    /// usize in `[lo, hi]` inclusive.
    pub fn size(&mut self, lo: usize, hi: usize) -> usize {
        self.int(lo as i64, hi as i64) as usize
    }

    /// f32 uniform in `[lo, hi)`.
    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.next_f32() * (hi - lo)
    }

    /// f32 from a widened distribution exercising magnitudes and signs:
    /// mixes uniform, exponential-scale and exact-zero values.
    pub fn f32_wide(&mut self) -> f32 {
        match self.rng.below(10) {
            0 => 0.0,
            1..=3 => self.f32(-1.0, 1.0),
            4..=6 => {
                let exp = self.int(-20, 20) as f32;
                self.f32(-1.0, 1.0) * exp.exp2()
            }
            _ => self.rng.next_normal(),
        }
    }

    /// Fair coin flip.
    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Vec of length `len` built from `f`.
    pub fn vec_of<T>(&mut self, len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        (0..len).map(|_| f(self)).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u64) as usize]
    }
}

/// Run `cases` property cases; panics with the failing seed on error.
///
/// The property returns `Result<(), String>`; `Err` fails the case with a
/// message.  Panics inside the property also fail (and surface the seed via
/// the runner's own panic message ordering).
pub fn check(name: &str, cases: usize, mut prop: impl FnMut(&mut Gen) -> Result<(), String>) {
    // Fixed base so CI runs are reproducible; per-case seeds still vary.
    let base = 0x5EED_F00D_u64;
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut g = Gen {
            rng: Rng::new(seed),
            case,
        };
        if let Err(msg) = prop(&mut g) {
            panic!("property {name:?} failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Assert two f32 slices are elementwise close.
pub fn assert_close(a: &[f32], b: &[f32], atol: f32, rtol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs();
        if (x - y).abs() > tol && !(x.is_nan() && y.is_nan()) {
            return Err(format!("elem {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("add-commutes", 50, |g| {
            let a = g.int(-1000, 1000);
            let b = g.int(-1000, 1000);
            if a + b == b + a {
                Ok(())
            } else {
                Err("math is broken".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "failed at case")]
    fn check_reports_failures() {
        check("always-fails", 3, |_| Err("nope".into()));
    }

    #[test]
    fn wide_floats_cover_zero_and_large() {
        let mut g = Gen {
            rng: Rng::new(1),
            case: 0,
        };
        let xs: Vec<f32> = (0..2000).map(|_| g.f32_wide()).collect();
        assert!(xs.iter().any(|&x| x == 0.0));
        assert!(xs.iter().any(|&x| x.abs() > 100.0));
        assert!(xs.iter().any(|&x| x.abs() < 1e-3 && x != 0.0));
    }

    #[test]
    fn assert_close_catches_mismatch() {
        assert!(assert_close(&[1.0], &[1.0001], 0.0, 1e-3).is_ok());
        assert!(assert_close(&[1.0], &[2.0], 0.1, 0.1).is_err());
        assert!(assert_close(&[1.0, 2.0], &[1.0], 0.1, 0.1).is_err());
    }
}
