//! Leveled stderr logger with a global level switch.
//!
//! Tiny by design: FL runs emit structured metrics through [`crate::metrics`];
//! this logger is for human-facing progress and diagnostics only.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Process start, for relative timestamps.
fn start() -> Instant {
    use std::sync::OnceLock;
    static START: OnceLock<Instant> = OnceLock::new();
    *START.get_or_init(Instant::now)
}

pub fn set_level(level: Level) {
    start(); // pin t=0 at first configuration
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

pub fn enabled(l: Level) -> bool {
    l <= level()
}

pub fn log(l: Level, target: &str, msg: &str) {
    if !enabled(l) {
        return;
    }
    let t = start().elapsed().as_secs_f64();
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{t:9.3}s {tag} {target}] {msg}");
}

#[macro_export]
macro_rules! info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, $target, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn_ {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, $target, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, $target, &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Trace);
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
