//! Leveled stderr logger with a global level switch.
//!
//! Tiny by design: FL runs emit structured metrics through [`crate::metrics`];
//! this logger is for human-facing progress and diagnostics only.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

/// Log severity, most severe first; a message prints when its level is
/// at or below the global switch ([`set_level`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    /// Unrecoverable problems.
    Error = 0,
    /// Suspicious but survivable conditions.
    Warn = 1,
    /// Human-facing progress (the default level).
    Info = 2,
    /// Diagnostics enabled by `--verbose`.
    Debug = 3,
    /// Firehose detail.
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Process start, for relative timestamps.
fn start() -> Instant {
    use std::sync::OnceLock;
    static START: OnceLock<Instant> = OnceLock::new();
    *START.get_or_init(Instant::now)
}

/// Set the process-wide log level.
pub fn set_level(level: Level) {
    start(); // pin t=0 at first configuration
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// The current process-wide log level.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Would a message at level `l` print right now?
pub fn enabled(l: Level) -> bool {
    l <= level()
}

/// Print one line to stderr (relative timestamp, level tag, target),
/// if `l` is enabled.  Prefer the `info!` / `warn_!` / `debug!` macros.
pub fn log(l: Level, target: &str, msg: &str) {
    if !enabled(l) {
        return;
    }
    let t = start().elapsed().as_secs_f64();
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{t:9.3}s {tag} {target}] {msg}");
}

/// Log at [`Level::Info`]: `info!("target", "fmt {}", args)`.
#[macro_export]
macro_rules! info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, $target, &format!($($arg)*))
    };
}

/// Log at [`Level::Warn`] (trailing underscore dodges `core::warn`).
#[macro_export]
macro_rules! warn_ {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, $target, &format!($($arg)*))
    };
}

/// Log at [`Level::Debug`] (shown under `--verbose`).
#[macro_export]
macro_rules! debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, $target, &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Trace);
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
