//! Metrics: per-round records, the communication ledger and CSV/JSON
//! emitters used by the figure/table benches.
//!
//! The JSON schema round-trips: [`RunReport::to_json`] /
//! [`RunReport::from_json`] are inverses (modulo the NaN-as-`null`
//! convention for unevaluated rounds), and the `u64` bit counters
//! travel as exact decimal strings ([`u64_json`] / [`json_u64`])
//! because the in-tree [`Json`] number type is f64-backed and loses
//! integer exactness above 2^53.

use std::io::Write;

use anyhow::Context;

use crate::util::json::Json;
use crate::Result;

/// Everything measured in one federated round.
#[derive(Clone, Debug)]
pub struct RoundRecord {
    /// Round index (0-based).
    pub round: u32,
    /// Global average training loss (weighted by client sample counts).
    pub train_loss: f32,
    /// Test loss (mean NLL over the server validation set); NaN if the
    /// round was not evaluated.
    pub test_loss: f32,
    /// Test accuracy in [0,1]; NaN if not evaluated.
    pub test_accuracy: f32,
    /// Uplink payload bits this round (sum over clients, packed size).
    pub uplink_bits: u64,
    /// Cumulative uplink bits including this round.
    pub cum_uplink_bits: u64,
    /// Mean bits/element across clients and segments (Fig. 5's y-axis).
    pub mean_bits: f32,
    /// Mean update range across clients and segments (Fig. 1b's y-axis).
    pub mean_range: f32,
    /// Per-segment mean ranges across clients (Fig. 1b per-layer curves).
    pub seg_ranges: Vec<f32>,
    /// Wall-clock seconds spent in this round.
    pub wall_secs: f64,
    /// Seconds in the receive stage; with a pool attached, update
    /// decoding is pipelined into the same window, and with fold
    /// overlap the sharded fold itself also runs here (so `agg_secs`
    /// shrinks to the chunk application — that shift is the overlap).
    pub recv_decode_secs: f64,
    /// Seconds folding the (sharded) accumulator and applying it.
    pub agg_secs: f64,
    /// Seconds in server-side evaluation (0 when the round skipped it).
    pub eval_secs: f64,
    /// Clients that participated in this round (the sampled cohort the
    /// server actually folded; equals the full cohort when
    /// `participation = 1.0`).  0 in legacy reports that predate the
    /// scheduler.
    pub selected: u32,
    /// Candidates the deadline policy sampled but cut (0 without
    /// `--round-deadline`; unsampled clients are not counted).
    pub dropped: u32,
    /// Simulated completion time of the cohort's slowest member under
    /// the configured latency model (0 with the `off` profile).
    pub sim_makespan_secs: f64,
    /// Cohort members whose update never reached the fold this round —
    /// simulated faults (`--sim-faults`), dead sockets, or
    /// `--round-timeout` expiries.  Aggregation weights renormalized
    /// over the `selected - failed` survivors.
    pub failed: u32,
    /// Workers that re-attached mid-run this round via the TCP rejoin
    /// handshake (always 0 in-process).
    pub rejoined: u32,
    /// Banked late updates folded into this round with a staleness
    /// discount (semi-sync mode, `--staleness k > 0`; always 0 in
    /// strict mode).
    pub stale_folded: u32,
    /// Late updates dropped this round for exceeding the staleness
    /// bound (simulated overshoots plus real too-stale socket replies;
    /// always 0 in strict mode).
    pub stale_dropped: u32,
    /// Aggregation-tree depth of the fold that produced this round's
    /// model: 0 for the flat topology (leaves straight into the
    /// server), 2 with one aggregator tier between leaves and server.
    /// A TCP tree run and its in-process virtual-grouping twin report
    /// the same depth.
    pub agg_depth: u32,
    /// Resident server-side per-client state in bytes at the end of
    /// the round: the client arena (samples/flags/EWMA rows) plus, in
    /// in-process runs with `--ef-bits`, the banked residual codes.
    /// 0 in legacy reports that predate the arena.
    pub client_state_bytes: u64,
    /// Aggregator subtrees whose composite handle died mid-round this
    /// round (TCP tree mode only; the member leaves are counted in
    /// `failed` unless the aggregator rejoined in time).  Always 0 on
    /// the flat topology and in-process.
    pub subtree_failed: u32,
    /// Leaves folded via the degraded direct-to-root path this round
    /// after their aggregator stayed dead past the failover deadline
    /// (TCP tree mode only; always 0 otherwise).
    pub degraded: u32,
    /// Broadcast cost this round in bits, counted per dispatched leaf
    /// by the server's fanout-blind analytic ledger: a quantized delta
    /// (`--downlink-bits 1..=16`) costs its payload plus per-segment
    /// headers, a full broadcast (round 0, catch-up, or
    /// `--downlink-bits 32`) costs `d * 32` per leaf.  0 with the knob
    /// off entirely and in legacy reports that predate the downlink.
    pub downlink_bits: u64,
    /// Running total of `downlink_bits` across rounds.
    pub cum_downlink_bits: u64,
}

impl RoundRecord {
    /// True when this round ran server-side evaluation (accuracy is a
    /// number, not the NaN skip marker).
    pub fn evaluated(&self) -> bool {
        !self.test_accuracy.is_nan()
    }

    /// One round as a JSON object (the element type of a report's
    /// `rounds` array).  NaN metrics (unevaluated rounds) emit as
    /// `null`; bit counters emit as exact decimal strings.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("round", Json::from(self.round)),
            ("train_loss", Json::from(self.train_loss as f64)),
            ("test_loss", Json::from(self.test_loss as f64)),
            ("test_acc", Json::from(self.test_accuracy as f64)),
            // decimal strings, not numbers: Json's f64 backing loses
            // exactness above 2^53 and long large-model runs get
            // there — same fix as params_hash's hex string
            ("uplink_bits", u64_json(self.uplink_bits)),
            ("cum_uplink_bits", u64_json(self.cum_uplink_bits)),
            ("mean_bits", Json::from(self.mean_bits as f64)),
            ("mean_range", Json::from(self.mean_range as f64)),
            (
                "seg_ranges",
                Json::Arr(self.seg_ranges.iter().map(|&x| Json::from(x as f64)).collect()),
            ),
            ("wall_secs", Json::from(self.wall_secs)),
            ("recv_decode_secs", Json::from(self.recv_decode_secs)),
            ("agg_secs", Json::from(self.agg_secs)),
            ("eval_secs", Json::from(self.eval_secs)),
            ("selected", Json::from(self.selected)),
            ("dropped", Json::from(self.dropped)),
            ("sim_makespan_secs", Json::from(self.sim_makespan_secs)),
            ("failed", Json::from(self.failed)),
            ("rejoined", Json::from(self.rejoined)),
            ("stale_folded", Json::from(self.stale_folded)),
            ("stale_dropped", Json::from(self.stale_dropped)),
            ("agg_depth", Json::from(self.agg_depth)),
            // decimal string like the bit counters: a million-client
            // arena's byte count is small today, but the schema should
            // not bake in a 2^53 ceiling
            ("client_state_bytes", u64_json(self.client_state_bytes)),
            ("subtree_failed", Json::from(self.subtree_failed)),
            ("degraded", Json::from(self.degraded)),
            ("downlink_bits", u64_json(self.downlink_bits)),
            ("cum_downlink_bits", u64_json(self.cum_downlink_bits)),
        ])
    }

    /// Parse one round object written by [`Self::to_json`].  `null`
    /// metrics come back as NaN; fields introduced after the first
    /// report revision (the per-stage timings, and the scheduler's
    /// `selected` / `dropped` / `sim_makespan_secs`) default to 0 when
    /// absent — but error when present with the wrong type.
    pub fn from_json(j: &Json) -> Result<RoundRecord> {
        let f32_at = |k: &str| -> Result<f32> {
            match j.get(k) {
                Some(Json::Null) | None => Ok(f32::NAN),
                Some(v) => Ok(v.as_f64().with_context(|| format!("round: {k}"))? as f32),
            }
        };
        // `wall_secs` exists in every report version, so missing or
        // mistyped is corruption, not legacy — strict.  The per-stage
        // timings and scheduler fields arrived in later revisions and
        // default to 0 when *absent*; when present they must be
        // numbers.
        let f64_at = |k: &str| -> Result<f64> {
            j.get(k).and_then(Json::as_f64).with_context(|| format!("round: {k}"))
        };
        let f64_opt = |k: &str| -> Result<f64> {
            match j.get(k) {
                None => Ok(0.0),
                Some(v) => v.as_f64().with_context(|| format!("round: {k}")),
            }
        };
        let u64_at = |k: &str| -> Result<u64> {
            j.get(k)
                .and_then(json_u64)
                .with_context(|| format!("round: {k} missing or inexact"))
        };
        Ok(RoundRecord {
            round: j
                .get("round")
                .and_then(Json::as_usize)
                .context("round: round")? as u32,
            train_loss: f32_at("train_loss")?,
            test_loss: f32_at("test_loss")?,
            test_accuracy: f32_at("test_acc")?,
            uplink_bits: u64_at("uplink_bits")?,
            cum_uplink_bits: u64_at("cum_uplink_bits")?,
            mean_bits: f32_at("mean_bits")?,
            mean_range: f32_at("mean_range")?,
            seg_ranges: j
                .get("seg_ranges")
                .and_then(Json::as_arr)
                .context("round: seg_ranges")?
                .iter()
                .map(|v| v.as_f64().map(|x| x as f32).context("round: seg_ranges entry"))
                .collect::<Result<Vec<f32>>>()?,
            wall_secs: f64_at("wall_secs")?,
            recv_decode_secs: f64_opt("recv_decode_secs")?,
            agg_secs: f64_opt("agg_secs")?,
            eval_secs: f64_opt("eval_secs")?,
            selected: match j.get("selected") {
                None => 0,
                Some(v) => v.as_usize().context("round: selected")? as u32,
            },
            dropped: match j.get("dropped") {
                None => 0,
                Some(v) => v.as_usize().context("round: dropped")? as u32,
            },
            sim_makespan_secs: f64_opt("sim_makespan_secs")?,
            failed: match j.get("failed") {
                None => 0,
                Some(v) => v.as_usize().context("round: failed")? as u32,
            },
            rejoined: match j.get("rejoined") {
                None => 0,
                Some(v) => v.as_usize().context("round: rejoined")? as u32,
            },
            stale_folded: match j.get("stale_folded") {
                None => 0,
                Some(v) => v.as_usize().context("round: stale_folded")? as u32,
            },
            stale_dropped: match j.get("stale_dropped") {
                None => 0,
                Some(v) => v.as_usize().context("round: stale_dropped")? as u32,
            },
            agg_depth: match j.get("agg_depth") {
                None => 0,
                Some(v) => v.as_usize().context("round: agg_depth")? as u32,
            },
            client_state_bytes: match j.get("client_state_bytes") {
                None => 0,
                Some(v) => {
                    json_u64(v).context("round: client_state_bytes missing or inexact")?
                }
            },
            subtree_failed: match j.get("subtree_failed") {
                None => 0,
                Some(v) => v.as_usize().context("round: subtree_failed")? as u32,
            },
            degraded: match j.get("degraded") {
                None => 0,
                Some(v) => v.as_usize().context("round: degraded")? as u32,
            },
            downlink_bits: match j.get("downlink_bits") {
                None => 0,
                Some(v) => json_u64(v).context("round: downlink_bits missing or inexact")?,
            },
            cum_downlink_bits: match j.get("cum_downlink_bits") {
                None => 0,
                Some(v) => {
                    json_u64(v).context("round: cum_downlink_bits missing or inexact")?
                }
            },
        })
    }
}

/// A completed run: config label + per-round records.
#[derive(Clone, Debug)]
pub struct RunReport {
    /// Human-readable run label (`RunConfig::label`, `-tcp` suffixed in
    /// serve mode).
    pub label: String,
    /// Model name the run trained.
    pub model: String,
    /// Per-round records in round order.
    pub rounds: Vec<RoundRecord>,
    /// FNV-1a hash over the final global parameters' exact f32 bits.
    /// Lets determinism tests compare whole runs (e.g. threads=1 vs
    /// threads=4) without shipping the parameter vector around; 0 when
    /// the producer does not track parameters.
    pub params_hash: u64,
}

impl RunReport {
    /// First round index (1-based count) at which smoothed test accuracy
    /// reaches `target`, along with cumulative bits at that point.
    pub fn rounds_to_accuracy(&self, target: f32) -> Option<(usize, u64)> {
        for r in &self.rounds {
            if r.evaluated() && r.test_accuracy >= target {
                return Some((r.round as usize + 1, r.cum_uplink_bits));
            }
        }
        None
    }

    /// Best test accuracy seen.
    pub fn best_accuracy(&self) -> f32 {
        self.rounds
            .iter()
            .filter(|r| r.evaluated())
            .map(|r| r.test_accuracy)
            .fold(f32::NAN, f32::max)
    }

    /// Cumulative uplink bits over the whole run.
    pub fn total_uplink_bits(&self) -> u64 {
        self.rounds.last().map(|r| r.cum_uplink_bits).unwrap_or(0)
    }

    /// CSV with a fixed schema (one row per round).
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "round,train_loss,test_loss,test_acc,uplink_bits,cum_uplink_bits,mean_bits,mean_range,wall_secs,recv_decode_secs,agg_secs,eval_secs,selected,dropped,sim_makespan_secs,failed,rejoined,stale_folded,stale_dropped,agg_depth,client_state_bytes,subtree_failed,degraded,downlink_bits,cum_downlink_bits\n",
        );
        for r in &self.rounds {
            out.push_str(&format!(
                "{},{},{},{},{},{},{},{},{:.6},{:.6},{:.6},{:.6},{},{},{:.6},{},{},{},{},{},{},{},{},{},{}\n",
                r.round,
                r.train_loss,
                r.test_loss,
                r.test_accuracy,
                r.uplink_bits,
                r.cum_uplink_bits,
                r.mean_bits,
                r.mean_range,
                r.wall_secs,
                r.recv_decode_secs,
                r.agg_secs,
                r.eval_secs,
                r.selected,
                r.dropped,
                r.sim_makespan_secs,
                r.failed,
                r.rejoined,
                r.stale_folded,
                r.stale_dropped,
                r.agg_depth,
                r.client_state_bytes,
                r.subtree_failed,
                r.degraded,
                r.downlink_bits,
                r.cum_downlink_bits
            ));
        }
        out
    }

    /// The whole report as a JSON object ([`Self::from_json`] inverts).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::from(self.label.clone())),
            ("model", Json::from(self.model.clone())),
            // hex string: f64-backed Json numbers cannot hold u64 exactly
            ("params_hash", Json::from(format!("{:016x}", self.params_hash))),
            (
                "rounds",
                Json::Arr(self.rounds.iter().map(RoundRecord::to_json).collect()),
            ),
        ])
    }

    /// Parse a report written by [`Self::to_json`] (e.g. a saved
    /// `--out run.json`), tolerating legacy reports that predate the
    /// scheduler fields or the exact-decimal bit counters.
    pub fn from_json(j: &Json) -> Result<RunReport> {
        let str_at = |k: &str| -> Result<String> {
            Ok(j.get(k)
                .and_then(Json::as_str)
                .with_context(|| format!("report: {k}"))?
                .to_string())
        };
        let params_hash = match j.get("params_hash").and_then(Json::as_str) {
            Some(h) => u64::from_str_radix(h, 16).context("report: params_hash")?,
            None => 0,
        };
        let rounds = j
            .get("rounds")
            .and_then(Json::as_arr)
            .context("report: rounds")?
            .iter()
            .map(RoundRecord::from_json)
            .collect::<Result<Vec<RoundRecord>>>()?;
        Ok(RunReport { label: str_at("label")?, model: str_at("model")?, rounds, params_hash })
    }

    /// Parse a report from JSON text ([`Self::from_json`] over
    /// [`Json::parse`]).
    pub fn from_json_str(s: &str) -> Result<RunReport> {
        Self::from_json(&Json::parse(s).map_err(anyhow::Error::from)?)
    }

    /// Write [`Self::to_csv`] to `path`.
    pub fn write_csv(&self, path: &str) -> Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_csv().as_bytes())?;
        Ok(())
    }

    /// Write [`Self::to_json`] (pretty-printed) to `path`.
    pub fn write_json(&self, path: &str) -> Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_json().to_string_pretty().as_bytes())?;
        Ok(())
    }
}

/// A u64 counter as JSON, exact at any magnitude: emitted as a decimal
/// string because [`Json`] numbers are f64-backed and lose integer
/// exactness above 2^53 (the same reason `params_hash` is a hex
/// string).  Parse back with [`json_u64`].
pub fn u64_json(v: u64) -> Json {
    Json::Str(v.to_string())
}

/// Read a counter written by [`u64_json`]; also accepts plain numbers
/// (pre-exactness reports) when they are exactly representable.
pub fn json_u64(j: &Json) -> Option<u64> {
    match j {
        Json::Str(s) => s.parse().ok(),
        Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= 2f64.powi(53) => {
            Some(*n as u64)
        }
        _ => None,
    }
}

/// Format a bit count the way the paper's Table I does (Gb = 1e9 bits).
pub fn gbits(bits: u64) -> f64 {
    bits as f64 / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(round: u32, acc: f32, cum: u64) -> RoundRecord {
        RoundRecord {
            round,
            train_loss: 1.0,
            test_loss: 1.0,
            test_accuracy: acc,
            uplink_bits: 100,
            cum_uplink_bits: cum,
            mean_bits: 8.0,
            mean_range: 0.1,
            seg_ranges: vec![0.1, 0.2],
            wall_secs: 0.5,
            recv_decode_secs: 0.2,
            agg_secs: 0.1,
            eval_secs: 0.05,
            selected: 10,
            dropped: 2,
            sim_makespan_secs: 1.25,
            failed: 3,
            rejoined: 1,
            stale_folded: 2,
            stale_dropped: 1,
            agg_depth: 2,
            client_state_bytes: 160,
            subtree_failed: 1,
            degraded: 2,
            downlink_bits: 77,
            cum_downlink_bits: 154,
        }
    }

    #[test]
    fn rounds_to_accuracy_finds_first_crossing() {
        let rep = RunReport {
            label: "x".into(),
            model: "mlp".into(),
            rounds: vec![record(0, 0.2, 100), record(1, 0.6, 200), record(2, 0.7, 300)],
            params_hash: 0,
        };
        assert_eq!(rep.rounds_to_accuracy(0.5), Some((2, 200)));
        assert_eq!(rep.rounds_to_accuracy(0.9), None);
        assert!((rep.best_accuracy() - 0.7).abs() < 1e-6);
        assert_eq!(rep.total_uplink_bits(), 300);
    }

    #[test]
    fn skips_unevaluated_rounds() {
        let mut r = record(0, f32::NAN, 50);
        assert!(!r.evaluated());
        r.test_accuracy = 0.9;
        let rep = RunReport {
            label: "x".into(),
            model: "mlp".into(),
            rounds: vec![record(0, f32::NAN, 50), r],
            params_hash: 0,
        };
        assert_eq!(rep.rounds_to_accuracy(0.5).unwrap().0, 1);
    }

    #[test]
    fn csv_and_json_emit() {
        let rep = RunReport {
            label: "feddq".into(),
            model: "mlp".into(),
            rounds: vec![record(0, 0.5, 100)],
            params_hash: 0,
        };
        let csv = rep.to_csv();
        assert!(csv.lines().count() == 2);
        assert!(csv.contains("feddq") == false); // label not in rows
        let j = rep.to_json();
        assert_eq!(j.at(&["label"]).unwrap().as_str(), Some("feddq"));
    }

    #[test]
    fn gbits_scale() {
        assert!((gbits(2_070_000_000) - 2.07).abs() < 1e-9);
    }

    fn assert_records_equal(a: &RoundRecord, b: &RoundRecord) {
        assert_eq!(a.round, b.round);
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
        // NaN-tolerant: unevaluated rounds round-trip through null
        assert_eq!(a.test_loss.is_nan(), b.test_loss.is_nan());
        if !a.test_loss.is_nan() {
            assert_eq!(a.test_loss, b.test_loss);
        }
        assert_eq!(a.test_accuracy.is_nan(), b.test_accuracy.is_nan());
        if !a.test_accuracy.is_nan() {
            assert_eq!(a.test_accuracy, b.test_accuracy);
        }
        assert_eq!(a.uplink_bits, b.uplink_bits);
        assert_eq!(a.cum_uplink_bits, b.cum_uplink_bits);
        assert_eq!(a.mean_bits, b.mean_bits);
        assert_eq!(a.mean_range, b.mean_range);
        assert_eq!(a.seg_ranges, b.seg_ranges);
        assert_eq!(a.wall_secs, b.wall_secs);
        assert_eq!(a.recv_decode_secs, b.recv_decode_secs);
        assert_eq!(a.agg_secs, b.agg_secs);
        assert_eq!(a.eval_secs, b.eval_secs);
        assert_eq!(a.selected, b.selected);
        assert_eq!(a.dropped, b.dropped);
        assert_eq!(a.sim_makespan_secs, b.sim_makespan_secs);
        assert_eq!(a.failed, b.failed);
        assert_eq!(a.rejoined, b.rejoined);
        assert_eq!(a.stale_folded, b.stale_folded);
        assert_eq!(a.stale_dropped, b.stale_dropped);
        assert_eq!(a.agg_depth, b.agg_depth);
        assert_eq!(a.client_state_bytes, b.client_state_bytes);
        assert_eq!(a.subtree_failed, b.subtree_failed);
        assert_eq!(a.degraded, b.degraded);
        assert_eq!(a.downlink_bits, b.downlink_bits);
        assert_eq!(a.cum_downlink_bits, b.cum_downlink_bits);
    }

    #[test]
    fn report_json_schema_round_trips_through_text() {
        // An evaluated round, an unevaluated (NaN) round, and
        // above-2^53 bit counters — the whole schema incl. the
        // scheduler fields must survive emit -> text -> parse.
        let big: u64 = (1u64 << 60) + 1;
        let mut r0 = record(0, 0.5, big - 7);
        r0.uplink_bits = big - 9;
        r0.selected = 5;
        r0.dropped = 3;
        r0.sim_makespan_secs = 0.875; // exact in f64
        let mut r1 = record(1, f32::NAN, big);
        r1.test_loss = f32::NAN;
        let rep = RunReport {
            label: "sched".into(),
            model: "mlp".into(),
            rounds: vec![r0, r1],
            params_hash: 0xdead_beef_0bad_cafe,
        };
        let text = rep.to_json().to_string_pretty();
        let back = RunReport::from_json_str(&text).unwrap();
        assert_eq!(back.label, rep.label);
        assert_eq!(back.model, rep.model);
        assert_eq!(back.params_hash, rep.params_hash);
        assert_eq!(back.rounds.len(), rep.rounds.len());
        for (a, b) in rep.rounds.iter().zip(&back.rounds) {
            assert_records_equal(a, b);
        }
        // the bit counters specifically crossed the text layer as
        // exact decimal strings
        let parsed = Json::parse(&text).unwrap();
        let row = &parsed.get("rounds").unwrap().as_arr().unwrap()[0];
        assert_eq!(row.get("uplink_bits").unwrap(), &Json::Str((big - 9).to_string()));
        assert_eq!(row.get("selected").and_then(Json::as_usize), Some(5));
        assert_eq!(row.get("dropped").and_then(Json::as_usize), Some(3));
        assert_eq!(row.get("sim_makespan_secs").and_then(Json::as_f64), Some(0.875));
        assert_eq!(row.get("failed").and_then(Json::as_usize), Some(3));
        assert_eq!(row.get("rejoined").and_then(Json::as_usize), Some(1));
        assert_eq!(row.get("stale_folded").and_then(Json::as_usize), Some(2));
        assert_eq!(row.get("stale_dropped").and_then(Json::as_usize), Some(1));
        assert_eq!(row.get("agg_depth").and_then(Json::as_usize), Some(2));
        assert_eq!(row.get("client_state_bytes").unwrap(), &Json::Str("160".into()));
        assert_eq!(row.get("subtree_failed").and_then(Json::as_usize), Some(1));
        assert_eq!(row.get("degraded").and_then(Json::as_usize), Some(2));
        assert_eq!(row.get("downlink_bits").unwrap(), &Json::Str("77".into()));
        assert_eq!(row.get("cum_downlink_bits").unwrap(), &Json::Str("154".into()));
    }

    #[test]
    fn legacy_report_without_scheduler_fields_parses_with_zeros() {
        let rep = RunReport {
            label: "old".into(),
            model: "mlp".into(),
            rounds: vec![record(0, 0.5, 100)],
            params_hash: 7,
        };
        let mut j = rep.to_json();
        if let Json::Obj(o) = &mut j {
            let rounds = o.get_mut("rounds").unwrap();
            if let Json::Arr(rs) = rounds {
                if let Json::Obj(r) = &mut rs[0] {
                    // scheduler fields (this PR) and the per-stage
                    // timings (absent in first-revision reports, which
                    // carried only wall_secs) both default leniently
                    r.remove("selected");
                    r.remove("dropped");
                    r.remove("sim_makespan_secs");
                    r.remove("recv_decode_secs");
                    r.remove("agg_secs");
                    r.remove("eval_secs");
                    r.remove("failed");
                    r.remove("rejoined");
                    r.remove("stale_folded");
                    r.remove("stale_dropped");
                    r.remove("agg_depth");
                    r.remove("client_state_bytes");
                    r.remove("subtree_failed");
                    r.remove("degraded");
                    r.remove("downlink_bits");
                    r.remove("cum_downlink_bits");
                }
            }
        }
        let back = RunReport::from_json(&j).unwrap();
        assert_eq!(back.rounds[0].selected, 0);
        assert_eq!(back.rounds[0].dropped, 0);
        assert_eq!(back.rounds[0].sim_makespan_secs, 0.0);
        assert_eq!(back.rounds[0].recv_decode_secs, 0.0);
        assert_eq!(back.rounds[0].agg_secs, 0.0);
        assert_eq!(back.rounds[0].eval_secs, 0.0);
        assert_eq!(back.rounds[0].failed, 0);
        assert_eq!(back.rounds[0].rejoined, 0);
        assert_eq!(back.rounds[0].stale_folded, 0);
        assert_eq!(back.rounds[0].stale_dropped, 0);
        assert_eq!(back.rounds[0].agg_depth, 0);
        assert_eq!(back.rounds[0].client_state_bytes, 0);
        assert_eq!(back.rounds[0].subtree_failed, 0);
        assert_eq!(back.rounds[0].degraded, 0);
        assert_eq!(back.rounds[0].downlink_bits, 0);
        assert_eq!(back.rounds[0].cum_downlink_bits, 0);
        assert_eq!(back.rounds[0].wall_secs, 0.5, "wall_secs survives");
        // present-but-mistyped fields still error (corruption, not legacy)
        let mut bad = rep.to_json();
        if let Json::Obj(o) = &mut bad {
            if let Json::Arr(rs) = o.get_mut("rounds").unwrap() {
                if let Json::Obj(r) = &mut rs[0] {
                    r.insert("agg_secs".into(), Json::Str("fast".into()));
                }
            }
        }
        assert!(RunReport::from_json(&bad).is_err());
    }

    #[test]
    fn csv_schema_includes_scheduler_columns() {
        let rep = RunReport {
            label: "s".into(),
            model: "mlp".into(),
            rounds: vec![record(0, 0.5, 100)],
            params_hash: 0,
        };
        let csv = rep.to_csv();
        let header = csv.lines().next().unwrap();
        assert!(
            header.ends_with(
                "selected,dropped,sim_makespan_secs,failed,rejoined,stale_folded,stale_dropped,agg_depth,client_state_bytes,subtree_failed,degraded,downlink_bits,cum_downlink_bits"
            ),
            "{header}"
        );
        let row = csv.lines().nth(1).unwrap();
        let cols: Vec<&str> = row.split(',').collect();
        assert_eq!(cols.len(), header.split(',').count());
        assert_eq!(cols[12], "10");
        assert_eq!(cols[13], "2");
        assert_eq!(cols[15], "3");
        assert_eq!(cols[16], "1");
        assert_eq!(cols[17], "2");
        assert_eq!(cols[18], "1");
        assert_eq!(cols[19], "2");
        assert_eq!(cols[20], "160");
        assert_eq!(cols[21], "1");
        assert_eq!(cols[22], "2");
        assert_eq!(cols[23], "77");
        assert_eq!(cols[24], "154");
    }

    #[test]
    fn bit_counters_round_trip_exactly_above_2_53() {
        // (1 << 60) + 1 is NOT representable in f64: the old
        // `as f64` emission silently rounded it.  The decimal-string
        // emission must survive a parse round-trip bit for bit.
        let big: u64 = (1u64 << 60) + 1;
        assert_ne!(big as f64 as u64, big, "test value must exceed f64 exactness");
        let mut r = record(0, 0.5, big);
        r.uplink_bits = big - 7;
        let rep = RunReport {
            label: "big".into(),
            model: "mlp".into(),
            rounds: vec![r],
            params_hash: 1,
        };
        let parsed = Json::parse(&rep.to_json().to_string_pretty()).unwrap();
        let row = &parsed.get("rounds").unwrap().as_arr().unwrap()[0];
        assert_eq!(json_u64(row.get("uplink_bits").unwrap()), Some(big - 7));
        assert_eq!(json_u64(row.get("cum_uplink_bits").unwrap()), Some(big));
        // Legacy numeric rows still parse when exact.
        assert_eq!(json_u64(&Json::Num(1024.0)), Some(1024));
        assert_eq!(json_u64(&Json::Num(0.5)), None);
    }
}
