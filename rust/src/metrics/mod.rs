//! Metrics: per-round records, the communication ledger and CSV/JSON
//! emitters used by the figure/table benches.

use std::io::Write;

use crate::util::json::Json;
use crate::Result;

/// Everything measured in one federated round.
#[derive(Clone, Debug)]
pub struct RoundRecord {
    pub round: u32,
    /// Global average training loss (weighted by client sample counts).
    pub train_loss: f32,
    /// Test loss (mean NLL over the server validation set); NaN if the
    /// round was not evaluated.
    pub test_loss: f32,
    /// Test accuracy in [0,1]; NaN if not evaluated.
    pub test_accuracy: f32,
    /// Uplink payload bits this round (sum over clients, packed size).
    pub uplink_bits: u64,
    /// Cumulative uplink bits including this round.
    pub cum_uplink_bits: u64,
    /// Mean bits/element across clients and segments (Fig. 5's y-axis).
    pub mean_bits: f32,
    /// Mean update range across clients and segments (Fig. 1b's y-axis).
    pub mean_range: f32,
    /// Per-segment mean ranges across clients (Fig. 1b per-layer curves).
    pub seg_ranges: Vec<f32>,
    /// Wall-clock seconds spent in this round.
    pub wall_secs: f64,
    /// Seconds in the receive stage; with a pool attached, update
    /// decoding is pipelined into the same window, and with fold
    /// overlap the sharded fold itself also runs here (so `agg_secs`
    /// shrinks to the chunk application — that shift is the overlap).
    pub recv_decode_secs: f64,
    /// Seconds folding the (sharded) accumulator and applying it.
    pub agg_secs: f64,
    /// Seconds in server-side evaluation (0 when the round skipped it).
    pub eval_secs: f64,
}

impl RoundRecord {
    pub fn evaluated(&self) -> bool {
        !self.test_accuracy.is_nan()
    }
}

/// A completed run: config label + per-round records.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub label: String,
    pub model: String,
    pub rounds: Vec<RoundRecord>,
    /// FNV-1a hash over the final global parameters' exact f32 bits.
    /// Lets determinism tests compare whole runs (e.g. threads=1 vs
    /// threads=4) without shipping the parameter vector around; 0 when
    /// the producer does not track parameters.
    pub params_hash: u64,
}

impl RunReport {
    /// First round index (1-based count) at which smoothed test accuracy
    /// reaches `target`, along with cumulative bits at that point.
    pub fn rounds_to_accuracy(&self, target: f32) -> Option<(usize, u64)> {
        for r in &self.rounds {
            if r.evaluated() && r.test_accuracy >= target {
                return Some((r.round as usize + 1, r.cum_uplink_bits));
            }
        }
        None
    }

    /// Best test accuracy seen.
    pub fn best_accuracy(&self) -> f32 {
        self.rounds
            .iter()
            .filter(|r| r.evaluated())
            .map(|r| r.test_accuracy)
            .fold(f32::NAN, f32::max)
    }

    pub fn total_uplink_bits(&self) -> u64 {
        self.rounds.last().map(|r| r.cum_uplink_bits).unwrap_or(0)
    }

    /// CSV with a fixed schema (one row per round).
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "round,train_loss,test_loss,test_acc,uplink_bits,cum_uplink_bits,mean_bits,mean_range,wall_secs,recv_decode_secs,agg_secs,eval_secs\n",
        );
        for r in &self.rounds {
            out.push_str(&format!(
                "{},{},{},{},{},{},{},{},{:.6},{:.6},{:.6},{:.6}\n",
                r.round,
                r.train_loss,
                r.test_loss,
                r.test_accuracy,
                r.uplink_bits,
                r.cum_uplink_bits,
                r.mean_bits,
                r.mean_range,
                r.wall_secs,
                r.recv_decode_secs,
                r.agg_secs,
                r.eval_secs
            ));
        }
        out
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::from(self.label.clone())),
            ("model", Json::from(self.model.clone())),
            // hex string: f64-backed Json numbers cannot hold u64 exactly
            ("params_hash", Json::from(format!("{:016x}", self.params_hash))),
            (
                "rounds",
                Json::Arr(
                    self.rounds
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("round", Json::from(r.round)),
                                ("train_loss", Json::from(r.train_loss as f64)),
                                ("test_loss", Json::from(r.test_loss as f64)),
                                ("test_acc", Json::from(r.test_accuracy as f64)),
                                // decimal strings, not numbers: Json's
                                // f64 backing loses exactness above 2^53
                                // and long large-model runs get there —
                                // same fix as params_hash's hex string
                                ("uplink_bits", u64_json(r.uplink_bits)),
                                ("cum_uplink_bits", u64_json(r.cum_uplink_bits)),
                                ("mean_bits", Json::from(r.mean_bits as f64)),
                                ("mean_range", Json::from(r.mean_range as f64)),
                                (
                                    "seg_ranges",
                                    Json::Arr(
                                        r.seg_ranges
                                            .iter()
                                            .map(|&x| Json::from(x as f64))
                                            .collect(),
                                    ),
                                ),
                                ("wall_secs", Json::from(r.wall_secs)),
                                ("recv_decode_secs", Json::from(r.recv_decode_secs)),
                                ("agg_secs", Json::from(r.agg_secs)),
                                ("eval_secs", Json::from(r.eval_secs)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn write_csv(&self, path: &str) -> Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_csv().as_bytes())?;
        Ok(())
    }

    pub fn write_json(&self, path: &str) -> Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_json().to_string_pretty().as_bytes())?;
        Ok(())
    }
}

/// A u64 counter as JSON, exact at any magnitude: emitted as a decimal
/// string because [`Json`] numbers are f64-backed and lose integer
/// exactness above 2^53 (the same reason `params_hash` is a hex
/// string).  Parse back with [`json_u64`].
pub fn u64_json(v: u64) -> Json {
    Json::Str(v.to_string())
}

/// Read a counter written by [`u64_json`]; also accepts plain numbers
/// (pre-exactness reports) when they are exactly representable.
pub fn json_u64(j: &Json) -> Option<u64> {
    match j {
        Json::Str(s) => s.parse().ok(),
        Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= 2f64.powi(53) => {
            Some(*n as u64)
        }
        _ => None,
    }
}

/// Format a bit count the way the paper's Table I does (Gb = 1e9 bits).
pub fn gbits(bits: u64) -> f64 {
    bits as f64 / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(round: u32, acc: f32, cum: u64) -> RoundRecord {
        RoundRecord {
            round,
            train_loss: 1.0,
            test_loss: 1.0,
            test_accuracy: acc,
            uplink_bits: 100,
            cum_uplink_bits: cum,
            mean_bits: 8.0,
            mean_range: 0.1,
            seg_ranges: vec![0.1, 0.2],
            wall_secs: 0.5,
            recv_decode_secs: 0.2,
            agg_secs: 0.1,
            eval_secs: 0.05,
        }
    }

    #[test]
    fn rounds_to_accuracy_finds_first_crossing() {
        let rep = RunReport {
            label: "x".into(),
            model: "mlp".into(),
            rounds: vec![record(0, 0.2, 100), record(1, 0.6, 200), record(2, 0.7, 300)],
            params_hash: 0,
        };
        assert_eq!(rep.rounds_to_accuracy(0.5), Some((2, 200)));
        assert_eq!(rep.rounds_to_accuracy(0.9), None);
        assert!((rep.best_accuracy() - 0.7).abs() < 1e-6);
        assert_eq!(rep.total_uplink_bits(), 300);
    }

    #[test]
    fn skips_unevaluated_rounds() {
        let mut r = record(0, f32::NAN, 50);
        assert!(!r.evaluated());
        r.test_accuracy = 0.9;
        let rep = RunReport {
            label: "x".into(),
            model: "mlp".into(),
            rounds: vec![record(0, f32::NAN, 50), r],
            params_hash: 0,
        };
        assert_eq!(rep.rounds_to_accuracy(0.5).unwrap().0, 1);
    }

    #[test]
    fn csv_and_json_emit() {
        let rep = RunReport {
            label: "feddq".into(),
            model: "mlp".into(),
            rounds: vec![record(0, 0.5, 100)],
            params_hash: 0,
        };
        let csv = rep.to_csv();
        assert!(csv.lines().count() == 2);
        assert!(csv.contains("feddq") == false); // label not in rows
        let j = rep.to_json();
        assert_eq!(j.at(&["label"]).unwrap().as_str(), Some("feddq"));
    }

    #[test]
    fn gbits_scale() {
        assert!((gbits(2_070_000_000) - 2.07).abs() < 1e-9);
    }

    #[test]
    fn bit_counters_round_trip_exactly_above_2_53() {
        // (1 << 60) + 1 is NOT representable in f64: the old
        // `as f64` emission silently rounded it.  The decimal-string
        // emission must survive a parse round-trip bit for bit.
        let big: u64 = (1u64 << 60) + 1;
        assert_ne!(big as f64 as u64, big, "test value must exceed f64 exactness");
        let mut r = record(0, 0.5, big);
        r.uplink_bits = big - 7;
        let rep = RunReport {
            label: "big".into(),
            model: "mlp".into(),
            rounds: vec![r],
            params_hash: 1,
        };
        let parsed = Json::parse(&rep.to_json().to_string_pretty()).unwrap();
        let row = &parsed.get("rounds").unwrap().as_arr().unwrap()[0];
        assert_eq!(json_u64(row.get("uplink_bits").unwrap()), Some(big - 7));
        assert_eq!(json_u64(row.get("cum_uplink_bits").unwrap()), Some(big));
        // Legacy numeric rows still parse when exact.
        assert_eq!(json_u64(&Json::Num(1024.0)), Some(1024));
        assert_eq!(json_u64(&Json::Num(0.5)), None);
    }
}
