//! Parametric FL network model.
//!
//! Round structure (synchronous FedAvg, as in the paper):
//!
//! ```text
//! t_round = latency_rtt                                  (control)
//!         + downlink_bits / downlink_bps                 (broadcast, shared)
//!         + max_i( uplink_bits_i / uplink_bps_i )        (stragglers!)
//!         + compute_secs                                 (local training)
//! ```
//!
//! The uplink is the term quantization shrinks; with heterogeneous client
//! bandwidths the *slowest* client gates the round, which is why adaptive
//! per-client bit-widths (FedDQ quantizes each client by its own range)
//! also tighten the straggler tail.

use crate::metrics::{RoundRecord, RunReport};
use crate::util::rng::Rng;

/// Per-deployment link parameters.
#[derive(Clone, Debug)]
pub struct NetworkModel {
    /// Mean client uplink, bits/second (e.g. 10 Mbps home uplink = 10e6).
    pub uplink_bps: f64,
    /// Server->client broadcast bandwidth, bits/second.
    pub downlink_bps: f64,
    /// Per-round control-plane latency, seconds.
    pub latency: f64,
    /// Log-uniform spread factor for per-client uplink heterogeneity:
    /// client bandwidth ~ uplink_bps * U[1/spread, spread].  1.0 = uniform.
    pub spread: f64,
    /// Number of clients (straggler max is taken over this many draws).
    pub n_clients: usize,
}

impl NetworkModel {
    /// A 10 Mbps-up / 50 Mbps-down WAN profile with mild heterogeneity.
    pub fn wan(n_clients: usize) -> Self {
        NetworkModel {
            uplink_bps: 10e6,
            downlink_bps: 50e6,
            latency: 0.05,
            spread: 3.0,
            n_clients,
        }
    }

    /// Per-client uplink bandwidths for one round (deterministic in seed).
    fn client_bps(&self, rng: &mut Rng) -> Vec<f64> {
        (0..self.n_clients)
            .map(|_| {
                if self.spread <= 1.0 {
                    self.uplink_bps
                } else {
                    let u = rng.next_f64() * 2.0 - 1.0; // [-1, 1)
                    self.uplink_bps * self.spread.powf(u)
                }
            })
            .collect()
    }

    /// Wall-clock for one round given its measured bit volumes.
    ///
    /// `uplink_bits_total` is the round's summed uplink; per-client volume
    /// is approximated as total/n (exact when clients quantize alike; the
    /// straggler max over heterogeneous *bandwidths* still dominates).
    pub fn round_secs(
        &self,
        rec: &RoundRecord,
        downlink_bits_per_client: u64,
        rng: &mut Rng,
    ) -> f64 {
        let bps = self.client_bps(rng);
        let per_client_bits = rec.uplink_bits as f64 / self.n_clients as f64;
        let slowest_upload = bps
            .iter()
            .map(|&b| per_client_bits / b)
            .fold(0.0f64, f64::max);
        let broadcast =
            (downlink_bits_per_client as f64 * self.n_clients as f64) / self.downlink_bps;
        self.latency + broadcast + slowest_upload + rec.wall_secs
    }

    /// Replay a whole report; returns per-round cumulative times.
    pub fn replay(&self, report: &RunReport, model_d: usize, seed: u64) -> Vec<TimedRound> {
        let mut rng = Rng::new(seed).derive("netsim");
        // fp32 downlink of the full model + framing, as the coordinator sends.
        let downlink_bits = (model_d as u64) * 32 + 1024;
        let mut t = 0.0;
        report
            .rounds
            .iter()
            .map(|r| {
                t += self.round_secs(r, downlink_bits, &mut rng);
                TimedRound {
                    round: r.round,
                    cum_secs: t,
                    test_accuracy: r.test_accuracy,
                    cum_uplink_bits: r.cum_uplink_bits,
                }
            })
            .collect()
    }

    /// Seconds until `target` accuracy is first reached, if ever.
    pub fn time_to_accuracy(
        &self,
        report: &RunReport,
        model_d: usize,
        seed: u64,
        target: f32,
    ) -> Option<f64> {
        self.replay(report, model_d, seed)
            .into_iter()
            .find(|t| !t.test_accuracy.is_nan() && t.test_accuracy >= target)
            .map(|t| t.cum_secs)
    }
}

/// One replayed round on the simulated network.
#[derive(Clone, Debug)]
pub struct TimedRound {
    /// Round index.
    pub round: u32,
    /// Cumulative simulated wall-clock at the end of this round.
    pub cum_secs: f64,
    /// Test accuracy after this round (NaN when unevaluated).
    pub test_accuracy: f32,
    /// Cumulative uplink bits through this round.
    pub cum_uplink_bits: u64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::RoundRecord;

    fn rec(round: u32, uplink_bits: u64, acc: f32) -> RoundRecord {
        RoundRecord {
            round,
            train_loss: 1.0,
            test_loss: 1.0,
            test_accuracy: acc,
            uplink_bits,
            cum_uplink_bits: uplink_bits * (round as u64 + 1),
            mean_bits: 8.0,
            mean_range: 0.1,
            seg_ranges: vec![],
            wall_secs: 1.0,
            recv_decode_secs: 0.5,
            agg_secs: 0.2,
            eval_secs: 0.1,
            selected: 10,
            dropped: 0,
            sim_makespan_secs: 0.0,
            failed: 0,
            rejoined: 0,
            stale_folded: 0,
            stale_dropped: 0,
            agg_depth: 0,
            client_state_bytes: 0,
            subtree_failed: 0,
            degraded: 0,
            downlink_bits: 0,
            cum_downlink_bits: 0,
        }
    }

    fn report(rounds: Vec<RoundRecord>) -> RunReport {
        RunReport { label: "t".into(), model: "mlp".into(), rounds, params_hash: 0 }
    }

    #[test]
    fn fewer_bits_means_less_time() {
        let nm = NetworkModel::wan(10);
        let small = report(vec![rec(0, 1_000_000, 0.9)]);
        let large = report(vec![rec(0, 32_000_000, 0.9)]);
        let ts = nm.time_to_accuracy(&small, 100_000, 1, 0.5).unwrap();
        let tl = nm.time_to_accuracy(&large, 100_000, 1, 0.5).unwrap();
        assert!(ts < tl, "{ts} !< {tl}");
    }

    #[test]
    fn replay_is_monotone_and_deterministic() {
        let nm = NetworkModel::wan(4);
        let rep = report((0..5).map(|m| rec(m, 2_000_000, 0.1 * m as f32)).collect());
        let a = nm.replay(&rep, 50_000, 7);
        let b = nm.replay(&rep, 50_000, 7);
        assert_eq!(a.len(), 5);
        assert!(a.windows(2).all(|w| w[1].cum_secs > w[0].cum_secs));
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.cum_secs, y.cum_secs);
        }
    }

    #[test]
    fn unreached_target_is_none() {
        let nm = NetworkModel::wan(4);
        let rep = report(vec![rec(0, 1_000, 0.2)]);
        assert!(nm.time_to_accuracy(&rep, 1_000, 1, 0.9).is_none());
    }

    #[test]
    fn straggler_spread_increases_round_time() {
        let mut uniform = NetworkModel::wan(10);
        uniform.spread = 1.0;
        let spread = NetworkModel::wan(10); // spread = 3
        let r = rec(0, 10_000_000, 0.5);
        // average over several seeds: heterogeneity must cost time
        let avg = |nm: &NetworkModel| -> f64 {
            (0..20)
                .map(|s| nm.round_secs(&r, 1_000_000, &mut Rng::new(s)))
                .sum::<f64>()
                / 20.0
        };
        assert!(avg(&spread) > avg(&uniform));
    }
}
