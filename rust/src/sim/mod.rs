//! Simulation models: translate measured bit volumes into wall-clock
//! time, and give the round scheduler a per-client cost model.
//!
//! The paper's metric is communicated *bits*; what a deployment feels is
//! *time-to-accuracy* under constrained links.  Two models cover that:
//!
//! * [`NetworkModel`] (in [`network`]) replays a completed
//!   [`RunReport`](crate::metrics::RunReport) against per-client
//!   bandwidth and per-round latency, producing the time axis for the
//!   same curves — used by the ablation bench and downstream users.
//! * [`LatencyModel`] (in [`latency`]) is the *forward* model: a
//!   deterministic draw of simulated round seconds per `(client,
//!   round)`, consumed by the round scheduler
//!   ([`crate::coordinator::sched`]) for cohort selection, the
//!   `--round-deadline` policy and the per-round simulated makespan.
//! * [`FaultModel`] (in [`faults`]) is the churn model: deterministic
//!   per-`(client, round)` crash/stall/drop draws consumed by the
//!   scheduler's quorum layer, so a faulty run stays bit-reproducible.

pub mod faults;
pub mod latency;
pub mod network;

pub use faults::{FaultDraw, FaultModel, FaultProfile};
pub use latency::{LatencyModel, LatencyProfile};
pub use network::{NetworkModel, TimedRound};
