//! Network simulation: translate measured bit volumes into wall-clock
//! time under a parametric uplink/downlink model.
//!
//! The paper's metric is communicated *bits*; what a deployment feels is
//! *time-to-accuracy* under constrained links.  [`NetworkModel`] replays a
//! [`RunReport`](crate::metrics::RunReport) against per-client bandwidth
//! and per-round latency and produces the time axis for the same curves —
//! used by the ablation bench and available to downstream users.

pub mod network;

pub use network::{NetworkModel, TimedRound};
