//! Deterministic per-client fault model for churn simulation.
//!
//! The quorum/rejoin layer ([`crate::coordinator::sched`],
//! [`crate::coordinator::server`]) needs clients that crash, stall and
//! drop updates *reproducibly*: the determinism contract in
//! `ARCHITECTURE.md` promises bit-identical `RunReport`s for a given
//! seed regardless of thread count, so the failed set of a round must be
//! a pure function of `(seed, round, client_id)` — never of arrival
//! order.  [`FaultModel`] provides that, mirroring
//! [`LatencyModel`](crate::sim::latency::LatencyModel): every draw comes
//! from a labeled [`Rng::derive`](crate::util::rng::Rng::derive) child
//! keyed by client and round, so `draw(c, m)` is a pure function with no
//! draw-order dependence.
//!
//! Three failure shapes, selected by `--sim-faults`:
//!
//! * `crash:<p>` — with probability `p` per `(client, round)`, the
//!   client dies for the round: it never receives the broadcast, so its
//!   error-feedback residual and batch cursor stay banked exactly like
//!   an unselected cohort member's.
//! * `stall:<p>:<secs>` — with probability `p` the client completes but
//!   `secs` simulated seconds late; under `--round-timeout` a stalled
//!   client whose total completion time exceeds the timeout is dropped.
//! * `flaky:<p>` — with probability `p` the client's *update* is lost in
//!   transit.  In the simulated path this is indistinguishable from a
//!   crash at the aggregation layer (same banked-state semantics); on
//!   the TCP path the [`FaultTransport`](crate::wire::transport::FaultTransport)
//!   decorator swallows the send so the server must time the client out.

use anyhow::{bail, ensure, Result};

use crate::util::rng::Rng;

/// Shape of the simulated per-client fault distribution.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultProfile {
    /// No faults: every selected client delivers every round.
    Off,
    /// Per-round crash: with probability `p` the client drops out of the
    /// round entirely (no broadcast received, no update sent).
    Crash {
        /// Per `(client, round)` crash probability in `[0, 1]`.
        p: f64,
    },
    /// Mid-round stall: with probability `p` the client finishes `secs`
    /// simulated seconds late.
    Stall {
        /// Per `(client, round)` stall probability in `[0, 1]`.
        p: f64,
        /// Extra simulated seconds added to the client's round time.
        secs: f64,
    },
    /// Lost update: with probability `p` the client's update never
    /// reaches the server.
    Flaky {
        /// Per `(client, round)` drop probability in `[0, 1]`.
        p: f64,
    },
}

impl FaultProfile {
    /// Parse `off`, `crash:<p>`, `stall:<p>:<secs>` or `flaky:<p>`.
    pub fn parse(s: &str) -> Result<Self> {
        fn prob(s: &str) -> Result<f64> {
            let p: f64 = s.parse()?;
            ensure!(p.is_finite() && (0.0..=1.0).contains(&p), "fault probability must be in [0, 1]");
            Ok(p)
        }
        let mut it = s.split(':');
        let head = it.next().unwrap_or("");
        let args: Vec<&str> = it.collect();
        match head {
            "off" => {
                ensure!(args.is_empty(), "off takes no arguments");
                Ok(FaultProfile::Off)
            }
            "crash" => {
                ensure!(args.len() == 1, "want crash:<p>");
                Ok(FaultProfile::Crash { p: prob(args[0])? })
            }
            "stall" => {
                ensure!(args.len() == 2, "want stall:<p>:<secs>");
                let p = prob(args[0])?;
                let secs: f64 = args[1].parse()?;
                ensure!(secs.is_finite() && secs >= 0.0, "stall seconds must be >= 0");
                Ok(FaultProfile::Stall { p, secs })
            }
            "flaky" => {
                ensure!(args.len() == 1, "want flaky:<p>");
                Ok(FaultProfile::Flaky { p: prob(args[0])? })
            }
            _ => bail!("unknown fault profile {s:?} (want off|crash:<p>|stall:<p>:<secs>|flaky:<p>)"),
        }
    }

    /// True when the profile can never produce a fault.
    pub fn is_off(&self) -> bool {
        match self {
            FaultProfile::Off => true,
            FaultProfile::Crash { p } | FaultProfile::Flaky { p } => *p == 0.0,
            FaultProfile::Stall { p, secs } => *p == 0.0 || *secs == 0.0,
        }
    }

    /// The canonical string form, parseable by [`Self::parse`] (used by
    /// the config JSON round-trip).
    pub fn label(&self) -> String {
        match self {
            FaultProfile::Off => "off".to_string(),
            FaultProfile::Crash { p } => format!("crash:{p}"),
            FaultProfile::Stall { p, secs } => format!("stall:{p}:{secs}"),
            FaultProfile::Flaky { p } => format!("flaky:{p}"),
        }
    }
}

impl std::str::FromStr for FaultProfile {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Self::parse(s)
    }
}

impl std::fmt::Display for FaultProfile {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.label())
    }
}

/// What the fault model decided for one `(client, round)` pair.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultDraw {
    /// No fault: the client behaves normally this round.
    None,
    /// The client's update never arrives — crash before/during the
    /// round, or an update lost in transit.
    Drop,
    /// The client completes, but this many simulated seconds late.
    Stall(f64),
}

/// Deterministic per-client fault draws, seeded from the run.
#[derive(Clone, Debug)]
pub struct FaultModel {
    profile: FaultProfile,
    root: Rng,
}

impl FaultModel {
    /// Build the model for one run; `seed` is the run's root seed (the
    /// model derives its own independent stream from it).
    pub fn new(profile: FaultProfile, seed: u64) -> FaultModel {
        FaultModel { profile, root: Rng::new(seed).derive("sim.faults") }
    }

    /// The configured profile.
    pub fn profile(&self) -> FaultProfile {
        self.profile
    }

    /// True when the model can never produce a fault.
    pub fn is_off(&self) -> bool {
        self.profile.is_off()
    }

    /// The fault decision for `client_id` in `round` — a pure function
    /// of `(seed, profile, client_id, round)`, independent of call order
    /// and of every other client's draw.
    pub fn draw(&self, client_id: u32, round: u32) -> FaultDraw {
        let (p, on_hit) = match self.profile {
            FaultProfile::Off => return FaultDraw::None,
            FaultProfile::Crash { p } => (p, FaultDraw::Drop),
            FaultProfile::Flaky { p } => (p, FaultDraw::Drop),
            FaultProfile::Stall { p, secs } => (p, FaultDraw::Stall(secs)),
        };
        let mut rng = self.root.derive(&format!("c{client_id}.r{round}"));
        if rng.next_f64() < p {
            on_hit
        } else {
            FaultDraw::None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_label_roundtrip() {
        for s in ["off", "crash:0.1", "stall:0.25:3.5", "flaky:1"] {
            let p = FaultProfile::parse(s).unwrap();
            assert_eq!(FaultProfile::parse(&p.label()).unwrap(), p);
        }
        assert!(FaultProfile::parse("crash:1.5").is_err()); // p > 1
        assert!(FaultProfile::parse("crash:-0.1").is_err());
        assert!(FaultProfile::parse("crash").is_err());
        assert!(FaultProfile::parse("stall:0.5").is_err()); // missing secs
        assert!(FaultProfile::parse("stall:0.5:-1").is_err());
        assert!(FaultProfile::parse("flaky:0.5:2").is_err()); // extra arg
        assert!(FaultProfile::parse("meteor:0.5").is_err());
        assert!(FaultProfile::parse("off:1").is_err());
    }

    #[test]
    fn fromstr_display_roundtrip_property() {
        // parse -> Display -> parse is the identity for arbitrary valid
        // profiles (seeded generator; FromStr/Display are what the CLI
        // uses, so this is the CLI syntax contract)
        let mut rng = Rng::new(43).derive("faults.prop");
        for i in 0..200u32 {
            let prob = (rng.next_f64() * 1000.0).round() / 1000.0;
            let p = match i % 4 {
                0 => FaultProfile::Off,
                1 => FaultProfile::Crash { p: prob },
                2 => FaultProfile::Stall {
                    p: prob,
                    secs: (rng.next_f64() * 30.0 * 1000.0).round() / 1000.0,
                },
                _ => FaultProfile::Flaky { p: prob },
            };
            let shown = p.to_string();
            let back: FaultProfile = shown.parse().unwrap();
            assert_eq!(back, p, "{shown}");
            assert_eq!(back.to_string(), shown, "display must be canonical");
        }
    }

    #[test]
    fn off_detection_covers_degenerate_profiles() {
        assert!(FaultProfile::Off.is_off());
        assert!(FaultProfile::Crash { p: 0.0 }.is_off());
        assert!(FaultProfile::Flaky { p: 0.0 }.is_off());
        assert!(FaultProfile::Stall { p: 0.5, secs: 0.0 }.is_off());
        assert!(!FaultProfile::Crash { p: 0.1 }.is_off());
        assert!(!FaultProfile::Stall { p: 0.1, secs: 2.0 }.is_off());
    }

    #[test]
    fn draws_are_pure_functions_of_seed_client_round() {
        let a = FaultModel::new(FaultProfile::Crash { p: 0.5 }, 17);
        let b = FaultModel::new(FaultProfile::Crash { p: 0.5 }, 17);
        for c in 0..16u32 {
            for m in 0..8u32 {
                // identical across instances, and across call order
                assert_eq!(a.draw(c, m), b.draw(c, m));
                assert_eq!(a.draw(c, m), a.draw(c, m));
            }
        }
        let other = FaultModel::new(FaultProfile::Crash { p: 0.5 }, 18);
        let differs =
            (0..16u32).flat_map(|c| (0..8u32).map(move |m| (c, m))).any(|(c, m)| other.draw(c, m) != a.draw(c, m));
        assert!(differs, "different seeds must yield different fault sets");
    }

    #[test]
    fn probability_extremes_are_exact() {
        let never = FaultModel::new(FaultProfile::Crash { p: 0.0 }, 7);
        let always = FaultModel::new(FaultProfile::Crash { p: 1.0 }, 7);
        let off = FaultModel::new(FaultProfile::Off, 7);
        for c in 0..32u32 {
            assert_eq!(never.draw(c, 0), FaultDraw::None);
            assert_eq!(always.draw(c, 0), FaultDraw::Drop);
            assert_eq!(off.draw(c, 0), FaultDraw::None);
        }
    }

    #[test]
    fn stall_draws_carry_the_profile_seconds() {
        let m = FaultModel::new(FaultProfile::Stall { p: 1.0, secs: 2.5 }, 11);
        assert_eq!(m.draw(3, 4), FaultDraw::Stall(2.5));
        let hit_rate = {
            let half = FaultModel::new(FaultProfile::Stall { p: 0.5, secs: 1.0 }, 11);
            let hits = (0..200u32).filter(|&c| half.draw(c, 0) != FaultDraw::None).count();
            hits as f64 / 200.0
        };
        assert!((0.3..0.7).contains(&hit_rate), "p=0.5 hit rate was {hit_rate}");
    }

    #[test]
    fn flaky_and_crash_share_drop_semantics() {
        let f = FaultModel::new(FaultProfile::Flaky { p: 1.0 }, 3);
        assert_eq!(f.draw(0, 0), FaultDraw::Drop);
    }
}
