//! Per-client simulated wall-clock cost model for the round scheduler.
//!
//! The round scheduler ([`crate::coordinator::sched`]) needs a notion of
//! how long each client will take *before* the round runs — real
//! deployments schedule around stragglers they have not yet measured.
//! [`LatencyModel`] provides that: a deterministic draw of simulated
//! round seconds per `(client, round)` pair, derived purely from the run
//! seed, so cohort selection and the `--round-deadline` policy are
//! bit-reproducible for any thread count (the determinism contract in
//! `ARCHITECTURE.md`).
//!
//! The model separates **persistent heterogeneity** (a slow phone stays
//! slow: one per-client factor drawn once from the seed) from
//! **per-round jitter** (network weather: an independent factor per
//! `(client, round)`).  Both streams come from labeled
//! [`Rng::derive`](crate::util::rng::Rng::derive) children, so no draw
//! order dependence exists — `round_secs(c, m)` is a pure function.

use anyhow::{bail, ensure, Result};

use crate::util::rng::Rng;

/// Shape of the simulated per-client latency distribution.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LatencyProfile {
    /// No simulation: every client costs 0 simulated seconds.  Cohort
    /// selection still works (deadline ties break by client id) and the
    /// per-round simulated makespan is 0.
    Off,
    /// Persistent per-client cost uniform in `[lo, hi]` seconds, with a
    /// ±20% per-round jitter factor.
    Uniform {
        /// Fastest client's base round seconds.
        lo: f64,
        /// Slowest client's base round seconds.
        hi: f64,
    },
    /// Log-normal cost around `median` seconds: the classic heavy-tailed
    /// straggler shape (most clients fast, a few very slow).  The
    /// persistent per-client factor is `exp(sigma * z)`; per-round
    /// jitter uses a third of the same sigma.
    LogNormal {
        /// Median base round seconds across clients.
        median: f64,
        /// Log-scale spread; 0 collapses to a constant `median`.
        sigma: f64,
    },
}

impl LatencyProfile {
    /// Parse `off`, `uniform:<lo>:<hi>` or `lognormal:<median>:<sigma>`.
    pub fn parse(s: &str) -> Result<Self> {
        let mut it = s.split(':');
        let head = it.next().unwrap_or("");
        let args: Vec<&str> = it.collect();
        match head {
            "off" => {
                ensure!(args.is_empty(), "off takes no arguments");
                Ok(LatencyProfile::Off)
            }
            "uniform" => {
                ensure!(args.len() == 2, "want uniform:<lo>:<hi>");
                let lo: f64 = args[0].parse()?;
                let hi: f64 = args[1].parse()?;
                ensure!(
                    lo.is_finite() && hi.is_finite() && 0.0 <= lo && lo <= hi,
                    "uniform needs 0 <= lo <= hi"
                );
                Ok(LatencyProfile::Uniform { lo, hi })
            }
            "lognormal" => {
                ensure!(args.len() == 2, "want lognormal:<median>:<sigma>");
                let median: f64 = args[0].parse()?;
                let sigma: f64 = args[1].parse()?;
                ensure!(
                    median.is_finite() && median > 0.0,
                    "lognormal median must be positive"
                );
                ensure!(sigma.is_finite() && sigma >= 0.0, "lognormal sigma must be >= 0");
                Ok(LatencyProfile::LogNormal { median, sigma })
            }
            _ => bail!("unknown latency profile {s:?} (want off|uniform:<lo>:<hi>|lognormal:<median>:<sigma>)"),
        }
    }

    /// True when every draw is the same value — `off`, `uniform:0:0`
    /// (zero base kills the jitter factor too) or `lognormal:<m>:0`.
    /// The deadline policy rejects constant profiles: with all
    /// candidates tied, its client-id tie-break would keep the lowest
    /// ids round after round, permanently excluding high-id clients.
    pub fn is_constant(&self) -> bool {
        match self {
            LatencyProfile::Off => true,
            // lo == hi still spreads via the per-round jitter factor —
            // unless the base itself is 0, which zeroes everything.
            LatencyProfile::Uniform { hi, .. } => *hi == 0.0,
            LatencyProfile::LogNormal { sigma, .. } => *sigma == 0.0,
        }
    }

    /// The canonical string form, parseable by [`Self::parse`] (used by
    /// the config JSON round-trip).
    pub fn label(&self) -> String {
        match self {
            LatencyProfile::Off => "off".to_string(),
            LatencyProfile::Uniform { lo, hi } => format!("uniform:{lo}:{hi}"),
            LatencyProfile::LogNormal { median, sigma } => format!("lognormal:{median}:{sigma}"),
        }
    }
}

impl std::str::FromStr for LatencyProfile {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Self::parse(s)
    }
}

impl std::fmt::Display for LatencyProfile {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.label())
    }
}

/// Deterministic simulated per-client round cost, seeded from the run.
#[derive(Clone, Debug)]
pub struct LatencyModel {
    profile: LatencyProfile,
    root: Rng,
}

impl LatencyModel {
    /// Build the model for one run; `seed` is the run's root seed (the
    /// model derives its own independent stream from it).
    pub fn new(profile: LatencyProfile, seed: u64) -> LatencyModel {
        LatencyModel { profile, root: Rng::new(seed).derive("sim.latency") }
    }

    /// The configured profile.
    pub fn profile(&self) -> LatencyProfile {
        self.profile
    }

    /// Simulated wall-clock seconds for `client_id` to complete round
    /// `round` (download, local steps, upload — the scheduler treats it
    /// as one opaque cost).  A pure function of `(seed, profile,
    /// client_id, round)`: always finite and `>= 0`.
    pub fn round_secs(&self, client_id: u32, round: u32) -> f64 {
        match self.profile {
            LatencyProfile::Off => 0.0,
            LatencyProfile::Uniform { lo, hi } => {
                let mut base_rng = self.root.derive(&format!("c{client_id}.base"));
                let mut round_rng = self.root.derive(&format!("c{client_id}.r{round}"));
                let base = lo + (hi - lo) * base_rng.next_f64();
                // ±20% round-to-round jitter, never negative.
                let jitter = 0.8 + 0.4 * round_rng.next_f64();
                base * jitter
            }
            LatencyProfile::LogNormal { median, sigma } => {
                let mut base_rng = self.root.derive(&format!("c{client_id}.base"));
                let mut round_rng = self.root.derive(&format!("c{client_id}.r{round}"));
                let zc = base_rng.next_normal() as f64;
                let zr = round_rng.next_normal() as f64;
                // Persistent spread at full sigma, round jitter at a
                // third — slow clients stay slow across rounds.
                median * (sigma * zc + (sigma / 3.0) * zr).exp()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_profiles_are_detected() {
        assert!(LatencyProfile::Off.is_constant());
        assert!(LatencyProfile::LogNormal { median: 1.0, sigma: 0.0 }.is_constant());
        assert!(LatencyProfile::Uniform { lo: 0.0, hi: 0.0 }.is_constant());
        // lo == hi > 0 still spreads through the per-round jitter
        assert!(!LatencyProfile::Uniform { lo: 1.0, hi: 1.0 }.is_constant());
        assert!(!LatencyProfile::Uniform { lo: 0.5, hi: 1.5 }.is_constant());
        assert!(!LatencyProfile::LogNormal { median: 1.0, sigma: 0.3 }.is_constant());
        // and the detector is truthful: a "spreading" profile really
        // produces distinct draws, a constant one does not
        let spread = LatencyModel::new(LatencyProfile::Uniform { lo: 1.0, hi: 1.0 }, 9);
        assert_ne!(spread.round_secs(0, 0), spread.round_secs(1, 0));
        let flat = LatencyModel::new(LatencyProfile::LogNormal { median: 2.0, sigma: 0.0 }, 9);
        assert_eq!(flat.round_secs(0, 0), flat.round_secs(1, 0));
    }

    #[test]
    fn parse_and_label_roundtrip() {
        for s in ["off", "uniform:0.5:2", "lognormal:1:0.8"] {
            let p = LatencyProfile::parse(s).unwrap();
            assert_eq!(LatencyProfile::parse(&p.label()).unwrap(), p);
        }
        assert!(LatencyProfile::parse("uniform:2:1").is_err()); // lo > hi
        assert!(LatencyProfile::parse("uniform:1").is_err());
        assert!(LatencyProfile::parse("lognormal:0:1").is_err()); // median 0
        assert!(LatencyProfile::parse("gaussian:1:1").is_err());
        assert!(LatencyProfile::parse("off:1").is_err());
    }

    #[test]
    fn fromstr_display_roundtrip_property() {
        // parse -> Display -> parse is the identity for arbitrary valid
        // profiles (seeded generator; FromStr/Display are what the CLI
        // uses, so this is the CLI syntax contract)
        let mut rng = Rng::new(41).derive("latency.prop");
        for i in 0..200u32 {
            let p = match i % 3 {
                0 => LatencyProfile::Off,
                1 => {
                    let lo = (rng.next_f64() * 10.0 * 1000.0).round() / 1000.0;
                    let hi = lo + (rng.next_f64() * 10.0 * 1000.0).round() / 1000.0;
                    LatencyProfile::Uniform { lo, hi }
                }
                _ => LatencyProfile::LogNormal {
                    median: ((rng.next_f64() * 10.0 * 1000.0).round() / 1000.0).max(0.001),
                    sigma: (rng.next_f64() * 3.0 * 1000.0).round() / 1000.0,
                },
            };
            let shown = p.to_string();
            let back: LatencyProfile = shown.parse().unwrap();
            assert_eq!(back, p, "{shown}");
            assert_eq!(back.to_string(), shown, "display must be canonical");
        }
    }

    #[test]
    fn draws_are_pure_functions_of_seed_client_round() {
        let a = LatencyModel::new(LatencyProfile::LogNormal { median: 1.0, sigma: 0.8 }, 17);
        let b = LatencyModel::new(LatencyProfile::LogNormal { median: 1.0, sigma: 0.8 }, 17);
        for c in 0..10u32 {
            for m in 0..5u32 {
                // identical across instances, and across call order
                assert_eq!(a.round_secs(c, m).to_bits(), b.round_secs(c, m).to_bits());
                assert_eq!(a.round_secs(c, m).to_bits(), a.round_secs(c, m).to_bits());
            }
        }
        let other = LatencyModel::new(LatencyProfile::LogNormal { median: 1.0, sigma: 0.8 }, 18);
        let differs = (0..10u32).any(|c| other.round_secs(c, 0) != a.round_secs(c, 0));
        assert!(differs, "different seeds must yield different draws");
    }

    #[test]
    fn persistent_heterogeneity_dominates_round_jitter() {
        // A client's costs across rounds must correlate: the slowest
        // client at round 0 stays in the slow half at round 1, for
        // (at least) most seeds — per-round jitter is a third of the
        // persistent spread, so this holds overwhelmingly often.
        let mut wins = 0;
        for seed in 0..5u64 {
            let m =
                LatencyModel::new(LatencyProfile::LogNormal { median: 1.0, sigma: 1.0 }, seed);
            let n = 32u32;
            let at =
                |round: u32| -> Vec<f64> { (0..n).map(|c| m.round_secs(c, round)).collect() };
            let r0 = at(0);
            let r1 = at(1);
            let slowest =
                (0..n as usize).max_by(|&a, &b| r0[a].total_cmp(&r0[b])).unwrap();
            let median1 = {
                let mut s = r1.clone();
                s.sort_by(f64::total_cmp);
                s[s.len() / 2]
            };
            if r1[slowest] > median1 {
                wins += 1;
            }
        }
        assert!(wins >= 3, "straggler persistence held for only {wins}/5 seeds");
    }

    #[test]
    fn uniform_respects_bounds_and_off_is_free() {
        let m = LatencyModel::new(LatencyProfile::Uniform { lo: 1.0, hi: 3.0 }, 3);
        for c in 0..20u32 {
            let s = m.round_secs(c, 0);
            // base in [1, 3], jitter in [0.8, 1.2)
            assert!(s >= 0.8 && s < 3.6, "{s}");
        }
        let off = LatencyModel::new(LatencyProfile::Off, 3);
        assert_eq!(off.round_secs(0, 0), 0.0);
    }
}
