//! Hand-rolled CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag value`, `--flag=value` and boolean `--flag`; unknown
//! flags are an error with the list of accepted ones, so typos fail fast.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Parsed arguments: positional words + `--key value` options.
#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    opts: BTreeMap<String, String>,
    taken: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse a raw argv slice (without the program name).
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(flag) = a.strip_prefix("--") {
                if let Some((k, v)) = flag.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    args.opts
                        .insert(flag.to_string(), it.next().unwrap().clone());
                } else {
                    args.opts.insert(flag.to_string(), "true".to_string());
                }
            } else {
                args.positional.push(a.clone());
            }
        }
        Ok(args)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.taken.borrow_mut().push(key.to_string());
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_parse<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("--{key} {v:?}: {e}")),
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Error out on any flag that was never consumed (typo guard).
    /// Call after all `get*` calls.
    pub fn finish(&self) -> Result<()> {
        let taken = self.taken.borrow();
        let unknown: Vec<&String> = self
            .opts
            .keys()
            .filter(|k| !taken.contains(k))
            .collect();
        if !unknown.is_empty() {
            bail!(
                "unknown flag(s): {}; accepted: {}",
                unknown
                    .iter()
                    .map(|k| format!("--{k}"))
                    .collect::<Vec<_>>()
                    .join(", "),
                taken
                    .iter()
                    .map(|k| format!("--{k}"))
                    .collect::<Vec<_>>()
                    .join(", ")
            );
        }
        Ok(())
    }
}

/// Build a [`crate::config::RunConfig`] from common training flags.
pub fn run_config_from_args(args: &Args, default_model: &str) -> Result<crate::config::RunConfig> {
    let model = args.get_or("model", default_model).to_string();
    let mut cfg = crate::config::RunConfig::default_for(&model);
    if let Some(p) = args.get("policy") {
        cfg.policy = crate::quant::PolicyConfig::parse(p)?;
    }
    if let Some(r) = args.get_parse::<usize>("rounds")? {
        cfg.rounds = r;
    }
    if let Some(lr) = args.get_parse::<f32>("lr")? {
        cfg.lr = lr;
    }
    if let Some(s) = args.get_parse::<u64>("seed")? {
        cfg.seed = s;
    }
    if let Some(sh) = args.get("sharding") {
        cfg.sharding = crate::data::shard::Sharding::parse(sh)?;
    }
    if let Some(ds) = args.get("dataset") {
        cfg.dataset = crate::data::DatasetKind::parse(ds)?;
    }
    if let Some(e) = args.get_parse::<usize>("eval-every")? {
        cfg.eval_every = e;
    }
    if let Some(t) = args.get_parse::<usize>("train-size")? {
        cfg.train_size = t;
    }
    if let Some(t) = args.get_parse::<usize>("test-size")? {
        cfg.test_size = t;
    }
    if let Some(a) = args.get("artifacts") {
        cfg.artifacts_dir = a.to_string();
    }
    if let Some(d) = args.get("data-dir") {
        cfg.data_dir = d.to_string();
    }
    if let Some(t) = args.get_parse::<f32>("target-acc")? {
        cfg.target_accuracy = Some(t);
    }
    if args.flag("error-feedback") {
        cfg.error_feedback = true;
    }
    if let Some(t) = args.get_parse::<usize>("threads")? {
        cfg.threads = t;
    }
    if let Some(a) = args.get("aggregate") {
        cfg.aggregate = crate::config::AggregateMode::parse(a)?;
    }
    if let Some(s) = args.get_parse::<usize>("agg-shards")? {
        cfg.agg_shards = s;
    }
    if let Some(t) = args.get_parse::<usize>("eval-threads")? {
        cfg.eval_threads = t;
    }
    if let Some(b) = args.get_parse::<usize>("decode-buffers")? {
        cfg.decode_buffers = b;
    }
    if let Some(f) = args.get_parse::<bool>("fold-overlap")? {
        cfg.fold_overlap = f;
    }
    if let Some(c) = args.get("codec") {
        cfg.codec = crate::config::CodecMode::parse(c)?;
    }
    cfg.validate().context("invalid run config")?;
    Ok(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_styles() {
        // NB: a bare word after a flag is consumed as that flag's value
        // (schema-less parser), so positionals go before flags.
        let a = Args::parse(&argv("train x --model mlp --rounds=30 --verbose")).unwrap();
        assert_eq!(a.positional, vec!["train", "x"]);
        assert_eq!(a.get("model"), Some("mlp"));
        assert_eq!(a.get_parse::<usize>("rounds").unwrap(), Some(30));
        assert!(a.flag("verbose"));
        a.finish().unwrap();
    }

    #[test]
    fn unknown_flags_rejected() {
        let a = Args::parse(&argv("--modle mlp")).unwrap();
        let _ = a.get("model");
        assert!(a.finish().is_err());
    }

    #[test]
    fn bad_parse_is_error() {
        let a = Args::parse(&argv("--rounds ten")).unwrap();
        assert!(a.get_parse::<usize>("rounds").is_err());
    }

    #[test]
    fn config_from_args() {
        let a = Args::parse(&argv(
            "--model cnn4 --policy adaquantfl:4 --rounds 12 --lr 0.05 \
             --sharding dirichlet:0.5 --target-acc 0.8 --threads 4 \
             --aggregate fused --agg-shards 6 --eval-threads 2 \
             --decode-buffers 3 --fold-overlap false --codec reference",
        ))
        .unwrap();
        let cfg = run_config_from_args(&a, "mlp").unwrap();
        assert_eq!(cfg.model, "cnn4");
        assert_eq!(cfg.rounds, 12);
        assert_eq!(cfg.lr, 0.05);
        assert_eq!(cfg.target_accuracy, Some(0.8));
        assert_eq!(cfg.threads, 4);
        assert_eq!(cfg.aggregate, crate::config::AggregateMode::Fused);
        assert_eq!(cfg.agg_shards, 6);
        assert_eq!(cfg.eval_threads, 2);
        assert_eq!(cfg.decode_buffers, 3);
        assert!(!cfg.fold_overlap);
        assert_eq!(cfg.codec, crate::config::CodecMode::Reference);
        a.finish().unwrap();
    }

    #[test]
    fn bad_aggregate_mode_rejected() {
        let a = Args::parse(&argv("--aggregate turbo")).unwrap();
        assert!(run_config_from_args(&a, "mlp").is_err());
    }
}
