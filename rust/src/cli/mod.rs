//! Hand-rolled CLI argument parser (clap is unavailable offline), plus
//! the `feddq` binary's canonical usage text.
//!
//! Supports `--flag value`, `--flag=value` and boolean `--flag`; unknown
//! flags are an error with the list of accepted ones, so typos fail fast.
//!
//! [`USAGE`] and [`KNOWN_FLAGS`] live here (not in `main.rs`) so tests
//! can hold them honest: every accepted flag must appear in the usage
//! text, every `--flag` token in the usage text must be accepted, and
//! the fenced usage block in `docs/CLI.md` must match [`USAGE`]
//! byte-for-byte (see the tests at the bottom of this file).

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// The `feddq` binary's usage text (printed on `feddq` with no args).
/// `docs/CLI.md` embeds this exact text; a test diffs the two.
pub const USAGE: &str = "\
feddq — communication-efficient federated learning with descending quantization

USAGE: feddq <COMMAND> [FLAGS]

COMMANDS:
  train      run a federated training session in-process
  serve      run the federated server (TCP), waiting for workers
  worker     run one federated client process (TCP)
  aggregate  run one intermediate aggregator process (TCP tree)
  info       print the artifact manifest summary

TRAIN FLAGS (all also accepted by serve, which runs the same rounds over TCP):
  --model <mlp|vanilla_cnn|cnn4|resnet18>   model/benchmark    [mlp]
  --policy <feddq[:res]|feddq-whole[:res]|adaquantfl[:s0]|fixed:<bits>|fp32>
                        uplink quantization policy             [feddq:0.005]
  --rounds <n>          communication rounds                   [50]
  --lr <f>              local SGD step size                    [0.1]
  --seed <n>            root seed                              [17]
  --sharding <iid|dirichlet:<alpha>>                           [iid]
  --dataset <fashion_mnist|cifar10>  (must match the model)    [per model]
  --eval-every <k>      evaluate every k rounds                [1]
  --train-size <n>      synthetic train set size               [4000]
  --test-size <n>       synthetic test set size                [1000]
  --target-acc <f>      stop at this test accuracy             [off]
  --error-feedback      bank quantization residuals (EF-SGD)   [off]
  --ef-bits <b>         store banked residuals at b<=8 bits    [0 = fp32]
  --fanout <n>          aggregation-tree fanout, 0 = flat      [0]
  --threads <n>         client worker threads (0 = cores)      [0]
  --aggregate <streaming|fused>  server aggregation path       [streaming]
  --agg-shards <n>      accumulator shards (0 = pool, 1 = serial) [0]
  --eval-threads <n>    server eval slices (0 = pool, 1 = serial)  [0]
  --decode-buffers <n>  decode-buffer bound (0 = one per client)   [0]
  --fold-overlap <bool> overlap the shard fold with receives       [true]
  --codec <narrow|reference>  SWAR u16 rows vs scalar f32 oracle   [narrow]
  --participation <f>   client fraction sampled per round, (0,1]   [1.0]
  --round-deadline <s>  simulated round deadline (needs --sim-latency) [off]
  --sim-latency <off|uniform:<lo>:<hi>|lognormal:<median>:<sigma>>
                        simulated per-client latency model         [off]
  --sim-faults <off|crash:<p>|stall:<p>:<secs>|flaky:<p>>
                        simulated per-client fault model           [off]
  --round-timeout <s>   give up on missing updates after s seconds [off]
  --quorum <f>          update fraction that completes a round, (0,1] [1.0]
  --staleness <k>       accept up to k-round-late updates, discounted  [0]
  --bit-budget <bits>   round-level uplink payload bit budget, split per
                        client per segment (0 = off; needs --error-feedback) [0]
  --downlink-bits <b>   quantize the server broadcast to b bits (1..=16, needs
                        --error-feedback; 32 = fp32 ledger only; 0 = off)  [0]
  --artifacts <dir>     AOT artifacts directory                [artifacts]
  --data-dir <dir>      real dataset directory                 [data]
  --out <path>          write the per-round report (.csv/.json)
  --quiet               suppress per-round progress
  --verbose             debug logging

SERVE/WORKER/AGGREGATE FLAGS:
  --addr <host:port>    address to serve on / connect to       [127.0.0.1:7177]
  --id <n>              worker client id, or the aggregator's
                        lowest leaf id (worker/aggregate)
  --upstream <host:port> parent server address (aggregate only) [127.0.0.1:7177]
  --artifacts <dir>     AOT artifacts directory (worker/aggregate too)
";

/// Every flag the `feddq` binary accepts across its subcommands; tests
/// assert [`USAGE`] and `docs/CLI.md` mention each, and nothing else.
pub const KNOWN_FLAGS: &[&str] = &[
    "model",
    "policy",
    "rounds",
    "lr",
    "seed",
    "sharding",
    "dataset",
    "eval-every",
    "train-size",
    "test-size",
    "target-acc",
    "error-feedback",
    "ef-bits",
    "fanout",
    "threads",
    "aggregate",
    "agg-shards",
    "eval-threads",
    "decode-buffers",
    "fold-overlap",
    "codec",
    "participation",
    "round-deadline",
    "sim-latency",
    "sim-faults",
    "round-timeout",
    "quorum",
    "staleness",
    "bit-budget",
    "downlink-bits",
    "artifacts",
    "data-dir",
    "out",
    "quiet",
    "verbose",
    "addr",
    "id",
    "upstream",
];

/// Parsed arguments: positional words + `--key value` options.
#[derive(Debug, Default)]
pub struct Args {
    /// Bare words in argv order (subcommand names and the like).
    pub positional: Vec<String>,
    opts: BTreeMap<String, String>,
    taken: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse a raw argv slice (without the program name).
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(flag) = a.strip_prefix("--") {
                if let Some((k, v)) = flag.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    args.opts
                        .insert(flag.to_string(), it.next().unwrap().clone());
                } else {
                    args.opts.insert(flag.to_string(), "true".to_string());
                }
            } else {
                args.positional.push(a.clone());
            }
        }
        Ok(args)
    }

    /// Look up `--key`'s value, marking the flag as consumed (the
    /// [`Self::finish`] typo guard only accepts consumed flags).
    pub fn get(&self, key: &str) -> Option<&str> {
        self.taken.borrow_mut().push(key.to_string());
        self.opts.get(key).map(|s| s.as_str())
    }

    /// [`Self::get`] with a default for absent flags.
    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// [`Self::get`] parsed into `T`; `Ok(None)` when absent, an error
    /// naming the flag when the value does not parse.
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("--{key} {v:?}: {e}")),
        }
    }

    /// Boolean flag: true for bare `--key` or `--key true|1|yes`.
    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Error out on any flag that was never consumed (typo guard).
    /// Call after all `get*` calls.
    pub fn finish(&self) -> Result<()> {
        let taken = self.taken.borrow();
        let unknown: Vec<&String> = self
            .opts
            .keys()
            .filter(|k| !taken.contains(k))
            .collect();
        if !unknown.is_empty() {
            bail!(
                "unknown flag(s): {}; accepted: {}",
                unknown
                    .iter()
                    .map(|k| format!("--{k}"))
                    .collect::<Vec<_>>()
                    .join(", "),
                taken
                    .iter()
                    .map(|k| format!("--{k}"))
                    .collect::<Vec<_>>()
                    .join(", ")
            );
        }
        Ok(())
    }
}

/// Build a [`crate::config::RunConfig`] from common training flags.
pub fn run_config_from_args(args: &Args, default_model: &str) -> Result<crate::config::RunConfig> {
    let model = args.get_or("model", default_model).to_string();
    let mut cfg = crate::config::RunConfig::default_for(&model);
    if let Some(p) = args.get("policy") {
        cfg.policy = crate::quant::PolicyConfig::parse(p)?;
    }
    if let Some(r) = args.get_parse::<usize>("rounds")? {
        cfg.rounds = r;
    }
    if let Some(lr) = args.get_parse::<f32>("lr")? {
        cfg.lr = lr;
    }
    if let Some(s) = args.get_parse::<u64>("seed")? {
        cfg.seed = s;
    }
    if let Some(sh) = args.get("sharding") {
        cfg.sharding = crate::data::shard::Sharding::parse(sh)?;
    }
    if let Some(ds) = args.get("dataset") {
        cfg.dataset = crate::data::DatasetKind::parse(ds)?;
    }
    if let Some(e) = args.get_parse::<usize>("eval-every")? {
        cfg.eval_every = e;
    }
    if let Some(t) = args.get_parse::<usize>("train-size")? {
        cfg.train_size = t;
    }
    if let Some(t) = args.get_parse::<usize>("test-size")? {
        cfg.test_size = t;
    }
    if let Some(a) = args.get("artifacts") {
        cfg.artifacts_dir = a.to_string();
    }
    if let Some(d) = args.get("data-dir") {
        cfg.data_dir = d.to_string();
    }
    if let Some(t) = args.get_parse::<f32>("target-acc")? {
        cfg.target_accuracy = Some(t);
    }
    if args.flag("error-feedback") {
        cfg.error_feedback = true;
    }
    if let Some(b) = args.get_parse::<u32>("ef-bits")? {
        cfg.ef_bits = b;
    }
    if let Some(t) = args.get_parse::<usize>("threads")? {
        cfg.threads = t;
    }
    if let Some(a) = args.get("aggregate") {
        cfg.aggregate = crate::config::AggregateMode::parse(a)?;
    }
    if let Some(s) = args.get_parse::<usize>("agg-shards")? {
        cfg.agg_shards = s;
    }
    if let Some(t) = args.get_parse::<usize>("eval-threads")? {
        cfg.eval_threads = t;
    }
    // The sim models parse through their FromStr impls (same syntax as
    // before); latency goes first because the round-policy builder
    // validates the deadline against it.
    if let Some(l) = args.get_parse::<crate::sim::latency::LatencyProfile>("sim-latency")? {
        cfg.sim_latency = l;
    }
    if let Some(f) = args.get_parse::<crate::sim::faults::FaultProfile>("sim-faults")? {
        cfg.sim_faults = f;
    }
    // Round behavior flags compose through the typed RoundPolicy
    // builder — the single construction path, so the CLI gets the same
    // cross-field validation as programmatic configs.
    let mut rp = crate::config::RoundPolicy::builder();
    if let Some(p) = args.get_parse::<f32>("participation")? {
        rp = rp.participation(p);
    }
    if let Some(d) = args.get_parse::<f64>("round-deadline")? {
        rp = rp.deadline(d);
    }
    if let Some(q) = args.get_parse::<f32>("quorum")? {
        rp = rp.quorum(q);
    }
    if let Some(t) = args.get_parse::<f64>("round-timeout")? {
        rp = rp.round_timeout(t);
    }
    if let Some(k) = args.get_parse::<u32>("staleness")? {
        rp = rp.staleness(k);
    }
    if let Some(f) = args.get_parse::<bool>("fold-overlap")? {
        rp = rp.fold_overlap(f);
    }
    if let Some(b) = args.get_parse::<usize>("decode-buffers")? {
        rp = rp.decode_buffers(b);
    }
    if let Some(c) = args.get_parse::<crate::config::CodecMode>("codec")? {
        rp = rp.codec(c);
    }
    if let Some(f) = args.get_parse::<u32>("fanout")? {
        rp = rp.fanout(f);
    }
    if let Some(b) = args.get_parse::<u64>("bit-budget")? {
        rp = rp.bit_budget(b);
    }
    if let Some(b) = args.get_parse::<u32>("downlink-bits")? {
        rp = rp.downlink_bits(b);
    }
    cfg.round = rp
        .latency_context(cfg.sim_latency)
        .build()
        .context("invalid round policy")?;
    cfg.validate().context("invalid run config")?;
    Ok(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_styles() {
        // NB: a bare word after a flag is consumed as that flag's value
        // (schema-less parser), so positionals go before flags.
        let a = Args::parse(&argv("train x --model mlp --rounds=30 --verbose")).unwrap();
        assert_eq!(a.positional, vec!["train", "x"]);
        assert_eq!(a.get("model"), Some("mlp"));
        assert_eq!(a.get_parse::<usize>("rounds").unwrap(), Some(30));
        assert!(a.flag("verbose"));
        a.finish().unwrap();
    }

    #[test]
    fn unknown_flags_rejected() {
        let a = Args::parse(&argv("--modle mlp")).unwrap();
        let _ = a.get("model");
        assert!(a.finish().is_err());
    }

    #[test]
    fn bad_parse_is_error() {
        let a = Args::parse(&argv("--rounds ten")).unwrap();
        assert!(a.get_parse::<usize>("rounds").is_err());
    }

    #[test]
    fn config_from_args() {
        let a = Args::parse(&argv(
            "--model cnn4 --policy adaquantfl:4 --rounds 12 --lr 0.05 \
             --sharding dirichlet:0.5 --target-acc 0.8 --threads 4 \
             --aggregate fused --agg-shards 6 --eval-threads 2 \
             --decode-buffers 3 --fold-overlap false --codec reference \
             --participation 0.5 --round-deadline 2.5 \
             --sim-latency lognormal:1:0.8 --sim-faults crash:0.1 \
             --round-timeout 20 --quorum 0.6 --staleness 2",
        ))
        .unwrap();
        let cfg = run_config_from_args(&a, "mlp").unwrap();
        assert_eq!(cfg.model, "cnn4");
        assert_eq!(cfg.rounds, 12);
        assert_eq!(cfg.lr, 0.05);
        assert_eq!(cfg.target_accuracy, Some(0.8));
        assert_eq!(cfg.threads, 4);
        assert_eq!(cfg.aggregate, crate::config::AggregateMode::Fused);
        assert_eq!(cfg.agg_shards, 6);
        assert_eq!(cfg.eval_threads, 2);
        assert_eq!(cfg.round.pipeline.decode_buffers, 3);
        assert!(!cfg.round.pipeline.fold_overlap);
        assert_eq!(cfg.round.pipeline.codec, crate::config::CodecMode::Reference);
        assert_eq!(cfg.round.cohort.participation, 0.5);
        assert_eq!(cfg.round.cohort.deadline, Some(2.5));
        assert_eq!(
            cfg.sim_latency,
            crate::sim::latency::LatencyProfile::LogNormal { median: 1.0, sigma: 0.8 }
        );
        assert_eq!(
            cfg.sim_faults,
            crate::sim::faults::FaultProfile::Crash { p: 0.1 }
        );
        assert_eq!(cfg.round.tolerance.round_timeout, Some(20.0));
        assert_eq!(cfg.round.tolerance.quorum, 0.6);
        assert_eq!(cfg.round.tolerance.staleness, 2);
        a.finish().unwrap();
    }

    #[test]
    fn topology_and_banking_flags() {
        let a = Args::parse(&argv("--fanout 2 --error-feedback --ef-bits 4")).unwrap();
        let cfg = run_config_from_args(&a, "mlp").unwrap();
        assert_eq!(cfg.round.topology.fanout, 2);
        assert_eq!(cfg.ef_bits, 4);
        assert!(cfg.error_feedback);
        a.finish().unwrap();
        // fanout=1 is a degenerate tree: rejected by the builder
        let a = Args::parse(&argv("--fanout 1")).unwrap();
        assert!(run_config_from_args(&a, "mlp").is_err());
        // banked residuals require error feedback to exist at all
        let a = Args::parse(&argv("--ef-bits 4")).unwrap();
        assert!(run_config_from_args(&a, "mlp").is_err());
        // simulated faults compose with the tree: draws are pure over
        // leaf ids and the grouping excludes failed leaves identically
        // on every topology
        let a = Args::parse(&argv("--fanout 2 --sim-faults crash:0.1")).unwrap();
        assert!(run_config_from_args(&a, "mlp").is_ok());
    }

    #[test]
    fn bad_aggregate_mode_rejected() {
        let a = Args::parse(&argv("--aggregate turbo")).unwrap();
        assert!(run_config_from_args(&a, "mlp").is_err());
    }

    #[test]
    fn bad_scheduler_flags_rejected() {
        let a = Args::parse(&argv("--participation 1.5")).unwrap();
        assert!(run_config_from_args(&a, "mlp").is_err());
        let a = Args::parse(&argv("--round-deadline -2")).unwrap();
        assert!(run_config_from_args(&a, "mlp").is_err());
        let a = Args::parse(&argv("--sim-latency gaussian:1:1")).unwrap();
        assert!(run_config_from_args(&a, "mlp").is_err());
        // deadline without a latency model: rejected by validate
        let a = Args::parse(&argv("--round-deadline 2")).unwrap();
        assert!(run_config_from_args(&a, "mlp").is_err());
        let a = Args::parse(&argv("--round-deadline 2 --sim-latency lognormal:1:0.5")).unwrap();
        assert!(run_config_from_args(&a, "mlp").is_ok());
    }

    #[test]
    fn bad_robustness_flags_rejected() {
        let a = Args::parse(&argv("--sim-faults meteor:0.5")).unwrap();
        assert!(run_config_from_args(&a, "mlp").is_err());
        let a = Args::parse(&argv("--sim-faults crash:1.5")).unwrap();
        assert!(run_config_from_args(&a, "mlp").is_err());
        let a = Args::parse(&argv("--round-timeout 0")).unwrap();
        assert!(run_config_from_args(&a, "mlp").is_err());
        let a = Args::parse(&argv("--quorum 0")).unwrap();
        assert!(run_config_from_args(&a, "mlp").is_err());
        let a = Args::parse(&argv("--quorum 1.5")).unwrap();
        assert!(run_config_from_args(&a, "mlp").is_err());
        let a = Args::parse(&argv("--sim-faults crash:0.2 --quorum 0.5 --round-timeout 30")).unwrap();
        assert!(run_config_from_args(&a, "mlp").is_ok());
        // staleness needs a quorum mode (quorum < 1 or a timeout) —
        // bounded-staleness rounds must be able to close early
        let a = Args::parse(&argv("--staleness 2")).unwrap();
        assert!(run_config_from_args(&a, "mlp").is_err());
        let a = Args::parse(&argv("--staleness 2 --quorum 0.5")).unwrap();
        assert!(run_config_from_args(&a, "mlp").is_ok());
        let a = Args::parse(&argv("--staleness 2 --round-timeout 30")).unwrap();
        assert!(run_config_from_args(&a, "mlp").is_ok());
    }

    #[test]
    fn bad_budget_flags_rejected() {
        // a quantized downlink is lossy: EF required
        let a = Args::parse(&argv("--downlink-bits 3")).unwrap();
        assert!(run_config_from_args(&a, "mlp").is_err());
        // out-of-range widths
        let a = Args::parse(&argv("--downlink-bits 40 --error-feedback")).unwrap();
        assert!(run_config_from_args(&a, "mlp").is_err());
        let a = Args::parse(&argv("--downlink-bits 17 --error-feedback")).unwrap();
        assert!(run_config_from_args(&a, "mlp").is_err());
        // an uplink budget clamps the policy: EF required too
        let a = Args::parse(&argv("--bit-budget 1000")).unwrap();
        assert!(run_config_from_args(&a, "mlp").is_err());
        // good compositions
        let a = Args::parse(&argv("--downlink-bits 3 --error-feedback")).unwrap();
        let cfg = run_config_from_args(&a, "mlp").unwrap();
        assert_eq!(cfg.round.budget.downlink_bits, 3);
        let a = Args::parse(&argv("--bit-budget 1000000 --error-feedback --ef-bits 4")).unwrap();
        let cfg = run_config_from_args(&a, "mlp").unwrap();
        assert_eq!(cfg.round.budget.bit_budget, 1_000_000);
        // 32 = lossless fp32 ledger: no EF needed
        let a = Args::parse(&argv("--downlink-bits 32")).unwrap();
        let cfg = run_config_from_args(&a, "mlp").unwrap();
        assert_eq!(cfg.round.budget.downlink_bits, 32);
        // 0 = off is always fine
        let a = Args::parse(&argv("--downlink-bits 0 --bit-budget 0")).unwrap();
        assert!(run_config_from_args(&a, "mlp").is_ok());
    }

    /// Every `--flag` token appearing in [`USAGE`].
    fn usage_flags() -> Vec<String> {
        let mut out = Vec::new();
        let bytes = USAGE.as_bytes();
        let mut i = 0;
        while i + 1 < bytes.len() {
            if bytes[i] == b'-' && bytes[i + 1] == b'-' {
                let start = i + 2;
                let mut end = start;
                while end < bytes.len()
                    && (bytes[end].is_ascii_alphanumeric() || bytes[end] == b'-')
                {
                    end += 1;
                }
                if end > start {
                    out.push(String::from_utf8_lossy(&bytes[start..end]).into_owned());
                }
                i = end;
            } else {
                i += 1;
            }
        }
        out.sort();
        out.dedup();
        out
    }

    #[test]
    fn known_flags_match_what_the_commands_actually_consume() {
        // KNOWN_FLAGS must not be a third hand-maintained list: derive
        // the truly accepted set from the parser's own consumption
        // ledger (`Args::taken` records every get, present or not) by
        // exercising the config builder plus each command's extra gets
        // (mirroring main.rs), and diff it against KNOWN_FLAGS.  Adding
        // a flag to run_config_from_args without updating KNOWN_FLAGS —
        // and hence USAGE and docs/CLI.md — now fails here.
        let a = Args::parse(&[]).unwrap();
        run_config_from_args(&a, "mlp").unwrap();
        // train: --out/--quiet; dispatch: --verbose; serve/worker: --addr/--id;
        // aggregate: --upstream (its --addr/--id/--fanout/--artifacts overlap)
        let _ = a.get("out");
        let _ = a.get("quiet");
        let _ = a.get("verbose");
        let _ = a.get("addr");
        let _ = a.get("id");
        let _ = a.get("upstream");
        let consumed: std::collections::BTreeSet<String> =
            a.taken.borrow().iter().cloned().collect();
        let known: std::collections::BTreeSet<String> =
            KNOWN_FLAGS.iter().map(|s| s.to_string()).collect();
        assert_eq!(consumed, known, "KNOWN_FLAGS drifted from the flags the commands consume");
    }

    #[test]
    fn usage_lists_exactly_the_accepted_flags() {
        let in_usage = usage_flags();
        for f in KNOWN_FLAGS {
            assert!(
                in_usage.iter().any(|u| u == f),
                "--{f} is accepted but missing from USAGE"
            );
        }
        for u in &in_usage {
            assert!(
                KNOWN_FLAGS.contains(&u.as_str()),
                "--{u} appears in USAGE but no command accepts it"
            );
        }
    }

    #[test]
    fn cli_doc_usage_block_matches_binary() {
        // docs/CLI.md embeds USAGE in its first ```text fence; any
        // drift between the doc and the binary fails here.
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/docs/CLI.md");
        let doc = std::fs::read_to_string(path).expect("docs/CLI.md must exist");
        let fence = "```text\n";
        let start = doc.find(fence).expect("docs/CLI.md needs a ```text usage fence") + fence.len();
        let end = start + doc[start..].find("```").expect("unclosed usage fence");
        assert_eq!(
            &doc[start..end],
            USAGE,
            "docs/CLI.md usage block drifted from cli::USAGE — update the doc"
        );
        // and the prose must cover every flag at least once
        for f in KNOWN_FLAGS {
            assert!(doc.contains(&format!("--{f}")), "docs/CLI.md never mentions --{f}");
        }
    }
}
