//! Shared helpers for the figure/table bench binaries (`rust/benches/`).
//!
//! Every paper artifact regenerator funnels through [`run_policy`] so runs
//! are identically configured across figures, and prints through the same
//! series formatter so `bench_output.txt` is machine-greppable.
//!
//! Environment knobs (all optional):
//!   FEDDQ_BENCH_ROUNDS   override the per-figure round budget
//!   FEDDQ_BENCH_TRAIN    override train-set size
//!   FEDDQ_BENCH_FAST=1   quick mode (few rounds — smoke, not science)

use crate::config::RunConfig;
use crate::coordinator::Session;
use crate::metrics::{gbits, RunReport};
use crate::quant::PolicyConfig;
use crate::Result;

/// Per-benchmark workload defaults, scaled for the CPU backend (the
/// paper's round budgets: 100 / 82 / 25).
pub struct FigureSetup {
    /// Model name the figure benchmarks.
    pub model: &'static str,
    /// Communication rounds to run.
    pub rounds: usize,
    /// Train-set size (synthetic fallback).
    pub train_size: usize,
    /// Test-set size (synthetic fallback).
    pub test_size: usize,
    /// Evaluate every k rounds.
    pub eval_every: usize,
}

/// The shared workload defaults for `model`, honoring the env knobs in
/// the module docs.
pub fn setup_for(model: &'static str) -> FigureSetup {
    let fast = std::env::var("FEDDQ_BENCH_FAST").is_ok();
    // Round budgets tuned to the 1-core CPU testbed (~3s / ~7s / ~18s
    // per round for the three conv benchmarks; see EXPERIMENTS.md §Perf).
    let (rounds, train) = match model {
        "mlp" => (40, 2000),
        "vanilla_cnn" => (36, 2500),
        "cnn4" => (24, 1500),
        "resnet18" => (12, 800),
        _ => (30, 2000),
    };
    let rounds = std::env::var("FEDDQ_BENCH_ROUNDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if fast { rounds.min(6) } else { rounds });
    let train_size = std::env::var("FEDDQ_BENCH_TRAIN")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if fast { 1000 } else { train });
    FigureSetup {
        model,
        rounds,
        train_size,
        test_size: 500,
        // conv benchmarks evaluate every 2 rounds to keep eval cost <10%
        eval_every: if matches!(model, "cnn4" | "resnet18") { 2 } else { 1 },
    }
}

/// Run one (model, policy) cell with the shared setup.
pub fn run_policy(setup: &FigureSetup, policy: PolicyConfig) -> Result<RunReport> {
    let mut cfg = RunConfig::default_for(setup.model);
    cfg.policy = policy;
    cfg.rounds = setup.rounds;
    cfg.train_size = setup.train_size;
    cfg.test_size = setup.test_size;
    cfg.eval_every = setup.eval_every;
    let mut session = Session::new(cfg)?;
    session.run()
}

/// Print the per-round series the paper plots: both the vs-bits view
/// (Figs. 2a/3a/4a) and the vs-rounds view (Figs. 2b/3b/4b), plus the
/// bit-length curve (Fig. 5) and mean range (Fig. 1b).
pub fn print_series(report: &RunReport) {
    println!(
        "# {} — columns: round cum_Gb train_loss test_acc bits_per_elem mean_range",
        report.label
    );
    for r in &report.rounds {
        println!(
            "{:>4} {:>10.5} {:>9.4} {:>8.4} {:>6.2} {:>9.5}",
            r.round,
            gbits(r.cum_uplink_bits),
            r.train_loss,
            r.test_accuracy,
            r.mean_bits,
            r.mean_range,
        );
    }
}

/// The paper's Table-I style summary for one benchmark: bits and rounds
/// needed to reach `target` accuracy, FedDQ vs a baseline.
pub fn print_table1_row(
    bench: &str,
    target: f32,
    feddq: &RunReport,
    base_label: &str,
    base: &RunReport,
) {
    let f = feddq.rounds_to_accuracy(target);
    let b = base.rounds_to_accuracy(target);
    match (f, b) {
        (Some((fr, fb)), Some((br, bb))) => {
            let bit_red = 100.0 * (1.0 - fb as f64 / bb as f64);
            let round_red = 100.0 * (1.0 - fr as f64 / br as f64);
            println!(
                "{bench:<14} acc>={target:.2}: {base_label} {:.4} Gb / {br} rounds | FedDQ {:.4} Gb / {fr} rounds | reduced {bit_red:.1}% bits, {round_red:.1}% rounds",
                gbits(bb), gbits(fb)
            );
        }
        _ => {
            println!(
                "{bench:<14} acc>={target:.2}: target not reached (feddq best {:.3}, {base_label} best {:.3}) — raise rounds or lower target",
                feddq.best_accuracy(),
                base.best_accuracy()
            );
        }
    }
}

/// Write a report as CSV under reports/ (ignored dir), creating it.
pub fn save(report: &RunReport, name: &str) {
    std::fs::create_dir_all("reports").ok();
    let path = format!("reports/{name}.csv");
    if report.write_csv(&path).is_ok() {
        println!("# saved {path}");
    }
}

/// Write a flat `name -> number` JSON map as `BENCH_<name>.json` in the
/// working directory (the repo root under `cargo bench`), so the perf
/// trajectory is tracked across PRs instead of scraped from stdout.
/// Keys are sorted (BTreeMap) for stable diffs.
pub fn write_bench_json(name: &str, entries: &[(String, f64)]) {
    use crate::util::json::Json;
    let obj = Json::Obj(
        entries
            .iter()
            .map(|(k, v)| (k.clone(), Json::from(*v)))
            .collect(),
    );
    let path = format!("BENCH_{name}.json");
    match std::fs::write(&path, obj.to_string_pretty() + "\n") {
        Ok(()) => println!("# saved {path}"),
        Err(e) => eprintln!("# could not save {path}: {e}"),
    }
}
