//! Length-prefixed, checksummed framing for byte streams.
//!
//! Layout: `magic u32 | len u32 | crc32 u32 | payload[len]` (little-endian).
//! The CRC covers the payload only.  Used verbatim on TCP; the in-process
//! transport sends unframed buffers but accounts the same framed size so
//! both transports report identical bit volumes.

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

/// Frame magic: protocol marker + version.
pub const MAGIC: u32 = 0xFEDD_0001;
/// Frame header size: magic + length + CRC32, 4 bytes each.
pub const HEADER_BYTES: u64 = 12;

/// Maximum accepted frame (guards against corrupted length fields).
pub const MAX_FRAME: usize = 1 << 30;

/// CRC-32 (IEEE 802.3), slice-by-8.
///
/// §Perf: the classic byte-at-a-time table walk measured 0.41 GB/s on the
/// frame path (perf_hotpath bench); slice-by-8 processes a u64 per step
/// through eight derived tables and measures ~5x faster, taking framing
/// far off the uplink critical path (EXPERIMENTS.md §Perf L3-2).
pub fn crc32(data: &[u8]) -> u32 {
    fn tables() -> &'static [[u32; 256]; 8] {
        use std::sync::OnceLock;
        static TABLES: OnceLock<[[u32; 256]; 8]> = OnceLock::new();
        TABLES.get_or_init(|| {
            let mut t = [[0u32; 256]; 8];
            for i in 0..256usize {
                let mut c = i as u32;
                for _ in 0..8 {
                    c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
                }
                t[0][i] = c;
            }
            for i in 0..256usize {
                let mut c = t[0][i];
                for k in 1..8 {
                    c = t[0][(c & 0xFF) as usize] ^ (c >> 8);
                    t[k][i] = c;
                }
            }
            t
        })
    }
    let t = tables();
    let mut c = !0u32;
    let mut chunks = data.chunks_exact(8);
    for chunk in &mut chunks {
        let lo = u32::from_le_bytes(chunk[0..4].try_into().unwrap()) ^ c;
        let hi = u32::from_le_bytes(chunk[4..8].try_into().unwrap());
        c = t[7][(lo & 0xFF) as usize]
            ^ t[6][((lo >> 8) & 0xFF) as usize]
            ^ t[5][((lo >> 16) & 0xFF) as usize]
            ^ t[4][(lo >> 24) as usize]
            ^ t[3][(hi & 0xFF) as usize]
            ^ t[2][((hi >> 8) & 0xFF) as usize]
            ^ t[1][((hi >> 16) & 0xFF) as usize]
            ^ t[0][(hi >> 24) as usize];
    }
    for &b in chunks.remainder() {
        c = t[0][((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

/// Size on the wire of a payload of `len` bytes, including the header.
pub fn framed_len(payload_len: usize) -> u64 {
    HEADER_BYTES + payload_len as u64
}

/// Write one frame.
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> Result<()> {
    let mut header = [0u8; 12];
    header[0..4].copy_from_slice(&MAGIC.to_le_bytes());
    header[4..8].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    header[8..12].copy_from_slice(&crc32(payload).to_le_bytes());
    w.write_all(&header).context("frame header write")?;
    w.write_all(payload).context("frame payload write")?;
    w.flush().context("frame flush")?;
    Ok(())
}

/// Read one frame; verifies magic and CRC.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Vec<u8>> {
    let mut header = [0u8; 12];
    r.read_exact(&mut header).context("frame header read")?;
    let magic = u32::from_le_bytes(header[0..4].try_into().unwrap());
    if magic != MAGIC {
        bail!("bad frame magic {magic:#010x}");
    }
    let len = u32::from_le_bytes(header[4..8].try_into().unwrap()) as usize;
    if len > MAX_FRAME {
        bail!("frame length {len} exceeds cap");
    }
    let want_crc = u32::from_le_bytes(header[8..12].try_into().unwrap());
    // Allocate proportionally to the bytes that actually arrive, not to
    // the declared length: a corrupted (but under-cap) length field in
    // a short stream must fail with a clean error after a bounded
    // pre-allocation, not reserve up to MAX_FRAME up front.
    let mut payload = Vec::with_capacity(len.min(1 << 20));
    let got = r
        .by_ref()
        .take(len as u64)
        .read_to_end(&mut payload)
        .context("frame payload read")?;
    if got != len {
        bail!("frame truncated: {got} of {len} payload bytes");
    }
    let got_crc = crc32(&payload);
    if got_crc != want_crc {
        bail!("frame crc mismatch: {got_crc:#010x} != {want_crc:#010x}");
    }
    Ok(payload)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn crc32_known_vectors() {
        // Standard test vector for CRC-32/IEEE.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn frame_roundtrip() {
        let payload = b"hello federated world".to_vec();
        let mut buf = Vec::new();
        write_frame(&mut buf, &payload).unwrap();
        assert_eq!(buf.len() as u64, framed_len(payload.len()));
        let mut cur = Cursor::new(buf);
        assert_eq!(read_frame(&mut cur).unwrap(), payload);
    }

    #[test]
    fn multiple_frames_in_sequence() {
        let mut buf = Vec::new();
        for i in 0..5u8 {
            write_frame(&mut buf, &vec![i; i as usize * 10]).unwrap();
        }
        let mut cur = Cursor::new(buf);
        for i in 0..5u8 {
            assert_eq!(read_frame(&mut cur).unwrap(), vec![i; i as usize * 10]);
        }
    }

    #[test]
    fn corruption_detected() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"payload-bytes").unwrap();
        // flip a payload bit
        let last = buf.len() - 1;
        buf[last] ^= 0x40;
        let mut cur = Cursor::new(buf.clone());
        assert!(read_frame(&mut cur).err().unwrap().to_string().contains("crc"));
        // bad magic
        buf[0] ^= 0xFF;
        let mut cur = Cursor::new(buf);
        assert!(read_frame(&mut cur).err().unwrap().to_string().contains("magic"));
    }

    #[test]
    fn oversized_length_fields_fail_cleanly_without_allocating() {
        // A header declaring a huge (but under-cap) payload over a
        // short stream: must report truncation, never block or reserve
        // gigabytes.  Lengths beyond MAX_FRAME are rejected outright.
        for declared in [1_000u32, 1 << 24, MAX_FRAME as u32] {
            let mut buf = Vec::new();
            buf.extend_from_slice(&MAGIC.to_le_bytes());
            buf.extend_from_slice(&declared.to_le_bytes());
            buf.extend_from_slice(&crc32(b"x").to_le_bytes());
            buf.extend_from_slice(b"x"); // far fewer bytes than declared
            let err = read_frame(&mut Cursor::new(buf)).unwrap_err();
            assert!(format!("{err:#}").contains("truncated"), "{declared}: {err:#}");
        }
        let mut buf = Vec::new();
        buf.extend_from_slice(&MAGIC.to_le_bytes());
        buf.extend_from_slice(&(MAX_FRAME as u32 + 1).to_le_bytes());
        buf.extend_from_slice(&[0u8; 4]);
        let err = read_frame(&mut Cursor::new(buf)).unwrap_err();
        assert!(format!("{err:#}").contains("exceeds cap"), "{err:#}");
    }

    #[test]
    fn prop_truncated_frames_are_errors() {
        use crate::util::prop::{check, Gen};
        check("frame-truncation", 100, |g: &mut Gen| {
            let n = g.size(0, 300);
            let payload = g.vec_of(n, |g| g.rng.next_u32() as u8);
            let mut buf = Vec::new();
            write_frame(&mut buf, &payload).unwrap();
            let cut = g.size(0, buf.len() - 1);
            match read_frame(&mut Cursor::new(&buf[..cut])) {
                Err(_) => Ok(()),
                Ok(p) => Err(format!("{cut}-byte prefix decoded a {}-byte payload", p.len())),
            }
        });
    }

    #[test]
    fn prop_bit_flips_are_errors_never_panics() {
        use crate::util::prop::{check, Gen};
        // Any single flipped bit lands in the magic, the length, the
        // CRC or the payload; all four must surface as Err (magic
        // mismatch, truncation/trailing length, or CRC failure) — the
        // 1-in-2^32 chance of a CRC collision does not exist for single
        // bit flips, which CRC-32 detects by construction.
        check("frame-bit-flip", 200, |g: &mut Gen| {
            let n = g.size(1, 300);
            let payload = g.vec_of(n, |g| g.rng.next_u32() as u8);
            let mut buf = Vec::new();
            write_frame(&mut buf, &payload).unwrap();
            let bit = g.size(0, buf.len() * 8 - 1);
            buf[bit / 8] ^= 1 << (bit % 8);
            match read_frame(&mut Cursor::new(&buf)) {
                Err(_) => Ok(()),
                Ok(_) => Err(format!("flipped bit {bit} went undetected")),
            }
        });
    }
}
