//! Wire message types and their binary serialization.
//!
//! The format is a hand-rolled little-endian binary layout (no serde
//! offline): one tag byte, then fixed-width fields, then length-prefixed
//! payloads.  The *uplink* `Update` message is the object of study — its
//! size is exactly what the paper's "communicated bit volume" counts:
//! per-segment headers (bits, min, step — the `2 x 32` bit overhead per
//! segment acknowledged in the paper's `C_s` model) plus the bit-packed
//! codes.

use std::sync::Arc;

use anyhow::{bail, Result};

/// Per-segment quantization header.
///
/// The decoder needs (bits, min, step); `level` (the quantization level
/// `s`) additionally lets the server recover the client's observed update
/// range as `step * level` for telemetry (Fig. 1b) without a second pass.
/// All four fields are wire-accounted: 8 + 16 + 32 + 32 = 88 bits per
/// segment (the paper's overhead model counts the two f32s).
#[derive(Clone, Debug, PartialEq)]
pub struct SegmentHeader {
    /// Wire bits per code; 32 means raw f32 passthrough (fp32 policy).
    pub bits: u8,
    /// Quantization level `s` (codes in 0..=s); 0 for fp32 segments.
    pub level: u16,
    /// Segment minimum (dequantization offset).
    pub min: f32,
    /// Dequantization step `range / s` (for fp32 segments this field
    /// carries the raw range, telemetry only).
    pub step: f32,
}

impl SegmentHeader {
    /// The update range this header implies (telemetry).
    pub fn range(&self) -> f32 {
        if self.bits == 32 {
            self.step // fp32 convention: step field carries the raw range
        } else {
            self.step * self.level as f32
        }
    }
}

/// A client's quantized model update for one round.
#[derive(Clone, Debug, PartialEq)]
pub struct Update {
    /// Round the update answers.
    pub round: u32,
    /// Sending client's id.
    pub client_id: u32,
    /// Client dataset size (aggregation weight numerator, paper `p_i`).
    pub num_samples: u32,
    /// Mean local training loss over the tau local steps (AdaQuantFL input).
    pub train_loss: f32,
    /// Per-segment quantization headers, in manifest segment order.
    pub segments: Vec<SegmentHeader>,
    /// Bit-packed codes (or raw f32 LE bytes for 32-bit segments).
    pub payload: Vec<u8>,
}

/// The server's quantized params delta riding a `Broadcast`: the same
/// per-segment header + bit-packed payload shape as an [`Update`], but
/// traveling downlink.  A receiver that is in sync (it applied the
/// previous round's delta) advances its replica by
/// `replica[j] += min + code * step` per element; everyone else gets a
/// full fp32 broadcast instead.
#[derive(Clone, Debug, PartialEq)]
pub struct DownlinkDelta {
    /// Per-segment quantization headers, in manifest segment order.
    pub segments: Vec<SegmentHeader>,
    /// Bit-packed codes.
    pub payload: Vec<u8>,
}

/// Everything that can cross a transport.
#[derive(Clone, Debug, PartialEq)]
pub enum Message {
    /// Client -> server: join the federation.
    ///
    /// `num_samples` (the client's shard size, the aggregation-weight
    /// numerator) is optional on the wire: `None` encodes the legacy
    /// 5-byte frame, `Some` appends one u32, and the decoder accepts
    /// both — version-tolerant in each direction.  A worker sends
    /// `None` on connect (the sharding config only arrives in the
    /// `Welcome`) and re-sends `Some(n)` as its ready handshake, which
    /// gives the server the fold-overlap weight plan at round 0.
    Join {
        /// The joining client's id (`0..n_clients`).
        client_id: u32,
        /// Shard size, when known (the ready handshake; see above).
        num_samples: Option<u32>,
    },
    /// Server -> client: accepted; carries the run-config JSON so remote
    /// workers configure themselves identically.
    ///
    /// `round` is the round the server will broadcast next, present only
    /// when a worker (re)joins a run already in progress — a rejoining
    /// worker must know where training stands so it answers the right
    /// broadcast.  Like `Join::num_samples` it is a trailing optional
    /// field: `None` encodes the legacy frame, `Some` appends one u32,
    /// and the decoder accepts both.
    Welcome {
        /// The id the server accepted the client under.
        client_id: u32,
        /// The full [`RunConfig`](crate::config::RunConfig) as JSON.
        config_json: String,
        /// Next round index when joining mid-run; `None` at run start.
        round: Option<u32>,
    },
    /// Server -> client: global model for round `round` (fp32 downlink,
    /// as in the paper — only the uplink is quantized).  Carries the
    /// global loss trajectory (initial, previous-round) that loss-driven
    /// policies (AdaQuantFL) condition on; `None` before round 1.
    ///
    /// `params` is an `Arc` so the coordinator broadcasts the same
    /// buffer to every client without copying it n times per round:
    /// cloning the message is a refcount bump, and the round engine's
    /// worker pool reads the shared vector concurrently.
    Broadcast {
        /// Round the recipients must answer.
        round: u32,
        /// The shared global parameter vector (see above).
        params: Arc<[f32]>,
        /// Global (initial, previous-round) training loss; `None`
        /// before round 1.
        losses: Option<(f32, f32)>,
        /// This round's on-time leaf cohort (ascending client ids),
        /// present only in tree topologies so an intermediate aggregator
        /// knows which of its children to relay to and fold.  A trailing
        /// optional field like `Join::num_samples`: `None` encodes the
        /// legacy frame byte for byte, and leaf workers ignore it.
        cohort: Option<Vec<u32>>,
        /// Leaves the scheduler expects to answer *late* (semi-sync
        /// banking): an aggregator relays the broadcast to these children
        /// too but forwards their updates upstream raw instead of folding
        /// them, so the root banks exactly what the in-process engine
        /// banks.  Second trailing optional region — present on the wire
        /// only after `cohort` (the encoder writes an empty cohort if
        /// necessary), so legacy frames stay byte-identical.
        late: Option<Vec<u32>>,
        /// Quantized downlink delta (`--downlink-bits 1..=16`): when
        /// present, `params` is the *delta base* convention — receivers
        /// that are in sync apply this delta to their replica and
        /// ignore `params` (the server sends an empty vector).  Third
        /// trailing optional region, gated by a flags byte shared with
        /// `budgets`; its presence forces `cohort` and `late` onto the
        /// wire (empty lists if unset) so the frame stays parseable by
        /// position, exactly like `late` forcing `cohort`.
        downlink: Option<DownlinkDelta>,
        /// Per-client uplink bit budgets for this round
        /// (`--bit-budget`): `(client_id, per-segment widths in bits)`
        /// sorted by id.  Each recipient looks up its own id and clamps
        /// its policy decision; aggregators relay the list verbatim.
        /// Shares the flags byte with `downlink` (see above).
        budgets: Option<Vec<(u32, Vec<u8>)>>,
    },
    /// Client -> server: the quantized update.
    Update(Update),
    /// Server -> client: training is over.
    Shutdown,
    /// Aggregator -> server (or upstream aggregator): one subtree's
    /// pre-folded contribution to the round (tree topology).
    Partial(PartialAggregate),
}

/// A subtree's pre-folded weighted accumulator plus the bookkeeping the
/// server needs to treat it exactly like a (pseudo-)client update: the
/// member-id set with per-member sample counts (aggregation weights and
/// the fold-overlap plan), the subtree-weighted mean training loss, and
/// a telemetry tail (tree depth, summed leaf uplink wire bits).
///
/// The accumulator is `sum_i (s_i / S) * dequant(delta_i)` over the
/// subtree's members, i.e. already normalized *within* the subtree; the
/// upstream fold then weights the whole message by `S / T` (subtree
/// samples over round total), which the existing `fold_range` kernel
/// applies unchanged through [`crate::coordinator::codec`]'s
/// pseudo-update conversion.
///
/// The telemetry tail is a trailing optional region (like
/// `Join::num_samples`): `None` encodes the shorter legacy frame and
/// decoders accept both, so the frame can grow again without breaking
/// deployed aggregators.
#[derive(Clone, Debug, PartialEq)]
pub struct PartialAggregate {
    /// Round this partial answers.
    pub round: u32,
    /// Subtree root id: the lowest leaf id in the aggregator's span.
    /// Folds upstream are keyed by this id (sorted-key fold order).
    pub agg_id: u32,
    /// Subtree-weighted mean training loss (`sum_i (s_i / S) * loss_i`).
    pub train_loss: f32,
    /// Member leaf ids, strictly ascending.
    pub members: Vec<u32>,
    /// Per-member sample counts, parallel to `members`.
    pub samples: Vec<u32>,
    /// The pre-folded weighted accumulator (length `d`).
    pub acc: Vec<f32>,
    /// Optional telemetry tail: `(tree depth below the receiver, summed
    /// leaf uplink wire bits)`.  `None` on legacy frames.
    pub telemetry: Option<(u32, u64)>,
}

impl PartialAggregate {
    /// Aggregation tiers below the receiver (1 = folded leaf updates
    /// directly); legacy frames without the tail report 1.
    pub fn depth(&self) -> u32 {
        self.telemetry.map(|(d, _)| d).unwrap_or(1)
    }

    /// Summed leaf uplink wire bits of the members' original updates
    /// (the paper's communication ledger); 0 on legacy frames.
    pub fn wire_bits(&self) -> u64 {
        self.telemetry.map(|(_, b)| b).unwrap_or(0)
    }

    /// Total subtree sample mass (the upstream aggregation weight
    /// numerator).
    pub fn total_samples(&self) -> u64 {
        self.samples.iter().map(|&s| s as u64).sum()
    }

    /// The server-side bookkeeping view (everything but the
    /// accumulator), harvested by the receive path for telemetry and
    /// the client arena.
    pub fn meta(&self) -> PartialMeta {
        PartialMeta {
            agg_id: self.agg_id,
            depth: self.depth(),
            wire_bits: self.wire_bits(),
            members: self.members.clone(),
            samples: self.samples.clone(),
        }
    }
}

/// The non-accumulator part of a [`PartialAggregate`]: what the server
/// keeps after converting the partial into a pseudo-update (telemetry
/// partials plus the member registry for the client arena).
#[derive(Clone, Debug, PartialEq)]
pub struct PartialMeta {
    /// Subtree root id.
    pub agg_id: u32,
    /// Aggregation tiers below the server.
    pub depth: u32,
    /// Summed leaf uplink wire bits.
    pub wire_bits: u64,
    /// Member leaf ids, ascending.
    pub members: Vec<u32>,
    /// Per-member sample counts, parallel to `members`.
    pub samples: Vec<u32>,
}

/// Encoded size of an [`Update`]'s body (without the message tag byte):
/// fixed header fields + segment headers + length-prefixed payload.
pub fn update_encoded_len(u: &Update) -> usize {
    4 + 4 + 4 + 4 + 4 + u.segments.len() * (1 + 2 + 4 + 4) + 4 + u.payload.len()
}

const TAG_JOIN: u8 = 1;
const TAG_WELCOME: u8 = 2;
const TAG_BROADCAST: u8 = 3;
const TAG_UPDATE: u8 = 4;
const TAG_SHUTDOWN: u8 = 5;
const TAG_PARTIAL: u8 = 6;

struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn new() -> Self {
        Writer { buf: Vec::new() }
    }
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn bytes(&mut self, v: &[u8]) {
        self.u32(v.len() as u32);
        self.buf.extend_from_slice(v);
    }
    fn str(&mut self, v: &str) {
        self.bytes(v.as_bytes());
    }
    fn f32s(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        // bulk copy — this is the downlink hot path
        super::extend_f32_le(&mut self.buf, v);
    }
    fn u32s(&mut self, v: &[u32]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("message truncated: need {n} bytes at {}", self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.u32()? as usize;
        Ok(self.take(n)?.to_vec())
    }
    fn str(&mut self) -> Result<String> {
        Ok(String::from_utf8(self.bytes()?)?)
    }
    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let raw = self.take(n * 4)?;
        let mut out = Vec::with_capacity(n);
        for c in raw.chunks_exact(4) {
            out.push(f32::from_le_bytes(c.try_into().unwrap()));
        }
        Ok(out)
    }
    fn u32s(&mut self) -> Result<Vec<u32>> {
        // take() before with_capacity: a corrupt count in a tiny frame
        // fails on the read, never reserves memory first (same OOM
        // hardening as the Update segment loop).
        let n = self.u32()? as usize;
        let raw = self.take(n * 4)?;
        let mut out = Vec::with_capacity(n);
        for c in raw.chunks_exact(4) {
            out.push(u32::from_le_bytes(c.try_into().unwrap()));
        }
        Ok(out)
    }
    fn done(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!("trailing bytes in message: {} of {}", self.pos, self.buf.len());
        }
        Ok(())
    }
}

impl Message {
    /// Serialize to the wire byte layout.
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        match self {
            Message::Join { client_id, num_samples } => {
                w.u8(TAG_JOIN);
                w.u32(*client_id);
                // present-by-length: None is exactly the legacy frame
                if let Some(s) = num_samples {
                    w.u32(*s);
                }
            }
            Message::Welcome { client_id, config_json, round } => {
                w.u8(TAG_WELCOME);
                w.u32(*client_id);
                w.str(config_json);
                // present-by-length, like Join::num_samples
                if let Some(m) = round {
                    w.u32(*m);
                }
            }
            Message::Broadcast { round, params, losses, cohort, late, downlink, budgets } => {
                w.u8(TAG_BROADCAST);
                w.u32(*round);
                match losses {
                    None => w.u8(0),
                    Some((f0, fm)) => {
                        w.u8(1);
                        w.f32(*f0);
                        w.f32(*fm);
                    }
                }
                w.f32s(params);
                // present-by-length, like Join::num_samples; each later
                // region can only follow present earlier ones, so a
                // Some(late) forces at least an empty cohort list onto
                // the wire, and the budget extension forces both lists
                let ext = downlink.is_some() || budgets.is_some();
                if let Some(c) = cohort {
                    w.u32s(c);
                } else if late.is_some() || ext {
                    w.u32s(&[]);
                }
                if let Some(l) = late {
                    w.u32s(l);
                } else if ext {
                    w.u32s(&[]);
                }
                if ext {
                    let flags = (downlink.is_some() as u8) | ((budgets.is_some() as u8) << 1);
                    w.u8(flags);
                    if let Some(d) = downlink {
                        w.u32(d.segments.len() as u32);
                        for s in &d.segments {
                            w.u8(s.bits);
                            w.u16(s.level);
                            w.f32(s.min);
                            w.f32(s.step);
                        }
                        w.bytes(&d.payload);
                    }
                    if let Some(b) = budgets {
                        w.u32(b.len() as u32);
                        for (id, widths) in b {
                            w.u32(*id);
                            w.bytes(widths);
                        }
                    }
                }
            }
            Message::Update(u) => {
                w.u8(TAG_UPDATE);
                w.u32(u.round);
                w.u32(u.client_id);
                w.u32(u.num_samples);
                w.f32(u.train_loss);
                w.u32(u.segments.len() as u32);
                for s in &u.segments {
                    w.u8(s.bits);
                    w.u16(s.level);
                    w.f32(s.min);
                    w.f32(s.step);
                }
                w.bytes(&u.payload);
            }
            Message::Shutdown => w.u8(TAG_SHUTDOWN),
            Message::Partial(p) => {
                w.u8(TAG_PARTIAL);
                w.u32(p.round);
                w.u32(p.agg_id);
                w.f32(p.train_loss);
                w.u32s(&p.members);
                w.u32s(&p.samples);
                w.f32s(&p.acc);
                // trailing-optional telemetry tail
                if let Some((depth, wire_bits)) = p.telemetry {
                    w.u32(depth);
                    w.u64(wire_bits);
                }
            }
        }
        w.buf
    }

    /// Exact length of [`Self::encode`]'s output, computed without
    /// allocating or serializing.  The in-process transports account
    /// framed byte volumes from this, which keeps a whole
    /// encode-per-client off the round hot path (the bytes never cross a
    /// real wire there).  Must stay in lockstep with `encode`; a
    /// property test asserts equality.
    pub fn encoded_len(&self) -> usize {
        match self {
            Message::Join { num_samples, .. } => 1 + 4 + if num_samples.is_some() { 4 } else { 0 },
            Message::Welcome { config_json, round, .. } => {
                1 + 4 + 4 + config_json.len() + if round.is_some() { 4 } else { 0 }
            }
            Message::Broadcast { params, losses, cohort, late, downlink, budgets, .. } => {
                let ext = downlink.is_some() || budgets.is_some();
                let losses_len = match losses {
                    None => 1,
                    Some(_) => 1 + 4 + 4,
                };
                let cohort_len = match cohort {
                    Some(c) => 4 + c.len() * 4,
                    None if late.is_some() || ext => 4, // forced empty list
                    None => 0,
                };
                let late_len = match late {
                    Some(l) => 4 + l.len() * 4,
                    None if ext => 4, // forced empty list
                    None => 0,
                };
                let ext_len = if ext {
                    let down_len = match downlink {
                        Some(d) => 4 + d.segments.len() * (1 + 2 + 4 + 4) + 4 + d.payload.len(),
                        None => 0,
                    };
                    let budget_len = match budgets {
                        Some(b) => {
                            4 + b.iter().map(|(_, ws)| 4 + 4 + ws.len()).sum::<usize>()
                        }
                        None => 0,
                    };
                    1 + down_len + budget_len
                } else {
                    0
                };
                1 + 4 + losses_len + 4 + params.len() * 4 + cohort_len + late_len + ext_len
            }
            Message::Update(u) => 1 + update_encoded_len(u),
            Message::Shutdown => 1,
            Message::Partial(p) => {
                let tail = if p.telemetry.is_some() { 4 + 8 } else { 0 };
                1 + 4 + 4 + 4
                    + (4 + p.members.len() * 4)
                    + (4 + p.samples.len() * 4)
                    + (4 + p.acc.len() * 4)
                    + tail
            }
        }
    }

    /// Parse from the wire byte layout (strict: rejects trailing bytes).
    pub fn decode(buf: &[u8]) -> Result<Message> {
        let mut r = Reader::new(buf);
        let msg = match r.u8()? {
            TAG_JOIN => Message::Join {
                client_id: r.u32()?,
                // version-tolerant: old frames end after client_id
                num_samples: if r.pos < r.buf.len() { Some(r.u32()?) } else { None },
            },
            TAG_WELCOME => Message::Welcome {
                client_id: r.u32()?,
                config_json: r.str()?,
                // version-tolerant: old frames end after the config
                round: if r.pos < r.buf.len() { Some(r.u32()?) } else { None },
            },
            TAG_BROADCAST => {
                let round = r.u32()?;
                let losses = match r.u8()? {
                    0 => None,
                    1 => Some((r.f32()?, r.f32()?)),
                    t => bail!("bad losses flag {t}"),
                };
                let params: Arc<[f32]> = r.f32s()?.into();
                // version-tolerant: old frames end after the params,
                // pre-`late` frames end after the cohort, and
                // pre-budget frames end after the late list
                let cohort = if r.pos < r.buf.len() { Some(r.u32s()?) } else { None };
                let late = if r.pos < r.buf.len() { Some(r.u32s()?) } else { None };
                let (mut downlink, mut budgets) = (None, None);
                if r.pos < r.buf.len() {
                    let flags = r.u8()?;
                    if flags & !3 != 0 || flags == 0 {
                        bail!("bad broadcast extension flags {flags:#x}");
                    }
                    if flags & 1 != 0 {
                        let nseg = r.u32()? as usize;
                        if nseg > 1_000_000 {
                            bail!("absurd downlink segment count {nseg}");
                        }
                        let mut segments =
                            Vec::with_capacity(nseg.min((r.buf.len() - r.pos) / 11));
                        for _ in 0..nseg {
                            segments.push(SegmentHeader {
                                bits: r.u8()?,
                                level: r.u16()?,
                                min: r.f32()?,
                                step: r.f32()?,
                            });
                        }
                        downlink = Some(DownlinkDelta { segments, payload: r.bytes()? });
                    }
                    if flags & 2 != 0 {
                        let n = r.u32()? as usize;
                        if n > 1_000_000 {
                            bail!("absurd budget count {n}");
                        }
                        // 8 = the smallest encoded entry (id + empty list)
                        let mut b = Vec::with_capacity(n.min((r.buf.len() - r.pos) / 8));
                        for _ in 0..n {
                            let id = r.u32()?;
                            b.push((id, r.bytes()?));
                        }
                        budgets = Some(b);
                    }
                }
                Message::Broadcast { round, params, losses, cohort, late, downlink, budgets }
            }
            TAG_UPDATE => {
                let round = r.u32()?;
                let client_id = r.u32()?;
                let num_samples = r.u32()?;
                let train_loss = r.f32()?;
                let nseg = r.u32()? as usize;
                if nseg > 1_000_000 {
                    bail!("absurd segment count {nseg}");
                }
                // Pre-allocate no more than the buffer can actually
                // hold (11 encoded bytes per header): a corrupt count
                // in a tiny frame must fail on the first read, not
                // reserve megabytes first.
                let mut segments =
                    Vec::with_capacity(nseg.min((r.buf.len() - r.pos) / 11));
                for _ in 0..nseg {
                    segments.push(SegmentHeader {
                        bits: r.u8()?,
                        level: r.u16()?,
                        min: r.f32()?,
                        step: r.f32()?,
                    });
                }
                Message::Update(Update {
                    round,
                    client_id,
                    num_samples,
                    train_loss,
                    segments,
                    payload: r.bytes()?,
                })
            }
            TAG_SHUTDOWN => Message::Shutdown,
            TAG_PARTIAL => {
                let round = r.u32()?;
                let agg_id = r.u32()?;
                let train_loss = r.f32()?;
                let members = r.u32s()?;
                if members.len() > 1_000_000 {
                    bail!("absurd member count {}", members.len());
                }
                if !members.windows(2).all(|w| w[0] < w[1]) {
                    bail!("partial members not strictly ascending");
                }
                let samples = r.u32s()?;
                if samples.len() != members.len() {
                    bail!(
                        "partial samples/members length mismatch: {} vs {}",
                        samples.len(),
                        members.len()
                    );
                }
                let acc = r.f32s()?;
                // version-tolerant: legacy frames end after the
                // accumulator; a present tail must be complete.
                let telemetry = if r.pos < r.buf.len() {
                    Some((r.u32()?, r.u64()?))
                } else {
                    None
                };
                Message::Partial(PartialAggregate {
                    round,
                    agg_id,
                    train_loss,
                    members,
                    samples,
                    acc,
                    telemetry,
                })
            }
            t => bail!("unknown message tag {t}"),
        };
        r.done()?;
        Ok(msg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};

    fn roundtrip(m: &Message) {
        let bytes = m.encode();
        let back = Message::decode(&bytes).unwrap();
        assert_eq!(*m, back);
    }

    #[test]
    fn roundtrip_all_variants() {
        roundtrip(&Message::Join { client_id: 7, num_samples: None });
        roundtrip(&Message::Join { client_id: 7, num_samples: Some(4200) });
        roundtrip(&Message::Welcome {
            client_id: 7,
            config_json: r#"{"model":"mlp"}"#.into(),
            round: None,
        });
        roundtrip(&Message::Welcome {
            client_id: 7,
            config_json: r#"{"model":"mlp"}"#.into(),
            round: Some(12),
        });
        roundtrip(&Message::Broadcast {
            round: 3,
            params: vec![1.0, -2.5, 0.0, f32::MIN_POSITIVE].into(),
            losses: None,
            cohort: None,
            late: None,
            downlink: None,
            budgets: None,
        });
        roundtrip(&Message::Broadcast {
            round: 4,
            params: vec![0.5; 3].into(),
            losses: Some((2.3, 0.7)),
            cohort: None,
            late: None,
            downlink: None,
            budgets: None,
        });
        roundtrip(&Message::Broadcast {
            round: 5,
            params: vec![0.5; 3].into(),
            losses: Some((2.3, 0.7)),
            cohort: Some(vec![0, 3, 7, 11]),
            late: None,
            downlink: None,
            budgets: None,
        });
        roundtrip(&Message::Broadcast {
            round: 6,
            params: vec![0.5; 2].into(),
            losses: None,
            cohort: Some(Vec::new()),
            late: None,
            downlink: None,
            budgets: None,
        });
        roundtrip(&Message::Broadcast {
            round: 7,
            params: vec![0.5; 2].into(),
            losses: Some((2.3, 0.7)),
            cohort: Some(vec![0, 2]),
            late: Some(vec![1, 5]),
            downlink: None,
            budgets: None,
        });
        roundtrip(&Message::Broadcast {
            round: 8,
            params: vec![0.5; 2].into(),
            losses: None,
            cohort: Some(vec![4]),
            late: Some(Vec::new()),
            downlink: None,
            budgets: None,
        });
        roundtrip(&Message::Partial(PartialAggregate {
            round: 3,
            agg_id: 4,
            train_loss: 1.5,
            members: vec![4, 5, 6, 7],
            samples: vec![100, 200, 50, 75],
            acc: vec![0.25, -1.0, 3.5],
            telemetry: Some((1, u64::MAX - 7)),
        }));
        roundtrip(&Message::Partial(PartialAggregate {
            round: 0,
            agg_id: 0,
            train_loss: 0.0,
            members: vec![0],
            samples: vec![1],
            acc: Vec::new(),
            telemetry: None,
        }));
        roundtrip(&Message::Update(Update {
            round: 3,
            client_id: 1,
            num_samples: 600,
            train_loss: 1.25,
            segments: vec![
                SegmentHeader { bits: 7, level: 100, min: -0.5, step: 0.01 },
                SegmentHeader { bits: 32, level: 0, min: 0.0, step: 0.0 },
            ],
            payload: vec![0xde, 0xad, 0xbe, 0xef],
        }));
        roundtrip(&Message::Shutdown);
    }

    #[test]
    fn join_decodes_legacy_and_extended_frames() {
        // A pre-`num_samples` sender emits exactly tag + u32: the new
        // decoder must accept it as None (version tolerance), and a
        // None Join must encode back to that same legacy layout.
        let legacy = [1u8, 42, 0, 0, 0];
        assert_eq!(
            Message::decode(&legacy).unwrap(),
            Message::Join { client_id: 42, num_samples: None }
        );
        assert_eq!(
            Message::Join { client_id: 42, num_samples: None }.encode(),
            legacy.to_vec()
        );
        // The extended frame appends one u32 and still round-trips.
        let extended = [1u8, 42, 0, 0, 0, 88, 1, 0, 0];
        assert_eq!(
            Message::decode(&extended).unwrap(),
            Message::Join { client_id: 42, num_samples: Some(344) }
        );
        // A half-written samples field is rejected, not misread.
        assert!(Message::decode(&[1u8, 42, 0, 0, 0, 88]).is_err());
    }

    #[test]
    fn welcome_decodes_legacy_and_extended_frames() {
        // A pre-`round` sender emits tag + id + length-prefixed JSON:
        // the new decoder must accept it as None, and a None Welcome
        // must encode back to the same legacy layout.
        let legacy = [2u8, 9, 0, 0, 0, 2, 0, 0, 0, b'{', b'}'];
        let none = Message::Welcome { client_id: 9, config_json: "{}".into(), round: None };
        assert_eq!(Message::decode(&legacy).unwrap(), none);
        assert_eq!(none.encode(), legacy.to_vec());
        // The extended frame appends one u32 (the mid-run round).
        let mut extended = legacy.to_vec();
        extended.extend_from_slice(&7u32.to_le_bytes());
        assert_eq!(
            Message::decode(&extended).unwrap(),
            Message::Welcome { client_id: 9, config_json: "{}".into(), round: Some(7) }
        );
        // A half-written round field is rejected, not misread.
        assert!(Message::decode(&extended[..extended.len() - 2]).is_err());
    }

    #[test]
    fn rejects_truncation_and_trailing() {
        let bytes =
            Message::Broadcast {
                round: 1,
                params: vec![1.0; 8].into(),
                losses: None,
                cohort: None,
                late: None,
                downlink: None,
                budgets: None,
            }
            .encode();
        assert!(Message::decode(&bytes[..bytes.len() - 1]).is_err());
        let mut extended = bytes.clone();
        extended.push(0);
        assert!(Message::decode(&extended).is_err());
        assert!(Message::decode(&[99]).is_err());
    }

    #[test]
    fn encoded_len_matches_encode() {
        let msgs = vec![
            Message::Join { client_id: 7, num_samples: None },
            Message::Join { client_id: 7, num_samples: Some(600) },
            Message::Welcome { client_id: 7, config_json: r#"{"model":"mlp"}"#.into(), round: None },
            Message::Welcome { client_id: 7, config_json: "{}".into(), round: Some(3) },
            Message::Broadcast {
                round: 3,
                params: vec![1.0; 13].into(),
                losses: None,
                cohort: None,
                late: None,
                downlink: None,
                budgets: None,
            },
            Message::Broadcast {
                round: 4,
                params: vec![0.5; 3].into(),
                losses: Some((2.3, 0.7)),
                cohort: None,
                late: None,
                downlink: None,
                budgets: None,
            },
            Message::Broadcast {
                round: 5,
                params: vec![0.5; 3].into(),
                losses: None,
                cohort: Some(vec![1, 2, 9]),
                late: None,
                downlink: None,
                budgets: None,
            },
            Message::Broadcast {
                round: 6,
                params: vec![0.5; 3].into(),
                losses: None,
                cohort: Some(vec![1, 2, 9]),
                late: Some(vec![4, 7]),
                downlink: None,
                budgets: None,
            },
            // a Some(late) with no cohort forces an empty cohort list
            // onto the wire; encoded_len must account for those 4 bytes
            Message::Broadcast {
                round: 7,
                params: vec![0.5; 3].into(),
                losses: None,
                cohort: None,
                late: Some(vec![4, 7]),
                downlink: None,
                budgets: None,
            },
            Message::Partial(PartialAggregate {
                round: 2,
                agg_id: 8,
                train_loss: 0.5,
                members: vec![8, 9],
                samples: vec![10, 20],
                acc: vec![1.0; 7],
                telemetry: Some((1, 12345)),
            }),
            Message::Partial(PartialAggregate {
                round: 2,
                agg_id: 8,
                train_loss: 0.5,
                members: vec![8, 9],
                samples: vec![10, 20],
                acc: vec![1.0; 7],
                telemetry: None,
            }),
            Message::Shutdown,
        ];
        for m in &msgs {
            assert_eq!(m.encoded_len(), m.encode().len(), "{m:?}");
        }
    }

    #[test]
    fn prop_update_encoded_len() {
        check("message-update-encoded-len", 50, |g: &mut Gen| {
            let nseg = g.size(0, 40);
            let u = Update {
                round: g.rng.next_u32(),
                client_id: g.rng.next_u32(),
                num_samples: g.rng.next_u32(),
                train_loss: g.f32_wide(),
                segments: g.vec_of(nseg, |g| SegmentHeader {
                    bits: g.int(0, 32) as u8,
                    level: g.int(0, 65535) as u16,
                    min: g.f32_wide(),
                    step: g.f32_wide(),
                }),
                payload: { let n = g.size(0, 2000); g.vec_of(n, |g| g.rng.next_u32() as u8) },
            };
            let m = Message::Update(u);
            if m.encoded_len() != m.encode().len() {
                return Err("encoded_len diverged from encode".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_update_roundtrip() {
        check("message-update-roundtrip", 100, |g: &mut Gen| {
            let nseg = g.size(0, 40);
            let u = Update {
                round: g.rng.next_u32(),
                client_id: g.rng.next_u32(),
                num_samples: g.rng.next_u32(),
                train_loss: g.f32_wide(),
                segments: g.vec_of(nseg, |g| SegmentHeader {
                    bits: g.int(0, 32) as u8,
                    level: g.int(0, 65535) as u16,
                    min: g.f32_wide(),
                    step: g.f32_wide(),
                }),
                payload: { let n = g.size(0, 2000); g.vec_of(n, |g| g.rng.next_u32() as u8) },
            };
            let m = Message::Update(u);
            let back = Message::decode(&m.encode()).map_err(|e| e.to_string())?;
            if back != m {
                return Err("roundtrip mismatch".into());
            }
            Ok(())
        });
    }

    fn gen_update(g: &mut Gen) -> Message {
        let nseg = g.size(0, 20);
        Message::Update(Update {
            round: g.rng.next_u32(),
            client_id: g.rng.next_u32(),
            num_samples: g.rng.next_u32(),
            train_loss: g.f32_wide(),
            segments: g.vec_of(nseg, |g| SegmentHeader {
                bits: g.int(0, 32) as u8,
                level: g.int(0, 65535) as u16,
                min: g.f32_wide(),
                step: g.f32_wide(),
            }),
            payload: { let n = g.size(0, 500); g.vec_of(n, |g| g.rng.next_u32() as u8) },
        })
    }

    #[test]
    fn prop_truncated_update_is_an_error_never_a_panic() {
        // Updates have no trailing-optional fields, so *every* strict
        // prefix must decode to Err — and none may panic or allocate
        // absurdly.  (Join/Welcome prefixes can legitimately decode as
        // their legacy layouts; Update must not.)
        check("message-truncated-update", 100, |g: &mut Gen| {
            let bytes = gen_update(g).encode();
            let cut = g.size(0, bytes.len() - 1);
            match Message::decode(&bytes[..cut]) {
                Err(_) => Ok(()),
                Ok(m) => Err(format!("truncated update decoded as {m:?}")),
            }
        });
    }

    #[test]
    fn prop_bit_flips_never_panic() {
        // A single flipped bit may still decode (payload/float bytes
        // carry no structure), but it must never panic the decoder; a
        // flip in the segment count must not cause a huge allocation
        // (the decoder caps pre-allocation by the remaining bytes).
        check("message-bit-flip", 200, |g: &mut Gen| {
            let mut bytes = gen_update(g).encode();
            let bit = g.size(0, bytes.len() * 8 - 1);
            bytes[bit / 8] ^= 1 << (bit % 8);
            let _ = Message::decode(&bytes);
            Ok(())
        });
    }

    #[test]
    fn prop_random_bytes_never_panic() {
        check("message-byte-soup", 200, |g: &mut Gen| {
            let n = g.size(0, 300);
            let soup = g.vec_of(n, |g| g.rng.next_u32() as u8);
            let _ = Message::decode(&soup);
            Ok(())
        });
    }

    #[test]
    fn broadcast_decodes_legacy_and_cohort_frames() {
        // A pre-cohort sender emits tag + round + losses flag + params:
        // the new decoder must accept it as cohort None, and a None
        // cohort must encode back to that same legacy layout.
        let legacy = {
            let mut b = vec![3u8];
            b.extend_from_slice(&9u32.to_le_bytes());
            b.push(0); // losses flag
            b.extend_from_slice(&2u32.to_le_bytes());
            b.extend_from_slice(&1.0f32.to_le_bytes());
            b.extend_from_slice(&2.0f32.to_le_bytes());
            b
        };
        let none = Message::Broadcast {
            round: 9,
            params: vec![1.0, 2.0].into(),
            losses: None,
            cohort: None,
            late: None,
            downlink: None,
            budgets: None,
        };
        assert_eq!(Message::decode(&legacy).unwrap(), none);
        assert_eq!(none.encode(), legacy);
        // The extended frame appends a length-prefixed id list.
        let mut extended = legacy.clone();
        extended.extend_from_slice(&2u32.to_le_bytes());
        extended.extend_from_slice(&3u32.to_le_bytes());
        extended.extend_from_slice(&5u32.to_le_bytes());
        assert_eq!(
            Message::decode(&extended).unwrap(),
            Message::Broadcast {
                round: 9,
                params: vec![1.0, 2.0].into(),
                losses: None,
                cohort: Some(vec![3, 5]),
                late: None,
                downlink: None,
                budgets: None,
            }
        );
        // A half-written cohort is rejected, not misread.
        assert!(Message::decode(&extended[..extended.len() - 2]).is_err());
        // A second id list appends the late set (semi-sync x tree).
        let mut with_late = extended.clone();
        with_late.extend_from_slice(&1u32.to_le_bytes());
        with_late.extend_from_slice(&4u32.to_le_bytes());
        assert_eq!(
            Message::decode(&with_late).unwrap(),
            Message::Broadcast {
                round: 9,
                params: vec![1.0, 2.0].into(),
                losses: None,
                cohort: Some(vec![3, 5]),
                late: Some(vec![4]),
                downlink: None,
                budgets: None,
            }
        );
        // A half-written late list is rejected, not misread.
        assert!(Message::decode(&with_late[..with_late.len() - 2]).is_err());
        // A late set without a cohort encodes a forced empty cohort, so
        // the frame stays parseable by the two-list layout.
        let forced = Message::Broadcast {
            round: 9,
            params: vec![1.0, 2.0].into(),
            losses: None,
            cohort: None,
            late: Some(vec![4]),
            downlink: None,
            budgets: None,
        };
        assert_eq!(
            Message::decode(&forced.encode()).unwrap(),
            Message::Broadcast {
                round: 9,
                params: vec![1.0, 2.0].into(),
                losses: None,
                cohort: Some(Vec::new()),
                late: Some(vec![4]),
                downlink: None,
                budgets: None,
            }
        );
    }

    fn gen_downlink(g: &mut Gen) -> DownlinkDelta {
        let nseg = g.size(1, 12);
        let segments = g.vec_of(nseg, |g| SegmentHeader {
            bits: g.int(1, 16) as u8,
            level: g.int(1, 65535) as u16,
            min: g.f32_wide(),
            step: g.f32_wide(),
        });
        let n = g.size(0, 400);
        DownlinkDelta { segments, payload: g.vec_of(n, |g| g.rng.next_u32() as u8) }
    }

    fn gen_budgets(g: &mut Gen) -> Vec<(u32, Vec<u8>)> {
        let n = g.size(0, 8);
        (0..n as u32)
            .map(|id| {
                let nseg = g.size(1, 12);
                (id * 3, g.vec_of(nseg, |g| g.int(1, 16) as u8))
            })
            .collect()
    }

    #[test]
    fn broadcast_budget_extension_roundtrips_and_sizes() {
        // every flag combination, with present cohort/late lists so the
        // roundtrip is exact (see the normalization test for None)
        for (down, budget) in
            [(true, false), (false, true), (true, true)]
        {
            let m = Message::Broadcast {
                round: 11,
                params: vec![0.25, -1.5].into(),
                losses: Some((2.0, 0.5)),
                cohort: Some(vec![0, 2, 5]),
                late: Some(vec![1]),
                downlink: down.then(|| DownlinkDelta {
                    segments: vec![
                        SegmentHeader { bits: 4, level: 15, min: -0.5, step: 0.0625 },
                        SegmentHeader { bits: 2, level: 3, min: 0.0, step: 0.125 },
                    ],
                    payload: vec![0xab, 0xcd, 0x12],
                }),
                budgets: budget.then(|| vec![(0, vec![4, 2]), (5, vec![1, 1])]),
            };
            roundtrip(&m);
            assert_eq!(m.encoded_len(), m.encode().len(), "{m:?}");
        }
    }

    #[test]
    fn broadcast_extension_forces_cohort_and_late_lists() {
        // The ext region can only follow both id lists, so an encoder
        // given None lists writes empty ones; the decode normalizes
        // None -> Some(vec![]) exactly like the forced-cohort case.
        let m = Message::Broadcast {
            round: 2,
            params: vec![1.0].into(),
            losses: None,
            cohort: None,
            late: None,
            downlink: None,
            budgets: Some(vec![(3, vec![2])]),
        };
        assert_eq!(m.encoded_len(), m.encode().len());
        match Message::decode(&m.encode()).unwrap() {
            Message::Broadcast { cohort, late, budgets, .. } => {
                assert_eq!(cohort, Some(Vec::new()));
                assert_eq!(late, Some(Vec::new()));
                assert_eq!(budgets, Some(vec![(3, vec![2])]));
            }
            other => panic!("decoded as {other:?}"),
        }
    }

    #[test]
    fn broadcast_rejects_bad_extension_flags() {
        // a trailing zero or unknown-bit flags byte is corruption, not
        // a legal empty extension
        let base = Message::Broadcast {
            round: 1,
            params: vec![1.0].into(),
            losses: None,
            cohort: Some(vec![0]),
            late: Some(Vec::new()),
            downlink: None,
            budgets: None,
        }
        .encode();
        for flags in [0u8, 4, 0xff] {
            let mut bytes = base.clone();
            bytes.push(flags);
            assert!(Message::decode(&bytes).is_err(), "flags {flags:#x} accepted");
        }
    }

    #[test]
    fn prop_quantized_broadcast_cuts_err_exactly_off_region_boundaries() {
        // A Broadcast has three trailing-optional regions, so a cut at
        // a region boundary legitimately decodes as an older layout —
        // but every other cut, including anywhere inside the extension
        // bodies, must Err and never panic.  This pins the exact
        // version-tolerance surface of the quantized-downlink frame.
        check("message-broadcast-cuts", 60, |g: &mut Gen| {
            let nparams = g.size(0, 20);
            let ncohort = g.size(0, 6);
            let nlate = g.size(0, 4);
            let losses = g.int(0, 1) == 1;
            let m = Message::Broadcast {
                round: g.rng.next_u32(),
                params: g.vec_of(nparams, |g| g.f32_wide()).into(),
                losses: losses.then(|| (1.0, 0.5)),
                cohort: Some(g.vec_of(ncohort, |g| g.rng.next_u32())),
                late: Some(g.vec_of(nlate, |g| g.rng.next_u32())),
                downlink: Some(gen_downlink(g)),
                budgets: Some(gen_budgets(g)),
            };
            let bytes = m.encode();
            let losses_len = if losses { 9 } else { 1 };
            let base = 1 + 4 + losses_len + 4 + nparams * 4;
            let after_cohort = base + 4 + ncohort * 4;
            let after_late = after_cohort + 4 + nlate * 4;
            let boundaries = [base, after_cohort, after_late, bytes.len()];
            for cut in 0..=bytes.len() {
                let ok = Message::decode(&bytes[..cut]).is_ok();
                if boundaries.contains(&cut) {
                    if !ok {
                        return Err(format!("boundary cut {cut} failed to decode"));
                    }
                } else if ok {
                    return Err(format!("mid-region cut {cut} decoded"));
                }
            }
            // oversized: one trailing byte after a complete frame
            let mut over = bytes.clone();
            over.push(0x01);
            if Message::decode(&over).is_ok() {
                return Err("oversized quantized broadcast decoded".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_quantized_broadcast_bit_flips_never_panic() {
        check("message-broadcast-bit-flip", 200, |g: &mut Gen| {
            let m = Message::Broadcast {
                round: g.rng.next_u32(),
                params: { let n = g.size(1, 16); g.vec_of(n, |g| g.f32_wide()).into() },
                losses: None,
                cohort: Some(vec![0, 1]),
                late: None,
                downlink: Some(gen_downlink(g)),
                budgets: Some(gen_budgets(g)),
            };
            let mut bytes = m.encode();
            let bit = g.size(0, bytes.len() * 8 - 1);
            bytes[bit / 8] ^= 1 << (bit % 8);
            let _ = Message::decode(&bytes);
            Ok(())
        });
    }

    fn gen_partial(g: &mut Gen) -> PartialAggregate {
        let n = g.size(1, 16);
        let mut members: Vec<u32> = g.vec_of(n, |g| g.rng.next_u32() >> 8);
        members.sort_unstable();
        members.dedup();
        let samples = g.vec_of(members.len(), |g| g.rng.next_u32());
        let d = g.size(0, 64);
        PartialAggregate {
            round: g.rng.next_u32(),
            agg_id: members[0],
            train_loss: g.f32_wide(),
            members,
            samples,
            acc: g.vec_of(d, |g| g.f32_wide()),
            telemetry: if g.int(0, 1) == 1 {
                Some((g.int(0, 7) as u32, g.rng.next_u32() as u64))
            } else {
                None
            },
        }
    }

    #[test]
    fn partial_decodes_legacy_frames_without_telemetry_tail() {
        // The telemetry tail is trailing-optional: a frame that ends
        // after the accumulator decodes with tail defaults (depth 1,
        // wire_bits 0), and a tail-less partial encodes back to exactly
        // that shorter layout.
        let p = PartialAggregate {
            round: 4,
            agg_id: 2,
            train_loss: 1.0,
            members: vec![2, 3],
            samples: vec![5, 7],
            acc: vec![0.5, 0.25],
            telemetry: None,
        };
        let with_tail = Message::Partial(PartialAggregate {
            telemetry: Some((1, 99)),
            ..p.clone()
        })
        .encode();
        let legacy = Message::Partial(p.clone()).encode();
        assert_eq!(legacy.len() + 12, with_tail.len());
        assert_eq!(&with_tail[..legacy.len()], &legacy[..], "tail appends, never reorders");
        match Message::decode(&legacy).unwrap() {
            Message::Partial(back) => {
                assert_eq!(back, p);
                assert_eq!(back.depth(), 1, "legacy depth default");
                assert_eq!(back.wire_bits(), 0, "legacy wire-bits default");
            }
            other => panic!("decoded as {other:?}"),
        }
        // A half-written tail is rejected, not misread.
        assert!(Message::decode(&with_tail[..legacy.len() + 4]).is_err());
        assert!(Message::decode(&with_tail[..with_tail.len() - 3]).is_err());
    }

    #[test]
    fn partial_rejects_malformed_member_sets() {
        let good = PartialAggregate {
            round: 1,
            agg_id: 0,
            train_loss: 0.0,
            members: vec![0, 1],
            samples: vec![3, 4],
            acc: vec![1.0],
            telemetry: Some((1, 8)),
        };
        // unsorted members
        let mut bad = good.clone();
        bad.members = vec![1, 0];
        assert!(Message::decode(&Message::Partial(bad).encode()).is_err());
        // duplicate members
        let mut bad = good.clone();
        bad.members = vec![1, 1];
        assert!(Message::decode(&Message::Partial(bad).encode()).is_err());
        // samples/members length mismatch
        let mut bad = good.clone();
        bad.samples = vec![3];
        assert!(Message::decode(&Message::Partial(bad).encode()).is_err());
        assert!(Message::decode(&Message::Partial(good).encode()).is_ok());
    }

    #[test]
    fn prop_partial_roundtrip_and_encoded_len() {
        check("message-partial-roundtrip", 100, |g: &mut Gen| {
            let m = Message::Partial(gen_partial(g));
            if m.encoded_len() != m.encode().len() {
                return Err("encoded_len diverged from encode".into());
            }
            let back = Message::decode(&m.encode()).map_err(|e| e.to_string())?;
            if back != m {
                return Err("roundtrip mismatch".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_truncated_partial_is_an_error_never_a_panic() {
        // Every cut strictly before the trailing-optional telemetry
        // tail must decode to Err (a cut exactly at the tail boundary
        // legitimately decodes as the legacy layout, like Join/Welcome
        // prefixes); no cut may panic or allocate absurdly.
        check("message-truncated-partial", 100, |g: &mut Gen| {
            let mut p = gen_partial(g);
            p.telemetry = None;
            let bytes = Message::Partial(p).encode();
            let cut = g.size(0, bytes.len() - 1);
            match Message::decode(&bytes[..cut]) {
                Err(_) => Ok(()),
                Ok(m) => Err(format!("truncated partial decoded as {m:?}")),
            }
        });
    }

    #[test]
    fn prop_partial_bit_flips_never_panic() {
        // A flipped bit may still decode (float/payload bytes carry no
        // structure) but must never panic, and a flip in a length field
        // must not cause a huge allocation (counts are bounded by the
        // remaining bytes before any reserve).
        check("message-partial-bit-flip", 200, |g: &mut Gen| {
            let mut bytes = Message::Partial(gen_partial(g)).encode();
            let bit = g.size(0, bytes.len() * 8 - 1);
            bytes[bit / 8] ^= 1 << (bit % 8);
            let _ = Message::decode(&bytes);
            Ok(())
        });
    }

    #[test]
    fn prop_partial_and_legacy_update_streams_interleave() {
        // Version tolerance on the receive path: one decoder must
        // accept a stream mixing legacy leaf Updates and tree
        // PartialAggregates, frame by frame, with no mode switch.
        check("message-partial-update-interleave", 50, |g: &mut Gen| {
            let frames: Vec<Message> = (0..6)
                .map(|i| {
                    if i % 2 == 0 {
                        gen_update(g)
                    } else {
                        Message::Partial(gen_partial(g))
                    }
                })
                .collect();
            for m in &frames {
                let back = Message::decode(&m.encode()).map_err(|e| e.to_string())?;
                if back != *m {
                    return Err("interleaved stream frame mismatch".into());
                }
            }
            Ok(())
        });
    }
}
