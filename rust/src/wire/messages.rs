//! Wire message types and their binary serialization.
//!
//! The format is a hand-rolled little-endian binary layout (no serde
//! offline): one tag byte, then fixed-width fields, then length-prefixed
//! payloads.  The *uplink* `Update` message is the object of study — its
//! size is exactly what the paper's "communicated bit volume" counts:
//! per-segment headers (bits, min, step — the `2 x 32` bit overhead per
//! segment acknowledged in the paper's `C_s` model) plus the bit-packed
//! codes.

use std::sync::Arc;

use anyhow::{bail, Result};

/// Per-segment quantization header.
///
/// The decoder needs (bits, min, step); `level` (the quantization level
/// `s`) additionally lets the server recover the client's observed update
/// range as `step * level` for telemetry (Fig. 1b) without a second pass.
/// All four fields are wire-accounted: 8 + 16 + 32 + 32 = 88 bits per
/// segment (the paper's overhead model counts the two f32s).
#[derive(Clone, Debug, PartialEq)]
pub struct SegmentHeader {
    /// Wire bits per code; 32 means raw f32 passthrough (fp32 policy).
    pub bits: u8,
    /// Quantization level `s` (codes in 0..=s); 0 for fp32 segments.
    pub level: u16,
    /// Segment minimum (dequantization offset).
    pub min: f32,
    /// Dequantization step `range / s` (for fp32 segments this field
    /// carries the raw range, telemetry only).
    pub step: f32,
}

impl SegmentHeader {
    /// The update range this header implies (telemetry).
    pub fn range(&self) -> f32 {
        if self.bits == 32 {
            self.step // fp32 convention: step field carries the raw range
        } else {
            self.step * self.level as f32
        }
    }
}

/// A client's quantized model update for one round.
#[derive(Clone, Debug, PartialEq)]
pub struct Update {
    /// Round the update answers.
    pub round: u32,
    /// Sending client's id.
    pub client_id: u32,
    /// Client dataset size (aggregation weight numerator, paper `p_i`).
    pub num_samples: u32,
    /// Mean local training loss over the tau local steps (AdaQuantFL input).
    pub train_loss: f32,
    /// Per-segment quantization headers, in manifest segment order.
    pub segments: Vec<SegmentHeader>,
    /// Bit-packed codes (or raw f32 LE bytes for 32-bit segments).
    pub payload: Vec<u8>,
}

/// Everything that can cross a transport.
#[derive(Clone, Debug, PartialEq)]
pub enum Message {
    /// Client -> server: join the federation.
    ///
    /// `num_samples` (the client's shard size, the aggregation-weight
    /// numerator) is optional on the wire: `None` encodes the legacy
    /// 5-byte frame, `Some` appends one u32, and the decoder accepts
    /// both — version-tolerant in each direction.  A worker sends
    /// `None` on connect (the sharding config only arrives in the
    /// `Welcome`) and re-sends `Some(n)` as its ready handshake, which
    /// gives the server the fold-overlap weight plan at round 0.
    Join {
        /// The joining client's id (`0..n_clients`).
        client_id: u32,
        /// Shard size, when known (the ready handshake; see above).
        num_samples: Option<u32>,
    },
    /// Server -> client: accepted; carries the run-config JSON so remote
    /// workers configure themselves identically.
    ///
    /// `round` is the round the server will broadcast next, present only
    /// when a worker (re)joins a run already in progress — a rejoining
    /// worker must know where training stands so it answers the right
    /// broadcast.  Like `Join::num_samples` it is a trailing optional
    /// field: `None` encodes the legacy frame, `Some` appends one u32,
    /// and the decoder accepts both.
    Welcome {
        /// The id the server accepted the client under.
        client_id: u32,
        /// The full [`RunConfig`](crate::config::RunConfig) as JSON.
        config_json: String,
        /// Next round index when joining mid-run; `None` at run start.
        round: Option<u32>,
    },
    /// Server -> client: global model for round `round` (fp32 downlink,
    /// as in the paper — only the uplink is quantized).  Carries the
    /// global loss trajectory (initial, previous-round) that loss-driven
    /// policies (AdaQuantFL) condition on; `None` before round 1.
    ///
    /// `params` is an `Arc` so the coordinator broadcasts the same
    /// buffer to every client without copying it n times per round:
    /// cloning the message is a refcount bump, and the round engine's
    /// worker pool reads the shared vector concurrently.
    Broadcast {
        /// Round the recipients must answer.
        round: u32,
        /// The shared global parameter vector (see above).
        params: Arc<[f32]>,
        /// Global (initial, previous-round) training loss; `None`
        /// before round 1.
        losses: Option<(f32, f32)>,
    },
    /// Client -> server: the quantized update.
    Update(Update),
    /// Server -> client: training is over.
    Shutdown,
}

/// Encoded size of an [`Update`]'s body (without the message tag byte):
/// fixed header fields + segment headers + length-prefixed payload.
pub fn update_encoded_len(u: &Update) -> usize {
    4 + 4 + 4 + 4 + 4 + u.segments.len() * (1 + 2 + 4 + 4) + 4 + u.payload.len()
}

const TAG_JOIN: u8 = 1;
const TAG_WELCOME: u8 = 2;
const TAG_BROADCAST: u8 = 3;
const TAG_UPDATE: u8 = 4;
const TAG_SHUTDOWN: u8 = 5;

struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn new() -> Self {
        Writer { buf: Vec::new() }
    }
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn bytes(&mut self, v: &[u8]) {
        self.u32(v.len() as u32);
        self.buf.extend_from_slice(v);
    }
    fn str(&mut self, v: &str) {
        self.bytes(v.as_bytes());
    }
    fn f32s(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        // bulk copy — this is the downlink hot path
        super::extend_f32_le(&mut self.buf, v);
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("message truncated: need {n} bytes at {}", self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.u32()? as usize;
        Ok(self.take(n)?.to_vec())
    }
    fn str(&mut self) -> Result<String> {
        Ok(String::from_utf8(self.bytes()?)?)
    }
    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let raw = self.take(n * 4)?;
        let mut out = Vec::with_capacity(n);
        for c in raw.chunks_exact(4) {
            out.push(f32::from_le_bytes(c.try_into().unwrap()));
        }
        Ok(out)
    }
    fn done(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!("trailing bytes in message: {} of {}", self.pos, self.buf.len());
        }
        Ok(())
    }
}

impl Message {
    /// Serialize to the wire byte layout.
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        match self {
            Message::Join { client_id, num_samples } => {
                w.u8(TAG_JOIN);
                w.u32(*client_id);
                // present-by-length: None is exactly the legacy frame
                if let Some(s) = num_samples {
                    w.u32(*s);
                }
            }
            Message::Welcome { client_id, config_json, round } => {
                w.u8(TAG_WELCOME);
                w.u32(*client_id);
                w.str(config_json);
                // present-by-length, like Join::num_samples
                if let Some(m) = round {
                    w.u32(*m);
                }
            }
            Message::Broadcast { round, params, losses } => {
                w.u8(TAG_BROADCAST);
                w.u32(*round);
                match losses {
                    None => w.u8(0),
                    Some((f0, fm)) => {
                        w.u8(1);
                        w.f32(*f0);
                        w.f32(*fm);
                    }
                }
                w.f32s(params);
            }
            Message::Update(u) => {
                w.u8(TAG_UPDATE);
                w.u32(u.round);
                w.u32(u.client_id);
                w.u32(u.num_samples);
                w.f32(u.train_loss);
                w.u32(u.segments.len() as u32);
                for s in &u.segments {
                    w.u8(s.bits);
                    w.u16(s.level);
                    w.f32(s.min);
                    w.f32(s.step);
                }
                w.bytes(&u.payload);
            }
            Message::Shutdown => w.u8(TAG_SHUTDOWN),
        }
        w.buf
    }

    /// Exact length of [`Self::encode`]'s output, computed without
    /// allocating or serializing.  The in-process transports account
    /// framed byte volumes from this, which keeps a whole
    /// encode-per-client off the round hot path (the bytes never cross a
    /// real wire there).  Must stay in lockstep with `encode`; a
    /// property test asserts equality.
    pub fn encoded_len(&self) -> usize {
        match self {
            Message::Join { num_samples, .. } => 1 + 4 + if num_samples.is_some() { 4 } else { 0 },
            Message::Welcome { config_json, round, .. } => {
                1 + 4 + 4 + config_json.len() + if round.is_some() { 4 } else { 0 }
            }
            Message::Broadcast { params, losses, .. } => {
                let losses_len = match losses {
                    None => 1,
                    Some(_) => 1 + 4 + 4,
                };
                1 + 4 + losses_len + 4 + params.len() * 4
            }
            Message::Update(u) => 1 + update_encoded_len(u),
            Message::Shutdown => 1,
        }
    }

    /// Parse from the wire byte layout (strict: rejects trailing bytes).
    pub fn decode(buf: &[u8]) -> Result<Message> {
        let mut r = Reader::new(buf);
        let msg = match r.u8()? {
            TAG_JOIN => Message::Join {
                client_id: r.u32()?,
                // version-tolerant: old frames end after client_id
                num_samples: if r.pos < r.buf.len() { Some(r.u32()?) } else { None },
            },
            TAG_WELCOME => Message::Welcome {
                client_id: r.u32()?,
                config_json: r.str()?,
                // version-tolerant: old frames end after the config
                round: if r.pos < r.buf.len() { Some(r.u32()?) } else { None },
            },
            TAG_BROADCAST => {
                let round = r.u32()?;
                let losses = match r.u8()? {
                    0 => None,
                    1 => Some((r.f32()?, r.f32()?)),
                    t => bail!("bad losses flag {t}"),
                };
                Message::Broadcast { round, params: r.f32s()?.into(), losses }
            }
            TAG_UPDATE => {
                let round = r.u32()?;
                let client_id = r.u32()?;
                let num_samples = r.u32()?;
                let train_loss = r.f32()?;
                let nseg = r.u32()? as usize;
                if nseg > 1_000_000 {
                    bail!("absurd segment count {nseg}");
                }
                // Pre-allocate no more than the buffer can actually
                // hold (11 encoded bytes per header): a corrupt count
                // in a tiny frame must fail on the first read, not
                // reserve megabytes first.
                let mut segments =
                    Vec::with_capacity(nseg.min((r.buf.len() - r.pos) / 11));
                for _ in 0..nseg {
                    segments.push(SegmentHeader {
                        bits: r.u8()?,
                        level: r.u16()?,
                        min: r.f32()?,
                        step: r.f32()?,
                    });
                }
                Message::Update(Update {
                    round,
                    client_id,
                    num_samples,
                    train_loss,
                    segments,
                    payload: r.bytes()?,
                })
            }
            TAG_SHUTDOWN => Message::Shutdown,
            t => bail!("unknown message tag {t}"),
        };
        r.done()?;
        Ok(msg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};

    fn roundtrip(m: &Message) {
        let bytes = m.encode();
        let back = Message::decode(&bytes).unwrap();
        assert_eq!(*m, back);
    }

    #[test]
    fn roundtrip_all_variants() {
        roundtrip(&Message::Join { client_id: 7, num_samples: None });
        roundtrip(&Message::Join { client_id: 7, num_samples: Some(4200) });
        roundtrip(&Message::Welcome {
            client_id: 7,
            config_json: r#"{"model":"mlp"}"#.into(),
            round: None,
        });
        roundtrip(&Message::Welcome {
            client_id: 7,
            config_json: r#"{"model":"mlp"}"#.into(),
            round: Some(12),
        });
        roundtrip(&Message::Broadcast {
            round: 3,
            params: vec![1.0, -2.5, 0.0, f32::MIN_POSITIVE].into(),
            losses: None,
        });
        roundtrip(&Message::Broadcast {
            round: 4,
            params: vec![0.5; 3].into(),
            losses: Some((2.3, 0.7)),
        });
        roundtrip(&Message::Update(Update {
            round: 3,
            client_id: 1,
            num_samples: 600,
            train_loss: 1.25,
            segments: vec![
                SegmentHeader { bits: 7, level: 100, min: -0.5, step: 0.01 },
                SegmentHeader { bits: 32, level: 0, min: 0.0, step: 0.0 },
            ],
            payload: vec![0xde, 0xad, 0xbe, 0xef],
        }));
        roundtrip(&Message::Shutdown);
    }

    #[test]
    fn join_decodes_legacy_and_extended_frames() {
        // A pre-`num_samples` sender emits exactly tag + u32: the new
        // decoder must accept it as None (version tolerance), and a
        // None Join must encode back to that same legacy layout.
        let legacy = [1u8, 42, 0, 0, 0];
        assert_eq!(
            Message::decode(&legacy).unwrap(),
            Message::Join { client_id: 42, num_samples: None }
        );
        assert_eq!(
            Message::Join { client_id: 42, num_samples: None }.encode(),
            legacy.to_vec()
        );
        // The extended frame appends one u32 and still round-trips.
        let extended = [1u8, 42, 0, 0, 0, 88, 1, 0, 0];
        assert_eq!(
            Message::decode(&extended).unwrap(),
            Message::Join { client_id: 42, num_samples: Some(344) }
        );
        // A half-written samples field is rejected, not misread.
        assert!(Message::decode(&[1u8, 42, 0, 0, 0, 88]).is_err());
    }

    #[test]
    fn welcome_decodes_legacy_and_extended_frames() {
        // A pre-`round` sender emits tag + id + length-prefixed JSON:
        // the new decoder must accept it as None, and a None Welcome
        // must encode back to the same legacy layout.
        let legacy = [2u8, 9, 0, 0, 0, 2, 0, 0, 0, b'{', b'}'];
        let none = Message::Welcome { client_id: 9, config_json: "{}".into(), round: None };
        assert_eq!(Message::decode(&legacy).unwrap(), none);
        assert_eq!(none.encode(), legacy.to_vec());
        // The extended frame appends one u32 (the mid-run round).
        let mut extended = legacy.to_vec();
        extended.extend_from_slice(&7u32.to_le_bytes());
        assert_eq!(
            Message::decode(&extended).unwrap(),
            Message::Welcome { client_id: 9, config_json: "{}".into(), round: Some(7) }
        );
        // A half-written round field is rejected, not misread.
        assert!(Message::decode(&extended[..extended.len() - 2]).is_err());
    }

    #[test]
    fn rejects_truncation_and_trailing() {
        let bytes = Message::Broadcast { round: 1, params: vec![1.0; 8].into(), losses: None }.encode();
        assert!(Message::decode(&bytes[..bytes.len() - 1]).is_err());
        let mut extended = bytes.clone();
        extended.push(0);
        assert!(Message::decode(&extended).is_err());
        assert!(Message::decode(&[99]).is_err());
    }

    #[test]
    fn encoded_len_matches_encode() {
        let msgs = vec![
            Message::Join { client_id: 7, num_samples: None },
            Message::Join { client_id: 7, num_samples: Some(600) },
            Message::Welcome { client_id: 7, config_json: r#"{"model":"mlp"}"#.into(), round: None },
            Message::Welcome { client_id: 7, config_json: "{}".into(), round: Some(3) },
            Message::Broadcast { round: 3, params: vec![1.0; 13].into(), losses: None },
            Message::Broadcast { round: 4, params: vec![0.5; 3].into(), losses: Some((2.3, 0.7)) },
            Message::Shutdown,
        ];
        for m in &msgs {
            assert_eq!(m.encoded_len(), m.encode().len(), "{m:?}");
        }
    }

    #[test]
    fn prop_update_encoded_len() {
        check("message-update-encoded-len", 50, |g: &mut Gen| {
            let nseg = g.size(0, 40);
            let u = Update {
                round: g.rng.next_u32(),
                client_id: g.rng.next_u32(),
                num_samples: g.rng.next_u32(),
                train_loss: g.f32_wide(),
                segments: g.vec_of(nseg, |g| SegmentHeader {
                    bits: g.int(0, 32) as u8,
                    level: g.int(0, 65535) as u16,
                    min: g.f32_wide(),
                    step: g.f32_wide(),
                }),
                payload: { let n = g.size(0, 2000); g.vec_of(n, |g| g.rng.next_u32() as u8) },
            };
            let m = Message::Update(u);
            if m.encoded_len() != m.encode().len() {
                return Err("encoded_len diverged from encode".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_update_roundtrip() {
        check("message-update-roundtrip", 100, |g: &mut Gen| {
            let nseg = g.size(0, 40);
            let u = Update {
                round: g.rng.next_u32(),
                client_id: g.rng.next_u32(),
                num_samples: g.rng.next_u32(),
                train_loss: g.f32_wide(),
                segments: g.vec_of(nseg, |g| SegmentHeader {
                    bits: g.int(0, 32) as u8,
                    level: g.int(0, 65535) as u16,
                    min: g.f32_wide(),
                    step: g.f32_wide(),
                }),
                payload: { let n = g.size(0, 2000); g.vec_of(n, |g| g.rng.next_u32() as u8) },
            };
            let m = Message::Update(u);
            let back = Message::decode(&m.encode()).map_err(|e| e.to_string())?;
            if back != m {
                return Err("roundtrip mismatch".into());
            }
            Ok(())
        });
    }

    fn gen_update(g: &mut Gen) -> Message {
        let nseg = g.size(0, 20);
        Message::Update(Update {
            round: g.rng.next_u32(),
            client_id: g.rng.next_u32(),
            num_samples: g.rng.next_u32(),
            train_loss: g.f32_wide(),
            segments: g.vec_of(nseg, |g| SegmentHeader {
                bits: g.int(0, 32) as u8,
                level: g.int(0, 65535) as u16,
                min: g.f32_wide(),
                step: g.f32_wide(),
            }),
            payload: { let n = g.size(0, 500); g.vec_of(n, |g| g.rng.next_u32() as u8) },
        })
    }

    #[test]
    fn prop_truncated_update_is_an_error_never_a_panic() {
        // Updates have no trailing-optional fields, so *every* strict
        // prefix must decode to Err — and none may panic or allocate
        // absurdly.  (Join/Welcome prefixes can legitimately decode as
        // their legacy layouts; Update must not.)
        check("message-truncated-update", 100, |g: &mut Gen| {
            let bytes = gen_update(g).encode();
            let cut = g.size(0, bytes.len() - 1);
            match Message::decode(&bytes[..cut]) {
                Err(_) => Ok(()),
                Ok(m) => Err(format!("truncated update decoded as {m:?}")),
            }
        });
    }

    #[test]
    fn prop_bit_flips_never_panic() {
        // A single flipped bit may still decode (payload/float bytes
        // carry no structure), but it must never panic the decoder; a
        // flip in the segment count must not cause a huge allocation
        // (the decoder caps pre-allocation by the remaining bytes).
        check("message-bit-flip", 200, |g: &mut Gen| {
            let mut bytes = gen_update(g).encode();
            let bit = g.size(0, bytes.len() * 8 - 1);
            bytes[bit / 8] ^= 1 << (bit % 8);
            let _ = Message::decode(&bytes);
            Ok(())
        });
    }

    #[test]
    fn prop_random_bytes_never_panic() {
        check("message-byte-soup", 200, |g: &mut Gen| {
            let n = g.size(0, 300);
            let soup = g.vec_of(n, |g| g.rng.next_u32() as u8);
            let _ = Message::decode(&soup);
            Ok(())
        });
    }
}
