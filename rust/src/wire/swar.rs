//! Width-specialized codec kernels: SWAR unpack, SWAR pack, and the
//! fused quantize→pack pass.
//!
//! FedDQ's code width *descends* as training converges (Eq. 10), so the
//! narrow widths — 1/2/4/8 bits, occasionally 16 — are the steady-state
//! common case on the wire.  The generic [`BitReader::get_slice`] loop
//! pays a refill check plus a 128-bit shift *per code*; at 4 bits that
//! is ~16 branchy operations per payload byte.  The kernels here splat
//! whole 64-bit words instead (SWAR — SIMD within a register):
//!
//! | width | codes per `u64` splat |
//! |-------|-----------------------|
//! |   1   | 64                    |
//! |   2   | 32                    |
//! |   4   | 16                    |
//! |   8   |  8                    |
//! |  16   |  4                    |
//!
//! One unaligned load, then `codes-per-word` shift-mask extractions
//! with no per-code refill logic.  Odd widths (3, 5, ..., 15) fall back
//! to the generic loop — they only appear transiently while FedDQ's
//! bit curve descends through them.
//!
//! All kernels produce/consume **exactly** the bit stream of the scalar
//! reference ([`BitWriter::put_slice`] / [`BitReader::get_slice`]): the
//! byte layout is fully determined by the logical bit stream, not by
//! the flush schedule, and the property tests below cross-check every
//! width against the scalar path over random lengths, bit phases and
//! degenerate plans.  Codes are `u16` (wire widths are <= 16 bits), the
//! narrow-row representation of
//! [`DecodedUpdate`](crate::coordinator::codec::DecodedUpdate).

use super::bitpack::{BitReader, BitWriter};
use crate::util::rng::Rng;

/// Unpack `n` codes of `width` (0..=16) bits into `out`, appending.
///
/// Dispatches to a width-specialized SWAR kernel for 1/2/4/8/16 and a
/// generic shift loop otherwise.  Returns `None` when fewer than
/// `n * width` bits remain.  The failure contract is deliberately
/// *stricter* than [`BitReader::get_slice`]'s: the reader state is
/// unchanged (as there) **and** nothing is appended to `out`, whereas
/// `get_slice` can leave the decodable prefix in its output vector.
/// Callers that reuse scratch buffers across segments rely on this.
pub fn unpack_u16(r: &mut BitReader, out: &mut Vec<u16>, n: usize, width: u32) -> Option<()> {
    debug_assert!(width <= 16);
    match width {
        0 => {
            out.extend(std::iter::repeat(0).take(n));
            Some(())
        }
        1 => unpack_swar::<1>(r, out, n),
        2 => unpack_swar::<2>(r, out, n),
        4 => unpack_swar::<4>(r, out, n),
        8 => unpack_swar::<8>(r, out, n),
        16 => unpack_swar::<16>(r, out, n),
        w => unpack_generic(r, out, n, w),
    }
}

/// Pack `codes` at `width` (0..=16) bits, appending to the writer.
///
/// Mirrors [`unpack_u16`]: width-specialized SWAR for 1/2/4/8/16
/// (`64/width` codes combined into one `u64` store), generic loop
/// otherwise.  Byte output is identical to [`BitWriter::put_slice`].
pub fn pack_u16(w: &mut BitWriter, codes: &[u16], width: u32) {
    debug_assert!(width <= 16);
    match width {
        0 => {}
        1 => pack_swar::<1>(w, codes),
        2 => pack_swar::<2>(w, codes),
        4 => pack_swar::<4>(w, codes),
        8 => pack_swar::<8>(w, codes),
        16 => pack_swar::<16>(w, codes),
        _ => pack_generic(w, codes, width),
    }
}

/// The reader's absolute bit position: bytes consumed minus the bits
/// still buffered in the accumulator (see the invariant on
/// [`BitReader`]).
fn bit_position(r: &BitReader) -> u64 {
    r.byte as u64 * 8 - r.nbits as u64
}

/// Re-point the reader at an absolute bit position, rebuilding the
/// accumulator invariant from the underlying bytes.
fn set_bit_position(r: &mut BitReader, bitpos: u64) {
    let byte = (bitpos / 8) as usize;
    let phase = (bitpos % 8) as u32;
    if phase == 0 {
        r.byte = byte;
        r.acc = 0;
        r.nbits = 0;
    } else {
        // Partial byte: buffer its remaining high bits.
        r.acc = (r.buf[byte] as u64) >> phase;
        r.nbits = 8 - phase;
        r.byte = byte + 1;
    }
}

/// SWAR unpack at a const width `W` in {1, 2, 4, 8, 16}.
///
/// Works in absolute bit positions: each iteration loads one unaligned
/// `u64` at the current byte, shifts out the sub-byte phase, and
/// extracts every whole code the word holds (`(64 - phase) / W`,
/// i.e. the full `64 / W` splat once the stream is byte-phase 0).  The
/// final sub-word tail is assembled from the remaining bytes.
fn unpack_swar<const W: u32>(r: &mut BitReader, out: &mut Vec<u16>, n: usize) -> Option<()> {
    let buf = r.buf;
    let mut bitpos = bit_position(r);
    // Fail atomically (nothing consumed, nothing appended) when the
    // payload cannot hold n codes — get_slice's truncation contract.
    if (buf.len() as u64 * 8).saturating_sub(bitpos) < n as u64 * W as u64 {
        return None;
    }
    out.reserve(n);
    let mask = (1u64 << W) - 1; // W <= 16
    let mut rem = n;
    while rem > 0 {
        let byte = (bitpos / 8) as usize;
        let phase = (bitpos % 8) as u32;
        if byte + 8 <= buf.len() {
            let mut word = u64::from_le_bytes(buf[byte..byte + 8].try_into().unwrap()) >> phase;
            // >= 57 valid bits, so k >= 1 for every W <= 16.
            let k = (((64 - phase) / W) as usize).min(rem);
            for _ in 0..k {
                out.push((word & mask) as u16);
                word >>= W;
            }
            bitpos += k as u64 * W as u64;
            rem -= k;
        } else {
            // Byte tail: assemble the final partial word.  The up-front
            // size check guarantees it holds all `rem` remaining codes.
            let mut word = 0u64;
            for (i, &b) in buf[byte..].iter().enumerate() {
                word |= (b as u64) << (8 * i as u32);
            }
            word >>= phase;
            for _ in 0..rem {
                out.push((word & mask) as u16);
                word >>= W;
            }
            bitpos += rem as u64 * W as u64;
            rem = 0;
        }
    }
    set_bit_position(r, bitpos);
    Some(())
}

/// Generic unpack for odd widths: the [`BitReader::get_slice`] loop,
/// writing `u16` codes.
fn unpack_generic(r: &mut BitReader, out: &mut Vec<u16>, n: usize, width: u32) -> Option<()> {
    debug_assert!((1..=16).contains(&width));
    out.reserve(n);
    let mask = (1u64 << width) - 1;
    // Same u128 widening as get_slice: a u64 refill always fits above
    // the < 64-bit residue.
    let mut acc = r.acc as u128;
    let mut nbits = r.nbits;
    let mut byte = r.byte;
    let start = out.len();
    for _ in 0..n {
        while nbits < width {
            if byte + 8 <= r.buf.len() {
                let w = u64::from_le_bytes(r.buf[byte..byte + 8].try_into().unwrap());
                acc |= (w as u128) << nbits;
                nbits += 64;
                byte += 8;
            } else if byte < r.buf.len() {
                acc |= (r.buf[byte] as u128) << nbits;
                nbits += 8;
                byte += 1;
            } else {
                out.truncate(start); // commit nothing on truncation
                return None;
            }
        }
        out.push((acc as u64 & mask) as u16);
        acc >>= width;
        nbits -= width;
    }
    debug_assert!(nbits < 64, "residue must fit the u64 accumulator");
    r.acc = acc as u64;
    r.nbits = nbits;
    r.byte = byte;
    Some(())
}

/// SWAR pack at a const width `W` in {1, 2, 4, 8, 16}: combine
/// `64 / W` codes into one word, splice it over the sub-byte residue
/// and store 8 bytes at once.  Because `(64 / W) * W == 64` exactly,
/// the residue phase is invariant across groups.
fn pack_swar<const W: u32>(bw: &mut BitWriter, codes: &[u16]) {
    let k = (64 / W) as usize;
    bw.buf.reserve(codes.len() * W as usize / 8 + 16);
    let mut acc = bw.acc; // < 8 bits (the BitWriter invariant)
    let nbits = bw.nbits;
    debug_assert!(nbits < 8);
    let groups = codes.chunks_exact(k);
    let tail = groups.remainder();
    for group in groups {
        let mut word = 0u64;
        for (i, &c) in group.iter().enumerate() {
            debug_assert!(W == 16 || (c as u64) < (1u64 << W));
            word |= (c as u64) << (i as u32 * W);
        }
        // nbits residue + exactly 64 new bits: flush the low 64,
        // keep the (unchanged-width) high residue.
        let wide = ((word as u128) << nbits) | acc as u128;
        bw.buf.extend_from_slice(&(wide as u64).to_le_bytes());
        acc = (wide >> 64) as u64;
    }
    bw.acc = acc;
    // nbits is phase-invariant over whole groups; the tail goes through
    // the generic byte-flush path.
    pack_generic(bw, tail, W);
}

/// Generic pack for odd widths (and SWAR group tails): the
/// [`BitWriter::put_slice`] loop over `u16` codes.
fn pack_generic(bw: &mut BitWriter, codes: &[u16], width: u32) {
    let mut acc = bw.acc;
    let mut nbits = bw.nbits;
    for &c in codes {
        debug_assert!(width >= 16 || (c as u64) < (1u64 << width));
        acc |= (c as u64) << nbits;
        nbits += width;
        if nbits >= 32 {
            bw.buf.extend_from_slice(&(acc as u32).to_le_bytes());
            acc >>= 32;
            nbits -= 32;
        }
    }
    while nbits >= 8 {
        bw.buf.push(acc as u8);
        acc >>= 8;
        nbits -= 8;
    }
    bw.acc = acc;
    bw.nbits = nbits;
}

/// Fused quantize→pack over one segment: the client's encode hot path
/// collapsed into a single pass.
///
/// For every element of `delta` this computes the stochastic code
/// exactly as the quantize executable does —
/// `c = clamp(floor((x - min) * sinv + u), 0, maxcode)` with
/// `u ~ U[0,1)` drawn from `rng` in flat element order (the
/// `kernels/ref.py` contract, mirrored by
/// [`stochastic_quantize`](crate::runtime::native::stochastic_quantize))
/// — and packs it straight into the writer at `width` bits.  No
/// `d`-length codes vector, no `u32` scratch: one read of the delta,
/// one write of wire bytes.
///
/// When `residual` is given (error feedback), it receives
/// `delta[j] - (min + c * step)` per element — the identical expression
/// the unfused client path computes, so EF trajectories are
/// bit-identical across paths.
///
/// The f32 arithmetic is kept expression-for-expression identical to
/// the unfused path; codes are exact small integers in f32, so the
/// packed payload is byte-identical too (property-tested below).
#[allow(clippy::too_many_arguments)]
pub fn quantize_pack_segment(
    bw: &mut BitWriter,
    delta: &[f32],
    min: f32,
    sinv: f32,
    maxcode: f32,
    step: f32,
    width: u32,
    rng: &mut Rng,
    residual: Option<&mut [f32]>,
) {
    debug_assert!((1..=16).contains(&width));
    bw.buf.reserve(delta.len() * width as usize / 8 + 16);
    let mut acc = bw.acc;
    let mut nbits = bw.nbits;
    let mut res = residual;
    if let Some(r) = &res {
        debug_assert_eq!(r.len(), delta.len());
    }
    for (j, &x) in delta.iter().enumerate() {
        // Exactly stochastic_quantize's per-element expression (same
        // ops, same order — bit-identical codes).
        let u = rng.next_f32();
        let y = ((x - min) * sinv + u).floor();
        let c = y.clamp(0.0, maxcode);
        if let Some(r) = &mut res {
            r[j] = x - (min + c * step);
        }
        // `as u32` matches the unfused encoder's f32 -> u32 conversion
        // (clamped codes are integral and <= 65535; NaN saturates to 0
        // on both paths).
        acc |= (c as u64) << nbits;
        nbits += width;
        if nbits >= 32 {
            bw.buf.extend_from_slice(&(acc as u32).to_le_bytes());
            acc >>= 32;
            nbits -= 32;
        }
    }
    while nbits >= 8 {
        bw.buf.push(acc as u8);
        acc >>= 8;
        nbits -= 8;
    }
    bw.acc = acc;
    bw.nbits = nbits;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};

    fn random_codes(g: &mut Gen, n: usize, width: u32) -> Vec<u16> {
        let max = if width == 0 { 0u64 } else { (1u64 << width) - 1 };
        g.vec_of(n, |g| (g.rng.next_u64() % (max + 1)) as u16)
    }

    #[test]
    fn prop_pack_matches_scalar_reference_at_any_phase() {
        // Every width (specialized and odd), random lengths, and a
        // random-width prefix so the kernels start at all 8 bit phases.
        check("swar-pack-equiv", 300, |g: &mut Gen| {
            let width = g.int(0, 16) as u32;
            let n = g.size(0, 400);
            let pre_w = g.int(0, 7) as u32;
            let pre_v = if pre_w == 0 { 0 } else { (g.rng.next_u64() % (1 << pre_w)) as u32 };
            let codes = random_codes(g, n, width);
            let mut ws = BitWriter::new();
            ws.put(pre_v, pre_w);
            let scalar: Vec<u32> = codes.iter().map(|&c| c as u32).collect();
            ws.put_slice(&scalar, width);
            let mut wk = BitWriter::new();
            wk.put(pre_v, pre_w);
            pack_u16(&mut wk, &codes, width);
            if ws.bit_len() != wk.bit_len() {
                return Err(format!("bit_len {} != {}", wk.bit_len(), ws.bit_len()));
            }
            if ws.finish() != wk.finish() {
                return Err(format!("width {width} n {n} phase {pre_w}: bytes diverged"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_unpack_matches_scalar_reference_at_any_phase() {
        check("swar-unpack-equiv", 300, |g: &mut Gen| {
            let width = g.int(0, 16) as u32;
            let n = g.size(0, 400);
            let pre_w = g.int(0, 7) as u32;
            let pre_v = if pre_w == 0 { 0 } else { (g.rng.next_u64() % (1 << pre_w)) as u32 };
            let codes = random_codes(g, n, width);
            let mut w = BitWriter::new();
            w.put(pre_v, pre_w);
            let scalar: Vec<u32> = codes.iter().map(|&c| c as u32).collect();
            w.put_slice(&scalar, width);
            let bytes = w.finish();

            // Scalar reference: get_slice after the same prefix.
            let mut rr = BitReader::new(&bytes);
            if pre_w > 0 && rr.get(pre_w) != Some(pre_v) {
                return Err("prefix mismatch (reference)".into());
            }
            let mut want = Vec::new();
            rr.get_slice(&mut want, n, width).ok_or("reference truncated")?;

            // Kernel under test.
            let mut rk = BitReader::new(&bytes);
            if pre_w > 0 && rk.get(pre_w) != Some(pre_v) {
                return Err("prefix mismatch (kernel)".into());
            }
            let mut got = Vec::new();
            unpack_u16(&mut rk, &mut got, n, width).ok_or("kernel truncated")?;
            let got32: Vec<u32> = got.iter().map(|&c| c as u32).collect();
            if got32 != want {
                return Err(format!("width {width} n {n} phase {pre_w}: codes diverged"));
            }
            // Reader state must agree too: both readers continue in
            // lockstep on a trailing sentinel.
            let mut wt = BitWriter::new();
            wt.put(pre_v, pre_w);
            wt.put_slice(&scalar, width);
            wt.put(0x5a, 7);
            let bytes2 = wt.finish();
            let mut rr2 = BitReader::new(&bytes2);
            let mut rk2 = BitReader::new(&bytes2);
            if pre_w > 0 {
                rr2.get(pre_w);
                rk2.get(pre_w);
            }
            let mut sink = Vec::new();
            rr2.get_slice(&mut sink, n, width).ok_or("ref re-read")?;
            let mut sink16 = Vec::new();
            unpack_u16(&mut rk2, &mut sink16, n, width).ok_or("kernel re-read")?;
            if rr2.get(7) != Some(0x5a) || rk2.get(7) != Some(0x5a) {
                return Err(format!("width {width}: reader positions diverged after unpack"));
            }
            Ok(())
        });
    }

    #[test]
    fn unpack_truncated_fails_atomically() {
        for width in [1u32, 2, 3, 4, 8, 11, 16] {
            let n = 50usize;
            let codes = vec![0u16; n];
            let mut w = BitWriter::new();
            pack_u16(&mut w, &codes, width);
            let mut bytes = w.finish();
            bytes.truncate(bytes.len() - 1);
            let mut r = BitReader::new(&bytes);
            let mut out = vec![7u16; 3]; // pre-existing content survives
            assert_eq!(unpack_u16(&mut r, &mut out, n, width), None, "width {width}");
            assert_eq!(out, vec![7u16; 3]);
            // reader still usable from the same position
            assert_eq!(r.get(width), Some(0));
        }
    }

    #[test]
    fn prop_fused_quantize_pack_matches_split_path() {
        use crate::coordinator::codec::QuantPlan;
        // The fused kernel must produce byte-identical payload and
        // bit-identical residuals vs quantize-then-pack, including on
        // degenerate plans (zero/subnormal/inf ranges -> collapsed
        // segments) and deltas containing extremes.
        check("swar-fused-encode-equiv", 150, |g: &mut Gen| {
            let n = g.size(0, 300);
            let level = g.int(1, 65_535) as u32;
            let range = match g.int(0, 4) {
                0 => 0.0,
                1 => 1.0e-40,
                2 => f32::INFINITY,
                _ => g.f32(1e-6, 4.0),
            };
            let min = g.f32(-2.0, 2.0);
            let plan = QuantPlan::new(&[level], &[range]);
            let width = crate::quant::math::bits_for_level(level);
            let delta: Vec<f32> = g.vec_of(n, |g| match g.int(0, 8) {
                0 => f32::NAN,
                1 => f32::INFINITY,
                2 => f32::NEG_INFINITY,
                _ => g.f32(-3.0, 3.0),
            });
            let seed = g.rng.next_u32();

            // Split path: quantize (flat rng order) then u32 pack.
            let mut rng_a = Rng::new(seed as u64);
            let mut codes = Vec::with_capacity(n);
            let mut res_a = vec![0.0f32; n];
            for (j, &x) in delta.iter().enumerate() {
                let u = rng_a.next_f32();
                let y = ((x - min) * plan.sinv[0] + u).floor();
                let c = y.clamp(0.0, plan.maxcode[0]);
                res_a[j] = x - (min + c * plan.step[0]);
                codes.push(c as u32);
            }
            let mut wa = BitWriter::new();
            wa.put_slice(&codes, width);

            // Fused path.
            let mut rng_b = Rng::new(seed as u64);
            let mut res_b = vec![0.0f32; n];
            let mut wb = BitWriter::new();
            quantize_pack_segment(
                &mut wb, &delta, min, plan.sinv[0], plan.maxcode[0], plan.step[0],
                width, &mut rng_b, Some(&mut res_b),
            );

            if wa.finish() != wb.finish() {
                return Err(format!("level {level} range {range}: payload diverged"));
            }
            let bits_a: Vec<u32> = res_a.iter().map(|x| x.to_bits()).collect();
            let bits_b: Vec<u32> = res_b.iter().map(|x| x.to_bits()).collect();
            if bits_a != bits_b {
                return Err("residuals diverged".into());
            }
            Ok(())
        });
    }
}
