//! Exact-width bit packing of quantization codes.
//!
//! Codes are integers in `{0, .., s}` where `s` is the quantization level;
//! each occupies exactly `width = ceil(log2(s+1))` bits on the wire —
//! the `C_s = d * ceil(log2(s+1))` cost model of the paper (Appendix,
//! Eq. 23 context).  Packing is little-endian within a `u64` accumulator,
//! which compiles to a handful of shifts per code (no per-bit loops);
//! see the `perf_hotpath` bench for measured GB/s.

/// Number of wire bits for quantization level `s` (codes in `0..=s`).
#[inline]
pub fn width_for_level(s: u32) -> u32 {
    // ceil(log2(s + 1)) — number of bits to represent s distinct steps + 0.
    32 - s.leading_zeros()
}

/// Writer that packs variable-width unsigned integers into bytes.
///
/// Invariant: outside of a `put*` call the accumulator holds fewer than
/// 8 bits (`nbits < 8`) — every entry point flushes whole bytes before
/// returning.  The width-specialized packers in [`super::swar`] rely on
/// this to splat whole `u64` words without overflow.
#[derive(Default)]
pub struct BitWriter {
    pub(crate) buf: Vec<u8>,
    pub(crate) acc: u64,
    pub(crate) nbits: u32,
}

impl BitWriter {
    /// Empty writer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Empty writer with `bytes` of output capacity pre-reserved.
    pub fn with_capacity(bytes: usize) -> Self {
        BitWriter {
            buf: Vec::with_capacity(bytes),
            acc: 0,
            nbits: 0,
        }
    }

    /// Append `width` low bits of `value` (width in 0..=32).
    #[inline]
    pub fn put(&mut self, value: u32, width: u32) {
        debug_assert!(width <= 32);
        debug_assert!(width == 32 || value < (1u32 << width).max(1));
        self.acc |= (value as u64) << self.nbits;
        self.nbits += width;
        while self.nbits >= 8 {
            self.buf.push(self.acc as u8);
            self.acc >>= 8;
            self.nbits -= 8;
        }
    }

    /// Pack a whole slice of codes at a fixed width (hot path).
    ///
    /// §Perf: flushes the accumulator four bytes at a time instead of the
    /// scalar path's byte-wise Vec::push (EXPERIMENTS.md §Perf L3-3).
    pub fn put_slice(&mut self, codes: &[u32], width: u32) {
        if width == 0 {
            return;
        }
        self.buf.reserve((codes.len() * width as usize + 7) / 8 + 8);
        let mut acc = self.acc;
        let mut nbits = self.nbits;
        for &c in codes {
            debug_assert!(width == 32 || c < (1u32 << width).max(1));
            acc |= (c as u64) << nbits;
            nbits += width;
            if nbits >= 32 {
                self.buf.extend_from_slice(&(acc as u32).to_le_bytes());
                acc >>= 32;
                nbits -= 32;
            }
        }
        self.acc = acc;
        self.nbits = nbits;
        while self.nbits >= 8 {
            self.buf.push(self.acc as u8);
            self.acc >>= 8;
            self.nbits -= 8;
        }
    }

    /// Total bits written so far.
    pub fn bit_len(&self) -> u64 {
        self.buf.len() as u64 * 8 + self.nbits as u64
    }

    /// Flush the final partial byte and return the buffer.
    pub fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            self.buf.push(self.acc as u8);
        }
        self.buf
    }
}

/// Reader over bit-packed bytes.
///
/// Invariant: the accumulator `acc` always holds the next `nbits` bits
/// of the stream verbatim (low bits first), sourced from
/// `buf[..byte]` — so the reader's absolute bit position is
/// `byte * 8 - nbits` and [`super::swar`]'s width-specialized unpackers
/// can recompute any suffix of the stream directly from `buf`.
pub struct BitReader<'a> {
    pub(crate) buf: &'a [u8],
    pub(crate) byte: usize,
    pub(crate) acc: u64,
    pub(crate) nbits: u32,
}

impl<'a> BitReader<'a> {
    /// Reader positioned at bit 0 of `buf`.
    pub fn new(buf: &'a [u8]) -> Self {
        BitReader {
            buf,
            byte: 0,
            acc: 0,
            nbits: 0,
        }
    }

    /// Read `width` bits (width in 0..=32); `None` past end of buffer.
    #[inline]
    pub fn get(&mut self, width: u32) -> Option<u32> {
        debug_assert!(width <= 32);
        if width == 0 {
            return Some(0);
        }
        while self.nbits < width {
            let b = *self.buf.get(self.byte)?;
            self.acc |= (b as u64) << self.nbits;
            self.nbits += 8;
            self.byte += 1;
        }
        let mask = if width == 32 {
            u32::MAX as u64
        } else {
            (1u64 << width) - 1
        };
        let v = (self.acc & mask) as u32;
        self.acc >>= width;
        self.nbits -= width;
        Some(v)
    }

    /// Unpack `n` codes at fixed width into `out` (hot path).
    ///
    /// §Perf: refills a 128-bit accumulator with 64-bit unaligned loads —
    /// at width <= 16 that is one load per four-plus codes, roughly
    /// halving the refill traffic of the earlier 32-bit scheme (see
    /// `perf_hotpath` / BENCH_hotpath.json).  Falls back to byte loads
    /// near the end of the buffer.
    pub fn get_slice(&mut self, out: &mut Vec<u32>, n: usize, width: u32) -> Option<()> {
        debug_assert!(width <= 32);
        out.reserve(n);
        if width == 0 {
            out.extend(std::iter::repeat(0).take(n));
            return Some(());
        }
        let mask = if width == 32 {
            u32::MAX as u64
        } else {
            (1u64 << width) - 1
        };
        // The resident accumulator is a u64 holding < 64 bits; widen to
        // u128 locally so a full u64 refill always fits.  Refills only
        // trigger at nbits < width <= 32, so nbits never exceeds
        // width + 63 and the final residue fits back into the u64.
        let mut acc = self.acc as u128;
        let mut nbits = self.nbits;
        let mut byte = self.byte;
        for _ in 0..n {
            while nbits < width {
                if byte + 8 <= self.buf.len() {
                    let w = u64::from_le_bytes(self.buf[byte..byte + 8].try_into().unwrap());
                    acc |= (w as u128) << nbits;
                    nbits += 64;
                    byte += 8;
                } else if byte < self.buf.len() {
                    acc |= (self.buf[byte] as u128) << nbits;
                    nbits += 8;
                    byte += 1;
                } else {
                    // commit nothing: leave reader state unchanged on error
                    return None;
                }
            }
            out.push((acc as u64 & mask) as u32);
            acc >>= width;
            nbits -= width;
        }
        debug_assert!(nbits < 64, "residue must fit the u64 accumulator");
        self.acc = acc as u64;
        self.nbits = nbits;
        self.byte = byte;
        Some(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};

    #[test]
    fn width_for_levels() {
        assert_eq!(width_for_level(0), 0);
        assert_eq!(width_for_level(1), 1);
        assert_eq!(width_for_level(2), 2);
        assert_eq!(width_for_level(3), 2);
        assert_eq!(width_for_level(4), 3);
        assert_eq!(width_for_level(255), 8);
        assert_eq!(width_for_level(256), 9);
        assert_eq!(width_for_level(u32::MAX), 32);
    }

    #[test]
    fn roundtrip_simple() {
        let mut w = BitWriter::new();
        w.put(5, 3);
        w.put(0, 1);
        w.put(1023, 10);
        w.put(7, 32);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.get(3), Some(5));
        assert_eq!(r.get(1), Some(0));
        assert_eq!(r.get(10), Some(1023));
        assert_eq!(r.get(32), Some(7));
    }

    #[test]
    fn bit_len_is_exact() {
        let mut w = BitWriter::new();
        w.put_slice(&[1, 2, 3, 4, 5], 5);
        assert_eq!(w.bit_len(), 25);
        let bytes = w.finish();
        assert_eq!(bytes.len(), 4); // ceil(25/8)
    }

    #[test]
    fn read_past_end_is_none() {
        let mut w = BitWriter::new();
        w.put(3, 2);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.get(2), Some(3));
        assert_eq!(r.get(8), None); // only 6 padding bits remain
    }

    #[test]
    fn prop_roundtrip_mixed_widths() {
        check("bitpack-roundtrip", 200, |g: &mut Gen| {
            let n = g.size(0, 300);
            let items: Vec<(u32, u32)> = g.vec_of(n, |g| {
                let width = g.int(0, 32) as u32;
                let max = if width == 0 {
                    0
                } else if width == 32 {
                    u32::MAX
                } else {
                    (1u32 << width) - 1
                };
                let v = if max == 0 {
                    0
                } else {
                    (g.rng.next_u64() % (max as u64 + 1)) as u32
                };
                (v, width)
            });
            let mut w = BitWriter::new();
            for &(v, width) in &items {
                w.put(v, width);
            }
            let expect_bits: u64 = items.iter().map(|&(_, w)| w as u64).sum();
            if w.bit_len() != expect_bits {
                return Err(format!("bit_len {} != {}", w.bit_len(), expect_bits));
            }
            let bytes = w.finish();
            let mut r = BitReader::new(&bytes);
            for (i, &(v, width)) in items.iter().enumerate() {
                match r.get(width) {
                    Some(got) if got == v => {}
                    other => return Err(format!("item {i}: expected {v}, got {other:?}")),
                }
            }
            Ok(())
        });
    }

    #[test]
    fn get_slice_wide_widths_through_byte_tail_refill() {
        // Widths 17..=32 near the end of the buffer: the u64 bulk refill
        // needs 8 whole bytes, so the last values force the byte-at-a-time
        // tail path.  Buffer lengths here are deliberately not multiples
        // of 8 so every width crosses the bulk->tail boundary mid-value.
        for width in 17..=32u32 {
            let max = if width == 32 { u32::MAX as u64 } else { (1u64 << width) - 1 };
            let mut rng = crate::util::rng::Rng::new(width as u64);
            // Few enough values that most of the stream sits in the tail.
            for n in [1usize, 2, 3, 5, 9] {
                let vals: Vec<u32> = (0..n).map(|_| (rng.next_u64() % (max + 1)) as u32).collect();
                let mut w = BitWriter::new();
                w.put_slice(&vals, width);
                let bytes = w.finish();
                // bulk path where possible, tail path for the rest
                let mut r = BitReader::new(&bytes);
                let mut out = Vec::new();
                r.get_slice(&mut out, n, width).unwrap();
                assert_eq!(out, vals, "width {width} n {n}");
                // scalar reader agrees
                let mut r2 = BitReader::new(&bytes);
                for (i, &v) in vals.iter().enumerate() {
                    assert_eq!(r2.get(width), Some(v), "width {width} item {i}");
                }
            }
        }
    }

    #[test]
    fn get_slice_wide_width_truncation_leaves_reader_unchanged() {
        // A wide read that cannot be satisfied from the byte tail must
        // return None and commit nothing — the next, smaller read still
        // sees the stream from the same position.
        for width in [17u32, 23, 31, 32] {
            let mut w = BitWriter::new();
            w.put(0b1011, 4);
            let bytes = w.finish(); // 1 byte total: 4 bits of tail padding
            let mut r = BitReader::new(&bytes);
            let mut out = Vec::new();
            assert_eq!(r.get_slice(&mut out, 1, width), None, "width {width}");
            assert!(out.is_empty());
            assert_eq!(r.get(4), Some(0b1011), "reader state must be untouched");
        }
    }

    #[test]
    fn prop_slice_matches_scalar_path() {
        check("bitpack-slice-equiv", 100, |g: &mut Gen| {
            let width = g.int(1, 16) as u32;
            let n = g.size(0, 500);
            let max = (1u64 << width) - 1;
            let codes: Vec<u32> =
                g.vec_of(n, |g| (g.rng.next_u64() % (max + 1)) as u32);
            let mut w1 = BitWriter::new();
            w1.put_slice(&codes, width);
            let mut w2 = BitWriter::new();
            for &c in &codes {
                w2.put(c, width);
            }
            if w1.finish() != w2.finish() {
                return Err("slice path diverged from scalar path".into());
            }
            Ok(())
        });
    }
}
