//! Wire layer: bit-exact encoding of quantized model updates and the
//! transports that carry them.
//!
//! The paper's headline metric is *communicated bit volume*; this module
//! makes the measurement honest by actually packing each code into its
//! `ceil(log2(s+1))`-bit slot ([`bitpack`]), framing updates as messages
//! ([`messages`], [`frame`]) and shipping them over an in-process channel
//! or a real TCP socket ([`transport`]).  The ledger counts the bytes that
//! cross the transport — not an analytic estimate.

//! The narrow-width hot loops (1–16-bit codes, FedDQ's steady state)
//! run on width-specialized SWAR kernels ([`swar`]): whole-`u64`
//! splats for widths 1/2/4/8/16 plus the fused quantize→pack pass,
//! all byte-identical to the scalar [`bitpack`] reference.

pub mod bitpack;
pub mod frame;
pub mod messages;
pub mod swar;
pub mod transport;

/// Append `src` to `dst` as little-endian f32 bytes: one bulk memcpy on
/// little-endian targets, a per-element conversion elsewhere.  Shared by
/// the downlink broadcast writer and the fp32 uplink codec (both hot
/// paths).
pub fn extend_f32_le(dst: &mut Vec<u8>, src: &[f32]) {
    if cfg!(target_endian = "little") {
        // f32 slice -> byte view: safe for any properly-sized allocation
        let bytes =
            unsafe { std::slice::from_raw_parts(src.as_ptr() as *const u8, src.len() * 4) };
        dst.extend_from_slice(bytes);
    } else {
        for x in src {
            dst.extend_from_slice(&x.to_le_bytes());
        }
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn extend_f32_le_matches_per_element() {
        let xs = [1.5f32, -0.0, f32::MIN_POSITIVE, f32::NAN, 7e9];
        let mut bulk = Vec::new();
        super::extend_f32_le(&mut bulk, &xs);
        let mut scalar = Vec::new();
        for x in &xs {
            scalar.extend_from_slice(&x.to_le_bytes());
        }
        assert_eq!(bulk, scalar);
    }
}
