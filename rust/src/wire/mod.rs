//! Wire layer: bit-exact encoding of quantized model updates and the
//! transports that carry them.
//!
//! The paper's headline metric is *communicated bit volume*; this module
//! makes the measurement honest by actually packing each code into its
//! `ceil(log2(s+1))`-bit slot ([`bitpack`]), framing updates as messages
//! ([`messages`], [`frame`]) and shipping them over an in-process channel
//! or a real TCP socket ([`transport`]).  The ledger counts the bytes that
//! cross the transport — not an analytic estimate.

pub mod bitpack;
pub mod frame;
pub mod messages;
pub mod transport;
