//! Transports: message pipes with byte accounting.
//!
//! Two implementations of [`Transport`]:
//!
//! * [`InProcTransport`] — `std::sync::mpsc` channel pair used by the
//!   single-process simulator.  Buffers are moved, not copied, but the
//!   accounted size is the *framed* size so the reported bit volume is
//!   identical to what TCP mode would transmit.
//! * [`TcpTransport`] — a real `std::net::TcpStream` speaking the
//!   [`crate::wire::frame`] format; used by `feddq serve` / `feddq worker`
//!   multi-process mode.
//!
//! Byte counters are per-direction; the coordinator's ledger reads them at
//! round boundaries.

use std::net::TcpStream;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::frame;
use super::messages::Message;
use crate::sim::faults::{FaultDraw, FaultModel, FaultProfile};

/// A bidirectional, byte-accounted message pipe.
pub trait Transport: Send {
    /// Serialize and transmit one message.
    fn send(&mut self, msg: &Message) -> Result<()>;
    /// Send a message the caller already encoded (`msg.encode()` done
    /// once, fanned out to many peers — the broadcast hot path).
    /// Implementations must transmit and account `encoded` without
    /// re-serializing.
    fn send_encoded(&mut self, encoded: &[u8]) -> Result<()>;
    /// Block for the next message.
    fn recv(&mut self) -> Result<Message>;
    /// Bytes sent so far (framed size).
    fn bytes_sent(&self) -> u64;
    /// Bytes received so far (framed size).
    fn bytes_received(&self) -> u64;
}

impl<T: Transport + ?Sized> Transport for Box<T> {
    fn send(&mut self, msg: &Message) -> Result<()> {
        (**self).send(msg)
    }
    fn send_encoded(&mut self, encoded: &[u8]) -> Result<()> {
        (**self).send_encoded(encoded)
    }
    fn recv(&mut self) -> Result<Message> {
        (**self).recv()
    }
    fn bytes_sent(&self) -> u64 {
        (**self).bytes_sent()
    }
    fn bytes_received(&self) -> u64 {
        (**self).bytes_received()
    }
}

// ---------------------------------------------------------------------------
// in-process
// ---------------------------------------------------------------------------

/// One endpoint of an in-process transport pair.
pub struct InProcTransport {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
    sent: u64,
    received: u64,
}

/// Create a connected pair (server end, client end).
pub fn in_proc_pair() -> (InProcTransport, InProcTransport) {
    let (tx_a, rx_b) = channel();
    let (tx_b, rx_a) = channel();
    (
        InProcTransport { tx: tx_a, rx: rx_a, sent: 0, received: 0 },
        InProcTransport { tx: tx_b, rx: rx_b, sent: 0, received: 0 },
    )
}

impl Transport for InProcTransport {
    fn send(&mut self, msg: &Message) -> Result<()> {
        let payload = msg.encode();
        self.sent += frame::framed_len(payload.len());
        self.tx.send(payload).context("in-proc peer hung up")?;
        Ok(())
    }

    fn send_encoded(&mut self, encoded: &[u8]) -> Result<()> {
        self.sent += frame::framed_len(encoded.len());
        self.tx.send(encoded.to_vec()).context("in-proc peer hung up")?;
        Ok(())
    }

    fn recv(&mut self) -> Result<Message> {
        let payload = self.rx.recv().context("in-proc peer hung up")?;
        self.received += frame::framed_len(payload.len());
        Message::decode(&payload)
    }

    fn bytes_sent(&self) -> u64 {
        self.sent
    }

    fn bytes_received(&self) -> u64 {
        self.received
    }
}

// ---------------------------------------------------------------------------
// tcp
// ---------------------------------------------------------------------------

/// TCP transport speaking the framed wire format.
pub struct TcpTransport {
    stream: TcpStream,
    sent: u64,
    received: u64,
}

impl TcpTransport {
    /// Wrap an accepted stream (enables TCP_NODELAY — round messages
    /// are latency-sensitive).
    pub fn new(stream: TcpStream) -> Result<Self> {
        stream.set_nodelay(true).context("set_nodelay")?;
        Ok(TcpTransport { stream, sent: 0, received: 0 })
    }

    /// Connect to a listening server at `addr`.
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connect to {addr}"))?;
        Self::new(stream)
    }

    /// Connect with bounded retry: up to `attempts` tries, sleeping
    /// `initial_backoff` after the first failure and doubling up to a
    /// 2-second cap between tries.  A worker racing the coordinator's
    /// `bind()`, or rejoining after a coordinator restart, should not
    /// die on the first refused connection; a worker pointed at the
    /// wrong address still fails fast once the attempts are spent.
    pub fn connect_retry(
        addr: &str,
        attempts: u32,
        initial_backoff: Duration,
    ) -> Result<Self> {
        const BACKOFF_CAP: Duration = Duration::from_secs(2);
        let mut backoff = initial_backoff;
        let mut last_err = None;
        for attempt in 0..attempts.max(1) {
            match TcpStream::connect(addr) {
                Ok(stream) => return Self::new(stream),
                Err(e) => last_err = Some(e),
            }
            if attempt + 1 < attempts.max(1) {
                std::thread::sleep(backoff);
                backoff = (backoff * 2).min(BACKOFF_CAP);
            }
        }
        Err(last_err.unwrap()).with_context(|| {
            format!("connect to {addr} ({} attempts)", attempts.max(1))
        })
    }

    /// Bound how long a blocking [`Transport::recv`] may wait for bytes
    /// (`None` = wait forever).  The server's quorum path sets this per
    /// client while a `--round-timeout` deadline is running.
    pub fn set_read_timeout(&self, timeout: Option<Duration>) -> Result<()> {
        self.stream.set_read_timeout(timeout).context("set_read_timeout")
    }
}

impl Transport for TcpTransport {
    fn send(&mut self, msg: &Message) -> Result<()> {
        let payload = msg.encode();
        self.sent += frame::framed_len(payload.len());
        frame::write_frame(&mut self.stream, &payload)
    }

    fn send_encoded(&mut self, encoded: &[u8]) -> Result<()> {
        self.sent += frame::framed_len(encoded.len());
        frame::write_frame(&mut self.stream, encoded)
    }

    fn recv(&mut self) -> Result<Message> {
        let payload = frame::read_frame(&mut self.stream)?;
        self.received += frame::framed_len(payload.len());
        Message::decode(&payload)
    }

    fn bytes_sent(&self) -> u64 {
        self.sent
    }

    fn bytes_received(&self) -> u64 {
        self.received
    }
}

// ---------------------------------------------------------------------------
// fault injection
// ---------------------------------------------------------------------------

/// A [`Transport`] decorator that injects the seeded
/// [`FaultModel`](crate::sim::faults::FaultModel) into a *real* wire:
/// it intercepts outbound [`Message::Update`]s and, per the `(client,
/// round)` draw, loses them (`flaky`), kills the connection (`crash`) or
/// delays them (`stall`) — exercising the server's quorum/timeout/rejoin
/// machinery with genuine dead sockets and missing updates rather than
/// the scheduler's pre-excluded simulation.
///
/// This is a test/chaos harness, enabled on workers via the
/// `FEDDQ_WORKER_FAULTS` environment variable (see
/// [`crate::coordinator::topology::worker`]); the deterministic
/// simulation path never uses it, because a fault decided worker-side
/// would advance that worker's batch cursor before dropping the result,
/// diverging from the local-mode run.  Control messages (`Join`,
/// handshakes) and `recv` pass through untouched, as does
/// `send_encoded` (workers never pre-encode updates).
pub struct FaultTransport<T: Transport> {
    inner: T,
    faults: FaultModel,
    client_id: u32,
}

impl<T: Transport> FaultTransport<T> {
    /// Wrap `inner`, drawing faults for `client_id` from `faults`.
    pub fn new(inner: T, faults: FaultModel, client_id: u32) -> Self {
        FaultTransport { inner, faults, client_id }
    }
}

impl<T: Transport> Transport for FaultTransport<T> {
    fn send(&mut self, msg: &Message) -> Result<()> {
        let Message::Update(u) = msg else {
            return self.inner.send(msg);
        };
        match self.faults.draw(self.client_id, u.round) {
            FaultDraw::None => self.inner.send(msg),
            FaultDraw::Stall(secs) => {
                std::thread::sleep(Duration::from_secs_f64(secs));
                self.inner.send(msg)
            }
            FaultDraw::Drop => match self.faults.profile() {
                // Lost in transit: swallow the send; the server must
                // time this client out to finish the round.
                FaultProfile::Flaky { .. } => Ok(()),
                // Crash: the worker dies mid-round, so the server sees
                // a dead socket (and a later rejoin, if the worker is
                // restarted).
                _ => bail!(
                    "simulated crash: client {} dropping out of round {}",
                    self.client_id,
                    u.round
                ),
            },
        }
    }

    fn send_encoded(&mut self, encoded: &[u8]) -> Result<()> {
        self.inner.send_encoded(encoded)
    }

    fn recv(&mut self) -> Result<Message> {
        self.inner.recv()
    }

    fn bytes_sent(&self) -> u64 {
        self.inner.bytes_sent()
    }

    fn bytes_received(&self) -> u64 {
        self.inner.bytes_received()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;
    use std::thread;

    #[test]
    fn in_proc_roundtrip_and_accounting() {
        let (mut server, mut client) = in_proc_pair();
        let msg = Message::Broadcast {
            round: 1,
            params: vec![0.5; 100].into(),
            losses: None,
            cohort: None,
            late: None,
            downlink: None,
            budgets: None,
        };
        server.send(&msg).unwrap();
        let got = client.recv().unwrap();
        assert_eq!(got, msg);
        assert_eq!(server.bytes_sent(), client.bytes_received());
        assert!(server.bytes_sent() > 400); // 100 f32 + header
    }

    #[test]
    fn send_encoded_matches_send() {
        let msg = Message::Broadcast {
            round: 2,
            params: vec![0.25; 64].into(),
            losses: None,
            cohort: None,
            late: None,
            downlink: None,
            budgets: None,
        };
        let (mut a, mut b) = in_proc_pair();
        a.send(&msg).unwrap();
        let via_send = a.bytes_sent();
        a.send_encoded(&msg.encode()).unwrap();
        assert_eq!(a.bytes_sent(), via_send * 2, "pre-encoded path must account identically");
        assert_eq!(b.recv().unwrap(), msg);
        assert_eq!(b.recv().unwrap(), msg);
    }

    #[test]
    fn tcp_roundtrip_and_accounting() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let handle = thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut t = TcpTransport::new(stream).unwrap();
            let m = t.recv().unwrap();
            t.send(&m).unwrap(); // echo
            t.bytes_received()
        });
        let mut c = TcpTransport::connect(&addr.to_string()).unwrap();
        let msg = Message::Join { client_id: 42, num_samples: Some(1234) };
        c.send(&msg).unwrap();
        let echoed = c.recv().unwrap();
        assert_eq!(echoed, msg);
        let server_received = handle.join().unwrap();
        assert_eq!(c.bytes_sent(), server_received);
        assert_eq!(c.bytes_sent(), c.bytes_received());
    }

    #[test]
    fn in_proc_and_tcp_account_identically() {
        let msg = Message::Broadcast {
            round: 9,
            params: vec![1.0; 257].into(),
            losses: Some((2.3, 1.1)),
            cohort: None,
            late: None,
            downlink: None,
            budgets: None,
        };
        let (mut a, mut b) = in_proc_pair();
        a.send(&msg).unwrap();
        b.recv().unwrap();

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let msg2 = msg.clone();
        let handle = thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut t = TcpTransport::new(stream).unwrap();
            t.send(&msg2).unwrap();
            t.bytes_sent()
        });
        let mut c = TcpTransport::connect(&addr.to_string()).unwrap();
        c.recv().unwrap();
        let tcp_sent = handle.join().unwrap();
        assert_eq!(a.bytes_sent(), tcp_sent, "transports must account identically");
    }

    #[test]
    fn connect_retry_survives_a_late_bind() {
        // Reserve a port, release it, then bind it again *after* the
        // client has already started retrying — the race every worker
        // loses when it starts faster than the coordinator.
        let probe = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = probe.local_addr().unwrap();
        drop(probe);
        let server = thread::spawn(move || {
            thread::sleep(Duration::from_millis(60));
            let listener = TcpListener::bind(addr).unwrap();
            let (stream, _) = listener.accept().unwrap();
            let mut t = TcpTransport::new(stream).unwrap();
            t.recv().unwrap()
        });
        let mut c =
            TcpTransport::connect_retry(&addr.to_string(), 20, Duration::from_millis(20))
                .unwrap();
        let msg = Message::Join { client_id: 5, num_samples: None };
        c.send(&msg).unwrap();
        assert_eq!(server.join().unwrap(), msg);
    }

    #[test]
    fn connect_retry_exhausts_and_reports_attempts() {
        // Grab-and-drop a port so nothing listens on it.
        let probe = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = probe.local_addr().unwrap().to_string();
        drop(probe);
        let err = TcpTransport::connect_retry(&addr, 3, Duration::from_millis(1))
            .unwrap_err();
        assert!(format!("{err:#}").contains("3 attempts"), "{err:#}");
    }

    fn tiny_update(round: u32, client_id: u32) -> Message {
        Message::Update(crate::wire::messages::Update {
            round,
            client_id,
            num_samples: 10,
            train_loss: 0.5,
            segments: vec![],
            payload: vec![],
        })
    }

    #[test]
    fn flaky_transport_loses_updates_but_not_control_messages() {
        let (server, client) = in_proc_pair();
        let mut server = server;
        let mut t = FaultTransport::new(
            client,
            FaultModel::new(FaultProfile::Flaky { p: 1.0 }, 7),
            3,
        );
        // The update is swallowed silently...
        t.send(&tiny_update(0, 3)).unwrap();
        // ...but control traffic still flows, so the next real message
        // is the Join, not the Update.
        let join = Message::Join { client_id: 3, num_samples: Some(9) };
        t.send(&join).unwrap();
        assert_eq!(server.recv().unwrap(), join);
    }

    #[test]
    fn crash_transport_fails_the_send_and_spares_clean_rounds() {
        let (server, client) = in_proc_pair();
        let mut server = server;
        // p = 0.5 at seed 7: scan for one failing and one passing round
        // (draws are pure, so this is stable for a fixed seed).
        let model = FaultModel::new(FaultProfile::Crash { p: 0.5 }, 7);
        let hit = (0..64).find(|&m| model.draw(3, m) == FaultDraw::Drop).unwrap();
        let miss = (0..64).find(|&m| model.draw(3, m) == FaultDraw::None).unwrap();
        let mut t = FaultTransport::new(client, model, 3);
        let err = t.send(&tiny_update(hit, 3)).unwrap_err();
        assert!(format!("{err:#}").contains("simulated crash"), "{err:#}");
        t.send(&tiny_update(miss, 3)).unwrap();
        assert_eq!(server.recv().unwrap(), tiny_update(miss, 3));
    }
}
