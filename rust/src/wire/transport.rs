//! Transports: message pipes with byte accounting.
//!
//! Two implementations of [`Transport`]:
//!
//! * [`InProcTransport`] — `std::sync::mpsc` channel pair used by the
//!   single-process simulator.  Buffers are moved, not copied, but the
//!   accounted size is the *framed* size so the reported bit volume is
//!   identical to what TCP mode would transmit.
//! * [`TcpTransport`] — a real `std::net::TcpStream` speaking the
//!   [`crate::wire::frame`] format; used by `feddq serve` / `feddq worker`
//!   multi-process mode.
//!
//! Byte counters are per-direction; the coordinator's ledger reads them at
//! round boundaries.

use std::net::TcpStream;
use std::sync::mpsc::{channel, Receiver, Sender};

use anyhow::{Context, Result};

use super::frame;
use super::messages::Message;

/// A bidirectional, byte-accounted message pipe.
pub trait Transport: Send {
    /// Serialize and transmit one message.
    fn send(&mut self, msg: &Message) -> Result<()>;
    /// Send a message the caller already encoded (`msg.encode()` done
    /// once, fanned out to many peers — the broadcast hot path).
    /// Implementations must transmit and account `encoded` without
    /// re-serializing.
    fn send_encoded(&mut self, encoded: &[u8]) -> Result<()>;
    /// Block for the next message.
    fn recv(&mut self) -> Result<Message>;
    /// Bytes sent so far (framed size).
    fn bytes_sent(&self) -> u64;
    /// Bytes received so far (framed size).
    fn bytes_received(&self) -> u64;
}

// ---------------------------------------------------------------------------
// in-process
// ---------------------------------------------------------------------------

/// One endpoint of an in-process transport pair.
pub struct InProcTransport {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
    sent: u64,
    received: u64,
}

/// Create a connected pair (server end, client end).
pub fn in_proc_pair() -> (InProcTransport, InProcTransport) {
    let (tx_a, rx_b) = channel();
    let (tx_b, rx_a) = channel();
    (
        InProcTransport { tx: tx_a, rx: rx_a, sent: 0, received: 0 },
        InProcTransport { tx: tx_b, rx: rx_b, sent: 0, received: 0 },
    )
}

impl Transport for InProcTransport {
    fn send(&mut self, msg: &Message) -> Result<()> {
        let payload = msg.encode();
        self.sent += frame::framed_len(payload.len());
        self.tx.send(payload).context("in-proc peer hung up")?;
        Ok(())
    }

    fn send_encoded(&mut self, encoded: &[u8]) -> Result<()> {
        self.sent += frame::framed_len(encoded.len());
        self.tx.send(encoded.to_vec()).context("in-proc peer hung up")?;
        Ok(())
    }

    fn recv(&mut self) -> Result<Message> {
        let payload = self.rx.recv().context("in-proc peer hung up")?;
        self.received += frame::framed_len(payload.len());
        Message::decode(&payload)
    }

    fn bytes_sent(&self) -> u64 {
        self.sent
    }

    fn bytes_received(&self) -> u64 {
        self.received
    }
}

// ---------------------------------------------------------------------------
// tcp
// ---------------------------------------------------------------------------

/// TCP transport speaking the framed wire format.
pub struct TcpTransport {
    stream: TcpStream,
    sent: u64,
    received: u64,
}

impl TcpTransport {
    /// Wrap an accepted stream (enables TCP_NODELAY — round messages
    /// are latency-sensitive).
    pub fn new(stream: TcpStream) -> Result<Self> {
        stream.set_nodelay(true).context("set_nodelay")?;
        Ok(TcpTransport { stream, sent: 0, received: 0 })
    }

    /// Connect to a listening server at `addr`.
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connect to {addr}"))?;
        Self::new(stream)
    }
}

impl Transport for TcpTransport {
    fn send(&mut self, msg: &Message) -> Result<()> {
        let payload = msg.encode();
        self.sent += frame::framed_len(payload.len());
        frame::write_frame(&mut self.stream, &payload)
    }

    fn send_encoded(&mut self, encoded: &[u8]) -> Result<()> {
        self.sent += frame::framed_len(encoded.len());
        frame::write_frame(&mut self.stream, encoded)
    }

    fn recv(&mut self) -> Result<Message> {
        let payload = frame::read_frame(&mut self.stream)?;
        self.received += frame::framed_len(payload.len());
        Message::decode(&payload)
    }

    fn bytes_sent(&self) -> u64 {
        self.sent
    }

    fn bytes_received(&self) -> u64 {
        self.received
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;
    use std::thread;

    #[test]
    fn in_proc_roundtrip_and_accounting() {
        let (mut server, mut client) = in_proc_pair();
        let msg = Message::Broadcast { round: 1, params: vec![0.5; 100].into(), losses: None };
        server.send(&msg).unwrap();
        let got = client.recv().unwrap();
        assert_eq!(got, msg);
        assert_eq!(server.bytes_sent(), client.bytes_received());
        assert!(server.bytes_sent() > 400); // 100 f32 + header
    }

    #[test]
    fn send_encoded_matches_send() {
        let msg = Message::Broadcast { round: 2, params: vec![0.25; 64].into(), losses: None };
        let (mut a, mut b) = in_proc_pair();
        a.send(&msg).unwrap();
        let via_send = a.bytes_sent();
        a.send_encoded(&msg.encode()).unwrap();
        assert_eq!(a.bytes_sent(), via_send * 2, "pre-encoded path must account identically");
        assert_eq!(b.recv().unwrap(), msg);
        assert_eq!(b.recv().unwrap(), msg);
    }

    #[test]
    fn tcp_roundtrip_and_accounting() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let handle = thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut t = TcpTransport::new(stream).unwrap();
            let m = t.recv().unwrap();
            t.send(&m).unwrap(); // echo
            t.bytes_received()
        });
        let mut c = TcpTransport::connect(&addr.to_string()).unwrap();
        let msg = Message::Join { client_id: 42, num_samples: Some(1234) };
        c.send(&msg).unwrap();
        let echoed = c.recv().unwrap();
        assert_eq!(echoed, msg);
        let server_received = handle.join().unwrap();
        assert_eq!(c.bytes_sent(), server_received);
        assert_eq!(c.bytes_sent(), c.bytes_received());
    }

    #[test]
    fn in_proc_and_tcp_account_identically() {
        let msg = Message::Broadcast { round: 9, params: vec![1.0; 257].into(), losses: Some((2.3, 1.1)) };
        let (mut a, mut b) = in_proc_pair();
        a.send(&msg).unwrap();
        b.recv().unwrap();

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let msg2 = msg.clone();
        let handle = thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut t = TcpTransport::new(stream).unwrap();
            t.send(&msg2).unwrap();
            t.bytes_sent()
        });
        let mut c = TcpTransport::connect(&addr.to_string()).unwrap();
        c.recv().unwrap();
        let tcp_sent = handle.join().unwrap();
        assert_eq!(a.bytes_sent(), tcp_sent, "transports must account identically");
    }
}
