//! Update codec: turn a (quantized) model update into wire bytes and back.
//!
//! Encoding is the client's last hot-path step: per segment, pack each
//! code into its `bits_l`-wide slot (or copy raw f32 for fp32 segments).
//! Decoding on the server reconstructs the f32 code row plus per-segment
//! (min, step) that the fused dequantize-aggregate executable consumes.
//! fp32 segments decode to `codes = value, min = 0, step = 1`, so the
//! aggregation path is uniform across policies.

use anyhow::{bail, ensure, Result};

use crate::quant::{math, Decision};
use crate::runtime::ModelManifest;
use crate::wire::bitpack::{BitReader, BitWriter};
use crate::wire::messages::{SegmentHeader, Update};

/// Client-side quantization parameters derived from a policy decision and
/// the observed per-segment (min, range).
pub struct QuantPlan {
    /// s/range per segment (0 collapses the segment to its min).
    pub sinv: Vec<f32>,
    /// Level `s` per segment as f32 (the kernel's clamp bound).
    pub maxcode: Vec<f32>,
    /// range/s per segment (the decoder's step).
    pub step: Vec<f32>,
    pub levels: Vec<u32>,
}

/// Smallest range treated as non-degenerate.  Below this the segment is
/// transmitted as a constant (its min) — matching the kernel's guard.
pub const RANGE_EPS: f32 = 1e-12;

impl QuantPlan {
    pub fn new(levels: &[u32], ranges: &[f32]) -> QuantPlan {
        let mut sinv = Vec::with_capacity(levels.len());
        let mut maxcode = Vec::with_capacity(levels.len());
        let mut step = Vec::with_capacity(levels.len());
        for (&s, &r) in levels.iter().zip(ranges) {
            let s_f = s.max(1) as f32;
            if r > RANGE_EPS && r.is_finite() {
                sinv.push(s_f / r);
                step.push(r / s_f);
            } else {
                sinv.push(0.0);
                step.push(0.0);
            }
            maxcode.push(s_f);
        }
        QuantPlan {
            sinv,
            maxcode,
            step,
            levels: levels.iter().map(|&s| s.max(1)).collect(),
        }
    }
}

/// Encode a quantized update (codes from the quantize executable).
pub fn encode_quantized(
    mm: &ModelManifest,
    plan: &QuantPlan,
    mins: &[f32],
    codes: &[f32],
) -> (Vec<SegmentHeader>, Vec<u8>) {
    debug_assert_eq!(codes.len(), mm.d);
    let mut headers = Vec::with_capacity(mm.num_segments());
    // Worst case 16 bits/code.
    let mut w = BitWriter::with_capacity(mm.d * 2 + 16);
    let mut scratch: Vec<u32> = Vec::with_capacity(1 << 14);
    for (l, seg) in mm.segments.iter().enumerate() {
        let s = plan.levels[l];
        let bits = math::bits_for_level(s);
        headers.push(SegmentHeader {
            bits: bits as u8,
            level: s as u16,
            min: mins[l],
            step: plan.step[l],
        });
        let slice = &codes[seg.offset..seg.offset + seg.size];
        // codes are exact small integers in f32; convert once and use the
        // word-at-a-time slice packer (§Perf L3-3)
        scratch.clear();
        scratch.extend(slice.iter().map(|&c| c as u32));
        w.put_slice(&scratch, bits);
    }
    (headers, w.finish())
}

/// Encode an fp32 (unquantized) update.  The header's (min, step) carry
/// (seg_min, seg_range) purely as telemetry — the payload is raw f32.
///
/// §Perf: on little-endian targets the payload is one bulk memcpy of
/// the f32 buffer instead of a per-element `to_le_bytes` loop
/// ([`crate::wire::extend_f32_le`], shared with the downlink writer).
pub fn encode_fp32(
    mm: &ModelManifest,
    mins: &[f32],
    ranges: &[f32],
    delta: &[f32],
) -> (Vec<SegmentHeader>, Vec<u8>) {
    debug_assert_eq!(delta.len(), mm.d);
    let headers = (0..mm.num_segments())
        .map(|l| SegmentHeader {
            bits: 32,
            level: 0,
            min: mins[l],
            step: ranges[l],
        })
        .collect();
    let mut payload = Vec::with_capacity(mm.d * 4);
    crate::wire::extend_f32_le(&mut payload, delta);
    (headers, payload)
}

/// Decoded update, shaped for the aggregate path.
///
/// Owns its buffers so a caller can hold one instance across clients
/// and rounds: [`decode_update_into`] clears and refills them without
/// reallocating once they reach `d` capacity.  The round engine keeps a
/// round-persistent `DecodedUpdate` in the server and streams every
/// client through it (no `n x d` codes matrix).
#[derive(Default)]
pub struct DecodedUpdate {
    /// f32 code (or raw value) per element, length `d`.
    pub codes: Vec<f32>,
    /// Per-segment min (0 for fp32 segments), length `L`.
    pub mins: Vec<f32>,
    /// Per-segment step (1 for fp32 segments), length `L`.
    pub steps: Vec<f32>,
    /// Bit-unpack scratch (reused between segments and calls).
    scratch: Vec<u32>,
}

impl DecodedUpdate {
    pub fn new() -> DecodedUpdate {
        DecodedUpdate::default()
    }
}

/// Decode an update's payload against the model manifest into
/// caller-owned buffers (allocation-free after warm-up).
pub fn decode_update_into(mm: &ModelManifest, u: &Update, out: &mut DecodedUpdate) -> Result<()> {
    ensure!(
        u.segments.len() == mm.num_segments(),
        "update has {} segments, model {} has {}",
        u.segments.len(),
        mm.name,
        mm.num_segments()
    );
    out.codes.clear();
    out.mins.clear();
    out.steps.clear();
    out.codes.reserve(mm.d);

    // fp32 segments are raw little-endian f32 at a byte offset computed
    // from the preceding segments; quantized segments are bit-packed.
    // Mixed layouts are legal: the reader tracks bit position, and fp32
    // rows are read through the same BitReader at 32-bit width.
    let mut r = BitReader::new(&u.payload);
    for (l, seg) in mm.segments.iter().enumerate() {
        let h = &u.segments[l];
        match h.bits {
            32 => {
                out.scratch.clear();
                if r.get_slice(&mut out.scratch, seg.size, 32).is_none() {
                    bail!("payload truncated in fp32 segment {}", seg.name);
                }
                out.codes
                    .extend(out.scratch.iter().map(|&raw| f32::from_le_bytes(raw.to_le_bytes())));
                out.mins.push(0.0);
                out.steps.push(1.0);
            }
            b if b as u32 <= 16 => {
                out.scratch.clear();
                if r.get_slice(&mut out.scratch, seg.size, b as u32).is_none() {
                    bail!("payload truncated in segment {}", seg.name);
                }
                out.codes.extend(out.scratch.iter().map(|&c| c as f32));
                out.mins.push(h.min);
                out.steps.push(h.step);
            }
            b => bail!("segment {} has unsupported width {b}", seg.name),
        }
    }
    Ok(())
}

/// Fold `w * dequant(dec)` into `acc` for the flat element range
/// `[lo, hi)`; `acc[0]` aligns with flat index `lo` and `acc` must be
/// exactly `hi - lo` long.
///
/// The per-element expression is the aggregation path's single source
/// of truth: because element `j`'s accumulation never reads any other
/// element, folding an arbitrary contiguous partition of `[0, d)`
/// shard-by-shard — with the same client order inside every shard — is
/// bit-identical to one serial pass over the whole vector.  That is the
/// sharded accumulator's determinism argument (see
/// `coordinator::server`).
pub fn fold_range(
    mm: &ModelManifest,
    dec: &DecodedUpdate,
    w: f32,
    lo: usize,
    hi: usize,
    acc: &mut [f32],
) {
    debug_assert_eq!(acc.len(), hi - lo);
    for (l, seg) in mm.segments.iter().enumerate() {
        let a = seg.offset.max(lo);
        let b = (seg.offset + seg.size).min(hi);
        if a >= b {
            continue;
        }
        let (mn, st) = (dec.mins[l], dec.steps[l]);
        let codes = &dec.codes[a..b];
        let out = &mut acc[a - lo..b - lo];
        for (o, &c) in out.iter_mut().zip(codes) {
            *o += w * (c * st + mn);
        }
    }
}

/// Decode an update into freshly allocated buffers (convenience wrapper
/// over [`decode_update_into`]).
pub fn decode_update(mm: &ModelManifest, u: &Update) -> Result<DecodedUpdate> {
    let mut out = DecodedUpdate::new();
    decode_update_into(mm, u, &mut out)?;
    Ok(out)
}

/// The exact wire size (bits) the paper's volume metric counts for an
/// update: packed codes + headers.  Used to cross-check the transport
/// ledger in tests.
pub fn update_wire_bits(mm: &ModelManifest, u: &Update) -> u64 {
    let payload_bits = u.payload.len() as u64 * 8;
    let header_bits = u.segments.len() as u64 * math::SEGMENT_HEADER_BITS;
    let _ = mm;
    payload_bits + header_bits
}

/// Build a decision's bit widths per segment (metrics helper).
pub fn decision_bits(mm: &ModelManifest, d: &Decision) -> Vec<u32> {
    (0..mm.num_segments()).map(|l| d.bits(l)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Segment;
    use std::collections::BTreeMap;

    fn mm() -> ModelManifest {
        ModelManifest {
            name: "test".into(),
            d: 7,
            segments: vec![
                Segment { name: "a".into(), offset: 0, size: 4, shape: vec![4] },
                Segment { name: "b".into(), offset: 4, size: 3, shape: vec![3] },
            ],
            input_shape: vec![1],
            classes: 2,
            tau: 1,
            batch: 1,
            eval_batch: 1,
            n_clients: 2,
            files: BTreeMap::new(),
        }
    }

    #[test]
    fn quantized_roundtrip() {
        let m = mm();
        let levels = vec![15u32, 3];
        let ranges = vec![1.5f32, 0.3];
        let mins = vec![-0.75f32, -0.1];
        let plan = QuantPlan::new(&levels, &ranges);
        let codes = vec![0.0, 15.0, 7.0, 3.0, 0.0, 1.0, 3.0];
        let (headers, payload) = encode_quantized(&m, &plan, &mins, &codes);
        assert_eq!(headers[0].bits, 4);
        assert_eq!(headers[1].bits, 2);
        assert_eq!(payload.len(), (4 * 4 + 3 * 2 + 7) / 8);
        let u = Update {
            round: 0,
            client_id: 0,
            num_samples: 10,
            train_loss: 1.0,
            segments: headers,
            payload,
        };
        let dec = decode_update(&m, &u).unwrap();
        assert_eq!(dec.codes, codes);
        assert_eq!(dec.mins, mins);
        assert!((dec.steps[0] - 0.1).abs() < 1e-6);
        assert!((dec.steps[1] - 0.1).abs() < 1e-6);
    }

    #[test]
    fn fp32_roundtrip() {
        let m = mm();
        let delta = vec![0.5f32, -1.5, 3.25, 0.0, 9.0, -0.125, 2.0];
        let (headers, payload) =
            encode_fp32(&m, &[-1.5, -0.125], &[4.75, 9.125], &delta);
        let u = Update {
            round: 0,
            client_id: 1,
            num_samples: 5,
            train_loss: 2.0,
            segments: headers.clone(),
            payload,
        };
        let dec = decode_update(&m, &u).unwrap();
        assert_eq!(dec.codes, delta);
        assert_eq!(dec.mins, vec![0.0, 0.0]);
        assert_eq!(dec.steps, vec![1.0, 1.0]);
        // telemetry range comes back through the header
        assert!((headers[0].range() - 4.75).abs() < 1e-6);
    }

    #[test]
    fn decode_into_reuses_buffers_across_updates() {
        let m = mm();
        let mut out = DecodedUpdate::new();
        for (levels, fill) in [(vec![15u32, 3], 2.0f32), (vec![255, 255], 9.0)] {
            let ranges = vec![10.0f32, 10.0];
            let plan = QuantPlan::new(&levels, &ranges);
            let codes = vec![fill; 7];
            let (headers, payload) = encode_quantized(&m, &plan, &[0.0, 0.0], &codes);
            let u = Update {
                round: 0,
                client_id: 0,
                num_samples: 1,
                train_loss: 0.0,
                segments: headers,
                payload,
            };
            decode_update_into(&m, &u, &mut out).unwrap();
            assert_eq!(out.codes, codes);
            assert_eq!(out.mins.len(), 2);
        }
    }

    #[test]
    fn degenerate_range_collapses() {
        let plan = QuantPlan::new(&[7], &[0.0]);
        assert_eq!(plan.sinv[0], 0.0);
        assert_eq!(plan.step[0], 0.0);
        assert_eq!(plan.maxcode[0], 7.0);
    }

    #[test]
    fn prop_quant_plan_finite_for_degenerate_ranges() {
        use crate::util::prop::{check, Gen};
        // Frozen/blown-up layers report ranges of 0, subnormals, inf or
        // NaN: the plan must collapse those segments (sinv = step = 0)
        // and never leak a non-finite scale into the quantize kernel.
        check("quant-plan-degenerate", 100, |g: &mut Gen| {
            let l = g.size(1, 8);
            let levels: Vec<u32> = g.vec_of(l, |g| g.int(0, 65_535) as u32);
            let ranges: Vec<f32> = g.vec_of(l, |g| match g.int(0, 5) {
                0 => 0.0,
                1 => 1.0e-40, // subnormal: below RANGE_EPS, must collapse
                2 => f32::INFINITY,
                3 => f32::NAN,
                4 => -g.f32(0.0, 1.0),
                _ => g.f32(1e-6, 10.0),
            });
            let plan = QuantPlan::new(&levels, &ranges);
            for i in 0..l {
                if !plan.sinv[i].is_finite() || !plan.step[i].is_finite() {
                    return Err(format!(
                        "segment {i}: non-finite plan (sinv {}, step {}) for range {}",
                        plan.sinv[i], plan.step[i], ranges[i]
                    ));
                }
                if plan.levels[i] < 1 || plan.maxcode[i] < 1.0 {
                    return Err(format!("segment {i}: degenerate level"));
                }
                let degenerate = !(ranges[i] > RANGE_EPS && ranges[i].is_finite());
                if degenerate && (plan.sinv[i] != 0.0 || plan.step[i] != 0.0) {
                    return Err(format!(
                        "segment {i}: range {} must collapse, got sinv {}",
                        ranges[i], plan.sinv[i]
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn truncated_payload_rejected() {
        let m = mm();
        let plan = QuantPlan::new(&[255, 255], &[1.0, 1.0]);
        let codes = vec![1.0; 7];
        let (headers, mut payload) = encode_quantized(&m, &plan, &[0.0, 0.0], &codes);
        payload.truncate(payload.len() - 1);
        let u = Update {
            round: 0,
            client_id: 0,
            num_samples: 1,
            train_loss: 0.0,
            segments: headers,
            payload,
        };
        assert!(decode_update(&m, &u).is_err());
    }

    #[test]
    fn fold_range_partitions_reassemble_bit_identically() {
        let m = mm();
        let plan = QuantPlan::new(&[15, 7], &[1.0, 0.5]);
        let codes = vec![1.0, 5.0, 9.0, 15.0, 0.0, 3.0, 7.0];
        let (headers, payload) = encode_quantized(&m, &plan, &[-0.3, 0.1], &codes);
        let u = Update {
            round: 0,
            client_id: 0,
            num_samples: 4,
            train_loss: 0.0,
            segments: headers,
            payload,
        };
        let dec = decode_update(&m, &u).unwrap();
        let w = 0.251f32;
        let mut serial = vec![0.1f32; m.d];
        fold_range(&m, &dec, w, 0, m.d, &mut serial);
        // every two-way split, including ones that cut segment "a" in
        // half, must reproduce the serial fold bit for bit
        for split in 1..m.d {
            let mut left = vec![0.1f32; split];
            let mut right = vec![0.1f32; m.d - split];
            fold_range(&m, &dec, w, 0, split, &mut left);
            fold_range(&m, &dec, w, split, m.d, &mut right);
            left.extend_from_slice(&right);
            let got: Vec<u32> = left.iter().map(|x| x.to_bits()).collect();
            let want: Vec<u32> = serial.iter().map(|x| x.to_bits()).collect();
            assert_eq!(got, want, "split at {split}");
        }
    }

    #[test]
    fn wire_bits_matches_packed_size() {
        let m = mm();
        let plan = QuantPlan::new(&[15, 15], &[1.0, 1.0]);
        let codes = vec![3.0; 7];
        let (headers, payload) = encode_quantized(&m, &plan, &[0.0, 0.0], &codes);
        let u = Update {
            round: 0, client_id: 0, num_samples: 1, train_loss: 0.0,
            segments: headers, payload,
        };
        let bits = update_wire_bits(&m, &u);
        // 7 codes * 4 bits = 28 -> 4 payload bytes = 32 bits, + 2 headers * 88
        assert_eq!(bits, 32 + 2 * 88);
    }
}
