//! Update codec: turn a (quantized) model update into wire bytes and back.
//!
//! Encoding is the client's last hot-path step.  On the narrow path
//! ([`CodecMode::Narrow`], the default) quantize and pack are **fused**
//! into one pass over the delta ([`encode_quantized_fused`] →
//! [`swar::quantize_pack_segment`]): no `d`-length codes vector, no
//! `u32` scratch.  The unfused [`encode_quantized`] remains for the
//! PJRT backend (whose quantize executable produces the codes) and as
//! the scalar reference.
//!
//! Decoding on the server reconstructs **narrow code rows**: quantized
//! segments land as `u16` rows (codes are <= 16 wire bits, hence
//! <= 65535 — exact in `u16` *and* in `f32`), fp32 segments keep an
//! `f32` row.  Relative to the old all-f32 representation this halves
//! decode-buffer memory (which directly multiplies the
//! `--decode-buffers` bound) and halves the bytes the fold re-reads
//! per shard.  The per-element fold expression
//! `acc += w * (code as f32 * step + min)` is unchanged, so results
//! stay bit-identical — [`CodecMode::Reference`] keeps the all-f32
//! rows + generic-loop path alive as the cross-check oracle
//! (`rust/tests/parallel_determinism.rs`).
//!
//! fp32 segments decode to `codes = value, min = 0, step = 1`, so the
//! aggregation path is uniform across policies.

use anyhow::{bail, ensure, Result};

use crate::config::CodecMode;
use crate::quant::{math, Decision};
use crate::runtime::ModelManifest;
use crate::util::rng::Rng;
use crate::wire::bitpack::{BitReader, BitWriter};
use crate::wire::messages::{DownlinkDelta, PartialAggregate, SegmentHeader, Update};
use crate::wire::swar;

/// Client-side quantization parameters derived from a policy decision and
/// the observed per-segment (min, range).
pub struct QuantPlan {
    /// s/range per segment (0 collapses the segment to its min).
    pub sinv: Vec<f32>,
    /// Level `s` per segment as f32 (the kernel's clamp bound).
    pub maxcode: Vec<f32>,
    /// range/s per segment (the decoder's step).
    pub step: Vec<f32>,
    /// Quantization level `s` per segment (clamped to >= 1).
    pub levels: Vec<u32>,
}

/// Smallest range treated as non-degenerate.  Below this the segment is
/// transmitted as a constant (its min) — matching the kernel's guard.
pub const RANGE_EPS: f32 = 1e-12;

impl QuantPlan {
    /// Derive the kernel parameters from per-segment levels and ranges
    /// (degenerate ranges collapse to constant segments).
    pub fn new(levels: &[u32], ranges: &[f32]) -> QuantPlan {
        let mut sinv = Vec::with_capacity(levels.len());
        let mut maxcode = Vec::with_capacity(levels.len());
        let mut step = Vec::with_capacity(levels.len());
        for (&s, &r) in levels.iter().zip(ranges) {
            let s_f = s.max(1) as f32;
            if r > RANGE_EPS && r.is_finite() {
                sinv.push(s_f / r);
                step.push(r / s_f);
            } else {
                sinv.push(0.0);
                step.push(0.0);
            }
            maxcode.push(s_f);
        }
        QuantPlan {
            sinv,
            maxcode,
            step,
            levels: levels.iter().map(|&s| s.max(1)).collect(),
        }
    }
}

/// Exact packed-payload size in bytes for `plan` over `mm`'s segments:
/// `ceil(sum_l(size_l * bits_l) / 8)` — the capacity both encoders
/// reserve up front (no reallocation, no 16-bit worst-case slack).
fn packed_payload_bytes(mm: &ModelManifest, plan: &QuantPlan) -> usize {
    let bits: usize = mm
        .segments
        .iter()
        .zip(&plan.levels)
        .map(|(seg, &s)| seg.size * math::bits_for_level(s) as usize)
        .sum();
    (bits + 7) / 8
}

fn quant_headers(mm: &ModelManifest, plan: &QuantPlan, mins: &[f32]) -> Vec<SegmentHeader> {
    (0..mm.num_segments())
        .map(|l| SegmentHeader {
            bits: math::bits_for_level(plan.levels[l]) as u8,
            level: plan.levels[l] as u16,
            min: mins[l],
            step: plan.step[l],
        })
        .collect()
}

/// Encode a quantized update (codes from the quantize executable).
///
/// This is the unfused path — PJRT backend and scalar reference.  The
/// native hot path is [`encode_quantized_fused`].
pub fn encode_quantized(
    mm: &ModelManifest,
    plan: &QuantPlan,
    mins: &[f32],
    codes: &[f32],
) -> (Vec<SegmentHeader>, Vec<u8>) {
    debug_assert_eq!(codes.len(), mm.d);
    let headers = quant_headers(mm, plan, mins);
    let mut w = BitWriter::with_capacity(packed_payload_bytes(mm, plan));
    let mut scratch: Vec<u32> = Vec::with_capacity(1 << 14);
    for (l, seg) in mm.segments.iter().enumerate() {
        let bits = math::bits_for_level(plan.levels[l]);
        let slice = &codes[seg.offset..seg.offset + seg.size];
        // codes are exact small integers in f32; convert once and use the
        // word-at-a-time slice packer (§Perf L3-3)
        scratch.clear();
        scratch.extend(slice.iter().map(|&c| c as u32));
        w.put_slice(&scratch, bits);
    }
    (headers, w.finish())
}

/// Fused quantize→pack over the whole update: one clamp-round-pack pass
/// per segment straight off the delta ([`swar::quantize_pack_segment`]),
/// drawing the stochastic-rounding stream from `seed` in flat element
/// order — the exact contract of the quantize executable, so the packed
/// payload is byte-identical to `quantize` + [`encode_quantized`]
/// (property-tested in `wire::swar`).
///
/// `residual`, when present (error feedback), must be `d` long and
/// receives `delta - dequant(codes)` with the same per-element
/// expression the unfused client path uses.
pub fn encode_quantized_fused(
    mm: &ModelManifest,
    plan: &QuantPlan,
    mins: &[f32],
    delta: &[f32],
    seed: u32,
    mut residual: Option<&mut [f32]>,
) -> (Vec<SegmentHeader>, Vec<u8>) {
    debug_assert_eq!(delta.len(), mm.d);
    let headers = quant_headers(mm, plan, mins);
    let mut w = BitWriter::with_capacity(packed_payload_bytes(mm, plan));
    let mut rng = Rng::new(seed as u64);
    for (l, seg) in mm.segments.iter().enumerate() {
        let bits = math::bits_for_level(plan.levels[l]);
        let res = residual
            .as_mut()
            .map(|r| &mut r[seg.offset..seg.offset + seg.size]);
        swar::quantize_pack_segment(
            &mut w,
            &delta[seg.offset..seg.offset + seg.size],
            mins[l],
            plan.sinv[l],
            plan.maxcode[l],
            plan.step[l],
            bits,
            &mut rng,
            res,
        );
    }
    (headers, w.finish())
}

/// Encode an fp32 (unquantized) update.  The header's (min, step) carry
/// (seg_min, seg_range) purely as telemetry — the payload is raw f32.
///
/// §Perf: on little-endian targets the payload is one bulk memcpy of
/// the f32 buffer instead of a per-element `to_le_bytes` loop
/// ([`crate::wire::extend_f32_le`], shared with the downlink writer).
pub fn encode_fp32(
    mm: &ModelManifest,
    mins: &[f32],
    ranges: &[f32],
    delta: &[f32],
) -> (Vec<SegmentHeader>, Vec<u8>) {
    debug_assert_eq!(delta.len(), mm.d);
    let headers = (0..mm.num_segments())
        .map(|l| SegmentHeader {
            bits: 32,
            level: 0,
            min: mins[l],
            step: ranges[l],
        })
        .collect();
    let mut payload = Vec::with_capacity(mm.d * 4);
    crate::wire::extend_f32_le(&mut payload, delta);
    (headers, payload)
}

/// Where one decoded segment's code row lives: quantized segments are
/// `u16` rows in [`DecodedUpdate::qcodes`], fp32 segments (and, in
/// [`CodecMode::Reference`], every segment) are `f32` rows in
/// [`DecodedUpdate::fcodes`].  The payload is the row's start offset in
/// its backing vector; the row length is the segment's `size`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Row {
    /// `u16` code row starting at this offset in `qcodes`.
    Quant(usize),
    /// `f32` row starting at this offset in `fcodes`.
    Fp32(usize),
}

/// Decoded update, shaped for the aggregate path.
///
/// Owns its buffers so a caller can hold one instance across clients
/// and rounds: [`decode_update_into`] clears and refills them without
/// reallocating once they reach capacity.  The round engine keeps
/// round-persistent `DecodedUpdate`s in the server and streams every
/// client through them (no `n x d` codes matrix).
///
/// Quantized segments are stored as **`u16` code rows** — integer codes
/// below 2^16 are exact in both `u16` and `f32`, so narrowing the
/// at-rest representation cannot change any fold result while halving
/// buffer memory and fold read bandwidth.
#[derive(Default)]
pub struct DecodedUpdate {
    /// `u16` code rows of the quantized segments, concatenated.
    pub qcodes: Vec<u16>,
    /// `f32` rows of the fp32 segments (raw values), concatenated.
    pub fcodes: Vec<f32>,
    /// Per-segment row descriptor, length `L`.
    pub rows: Vec<Row>,
    /// Per-segment min (0 for fp32 segments), length `L`.
    pub mins: Vec<f32>,
    /// Per-segment step (1 for fp32 segments), length `L`.
    pub steps: Vec<f32>,
    /// Bit-unpack scratch (reused between segments and calls).
    scratch: Vec<u32>,
}

impl DecodedUpdate {
    /// Empty buffers (first decode sizes them).
    pub fn new() -> DecodedUpdate {
        DecodedUpdate::default()
    }

    /// Append the full `d`-length f32 code row (the pre-narrow-row
    /// representation) to `out` — the fused-aggregate shim, which
    /// materializes the `n x d` codes matrix for the aggregate
    /// executable, and the tests' comparison oracle.
    pub fn extend_codes_f32(&self, mm: &ModelManifest, out: &mut Vec<f32>) {
        out.reserve(mm.d);
        for (l, seg) in mm.segments.iter().enumerate() {
            match self.rows[l] {
                Row::Quant(off) => {
                    out.extend(self.qcodes[off..off + seg.size].iter().map(|&c| c as f32))
                }
                Row::Fp32(off) => out.extend_from_slice(&self.fcodes[off..off + seg.size]),
            }
        }
    }

    /// The full f32 code row as a fresh vector (convenience for tests).
    pub fn codes_f32(&self, mm: &ModelManifest) -> Vec<f32> {
        let mut out = Vec::with_capacity(mm.d);
        self.extend_codes_f32(mm, &mut out);
        out
    }
}

/// Decode an update's payload against the model manifest into
/// caller-owned buffers (allocation-free after warm-up), on the default
/// narrow-row path.
pub fn decode_update_into(mm: &ModelManifest, u: &Update, out: &mut DecodedUpdate) -> Result<()> {
    decode_update_into_mode(mm, u, out, CodecMode::Narrow)
}

/// [`decode_update_into`] with an explicit codec path:
/// [`CodecMode::Narrow`] unpacks quantized segments through the SWAR
/// kernels into `u16` rows; [`CodecMode::Reference`] replays the scalar
/// generic-loop path into f32 rows.  Both produce the same logical
/// codes — the determinism suite holds entire runs bit-identical across
/// the two.
pub fn decode_update_into_mode(
    mm: &ModelManifest,
    u: &Update,
    out: &mut DecodedUpdate,
    mode: CodecMode,
) -> Result<()> {
    ensure!(
        u.segments.len() == mm.num_segments(),
        "update has {} segments, model {} has {}",
        u.segments.len(),
        mm.name,
        mm.num_segments()
    );
    out.qcodes.clear();
    out.fcodes.clear();
    out.rows.clear();
    out.mins.clear();
    out.steps.clear();

    // fp32 segments are raw little-endian f32 at a bit offset determined
    // by the preceding segments; quantized segments are bit-packed.
    // Mixed layouts are legal: the reader tracks bit position across
    // segment kinds, and fp32 rows are read at 32-bit width.
    let mut r = BitReader::new(&u.payload);
    for (l, seg) in mm.segments.iter().enumerate() {
        let h = &u.segments[l];
        match h.bits {
            32 => {
                out.scratch.clear();
                if r.get_slice(&mut out.scratch, seg.size, 32).is_none() {
                    bail!("payload truncated in fp32 segment {}", seg.name);
                }
                out.rows.push(Row::Fp32(out.fcodes.len()));
                out.fcodes
                    .extend(out.scratch.iter().map(|&raw| f32::from_le_bytes(raw.to_le_bytes())));
                out.mins.push(0.0);
                out.steps.push(1.0);
            }
            b if b as u32 <= 16 => {
                let width = b as u32;
                match mode {
                    CodecMode::Narrow => {
                        out.rows.push(Row::Quant(out.qcodes.len()));
                        if swar::unpack_u16(&mut r, &mut out.qcodes, seg.size, width).is_none() {
                            bail!("payload truncated in segment {}", seg.name);
                        }
                    }
                    CodecMode::Reference => {
                        out.scratch.clear();
                        if r.get_slice(&mut out.scratch, seg.size, width).is_none() {
                            bail!("payload truncated in segment {}", seg.name);
                        }
                        out.rows.push(Row::Fp32(out.fcodes.len()));
                        out.fcodes.extend(out.scratch.iter().map(|&c| c as f32));
                    }
                }
                out.mins.push(h.min);
                out.steps.push(h.step);
            }
            b => bail!("segment {} has unsupported width {b}", seg.name),
        }
    }
    Ok(())
}

/// Fold `w * dequant(dec)` into `acc` for the flat element range
/// `[lo, hi)`; `acc[0]` aligns with flat index `lo` and `acc` must be
/// exactly `hi - lo` long.
///
/// The per-element expression is the aggregation path's single source
/// of truth: because element `j`'s accumulation never reads any other
/// element, folding an arbitrary contiguous partition of `[0, d)`
/// shard-by-shard — with the same client order inside every shard — is
/// bit-identical to one serial pass over the whole vector.  That is the
/// sharded accumulator's determinism argument (see
/// `coordinator::server`).
///
/// Quantized segments fold **straight off the `u16` row**
/// (`acc += w * (c as f32 * step + min)`): the widening is exact for
/// codes below 2^16, so this equals the old f32-row fold bit for bit
/// while reading half the bytes.  fp32 rows use the same expression
/// with `step = 1, min = 0` (also what [`CodecMode::Reference`] rows
/// use for quantized segments).
pub fn fold_range(
    mm: &ModelManifest,
    dec: &DecodedUpdate,
    w: f32,
    lo: usize,
    hi: usize,
    acc: &mut [f32],
) {
    debug_assert_eq!(acc.len(), hi - lo);
    for (l, seg) in mm.segments.iter().enumerate() {
        let a = seg.offset.max(lo);
        let b = (seg.offset + seg.size).min(hi);
        if a >= b {
            continue;
        }
        let (mn, st) = (dec.mins[l], dec.steps[l]);
        let out = &mut acc[a - lo..b - lo];
        match dec.rows[l] {
            Row::Quant(off) => {
                let row = &dec.qcodes[off + (a - seg.offset)..off + (b - seg.offset)];
                for (o, &c) in out.iter_mut().zip(row) {
                    *o += w * (c as f32 * st + mn);
                }
            }
            Row::Fp32(off) => {
                let row = &dec.fcodes[off + (a - seg.offset)..off + (b - seg.offset)];
                for (o, &c) in out.iter_mut().zip(row) {
                    *o += w * (c * st + mn);
                }
            }
        }
    }
}

/// Decode an update into freshly allocated buffers (convenience wrapper
/// over [`decode_update_into`]).
pub fn decode_update(mm: &ModelManifest, u: &Update) -> Result<DecodedUpdate> {
    let mut out = DecodedUpdate::new();
    decode_update_into(mm, u, &mut out)?;
    Ok(out)
}

/// The exact wire size (bits) the paper's volume metric counts for an
/// update: packed codes + headers.  Used to cross-check the transport
/// ledger in tests.  The manifest pins the expected segment count —
/// a mismatched update would make the byte ledger silently wrong, so
/// this asserts in release builds too (two-usize compare, called once
/// per update per round; decode has already rejected mismatches on
/// every production path, this is the ledger's own guard).
pub fn update_wire_bits(mm: &ModelManifest, u: &Update) -> u64 {
    assert_eq!(
        u.segments.len(),
        mm.num_segments(),
        "update from client {} has {} segments, model {} has {}",
        u.client_id,
        u.segments.len(),
        mm.name,
        mm.num_segments()
    );
    let payload_bits = u.payload.len() as u64 * 8;
    let header_bits = u.segments.len() as u64 * math::SEGMENT_HEADER_BITS;
    payload_bits + header_bits
}

/// Build a decision's bit widths per segment (metrics helper).
pub fn decision_bits(mm: &ModelManifest, d: &Decision) -> Vec<u32> {
    (0..mm.num_segments()).map(|l| d.bits(l)).collect()
}

/// Per-segment (min, range) envelope of `x`, computed with a scalar
/// loop — the downlink runs on the server, which has no `ranges`
/// executable; the envelope feeds [`QuantPlan::new`] exactly like the
/// client-side measurement does.
fn segment_envelope(mm: &ModelManifest, x: &[f32]) -> (Vec<f32>, Vec<f32>) {
    let mut mins = Vec::with_capacity(mm.num_segments());
    let mut ranges = Vec::with_capacity(mm.num_segments());
    for seg in &mm.segments {
        let mut mn = f32::INFINITY;
        let mut mx = f32::NEG_INFINITY;
        for &v in &x[seg.offset..seg.offset + seg.size] {
            mn = mn.min(v);
            mx = mx.max(v);
        }
        mins.push(mn);
        ranges.push((mx - mn).max(0.0));
    }
    (mins, ranges)
}

/// Encode the server's broadcast delta at a uniform `bits` width with
/// server-side error feedback.
///
/// The quantizer input is `x = (params - replica) + residual`: what the
/// in-sync receiver is missing plus the error carried from earlier
/// rounds.  `residual` is updated in place by the fused kernel to
/// `x - dequant(codes)`, and the caller advances its own `replica` by
/// [`apply_downlink`] on the returned delta — the *encoded* bytes, not
/// `x - residual'` — so the server-held replica stays bit-identical to
/// every receiver's (f32 addition is not associative; replaying the
/// wire is the only safe advance).
pub fn encode_downlink(
    mm: &ModelManifest,
    bits: u32,
    params: &[f32],
    replica: &[f32],
    residual: &mut [f32],
    seed: u32,
) -> Result<DownlinkDelta> {
    ensure!((1..=16).contains(&bits), "downlink bits must be in 1..=16, got {bits}");
    ensure!(
        params.len() == mm.d && replica.len() == mm.d && residual.len() == mm.d,
        "downlink buffers must all be d = {} long",
        mm.d
    );
    let x: Vec<f32> = (0..mm.d)
        .map(|i| (params[i] - replica[i]) + residual[i])
        .collect();
    let (mins, ranges) = segment_envelope(mm, &x);
    let levels = vec![math::max_level_for_bits(bits); mm.num_segments()];
    let plan = QuantPlan::new(&levels, &ranges);
    let (segments, payload) = encode_quantized_fused(mm, &plan, &mins, &x, seed, Some(residual));
    Ok(DownlinkDelta { segments, payload })
}

/// Apply a downlink delta to a replica: `out[j] += min + code * step`
/// per element — the same dequant expression the uplink fold uses, so
/// the server's replica advance and every worker's are bit-identical.
///
/// Rejects (never panics on) malformed frames: wrong segment count,
/// out-of-range widths, or a payload whose byte length does not match
/// the headers exactly.
pub fn apply_downlink(mm: &ModelManifest, dl: &DownlinkDelta, out: &mut [f32]) -> Result<()> {
    ensure!(
        dl.segments.len() == mm.num_segments(),
        "downlink delta has {} segments, model {} has {}",
        dl.segments.len(),
        mm.name,
        mm.num_segments()
    );
    ensure!(out.len() == mm.d, "replica must be d = {} long", mm.d);
    let mut payload_bits = 0usize;
    for (seg, h) in mm.segments.iter().zip(&dl.segments) {
        ensure!(
            (1..=16).contains(&h.bits),
            "downlink segment width {} out of range 1..=16",
            h.bits
        );
        payload_bits += seg.size * h.bits as usize;
    }
    ensure!(
        dl.payload.len() == (payload_bits + 7) / 8,
        "downlink payload is {} bytes, headers demand {}",
        dl.payload.len(),
        (payload_bits + 7) / 8
    );
    let mut r = BitReader::new(&dl.payload);
    let mut codes: Vec<u16> = Vec::new();
    for (l, seg) in mm.segments.iter().enumerate() {
        let h = &dl.segments[l];
        codes.clear();
        if swar::unpack_u16(&mut r, &mut codes, seg.size, h.bits as u32).is_none() {
            bail!("downlink payload truncated in segment {l}");
        }
        for (j, &c) in codes.iter().enumerate() {
            out[seg.offset + j] += h.min + c as f32 * h.step;
        }
    }
    Ok(())
}

/// Fold a subtree's leaf updates into one [`PartialAggregate`].
///
/// This is the tree topology's **single source of truth**: both the
/// remote `aggregate` role and the in-process engine's virtual grouping
/// call it, so a TCP tree run and a flat run with the same `fanout`
/// produce bit-identical accumulators.  Members fold in ascending
/// client-id order (`updates` must arrive sorted and strictly
/// ascending) with the subtree-local weight `s_i / S_g` — the server
/// then folds the partial with `S_g / T`, so the composed weight per
/// leaf element is `(S_g/T) * sum_i (s_i/S_g) * dequant_i`, the
/// grouping-defined canonical order (see ARCHITECTURE.md).
///
/// `wire_bits` in the telemetry tail is the **leaf** uplink ledger
/// (sum of each member update's packed bits + headers), so the paper's
/// volume metric is unchanged by the topology.
///
/// Under the tolerant tree (`--quorum` + `--round-timeout`) the
/// `members`/`samples` lists double as the composite's quorum manifest:
/// the root counts the listed *leaves* — never the partial itself —
/// toward the quorum floor, and renormalizes surviving weight as if the
/// leaves had arrived flat.  Late leaves are excluded from the fold and
/// forwarded raw by the aggregator (see [`crate::coordinator::topology`]),
/// so every leaf folds at exactly one tier.
pub fn fold_partial(
    mm: &ModelManifest,
    round: u32,
    agg_id: u32,
    updates: &[Update],
    mode: CodecMode,
    depth: u32,
) -> Result<PartialAggregate> {
    ensure!(!updates.is_empty(), "partial aggregate needs at least one member");
    for w in updates.windows(2) {
        ensure!(
            w[0].client_id < w[1].client_id,
            "partial members must be sorted by ascending client id"
        );
    }
    let total: u64 = updates.iter().map(|u| u.num_samples as u64).sum();
    ensure!(total > 0, "partial aggregate has zero total samples");
    ensure!(
        total <= u32::MAX as u64,
        "subtree sample total {total} overflows the pseudo-update's u32"
    );
    let mut acc = vec![0.0f32; mm.d];
    let mut dec = DecodedUpdate::new();
    let mut loss_acc = 0.0f64;
    let mut wire_bits = 0u64;
    for u in updates {
        decode_update_into_mode(mm, u, &mut dec, mode)?;
        let w = u.num_samples as f32 / total as f32;
        fold_range(mm, &dec, w, 0, mm.d, &mut acc);
        loss_acc += u.num_samples as f64 * u.train_loss as f64;
        wire_bits += update_wire_bits(mm, u);
    }
    Ok(PartialAggregate {
        round,
        agg_id,
        train_loss: (loss_acc / total as f64) as f32,
        members: updates.iter().map(|u| u.client_id).collect(),
        samples: updates.iter().map(|u| u.num_samples).collect(),
        acc,
        telemetry: Some((depth, wire_bits)),
    })
}

/// Shape a [`PartialAggregate`] as a pseudo-[`Update`] the server's
/// existing receive/fold machinery consumes unchanged: fp32 segment
/// headers (`bits: 32`), payload = the raw accumulator, `client_id` =
/// the subtree root id, `num_samples` = the subtree sample total.
///
/// fp32 rows decode with `min = 0, step = 1`, so the server's
/// `fold_range` contributes exactly `W_g * acc[j]` per element — the
/// outer half of the composed tree weight.  Weighting, sorted-id fold
/// order, quorum and staleness banking all apply to the pseudo-update
/// exactly as to a leaf update, keyed by the subtree root id.
pub fn partial_to_update(mm: &ModelManifest, p: &PartialAggregate) -> Result<Update> {
    ensure!(
        p.acc.len() == mm.d,
        "partial accumulator has {} elements, model {} has {}",
        p.acc.len(),
        mm.name,
        mm.d
    );
    let total = p.total_samples();
    ensure!(
        total <= u32::MAX as u64,
        "subtree sample total {total} overflows the pseudo-update's u32"
    );
    let segments = (0..mm.num_segments())
        .map(|_| SegmentHeader { bits: 32, level: 0, min: 0.0, step: 0.0 })
        .collect();
    let mut payload = Vec::with_capacity(mm.d * 4);
    crate::wire::extend_f32_le(&mut payload, &p.acc);
    Ok(Update {
        round: p.round,
        client_id: p.agg_id,
        num_samples: total as u32,
        train_loss: p.train_loss,
        segments,
        payload,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Segment;
    use std::collections::BTreeMap;

    fn mm() -> ModelManifest {
        ModelManifest {
            name: "test".into(),
            d: 7,
            segments: vec![
                Segment { name: "a".into(), offset: 0, size: 4, shape: vec![4] },
                Segment { name: "b".into(), offset: 4, size: 3, shape: vec![3] },
            ],
            input_shape: vec![1],
            classes: 2,
            tau: 1,
            batch: 1,
            eval_batch: 1,
            n_clients: 2,
            files: BTreeMap::new(),
        }
    }

    /// Three-segment manifest for mixed fp32/quantized layout tests.
    fn mm3() -> ModelManifest {
        ModelManifest {
            name: "test3".into(),
            d: 12,
            segments: vec![
                Segment { name: "a".into(), offset: 0, size: 5, shape: vec![5] },
                Segment { name: "b".into(), offset: 5, size: 4, shape: vec![4] },
                Segment { name: "c".into(), offset: 9, size: 3, shape: vec![3] },
            ],
            input_shape: vec![1],
            classes: 2,
            tau: 1,
            batch: 1,
            eval_batch: 1,
            n_clients: 2,
            files: BTreeMap::new(),
        }
    }

    #[test]
    fn quantized_roundtrip() {
        let m = mm();
        let levels = vec![15u32, 3];
        let ranges = vec![1.5f32, 0.3];
        let mins = vec![-0.75f32, -0.1];
        let plan = QuantPlan::new(&levels, &ranges);
        let codes = vec![0.0, 15.0, 7.0, 3.0, 0.0, 1.0, 3.0];
        let (headers, payload) = encode_quantized(&m, &plan, &mins, &codes);
        assert_eq!(headers[0].bits, 4);
        assert_eq!(headers[1].bits, 2);
        assert_eq!(payload.len(), (4 * 4 + 3 * 2 + 7) / 8);
        let u = Update {
            round: 0,
            client_id: 0,
            num_samples: 10,
            train_loss: 1.0,
            segments: headers,
            payload,
        };
        let dec = decode_update(&m, &u).unwrap();
        // narrow representation: both segments land as u16 rows
        assert_eq!(dec.rows, vec![Row::Quant(0), Row::Quant(4)]);
        assert_eq!(dec.qcodes, vec![0u16, 15, 7, 3, 0, 1, 3]);
        assert!(dec.fcodes.is_empty());
        assert_eq!(dec.codes_f32(&m), codes);
        assert_eq!(dec.mins, mins);
        assert!((dec.steps[0] - 0.1).abs() < 1e-6);
        assert!((dec.steps[1] - 0.1).abs() < 1e-6);
    }

    #[test]
    fn fp32_roundtrip() {
        let m = mm();
        let delta = vec![0.5f32, -1.5, 3.25, 0.0, 9.0, -0.125, 2.0];
        let (headers, payload) =
            encode_fp32(&m, &[-1.5, -0.125], &[4.75, 9.125], &delta);
        let u = Update {
            round: 0,
            client_id: 1,
            num_samples: 5,
            train_loss: 2.0,
            segments: headers.clone(),
            payload,
        };
        let dec = decode_update(&m, &u).unwrap();
        assert_eq!(dec.rows, vec![Row::Fp32(0), Row::Fp32(4)]);
        assert_eq!(dec.codes_f32(&m), delta);
        assert_eq!(dec.mins, vec![0.0, 0.0]);
        assert_eq!(dec.steps, vec![1.0, 1.0]);
        // telemetry range comes back through the header
        assert!((headers[0].range() - 4.75).abs() < 1e-6);
    }

    #[test]
    fn mixed_layout_decodes_through_narrow_rows() {
        // quantized (4-bit) + fp32 + quantized (9-bit, odd width →
        // generic fallback) in one payload: the narrow decoder must
        // track the bit position across row kinds and keep each row in
        // its own backing store.
        let m = mm3();
        let qcodes_a = vec![1u32, 15, 0, 9, 4];
        let raw_b = vec![0.5f32, -2.25, f32::MIN_POSITIVE, 7.0];
        let qcodes_c = vec![511u32, 0, 257];
        let mut w = BitWriter::new();
        w.put_slice(&qcodes_a, 4);
        for &v in &raw_b {
            w.put(u32::from_le_bytes(v.to_le_bytes()), 32);
        }
        w.put_slice(&qcodes_c, 9);
        let payload = w.finish();
        let segments = vec![
            SegmentHeader { bits: 4, level: 15, min: -0.5, step: 0.1 },
            SegmentHeader { bits: 32, level: 0, min: 0.0, step: 0.0 },
            SegmentHeader { bits: 9, level: 511, min: 0.25, step: 0.01 },
        ];
        let u = Update {
            round: 0, client_id: 0, num_samples: 1, train_loss: 0.0,
            segments, payload,
        };
        for mode in [CodecMode::Narrow, CodecMode::Reference] {
            let mut dec = DecodedUpdate::new();
            decode_update_into_mode(&m, &u, &mut dec, mode).unwrap();
            let want: Vec<f32> = qcodes_a
                .iter()
                .map(|&c| c as f32)
                .chain(raw_b.iter().copied())
                .chain(qcodes_c.iter().map(|&c| c as f32))
                .collect();
            assert_eq!(dec.codes_f32(&m), want, "{mode:?}");
            assert_eq!(dec.mins, vec![-0.5, 0.0, 0.25], "{mode:?}");
            assert_eq!(dec.steps, vec![0.1, 1.0, 0.01], "{mode:?}");
            if mode == CodecMode::Narrow {
                assert_eq!(dec.rows, vec![Row::Quant(0), Row::Fp32(0), Row::Quant(5)]);
                assert_eq!(dec.qcodes.len(), 8);
                assert_eq!(dec.fcodes.len(), 4);
            } else {
                // reference path: everything is an f32 row
                assert!(dec.qcodes.is_empty());
                assert_eq!(dec.fcodes.len(), 12);
            }
        }
    }

    #[test]
    fn narrow_and_reference_folds_are_bit_identical() {
        let m = mm3();
        let levels = vec![255u32, 1, 511];
        let ranges = vec![1.0f32, 0.5, 2.0];
        let mins = vec![-0.4f32, 0.0, -1.0];
        let plan = QuantPlan::new(&levels, &ranges);
        let codes = vec![3.0, 255.0, 17.0, 99.0, 0.0, 1.0, 0.0, 1.0, 1.0, 511.0, 0.0, 300.0];
        let (headers, payload) = encode_quantized(&m, &plan, &mins, &codes);
        let u = Update {
            round: 0, client_id: 0, num_samples: 1, train_loss: 0.0,
            segments: headers, payload,
        };
        let w = 0.173f32;
        let mut narrow = DecodedUpdate::new();
        decode_update_into_mode(&m, &u, &mut narrow, CodecMode::Narrow).unwrap();
        let mut reference = DecodedUpdate::new();
        decode_update_into_mode(&m, &u, &mut reference, CodecMode::Reference).unwrap();
        for (lo, hi) in [(0usize, m.d), (0, 3), (3, 11), (11, 12), (2, 7)] {
            let mut acc_n = vec![0.05f32; hi - lo];
            let mut acc_r = vec![0.05f32; hi - lo];
            fold_range(&m, &narrow, w, lo, hi, &mut acc_n);
            fold_range(&m, &reference, w, lo, hi, &mut acc_r);
            let bn: Vec<u32> = acc_n.iter().map(|x| x.to_bits()).collect();
            let br: Vec<u32> = acc_r.iter().map(|x| x.to_bits()).collect();
            assert_eq!(bn, br, "range [{lo}, {hi})");
        }
    }

    #[test]
    fn fused_encode_matches_split_encode_on_manifest() {
        // Whole-update check over a multi-segment manifest (the per-
        // segment kernel equivalence is property-tested in wire::swar):
        // identical headers, payload and EF residual.
        let m = mm3();
        let levels = vec![15u32, 255, 7];
        let ranges = vec![1.0f32, 0.0, 3.0]; // middle segment degenerate
        let plan = QuantPlan::new(&levels, &ranges);
        let delta: Vec<f32> = (0..m.d).map(|i| -0.6 + 0.13 * i as f32).collect();
        let mins = vec![-0.6f32, 0.0, 0.57];
        let seed = 1234u32;

        let codes = crate::runtime::native::stochastic_quantize(
            &m, &delta, &mins, &plan.sinv, &plan.maxcode, seed,
        );
        let mut res_split = vec![0.0f32; m.d];
        for (l, seg) in m.segments.iter().enumerate() {
            let (mn, st) = (mins[l], plan.step[l]);
            for j in seg.offset..seg.offset + seg.size {
                res_split[j] = delta[j] - (mn + codes[j] * st);
            }
        }
        let (h_split, p_split) = encode_quantized(&m, &plan, &mins, &codes);

        let mut res_fused = vec![0.0f32; m.d];
        let (h_fused, p_fused) =
            encode_quantized_fused(&m, &plan, &mins, &delta, seed, Some(&mut res_fused));

        assert_eq!(h_split, h_fused);
        assert_eq!(p_split, p_fused);
        let ba: Vec<u32> = res_split.iter().map(|x| x.to_bits()).collect();
        let bb: Vec<u32> = res_fused.iter().map(|x| x.to_bits()).collect();
        assert_eq!(ba, bb);
    }

    #[test]
    fn decode_into_reuses_buffers_across_updates() {
        let m = mm();
        let mut out = DecodedUpdate::new();
        for (levels, fill) in [(vec![15u32, 3], 2.0f32), (vec![255, 255], 9.0)] {
            let ranges = vec![10.0f32, 10.0];
            let plan = QuantPlan::new(&levels, &ranges);
            let codes = vec![fill; 7];
            let (headers, payload) = encode_quantized(&m, &plan, &[0.0, 0.0], &codes);
            let u = Update {
                round: 0,
                client_id: 0,
                num_samples: 1,
                train_loss: 0.0,
                segments: headers,
                payload,
            };
            decode_update_into(&m, &u, &mut out).unwrap();
            assert_eq!(out.codes_f32(&m), codes);
            assert_eq!(out.mins.len(), 2);
        }
    }

    #[test]
    fn degenerate_range_collapses() {
        let plan = QuantPlan::new(&[7], &[0.0]);
        assert_eq!(plan.sinv[0], 0.0);
        assert_eq!(plan.step[0], 0.0);
        assert_eq!(plan.maxcode[0], 7.0);
    }

    #[test]
    fn prop_quant_plan_finite_for_degenerate_ranges() {
        use crate::util::prop::{check, Gen};
        // Frozen/blown-up layers report ranges of 0, subnormals, inf or
        // NaN: the plan must collapse those segments (sinv = step = 0)
        // and never leak a non-finite scale into the quantize kernel.
        check("quant-plan-degenerate", 100, |g: &mut Gen| {
            let l = g.size(1, 8);
            let levels: Vec<u32> = g.vec_of(l, |g| g.int(0, 65_535) as u32);
            let ranges: Vec<f32> = g.vec_of(l, |g| match g.int(0, 5) {
                0 => 0.0,
                1 => 1.0e-40, // subnormal: below RANGE_EPS, must collapse
                2 => f32::INFINITY,
                3 => f32::NAN,
                4 => -g.f32(0.0, 1.0),
                _ => g.f32(1e-6, 10.0),
            });
            let plan = QuantPlan::new(&levels, &ranges);
            for i in 0..l {
                if !plan.sinv[i].is_finite() || !plan.step[i].is_finite() {
                    return Err(format!(
                        "segment {i}: non-finite plan (sinv {}, step {}) for range {}",
                        plan.sinv[i], plan.step[i], ranges[i]
                    ));
                }
                if plan.levels[i] < 1 || plan.maxcode[i] < 1.0 {
                    return Err(format!("segment {i}: degenerate level"));
                }
                let degenerate = !(ranges[i] > RANGE_EPS && ranges[i].is_finite());
                if degenerate && (plan.sinv[i] != 0.0 || plan.step[i] != 0.0) {
                    return Err(format!(
                        "segment {i}: range {} must collapse, got sinv {}",
                        ranges[i], plan.sinv[i]
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn truncated_payload_rejected_in_both_modes() {
        let m = mm();
        let plan = QuantPlan::new(&[255, 255], &[1.0, 1.0]);
        let codes = vec![1.0; 7];
        let (headers, mut payload) = encode_quantized(&m, &plan, &[0.0, 0.0], &codes);
        payload.truncate(payload.len() - 1);
        let u = Update {
            round: 0,
            client_id: 0,
            num_samples: 1,
            train_loss: 0.0,
            segments: headers,
            payload,
        };
        for mode in [CodecMode::Narrow, CodecMode::Reference] {
            let mut out = DecodedUpdate::new();
            assert!(decode_update_into_mode(&m, &u, &mut out, mode).is_err(), "{mode:?}");
        }
    }

    #[test]
    fn fold_range_partitions_reassemble_bit_identically() {
        let m = mm();
        let plan = QuantPlan::new(&[15, 7], &[1.0, 0.5]);
        let codes = vec![1.0, 5.0, 9.0, 15.0, 0.0, 3.0, 7.0];
        let (headers, payload) = encode_quantized(&m, &plan, &[-0.3, 0.1], &codes);
        let u = Update {
            round: 0,
            client_id: 0,
            num_samples: 4,
            train_loss: 0.0,
            segments: headers,
            payload,
        };
        let dec = decode_update(&m, &u).unwrap();
        let w = 0.251f32;
        let mut serial = vec![0.1f32; m.d];
        fold_range(&m, &dec, w, 0, m.d, &mut serial);
        // every two-way split, including ones that cut segment "a" in
        // half, must reproduce the serial fold bit for bit
        for split in 1..m.d {
            let mut left = vec![0.1f32; split];
            let mut right = vec![0.1f32; m.d - split];
            fold_range(&m, &dec, w, 0, split, &mut left);
            fold_range(&m, &dec, w, split, m.d, &mut right);
            left.extend_from_slice(&right);
            let got: Vec<u32> = left.iter().map(|x| x.to_bits()).collect();
            let want: Vec<u32> = serial.iter().map(|x| x.to_bits()).collect();
            assert_eq!(got, want, "split at {split}");
        }
    }

    #[test]
    fn wire_bits_matches_packed_size() {
        let m = mm();
        let plan = QuantPlan::new(&[15, 15], &[1.0, 1.0]);
        let codes = vec![3.0; 7];
        let (headers, payload) = encode_quantized(&m, &plan, &[0.0, 0.0], &codes);
        let u = Update {
            round: 0, client_id: 0, num_samples: 1, train_loss: 0.0,
            segments: headers, payload,
        };
        let bits = update_wire_bits(&m, &u);
        // 7 codes * 4 bits = 28 -> 4 payload bytes = 32 bits, + 2 headers * 88
        assert_eq!(bits, 32 + 2 * 88);
    }

    /// A small quantized update for the partial-aggregate tests.
    fn quant_update(m: &ModelManifest, id: u32, samples: u32, loss: f32, fill: f32) -> Update {
        let plan = QuantPlan::new(&[15, 7], &[1.0, 0.5]);
        let codes: Vec<f32> = (0..m.d).map(|i| (fill + i as f32) % 7.0).collect();
        let (segments, payload) = encode_quantized(m, &plan, &[-0.3, 0.1], &codes);
        Update { round: 2, client_id: id, num_samples: samples, train_loss: loss, segments, payload }
    }

    #[test]
    fn fold_partial_matches_manual_weighted_fold() {
        let m = mm();
        let us = vec![
            quant_update(&m, 4, 10, 1.5, 0.0),
            quant_update(&m, 5, 30, 0.5, 3.0),
        ];
        let p = fold_partial(&m, 2, 4, &us, CodecMode::Narrow, 1).unwrap();
        assert_eq!(p.agg_id, 4);
        assert_eq!(p.members, vec![4, 5]);
        assert_eq!(p.samples, vec![10, 30]);
        assert_eq!(p.total_samples(), 40);
        assert_eq!(p.depth(), 1);
        assert_eq!(
            p.wire_bits(),
            update_wire_bits(&m, &us[0]) + update_wire_bits(&m, &us[1]),
            "telemetry carries the leaf uplink ledger"
        );
        // manual: same decode + fold_range calls, member order, weights
        let mut want = vec![0.0f32; m.d];
        for u in &us {
            let dec = decode_update(&m, u).unwrap();
            fold_range(&m, &dec, u.num_samples as f32 / 40u64 as f32, 0, m.d, &mut want);
        }
        let got: Vec<u32> = p.acc.iter().map(|x| x.to_bits()).collect();
        let wantb: Vec<u32> = want.iter().map(|x| x.to_bits()).collect();
        assert_eq!(got, wantb);
        // subtree-weighted loss
        let want_loss = ((10.0 * 1.5 + 30.0 * 0.5) / 40.0) as f32;
        assert_eq!(p.train_loss.to_bits(), want_loss.to_bits());
        // narrow and reference modes agree bit-for-bit (determinism matrix)
        let p_ref = fold_partial(&m, 2, 4, &us, CodecMode::Reference, 1).unwrap();
        let refb: Vec<u32> = p_ref.acc.iter().map(|x| x.to_bits()).collect();
        assert_eq!(got, refb);
    }

    #[test]
    fn fold_partial_rejects_malformed_member_sets() {
        let m = mm();
        assert!(fold_partial(&m, 0, 0, &[], CodecMode::Narrow, 1).is_err(), "empty");
        let unsorted = vec![quant_update(&m, 5, 1, 0.0, 0.0), quant_update(&m, 4, 1, 0.0, 0.0)];
        assert!(fold_partial(&m, 0, 4, &unsorted, CodecMode::Narrow, 1).is_err());
        let dup = vec![quant_update(&m, 4, 1, 0.0, 0.0), quant_update(&m, 4, 1, 0.0, 0.0)];
        assert!(fold_partial(&m, 0, 4, &dup, CodecMode::Narrow, 1).is_err());
        let zero = vec![quant_update(&m, 4, 0, 0.0, 0.0)];
        assert!(fold_partial(&m, 0, 4, &zero, CodecMode::Narrow, 1).is_err(), "zero samples");
    }

    #[test]
    fn pseudo_update_folds_to_weighted_accumulator() {
        // The server folds the pseudo-update with weight W: fp32 rows
        // decode with min 0 / step 1, so each element contributes
        // exactly W * acc[j] — the outer half of the tree weight.
        let m = mm();
        let us = vec![
            quant_update(&m, 0, 7, 2.0, 1.0),
            quant_update(&m, 1, 9, 1.0, 4.0),
        ];
        let p = fold_partial(&m, 2, 0, &us, CodecMode::Narrow, 1).unwrap();
        let pu = partial_to_update(&m, &p).unwrap();
        assert_eq!(pu.client_id, 0);
        assert_eq!(pu.num_samples, 16);
        assert_eq!(pu.round, 2);
        assert!(pu.segments.iter().all(|h| h.bits == 32));
        let dec = decode_update(&m, &pu).unwrap();
        assert_eq!(dec.codes_f32(&m), p.acc, "payload round-trips the accumulator");
        let w = 0.37f32;
        let mut acc = vec![0.25f32; m.d];
        fold_range(&m, &dec, w, 0, m.d, &mut acc);
        for (j, (&got, &c)) in acc.iter().zip(&p.acc).enumerate() {
            let want = 0.25f32 + w * (c * 1.0 + 0.0);
            assert_eq!(got.to_bits(), want.to_bits(), "element {j}");
        }
        // dimension mismatch is rejected
        let mut bad = p.clone();
        bad.acc.pop();
        assert!(partial_to_update(&m, &bad).is_err());
    }

    #[test]
    fn encode_capacity_hint_is_exact() {
        // The encoder must reserve exactly ceil(sum(size_l * bits_l)/8):
        // the payload vector never reallocates and never over-reserves
        // to the 16-bit worst case.
        let m = mm3();
        let plan = QuantPlan::new(&[1, 255, 511], &[1.0, 1.0, 1.0]);
        assert_eq!(packed_payload_bytes(&m, &plan), (5 + 4 * 8 + 3 * 9 + 7) / 8);
        let codes = vec![0.0f32; m.d];
        let (_, payload) = encode_quantized(&m, &plan, &[0.0; 3], &codes);
        assert_eq!(payload.len(), packed_payload_bytes(&m, &plan));
    }

    #[test]
    fn downlink_roundtrip_advances_replica_within_one_step() {
        let m = mm3();
        let params: Vec<f32> =
            (0..m.d).map(|i| (i as f32 * 0.37 - 1.9).sin() * 2.0).collect();
        let mut replica = vec![0.0f32; m.d];
        let mut residual = vec![0.0f32; m.d];
        let dl = encode_downlink(&m, 4, &params, &replica, &mut residual, 7).unwrap();
        assert_eq!(dl.segments.len(), 3);
        assert!(dl.segments.iter().all(|h| h.bits == 4 && h.level == 15));
        apply_downlink(&m, &dl, &mut replica).unwrap();
        for (l, seg) in m.segments.iter().enumerate() {
            let step = dl.segments[l].step;
            for j in seg.offset..seg.offset + seg.size {
                // stochastic rounding: per-element error bounded by one
                // full step, not half
                assert!(
                    (replica[j] - params[j]).abs() <= step * (1.0 + 1e-5),
                    "element {j}: replica {} vs params {} (step {step})",
                    replica[j],
                    params[j]
                );
            }
        }
    }

    #[test]
    fn downlink_residual_is_bitwise_exact() {
        // residual' = x - dequant(codes) with x = (params - replica) +
        // residual, computed by the fused kernel.  Applying the delta to
        // a copy of the old replica must land exactly at x - residual'.
        let m = mm3();
        let params: Vec<f32> = (0..m.d).map(|i| (i as f32 * 1.7).cos()).collect();
        let mut replica: Vec<f32> = (0..m.d).map(|i| i as f32 * 0.01).collect();
        let mut residual: Vec<f32> = (0..m.d).map(|i| (i as f32 * 0.3).sin() * 0.05).collect();
        let x: Vec<f32> = (0..m.d)
            .map(|i| (params[i] - replica[i]) + residual[i])
            .collect();
        let old_replica = replica.clone();
        let dl = encode_downlink(&m, 6, &params, &replica, &mut residual, 99).unwrap();
        apply_downlink(&m, &dl, &mut replica).unwrap();
        for j in 0..m.d {
            let applied = replica[j] - old_replica[j];
            assert_eq!(
                residual[j].to_bits(),
                (x[j] - applied).to_bits(),
                "element {j}: residual must equal x - dequant exactly"
            );
        }
    }

    #[test]
    fn downlink_is_deterministic_in_its_seed() {
        let m = mm();
        let params: Vec<f32> = (0..m.d).map(|i| i as f32 * 0.3 - 1.0).collect();
        let replica = vec![0.1f32; m.d];
        let mk = |seed| {
            let mut res = vec![0.0f32; m.d];
            encode_downlink(&m, 3, &params, &replica, &mut res, seed).unwrap()
        };
        let (a, b, c) = (mk(5), mk(5), mk(6));
        assert_eq!(a.payload, b.payload, "same seed, same bytes");
        assert_ne!(a.payload, c.payload, "different seed, different rounding");
        assert_eq!(a.segments, b.segments);
    }

    #[test]
    fn downlink_degenerate_range_collapses_to_constant() {
        // A constant x per segment yields step 0: every code decodes to
        // the segment min and the residual is exactly zero.
        let m = mm();
        let params = vec![0.5f32; m.d];
        let mut replica = vec![0.25f32; m.d];
        let mut residual = vec![0.0f32; m.d];
        let dl = encode_downlink(&m, 8, &params, &replica, &mut residual, 1).unwrap();
        assert!(dl.segments.iter().all(|h| h.step == 0.0 && h.min == 0.25));
        apply_downlink(&m, &dl, &mut replica).unwrap();
        assert_eq!(replica, params);
        assert_eq!(residual, vec![0.0f32; m.d]);
    }

    #[test]
    fn apply_downlink_rejects_malformed_frames() {
        let m = mm();
        let params: Vec<f32> = (0..m.d).map(|i| i as f32).collect();
        let replica = vec![0.0f32; m.d];
        let mut residual = vec![0.0f32; m.d];
        let dl = encode_downlink(&m, 5, &params, &replica, &mut residual, 3).unwrap();
        let mut out = vec![0.0f32; m.d];

        let mut short = dl.clone();
        short.payload.pop();
        assert!(apply_downlink(&m, &short, &mut out).is_err(), "truncated payload");
        let mut long = dl.clone();
        long.payload.push(0);
        assert!(apply_downlink(&m, &long, &mut out).is_err(), "oversized payload");
        let mut few = dl.clone();
        few.segments.pop();
        assert!(apply_downlink(&m, &few, &mut out).is_err(), "segment count");
        let mut wide = dl.clone();
        wide.segments[0].bits = 32;
        assert!(apply_downlink(&m, &wide, &mut out).is_err(), "fp32 width on downlink");
        let mut zero = dl.clone();
        zero.segments[0].bits = 0;
        assert!(apply_downlink(&m, &zero, &mut out).is_err(), "zero width");
        assert!(apply_downlink(&m, &dl, &mut vec![0.0f32; m.d - 1]).is_err(), "short replica");
        // bit widths are in 1..=16 but the EXACT byte-length check must
        // hold for every legal width change too
        let mut rewidth = dl.clone();
        rewidth.segments[0].bits = 1;
        assert!(apply_downlink(&m, &rewidth, &mut out).is_err(), "width/payload mismatch");
        // encode rejects out-of-range widths and bad buffer lengths
        assert!(encode_downlink(&m, 0, &params, &replica, &mut residual, 0).is_err());
        assert!(encode_downlink(&m, 17, &params, &replica, &mut residual, 0).is_err());
        assert!(
            encode_downlink(&m, 4, &params[1..], &replica, &mut residual, 0).is_err(),
            "short params"
        );
    }
}
