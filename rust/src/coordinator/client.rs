//! Client-side FL logic: the uplink path.
//!
//! Per round: local `tau`-step SGD (AOT `round` executable) → per-segment
//! range measurement (`ranges` executable) → policy decision (bit-widths)
//! → stochastic quantization → bit-packing → `Update` message.  On the
//! native backend under [`CodecMode::Narrow`] the last two stages are
//! **fused**: [`codec::encode_quantized_fused`] clamp-round-packs the
//! delta in one pass (no `d`-length codes vector, no `u32` scratch),
//! byte-identical to the split quantize-executable-then-pack path used
//! by the PJRT backend and by [`CodecMode::Reference`].  The same
//! [`ClientState`] drives the in-process simulator and the remote TCP
//! worker, so both modes exercise identical code.

use std::sync::Arc;

use anyhow::Result;

use super::codec::{self, QuantPlan};
use crate::config::CodecMode;
use crate::data::batch::BatchCursor;
use crate::data::Dataset;
use crate::quant::{math, Decision, PolicyInputs, QuantPolicy};
use crate::runtime::ModelRuntime;
use crate::util::rng::Rng;
use crate::wire::messages::Update;

/// An error-feedback residual stored quantized between rounds.
///
/// EF keeps one model-sized fp32 vector per client — at million-client
/// scale that buffer, not the model, dominates resident memory.  The
/// bank re-quantizes the residual onto a per-segment affine grid of
/// `2^bits` points right after the uplink encode (u8 codes, so `d`
/// bytes instead of `4d`) and re-materializes it at the next
/// EF-apply.  Banking is itself lossy, but the loss is *re-captured*:
/// the reconstruction error of round `m`'s bank lands in round `m+1`'s
/// residual like any other quantization error, so nothing leaves the
/// EF loop.  Per-span absolute error is bounded by `step / 2` with
/// `step = (max - min) / (2^bits - 1)`.
pub struct ResidualBank {
    /// Per-span grid origin (the span's exact minimum).
    mins: Vec<f32>,
    /// Per-span grid step; 0.0 for constant spans (all codes decode to
    /// the origin exactly).
    steps: Vec<f32>,
    /// One code per element, `0..2^bits` (bits <= 8 by config
    /// validation, so a byte each).
    codes: Vec<u8>,
}

impl ResidualBank {
    /// Quantize `values` onto per-span grids of `2^bits` points.
    /// `spans` are `(offset, size)` pairs covering `values` (the model's
    /// segment layout).
    pub fn bank(spans: &[(usize, usize)], values: &[f32], bits: u32) -> ResidualBank {
        debug_assert!((1..=8).contains(&bits), "bank bits must be in 1..=8, got {bits}");
        let maxcode = ((1u32 << bits) - 1) as f32;
        let mut mins = Vec::with_capacity(spans.len());
        let mut steps = Vec::with_capacity(spans.len());
        let mut codes = vec![0u8; values.len()];
        for &(off, size) in spans {
            let mut mn = f32::INFINITY;
            let mut mx = f32::NEG_INFINITY;
            for &v in &values[off..off + size] {
                mn = mn.min(v);
                mx = mx.max(v);
            }
            if !(mn.is_finite() && mx.is_finite()) {
                // empty span: nothing to code
                (mn, mx) = (0.0, 0.0);
            }
            let step = (mx - mn) / maxcode;
            if step > 0.0 {
                for j in off..off + size {
                    let c = ((values[j] - mn) / step + 0.5).floor();
                    codes[j] = c.clamp(0.0, maxcode) as u8;
                }
            }
            // step == 0 (constant span): codes stay 0 and decode to the
            // span's value exactly.
            mins.push(mn);
            steps.push(step);
        }
        ResidualBank { mins, steps, codes }
    }

    /// Reconstruct the banked residual into `out` (same `spans` the
    /// bank was built with).  Elements outside the spans are untouched.
    pub fn dequantize_into(&self, spans: &[(usize, usize)], out: &mut [f32]) {
        debug_assert_eq!(spans.len(), self.mins.len(), "span layout changed under the bank");
        for (l, &(off, size)) in spans.iter().enumerate() {
            let (mn, st) = (self.mins[l], self.steps[l]);
            for j in off..off + size {
                out[j] = mn + self.codes[j] as f32 * st;
            }
        }
    }

    /// Resident bytes of the banked residual (the sub-fp32 claim the
    /// scale-smoke test asserts).
    pub fn resident_bytes(&self) -> usize {
        self.codes.len() + (self.mins.len() + self.steps.len()) * std::mem::size_of::<f32>()
    }
}

/// One federated client's local state.
///
/// Owns no thread affinity: the round engine moves a `ClientState`
/// through its worker pool each round, so everything here is `Send` and
/// all randomness comes from per-client streams derived at construction
/// (bit-identical results whatever thread runs the round).
pub struct ClientState {
    /// This client's id (index into the cohort registry).
    pub id: u32,
    /// Shared (read-only) training shard — `Arc` so the session keeps
    /// one copy per client across runs instead of cloning per state.
    shard: Arc<Dataset>,
    cursor: BatchCursor,
    policy: Box<dyn QuantPolicy>,
    lr: f32,
    quant_rng: Rng,
    // reusable round-batch buffers (no per-round allocation)
    xs: Vec<f32>,
    ys: Vec<i32>,
    /// Error-feedback residual (EF-SGD): what quantization dropped last
    /// round, folded into this round's update before quantizing.  Empty
    /// when EF is disabled — and, under banked EF (`bank_bits > 0`),
    /// empty *between* rounds too: the buffer is re-materialized from
    /// [`ResidualBank`] per active round and freed after banking.
    residual: Vec<f32>,
    /// Banked-EF bit-width (`--ef-bits`): > 0 stores the residual
    /// quantized between rounds (see [`ResidualBank`]); 0 keeps the
    /// historical resident fp32 buffer, bit-identical to before the
    /// knob existed.
    bank_bits: u32,
    /// The quantized residual carried between rounds when
    /// `bank_bits > 0` (`None` until the client's first update).
    bank: Option<ResidualBank>,
    /// Codec path: fused quantize→pack (narrow, native backend) or the
    /// split quantize-then-pack reference.
    codec: CodecMode,
    /// Per-segment ranges observed last round (telemetry).
    pub last_ranges: Vec<f32>,
    /// Per-segment wire bits decided last round (telemetry).
    pub last_bits: Vec<u32>,
}

impl ClientState {
    /// State with default options (no error feedback, narrow codec).
    pub fn new(
        id: u32,
        shard: Arc<Dataset>,
        policy: Box<dyn QuantPolicy>,
        lr: f32,
        model: &ModelRuntime,
        root_rng: &Rng,
    ) -> ClientState {
        Self::with_options(id, shard, policy, lr, model, root_rng, false, CodecMode::Narrow)
    }

    /// Like [`Self::new`] with explicit error-feedback and codec-path
    /// control.
    #[allow(clippy::too_many_arguments)]
    pub fn with_options(
        id: u32,
        shard: Arc<Dataset>,
        policy: Box<dyn QuantPolicy>,
        lr: f32,
        model: &ModelRuntime,
        root_rng: &Rng,
        error_feedback: bool,
        codec: CodecMode,
    ) -> ClientState {
        let mm = &model.mm;
        let cursor = BatchCursor::new(shard.len(), root_rng.derive(&format!("client{id}.batch")));
        let xs = vec![0.0f32; mm.tau * mm.batch * mm.input_len()];
        let ys = vec![0i32; mm.tau * mm.batch];
        ClientState {
            id,
            shard,
            cursor,
            policy,
            lr,
            quant_rng: root_rng.derive(&format!("client{id}.quant")),
            xs,
            ys,
            residual: if error_feedback { vec![0.0; mm.d] } else { Vec::new() },
            bank_bits: 0,
            bank: None,
            codec,
            last_ranges: Vec::new(),
            last_bits: Vec::new(),
        }
    }

    /// Bank the EF residual quantized to `bits` (`RunConfig::ef_bits`).
    /// A no-op when `bits == 0` or error feedback is off (config
    /// validation rejects `ef_bits > 0` without `--error-feedback`, but
    /// the gate here keeps the builder safe to call unconditionally).
    pub fn with_ef_bits(mut self, bits: u32) -> ClientState {
        if bits > 0 && !self.residual.is_empty() {
            self.bank_bits = bits;
            // Between rounds only the bank is resident; the fp32 buffer
            // (all zeros right now — banking it would be a zero grid) is
            // re-materialized per active round.
            self.residual = Vec::new();
        }
        self
    }

    /// The client's shard size (aggregation weight numerator).
    pub fn num_samples(&self) -> u32 {
        self.shard.len() as u32
    }

    /// Process one broadcast: run the local round and produce the update.
    ///
    /// `losses` is the (initial, previous) global training loss pair from
    /// the broadcast (None before round 1).  `budget`, when present, is
    /// the server's per-segment bit-width allocation for this client
    /// this round (`--bit-budget`): the policy's levels are clamped so
    /// no segment exceeds its allocated width — a hard cap, not advice.
    pub fn process_round(
        &mut self,
        model: &ModelRuntime,
        round: u32,
        params: &[f32],
        losses: Option<(f32, f32)>,
        budget: Option<&[u8]>,
    ) -> Result<Update> {
        let mm = &model.mm;
        // 1. local tau-step SGD
        self.cursor
            .fill_round_batch(&self.shard, mm.tau, mm.batch, &mut self.xs, &mut self.ys);
        let (mut delta, train_loss) = model.local_round(params, &self.xs, &self.ys, self.lr)?;

        // 1b. error feedback: fold in last round's quantization residual
        if self.bank_bits > 0 {
            // Banked EF: re-materialize the fp32 buffer from the
            // quantized bank (zeros before the first update).  The
            // bank's own reconstruction error lands back in this
            // round's residual below, so nothing leaves the EF loop.
            self.residual = vec![0.0f32; mm.d];
            if let Some(bank) = &self.bank {
                let spans: Vec<(usize, usize)> =
                    mm.segments.iter().map(|s| (s.offset, s.size)).collect();
                bank.dequantize_into(&spans, &mut self.residual);
            }
        }
        if !self.residual.is_empty() {
            for (d, r) in delta.iter_mut().zip(&self.residual) {
                *d += r;
            }
        }

        // 2. observe per-segment ranges
        let (mins, ranges) = model.ranges(&delta)?;
        self.last_ranges = ranges.iter().map(|&r| r.max(0.0)).collect();

        // 3. policy decision (mins + ranges = the exact per-segment
        // envelope, so whole-model policies see the true global range)
        let decision = self.policy.decide(&PolicyInputs {
            round,
            client_id: self.id,
            ranges: &self.last_ranges,
            mins: &mins,
            initial_loss: losses.map(|(f0, _)| f0),
            prev_loss: losses.map(|(_, fm)| fm),
        });

        // 3b. budget clamp: each segment's level may not exceed the
        // width the server allocated.  An fp32 decision under a budget
        // quantizes at exactly the allocated widths (fp32 would blow
        // the round cap by construction).
        let decision = match (decision.levels, budget) {
            (Some(levels), Some(ws)) => Decision {
                levels: Some(
                    levels
                        .iter()
                        .zip(ws)
                        .map(|(&s, &w)| s.min(math::max_level_for_bits(w as u32)))
                        .collect(),
                ),
            },
            (None, Some(ws)) => Decision {
                levels: Some(
                    ws.iter().map(|&w| math::max_level_for_bits(w as u32)).collect(),
                ),
            },
            (levels, None) => Decision { levels },
        };
        self.last_bits = codec::decision_bits(mm, &decision);

        // 4+5. quantize + pack (and, under EF, bank what was dropped)
        let (segments, payload) = match &decision.levels {
            None => {
                if !self.residual.is_empty() {
                    self.residual.iter_mut().for_each(|r| *r = 0.0); // lossless uplink
                }
                codec::encode_fp32(mm, &mins, &ranges, &delta)
            }
            Some(levels) => {
                let plan = QuantPlan::new(levels, &ranges);
                let seed = self.quant_rng.next_u32();
                if self.codec == CodecMode::Narrow && model.is_native() {
                    // Fused clamp-round-pack straight off the delta: the
                    // native quantize contract is mirrored element for
                    // element (same rng stream, same expressions), so the
                    // payload — and the EF residual — are bit-identical
                    // to the split path below.
                    let residual = if self.residual.is_empty() {
                        None
                    } else {
                        Some(&mut self.residual[..])
                    };
                    codec::encode_quantized_fused(mm, &plan, &mins, &delta, seed, residual)
                } else {
                    let codes = model.quantize(&delta, &mins, &plan.sinv, &plan.maxcode, seed)?;
                    if !self.residual.is_empty() {
                        // residual = delta - dequant(codes), segment-wise
                        for (l, seg) in mm.segments.iter().enumerate() {
                            let (mn, st) = (mins[l], plan.step[l]);
                            for j in seg.offset..seg.offset + seg.size {
                                self.residual[j] = delta[j] - (mn + codes[j] * st);
                            }
                        }
                    }
                    codec::encode_quantized(mm, &plan, &mins, &codes)
                }
            }
        };

        // 6. banked EF: re-quantize what the encode just left behind and
        // free the fp32 buffer until this client's next selected round.
        if self.bank_bits > 0 {
            let spans: Vec<(usize, usize)> =
                mm.segments.iter().map(|s| (s.offset, s.size)).collect();
            self.bank = Some(ResidualBank::bank(&spans, &self.residual, self.bank_bits));
            self.residual = Vec::new();
        }

        Ok(Update {
            round,
            client_id: self.id,
            num_samples: self.num_samples(),
            train_loss,
            segments,
            payload,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reconstruct(bank: &ResidualBank, spans: &[(usize, usize)], d: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; d];
        bank.dequantize_into(spans, &mut out);
        out
    }

    #[test]
    fn bank_round_trip_error_is_bounded_by_half_a_step() {
        // Two spans with very different scales: per-span grids must
        // adapt (a shared grid would blow the bound on the small span).
        let spans = [(0usize, 6usize), (6, 4)];
        let values: Vec<f32> =
            vec![-0.75, 0.3, 1.25, -0.1, 0.9, 0.0, 1e-3, -2e-3, 5e-4, 1.5e-3];
        for bits in [1u32, 2, 4, 6, 8] {
            let bank = ResidualBank::bank(&spans, &values, bits);
            let got = reconstruct(&bank, &spans, values.len());
            let maxcode = ((1u32 << bits) - 1) as f32;
            for &(off, size) in &spans {
                let seg = &values[off..off + size];
                let mn = seg.iter().copied().fold(f32::INFINITY, f32::min);
                let mx = seg.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                let step = (mx - mn) / maxcode;
                for j in off..off + size {
                    let err = (got[j] - values[j]).abs();
                    let bound = step * 0.5 * (1.0 + 1e-4) + 1e-12;
                    assert!(err <= bound, "bits={bits} j={j}: |{err}| > {bound}");
                }
            }
        }
    }

    #[test]
    fn bank_reconstruction_is_stable_across_skipped_rounds() {
        // An unselected client does not re-bank; dequantizing the same
        // bank again rounds later must give bit-identical values.
        let spans = [(0usize, 5usize)];
        let values = vec![0.2f32, -0.4, 0.0, 1.0, -1.0];
        let bank = ResidualBank::bank(&spans, &values, 4);
        let first = reconstruct(&bank, &spans, 5);
        for _skipped_round in 0..3 {
            assert_eq!(reconstruct(&bank, &spans, 5), first);
        }
    }

    #[test]
    fn constant_and_zero_spans_bank_exactly() {
        // step == 0 spans (all-equal values, the all-zero residual of a
        // lossless round) must reconstruct exactly, not divide by zero.
        let spans = [(0usize, 3usize), (3, 3)];
        let values = vec![0.0f32, 0.0, 0.0, 0.7, 0.7, 0.7];
        let bank = ResidualBank::bank(&spans, &values, 4);
        assert_eq!(reconstruct(&bank, &spans, 6), values);
    }

    #[test]
    fn bank_is_sub_fp32() {
        let d = 1024usize;
        let spans = [(0usize, d)];
        let values: Vec<f32> = (0..d).map(|j| (j as f32).sin()).collect();
        let bank = ResidualBank::bank(&spans, &values, 8);
        assert!(
            bank.resident_bytes() < d * 4,
            "{} bytes for a {}-element residual",
            bank.resident_bytes(),
            d
        );
    }
}
