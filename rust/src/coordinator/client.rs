//! Client-side FL logic: the uplink path.
//!
//! Per round: local `tau`-step SGD (AOT `round` executable) → per-segment
//! range measurement (`ranges` executable) → policy decision (bit-widths)
//! → stochastic quantization → bit-packing → `Update` message.  On the
//! native backend under [`CodecMode::Narrow`] the last two stages are
//! **fused**: [`codec::encode_quantized_fused`] clamp-round-packs the
//! delta in one pass (no `d`-length codes vector, no `u32` scratch),
//! byte-identical to the split quantize-executable-then-pack path used
//! by the PJRT backend and by [`CodecMode::Reference`].  The same
//! [`ClientState`] drives the in-process simulator and the remote TCP
//! worker, so both modes exercise identical code.

use std::sync::Arc;

use anyhow::Result;

use super::codec::{self, QuantPlan};
use crate::config::CodecMode;
use crate::data::batch::BatchCursor;
use crate::data::Dataset;
use crate::quant::{PolicyInputs, QuantPolicy};
use crate::runtime::ModelRuntime;
use crate::util::rng::Rng;
use crate::wire::messages::Update;

/// One federated client's local state.
///
/// Owns no thread affinity: the round engine moves a `ClientState`
/// through its worker pool each round, so everything here is `Send` and
/// all randomness comes from per-client streams derived at construction
/// (bit-identical results whatever thread runs the round).
pub struct ClientState {
    /// This client's id (index into the cohort registry).
    pub id: u32,
    /// Shared (read-only) training shard — `Arc` so the session keeps
    /// one copy per client across runs instead of cloning per state.
    shard: Arc<Dataset>,
    cursor: BatchCursor,
    policy: Box<dyn QuantPolicy>,
    lr: f32,
    quant_rng: Rng,
    // reusable round-batch buffers (no per-round allocation)
    xs: Vec<f32>,
    ys: Vec<i32>,
    /// Error-feedback residual (EF-SGD): what quantization dropped last
    /// round, folded into this round's update before quantizing.  Empty
    /// when EF is disabled.
    residual: Vec<f32>,
    /// Codec path: fused quantize→pack (narrow, native backend) or the
    /// split quantize-then-pack reference.
    codec: CodecMode,
    /// Per-segment ranges observed last round (telemetry).
    pub last_ranges: Vec<f32>,
    /// Per-segment wire bits decided last round (telemetry).
    pub last_bits: Vec<u32>,
}

impl ClientState {
    /// State with default options (no error feedback, narrow codec).
    pub fn new(
        id: u32,
        shard: Arc<Dataset>,
        policy: Box<dyn QuantPolicy>,
        lr: f32,
        model: &ModelRuntime,
        root_rng: &Rng,
    ) -> ClientState {
        Self::with_options(id, shard, policy, lr, model, root_rng, false, CodecMode::Narrow)
    }

    /// Like [`Self::new`] with explicit error-feedback and codec-path
    /// control.
    #[allow(clippy::too_many_arguments)]
    pub fn with_options(
        id: u32,
        shard: Arc<Dataset>,
        policy: Box<dyn QuantPolicy>,
        lr: f32,
        model: &ModelRuntime,
        root_rng: &Rng,
        error_feedback: bool,
        codec: CodecMode,
    ) -> ClientState {
        let mm = &model.mm;
        let cursor = BatchCursor::new(shard.len(), root_rng.derive(&format!("client{id}.batch")));
        let xs = vec![0.0f32; mm.tau * mm.batch * mm.input_len()];
        let ys = vec![0i32; mm.tau * mm.batch];
        ClientState {
            id,
            shard,
            cursor,
            policy,
            lr,
            quant_rng: root_rng.derive(&format!("client{id}.quant")),
            xs,
            ys,
            residual: if error_feedback { vec![0.0; mm.d] } else { Vec::new() },
            codec,
            last_ranges: Vec::new(),
            last_bits: Vec::new(),
        }
    }

    /// The client's shard size (aggregation weight numerator).
    pub fn num_samples(&self) -> u32 {
        self.shard.len() as u32
    }

    /// Process one broadcast: run the local round and produce the update.
    ///
    /// `losses` is the (initial, previous) global training loss pair from
    /// the broadcast (None before round 1).
    pub fn process_round(
        &mut self,
        model: &ModelRuntime,
        round: u32,
        params: &[f32],
        losses: Option<(f32, f32)>,
    ) -> Result<Update> {
        let mm = &model.mm;
        // 1. local tau-step SGD
        self.cursor
            .fill_round_batch(&self.shard, mm.tau, mm.batch, &mut self.xs, &mut self.ys);
        let (mut delta, train_loss) = model.local_round(params, &self.xs, &self.ys, self.lr)?;

        // 1b. error feedback: fold in last round's quantization residual
        if !self.residual.is_empty() {
            for (d, r) in delta.iter_mut().zip(&self.residual) {
                *d += r;
            }
        }

        // 2. observe per-segment ranges
        let (mins, ranges) = model.ranges(&delta)?;
        self.last_ranges = ranges.iter().map(|&r| r.max(0.0)).collect();

        // 3. policy decision (mins + ranges = the exact per-segment
        // envelope, so whole-model policies see the true global range)
        let decision = self.policy.decide(&PolicyInputs {
            round,
            client_id: self.id,
            ranges: &self.last_ranges,
            mins: &mins,
            initial_loss: losses.map(|(f0, _)| f0),
            prev_loss: losses.map(|(_, fm)| fm),
        });
        self.last_bits = codec::decision_bits(mm, &decision);

        // 4+5. quantize + pack (and, under EF, bank what was dropped)
        let (segments, payload) = match &decision.levels {
            None => {
                if !self.residual.is_empty() {
                    self.residual.iter_mut().for_each(|r| *r = 0.0); // lossless uplink
                }
                codec::encode_fp32(mm, &mins, &ranges, &delta)
            }
            Some(levels) => {
                let plan = QuantPlan::new(levels, &ranges);
                let seed = self.quant_rng.next_u32();
                if self.codec == CodecMode::Narrow && model.is_native() {
                    // Fused clamp-round-pack straight off the delta: the
                    // native quantize contract is mirrored element for
                    // element (same rng stream, same expressions), so the
                    // payload — and the EF residual — are bit-identical
                    // to the split path below.
                    let residual = if self.residual.is_empty() {
                        None
                    } else {
                        Some(&mut self.residual[..])
                    };
                    codec::encode_quantized_fused(mm, &plan, &mins, &delta, seed, residual)
                } else {
                    let codes = model.quantize(&delta, &mins, &plan.sinv, &plan.maxcode, seed)?;
                    if !self.residual.is_empty() {
                        // residual = delta - dequant(codes), segment-wise
                        for (l, seg) in mm.segments.iter().enumerate() {
                            let (mn, st) = (mins[l], plan.step[l]);
                            for j in seg.offset..seg.offset + seg.size {
                                self.residual[j] = delta[j] - (mn + codes[j] * st);
                            }
                        }
                    }
                    codec::encode_quantized(mm, &plan, &mins, &codes)
                }
            }
        };

        Ok(Update {
            round,
            client_id: self.id,
            num_samples: self.num_samples(),
            train_loss,
            segments,
            payload,
        })
    }
}
