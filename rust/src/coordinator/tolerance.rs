//! The fold-tolerance core shared by every tolerant receive loop.
//!
//! Three parties run "collect updates until the budget runs out, bank
//! what is late, fail what never answers" logic: the root server's
//! [`recv_tolerant`], the `feddq aggregate` role's leaf collection, and
//! (virtually) the in-process engine via the scheduler's simulated
//! churn.  Before this module each reimplemented the deadline
//! apportioning and arrival classification inline; keeping them here
//! guarantees a leaf is judged identically no matter which tier of the
//! tree receives it — the precondition for leaf-granularity quorum
//! (`--quorum` counts *leaves*, never subtree composites).
//!
//! [`recv_tolerant`]: super::server::Server

use std::time::{Duration, Instant};

/// One round's shared receive deadline, apportioned across peers: every
/// blocking receive gets whatever remains of the round budget, so a
/// straggler cannot starve the peers polled after it beyond the round
/// timeout (`--round-timeout`).
#[derive(Clone, Copy, Debug)]
pub struct RecvBudget {
    deadline: Option<Instant>,
}

impl RecvBudget {
    /// A budget of `timeout` seconds from now; `None` blocks forever.
    pub fn new(timeout: Option<f64>) -> RecvBudget {
        RecvBudget {
            deadline: timeout.map(|t| Instant::now() + Duration::from_secs_f64(t)),
        }
    }

    /// The share of the budget left for the next blocking receive:
    /// `None` = unbounded, `Some(ZERO)` = already expired.
    pub fn remaining(&self) -> Option<Duration> {
        self.deadline.map(|dl| dl.saturating_duration_since(Instant::now()))
    }

    /// True once the budget is exhausted (never true for unbounded).
    pub fn expired(&self) -> bool {
        matches!(self.remaining(), Some(d) if d.is_zero())
    }
}

/// How one arrived update relates to the round being collected.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Arrival {
    /// Answers the current round: fold it.
    OnTime,
    /// Answers a past round, `s >= 1` rounds stale: bank or drop per
    /// the `--staleness` bound.
    Stale(u32),
    /// Answers a round that has not been broadcast yet — a protocol
    /// violation, never a banking candidate.
    Future,
}

/// Classify an update answering `update_round` against the round being
/// collected.  Every tier of the tree must use this single definition
/// of staleness, or a leaf could fold at one tier and drop at another.
pub fn classify(update_round: u32, round: u32) -> Arrival {
    match update_round.cmp(&round) {
        std::cmp::Ordering::Equal => Arrival::OnTime,
        std::cmp::Ordering::Less => Arrival::Stale(round - update_round),
        std::cmp::Ordering::Greater => Arrival::Future,
    }
}

/// The quorum floor: how many of `n` expected leaves must fold before
/// the round may close.  `ceil(quorum * n)` clamped to `[1, n]` — the
/// same floor whether the leaves arrive flat or behind aggregators,
/// which is what makes the tree's quorum *leaf-granular*.
pub fn quorum_floor(quorum: f32, n: usize) -> usize {
    if n == 0 {
        return 0;
    }
    ((quorum as f64 * n as f64).ceil() as usize).clamp(1, n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classify_orders_rounds() {
        assert_eq!(classify(5, 5), Arrival::OnTime);
        assert_eq!(classify(3, 5), Arrival::Stale(2));
        assert_eq!(classify(4, 5), Arrival::Stale(1));
        assert_eq!(classify(6, 5), Arrival::Future);
    }

    #[test]
    fn quorum_floor_matches_flat_server_semantics() {
        // the historical server-side formula, now shared with the tree
        assert_eq!(quorum_floor(1.0, 10), 10);
        assert_eq!(quorum_floor(0.6, 10), 6);
        assert_eq!(quorum_floor(0.55, 10), 6); // ceil
        assert_eq!(quorum_floor(0.0, 10), 1); // floor clamp
        assert_eq!(quorum_floor(1.0, 0), 0); // degenerate registry
        assert_eq!(quorum_floor(0.6, 1), 1);
    }

    #[test]
    fn budget_apportions_and_expires() {
        let unbounded = RecvBudget::new(None);
        assert_eq!(unbounded.remaining(), None);
        assert!(!unbounded.expired());

        let b = RecvBudget::new(Some(30.0));
        let r = b.remaining().expect("bounded");
        assert!(r <= Duration::from_secs(30));
        assert!(r > Duration::from_secs(29), "fresh budget nearly whole");
        assert!(!b.expired());

        let spent = RecvBudget::new(Some(0.0));
        // zero-second budgets are expired from the start
        assert!(spent.expired());
        assert_eq!(spent.remaining(), Some(Duration::ZERO));
    }
}
