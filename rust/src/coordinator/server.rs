//! Server-side FL logic: the round loop, aggregation and evaluation —
//! plus [`Session`], the single-process driver that runs client rounds
//! on a persistent worker pool ([`super::pool`]) and talks to the server
//! through the same message types the TCP mode uses.
//!
//! ## Round data path
//!
//! * **Scheduling** happens above this module ([`super::sched`]): the
//!   session / TCP server plan each round's cohort (partial
//!   participation, deadline policy) and hand [`Server::run_round`]
//!   only the selected handles, ordered slowest-first.  Every stage
//!   below ranges over exactly that cohort — weights, loss averages,
//!   telemetry means and the bit ledger — and clients outside it are
//!   untouched.
//! * **Broadcast** is zero-copy: the global parameters live in an
//!   `Arc<[f32]>`, the `Broadcast` message is encoded **once** per round
//!   and every client handle receives the shared buffer / pre-encoded
//!   bytes ([`ClientHandle::send_broadcast`]).  After the round, the
//!   server updates the vector in place (`Arc::get_mut` — by then all
//!   clients have dropped their references).
//! * **Receive and decode are pipelined** when a pool is attached
//!   ([`ServerOpts::tasks`]): each arriving `ClientUpdate` is handed to
//!   a worker the moment it lands, on the pool's **priority lane**, so
//!   in-process decodes jump ahead of not-yet-started round jobs and
//!   overlap the receive window fully (matching TCP mode).  Updates are
//!   then ordered by `client_id`.  Decodes land in **narrow rows**
//!   (`u16` codes for quantized segments, [`codec::DecodedUpdate`]):
//!   half the buffer memory — which directly multiplies what a given
//!   `--decode-buffers` bound holds — and half the fold read traffic,
//!   unpacked through the width-specialized SWAR kernels
//!   ([`crate::wire::swar`]).
//! * **Fold overlap** ([`ServerOpts::fold_overlap`], on by default):
//!   when every client's sample count is known before the round (always
//!   in-process; from round 1 over TCP), aggregation weights are fixed
//!   up front and each accumulator shard folds the next client in
//!   sorted order *as soon as its decode lands* — per-shard prefix
//!   folds that overlap the still-arriving updates.  The fold order and
//!   per-element arithmetic are exactly those of the after-barrier
//!   sharded fold, so results stay bit-identical.  A client's decode
//!   buffer is recycled the moment every shard has folded it, which
//!   bounds the pipeline's live memory and enables:
//! * **Bounded decode buffers** ([`ServerOpts::decode_buffers`]): with
//!   fold overlap active, at most `k` decode buffers are ever allocated
//!   (`0` = unbounded, the historical behavior); the receive loop
//!   blocks for a recycled buffer while still servicing decode/fold
//!   completions, so progress is always possible.  Without fold overlap
//!   every decoded row must survive until aggregation, so there the
//!   knob only caps how many buffers are *retained* between rounds.
//! * **Aggregation** folds the decoded updates into the `d`-length
//!   accumulator.  With `agg_shards > 1` the accumulator is split into
//!   contiguous per-worker chunk ranges and the decode-free fold runs
//!   concurrently, each shard visiting clients in the same sorted
//!   order ([`codec::fold_range`]) — element-wise arithmetic never
//!   crosses a chunk boundary, so any shard count is bit-identical to
//!   the serial fold.  The fused dequantize-aggregate executable
//!   remains available as [`AggregateMode::Fused`].
//! * **Evaluation** splits the test set's eval batches into contiguous
//!   slices across the pool (`eval_threads`), then reduces the
//!   per-batch partials in batch order — bit-identical to the serial
//!   loop for any slice count.
//!
//! All paths visit updates in ascending `client_id` order, so reports
//! are bit-identical across thread counts, shard counts, eval slice
//! counts, decode-buffer bounds and fold-overlap settings (enforced by
//! `rust/tests/parallel_determinism.rs`).  Across the two aggregation
//! *modes*, equality holds element-for-element on the native backend
//! (same fixed-order f32 arithmetic); a hardware-backed fused kernel
//! may reduce in a different order and is only guaranteed close, not
//! bit-equal (see `streaming_and_fused_aggregation_agree`).
//!
//! Timing note: with fold overlap active the shard folds execute inside
//! the receive window, so `recv_decode_secs` absorbs most of the fold
//! work and `agg_secs` shrinks to the final chunk application — that
//! shift *is* the overlap win.

use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Context, Result};

use super::arena::ClientArena;
use super::client::ClientState;
use super::codec;
use super::pool::{self, Job, Task, TaskSender, WorkerPool};
use super::sched::{self, RoundScheduler};
use super::tolerance::{self, Arrival, RecvBudget};
use crate::config::{AggregateMode, CodecMode, RoundPolicy, RunConfig};
use crate::data::{self, shard};
use crate::metrics::{RoundRecord, RunReport};
use crate::quant::budget::BitBudgetController;
use crate::quant::math;
use crate::runtime::{ModelRuntime, Runtime};
use crate::util::rng::Rng;
use crate::util::stats;
use crate::wire::frame;
use crate::wire::messages::{self, Message, Update};

/// A connected client as the server sees it.
pub trait ClientHandle {
    /// The client's id (stable across rounds).
    fn id(&self) -> u32;
    /// Send one message to the client.
    fn send(&mut self, msg: &Message) -> Result<()>;
    /// Broadcast fast path: `encoded` is `msg.encode()`, produced once
    /// by the server for the whole round.  Implementations must not
    /// re-encode; the default falls back to [`Self::send`].
    fn send_broadcast(&mut self, msg: &Message, encoded: &[u8]) -> Result<()> {
        let _ = encoded;
        self.send(msg)
    }
    /// Block for the client's update of the current round.
    fn recv_update(&mut self) -> Result<Update>;
    /// Bound how long [`Self::recv_update`] may block (`None` = wait
    /// forever).  Transports without a timeout mechanism (in-process
    /// handles, whose workers always answer) ignore the hint; the TCP
    /// handle maps it onto the socket read timeout so the quorum path
    /// can give up on a stalled worker.
    fn set_recv_timeout(&mut self, timeout: Option<std::time::Duration>) -> Result<()> {
        let _ = timeout;
        Ok(())
    }
    /// The client's dataset size, when known *before* its update
    /// arrives (the fold-overlap path needs aggregation weights up
    /// front).  In-process handles know it from construction; remote
    /// handles return `None` and the server learns it from the first
    /// round's updates.
    fn num_samples(&self) -> Option<u32> {
        None
    }
    /// The client's measured compute seconds for its most recent round,
    /// when observable.  In-process handles get it from the worker's
    /// own timing (queue-position-free); remote handles return `None` —
    /// the server cannot separate a remote client's compute time from
    /// socket queueing, so the scheduler falls back to the simulated
    /// latency model for its dispatch cost.
    fn last_round_secs(&self) -> Option<f64> {
        None
    }
    /// Drain the handle's wire-volume counters: framed `(uplink,
    /// downlink)` bytes accumulated since the last call.  The server
    /// folds the deltas into the client arena rows at the end of each
    /// round, so the root keeps no per-handle O(n) byte maps.
    fn take_io_bytes(&mut self) -> (u64, u64) {
        (0, 0)
    }
    /// Is this handle an intermediate aggregator (tree topology)?  An
    /// aggregate handle's [`Self::recv_update`] delivers a subtree
    /// *pseudo-update* (the pre-folded accumulator shaped as an fp32
    /// update) and stashes the partial's metadata for
    /// [`Self::take_partial_meta`].
    fn is_aggregate(&self) -> bool {
        false
    }
    /// Does this handle cross a process boundary (TCP)?  Remote
    /// receivers keep their own replica of the broadcast parameters,
    /// so they — and only they — may be sent a quantized downlink
    /// delta (`--downlink-bits`) instead of the full vector.
    /// In-process handles share the server's `Arc` directly and always
    /// take the full broadcast.
    fn is_remote(&self) -> bool {
        false
    }
    /// For aggregate handles: whether the most recent
    /// [`Self::recv_update`] delivered the subtree's composite partial
    /// (`true`) or a relayed raw leaf update (`false` — the late/stale
    /// forwarding path, which the server banks instead of folding).
    /// Leaf handles never relay, so the default is `true`.
    fn last_recv_was_partial(&self) -> bool {
        true
    }
    /// Composite-handle failover: try to adopt a restarted aggregator
    /// from the rejoin map and re-send this round's encoded broadcast
    /// over the new transport.  Returns `true` once the handle is live
    /// again.  Leaf handles have no mid-round failover (their death
    /// costs one member, not a whole span), so the default never
    /// revives.
    fn retry_revive(&mut self, encoded_broadcast: &[u8]) -> Result<bool> {
        let _ = encoded_broadcast;
        Ok(false)
    }
    /// The most recently received partial's metadata (member ids,
    /// sample counts, leaf wire bits, depth), for aggregate handles.
    /// `None` for leaf handles or before any partial arrived.
    fn take_partial_meta(&mut self) -> Option<messages::PartialMeta> {
        None
    }
}

/// How the server schedules its own hot stages.
pub struct ServerOpts {
    /// Decode-fold strategy (streaming by default, fused executable on
    /// request).
    pub aggregate: AggregateMode,
    /// Accumulator shards for the parallel fold (>= 1); 1 = serial
    /// fold.  Bit-identical results for any value.
    pub agg_shards: usize,
    /// Worker slices for server-side eval batches (>= 1); 1 = serial.
    /// Bit-identical results for any value.
    pub eval_threads: usize,
    /// The round behavior policy — the **single** construction path for
    /// tolerance (quorum / timeout / bounded staleness) and pipeline
    /// shape (fold overlap, decode-buffer bound, codec).  Quorum below
    /// 1.0 or a timeout puts the receive path in tolerant mode
    /// (per-client failures land in `failed` instead of aborting);
    /// staleness `k > 0` additionally banks late updates for a
    /// discounted fold within `k` rounds ([`Server::run_round`]).
    pub round: RoundPolicy,
    /// Pool handle for server-side stages (decode pipeline, shard fold,
    /// eval slices); `None` runs the server fully serial.
    pub tasks: Option<TaskSender>,
}

impl ServerOpts {
    /// Fully serial server (no pool): the pre-parallel behavior.
    pub fn serial(aggregate: AggregateMode) -> ServerOpts {
        let mut round = RoundPolicy::strict_sync();
        // No pool, so there is nothing to overlap with.
        round.pipeline.fold_overlap = false;
        ServerOpts { aggregate, agg_shards: 1, eval_threads: 1, round, tasks: None }
    }
}

/// What the fold-overlap receive returns: updates in sorted-id order
/// plus the fully folded accumulator as `(ranges, chunks)`.
type OverlappedRound = (Vec<Update>, Vec<(usize, usize)>, Vec<Vec<f32>>);

/// Server-side quantized-downlink state (`--downlink-bits` in 1..=16).
///
/// The server keeps its *true* parameters for aggregation, evaluation
/// and `params_hash`, and separately this **replica** — the vector
/// every in-sync receiver holds, advanced once per round by replaying
/// the encoded delta through [`codec::apply_downlink`] (never by
/// analytic `x - residual'` arithmetic: f32 addition is not
/// associative, replaying the wire is the only advance that keeps the
/// server and every worker bit-identical).  Clients train on the
/// replica; their updates fold onto the true parameters.  The delta
/// chain advances every round whether or not anyone receives it, so
/// the replica stream is a pure function of the seed.
struct Downlink {
    /// The shared receiver replica (empty until the first round
    /// initializes it from the parameters — that round broadcasts
    /// full fp32 to everyone).
    replica: Vec<f32>,
    /// Server-side error-feedback residual: what the last delta's
    /// quantization dropped, folded into the next delta.
    residual: Vec<f32>,
    /// Stochastic-rounding stream for the delta encoder (seed-pure;
    /// one draw per round).
    rng: Rng,
    /// Last round each leaf id was sent (full or delta).  A leaf is
    /// in-sync for round `m` iff its entry reads `m - 1`; the map is
    /// updated for every dispatched leaf each round, so it is a pure
    /// function of the seed-pure dispatch stream.
    last: BTreeMap<u32, u32>,
}

/// One banked late update (semi-sync staleness): the update itself plus
/// the round its discounted fold is due.
struct BankedUpdate {
    /// Round the fold happens in (`answered round + staleness`).
    due: u32,
    /// The late client's update, still encoded (decode is pure, so
    /// deferring it to the fold round changes nothing).
    update: Update,
}

/// Events of the fold-overlap receive loop: a finished decode or a
/// shard's finished per-client prefix fold.  Errors (including panic
/// payload messages) travel in-band so the orchestrator can fail fast.
enum OverlapEv {
    /// `pos` is the client's position in sorted-id fold order.
    Decoded(usize, DecodeReply),
    /// Shard index plus its chunk buffer back for the next fold.
    Folded(usize, std::result::Result<Vec<f32>, String>),
}

/// What a pipelined decode task replies with: the update plus its
/// decoded row, or a task-level error message (decode failure or panic
/// payload) — shared by both the plain pipeline and the overlap path.
type DecodeReply = std::result::Result<(Update, codec::DecodedUpdate), String>;

/// Run one update's decode inside a pool task, containing panics: the
/// body of every pipelined decode closure.
fn decode_task(
    model: &ModelRuntime,
    u: Update,
    mut buf: codec::DecodedUpdate,
    mode: CodecMode,
) -> DecodeReply {
    let cid = u.client_id;
    let out = catch_unwind(AssertUnwindSafe(move || {
        let res = codec::decode_update_into_mode(&model.mm, &u, &mut buf, mode)
            .map_err(|e| format!("decoding update from client {cid}: {e:#}"));
        (u, buf, res)
    }));
    match out {
        Ok((u, buf, Ok(()))) => Ok((u, buf)),
        Ok((_, _, Err(m))) => Err(m),
        Err(p) => Err(format!("decode task panicked: {}", pool::panic_message(&*p))),
    }
}

/// Bookkeeping for one fold-overlap round (see
/// [`Server::recv_fold_overlapped`]).
struct OverlapState<'a> {
    tasks: &'a TaskSender,
    tx: &'a Sender<OverlapEv>,
    model: &'a Arc<ModelRuntime>,
    /// Aggregation weight per sorted client position.
    weights: &'a [f32],
    /// Accumulator chunk range per shard.
    ranges: &'a [(usize, usize)],
    /// Decoded rows by sorted position (None = not yet decoded or
    /// already fully folded and recycled).
    bufs: Vec<Option<Arc<codec::DecodedUpdate>>>,
    /// Updates by sorted position.
    updates: Vec<Option<Update>>,
    decoded: Vec<bool>,
    /// Leading run of decoded clients — the fold-eligible prefix.
    decoded_prefix: usize,
    /// Shards that have folded each client (recycle at == ranges.len()).
    folds_done: Vec<usize>,
    /// Next client each shard will fold.
    shard_next: Vec<usize>,
    /// Each shard's chunk buffer when idle (None = fold in flight).
    shard_chunk: Vec<Option<Vec<f32>>>,
    /// Recycled decode buffers.
    free: Vec<codec::DecodedUpdate>,
    /// Buffers allocated so far (the bound's ledger).
    allocated: usize,
}

impl OverlapState<'_> {
    fn n(&self) -> usize {
        self.bufs.len()
    }

    /// Every shard folded every client and returned its chunk.
    fn complete(&self) -> bool {
        let n = self.n();
        self.shard_next.iter().all(|&x| x == n)
            && self.shard_chunk.iter().all(Option::is_some)
    }

    /// Absorb one completion event, then dispatch any newly eligible
    /// per-shard prefix folds.
    fn process(&mut self, ev: OverlapEv) -> Result<()> {
        match ev {
            OverlapEv::Decoded(pos, out) => {
                let (u, b) = out.map_err(|m| anyhow!("{m}"))?;
                self.updates[pos] = Some(u);
                self.bufs[pos] = Some(Arc::new(b));
                self.decoded[pos] = true;
                while self.decoded_prefix < self.decoded.len()
                    && self.decoded[self.decoded_prefix]
                {
                    self.decoded_prefix += 1;
                }
            }
            OverlapEv::Folded(s, out) => {
                let chunk = out.map_err(|m| anyhow!("shard {s} fold failed: {m}"))?;
                let p = self.shard_next[s];
                self.shard_next[s] = p + 1;
                self.shard_chunk[s] = Some(chunk);
                self.folds_done[p] += 1;
                if self.folds_done[p] == self.ranges.len() {
                    // Every shard folded client p: recycle its buffer.
                    // Each fold task drops its Arc clone before
                    // replying, so unwrapping succeeds; if a clone ever
                    // straggled, give the cap a replacement allowance
                    // instead of deadlocking the acquire loop.
                    if let Some(arc) = self.bufs[p].take() {
                        match Arc::try_unwrap(arc) {
                            Ok(buf) => self.free.push(buf),
                            Err(_) => self.allocated = self.allocated.saturating_sub(1),
                        }
                    }
                }
            }
        }
        self.dispatch_folds()
    }

    /// For every idle shard whose next client (in sorted order) is
    /// decoded, launch its fold on the pool's priority lane.  At most
    /// one fold per shard is ever in flight, which serializes each
    /// shard's folds in sorted client order — the determinism argument.
    fn dispatch_folds(&mut self) -> Result<()> {
        for s in 0..self.shard_next.len() {
            let p = self.shard_next[s];
            if p >= self.decoded_prefix {
                continue;
            }
            let Some(mut chunk) = self.shard_chunk[s].take() else {
                continue;
            };
            let (clo, chi) = self.ranges[s];
            let dec = Arc::clone(self.bufs[p].as_ref().expect("prefix client decoded"));
            let w = self.weights[p];
            let zero = p == 0;
            let model = Arc::clone(self.model);
            let tx = self.tx.clone();
            self.tasks.send(Task::Exec(Box::new(move || {
                let out = catch_unwind(AssertUnwindSafe(move || {
                    if zero {
                        chunk.clear();
                        chunk.resize(chi - clo, 0.0);
                    }
                    codec::fold_range(&model.mm, &dec, w, clo, chi, &mut chunk);
                    // Drop the Arc clone *before* replying so the
                    // orchestrator can recycle the decode buffer.
                    drop(dec);
                    chunk
                }))
                .map_err(|p| pool::panic_message(&*p));
                let _ = tx.send(OverlapEv::Folded(s, out));
            })))?;
        }
        Ok(())
    }
}

/// The federated server: owns the global model and the round loop.
pub struct Server {
    /// The model runtime shared with workers and handles.
    pub model: Arc<ModelRuntime>,
    params: Arc<[f32]>,
    test: Arc<data::Dataset>,
    opts: ServerOpts,
    initial_loss: Option<f32>,
    prev_loss: Option<f32>,
    cum_uplink_bits: u64,
    /// Cumulative broadcast (downlink) bits by the analytic per-leaf
    /// ledger — what each dispatched leaf would cost sent directly,
    /// independent of fanout/topology, so reports stay bit-identical
    /// across them.  0 when `downlink_bits` is 0.
    cum_downlink_bits: u64,
    /// Quantized-downlink state, `Some` iff `downlink_bits` in 1..=16.
    down: Option<Downlink>,
    /// Closed-loop uplink budget allocator, `Some` iff `bit_budget > 0`.
    budget_ctl: Option<BitBudgetController>,
    /// Per-client resident state (sample counts, latency EWMAs, the
    /// uplink/downlink byte ledger) in one flat arena keyed by id —
    /// replacing the scattered `samples_by_id`/`ewma`/per-handle byte
    /// maps, 24 bytes per client.  Learned from
    /// handles (in-process) or from received updates / partial metadata
    /// (TCP, available from round 1) — the fold-overlap path needs
    /// aggregation weights before updates land.  Rows accumulate across
    /// sampled cohorts: a client absent this round keeps its row for
    /// the next round it joins.  Shared with the scheduler
    /// ([`Self::arena`]), which stores its dispatch EWMAs in the same
    /// rows.
    arena: Arc<Mutex<ClientArena>>,
    /// Leaf cohort to embed in the next broadcast (tree topology): the
    /// serve driver sets it so aggregators can relay the round to
    /// exactly their span's selected members.  Consumed per round.
    cohort_hint: Option<Vec<u32>>,
    /// Leaves the scheduler expects to answer late (semi-sync banking),
    /// embedded in the next broadcast so aggregators relay the round to
    /// them but forward their replies upstream *raw* instead of folding
    /// them.  Consumed per round; `None` keeps the frame legacy-shaped.
    late_hint: Option<Vec<u32>>,
    /// Tree rounds only: `(on_time, late)` *leaf* counts of the round's
    /// cohort, set by the serve driver so quorum and the failed count
    /// are judged over leaves, never subtree composites.  Consumed per
    /// round; `None` falls back to handle-granularity (flat topology and
    /// the in-process engine, where every handle already is a leaf).
    tree_leaf_cohort: Option<(usize, usize)>,
    /// Observed per-client round compute times of the last round
    /// (seconds, as measured by each client's own worker —
    /// [`ClientHandle::last_round_secs`]).  Feeds the scheduler's EWMA
    /// for slowest-first dispatch; handles that cannot observe compute
    /// time (TCP) simply contribute nothing.
    arrivals: Vec<(u32, f64)>,
    /// Semi-sync staleness bank: late updates keyed by `(round, client
    /// id)` — the round they *answer* — each carrying the round its
    /// fold is due.  A BTreeMap so harvesting iterates in exactly the
    /// `(round, client id)` fold order the determinism contract
    /// requires.  Empty in strict mode.
    banked: BTreeMap<(u32, u32), BankedUpdate>,
    // round-persistent scratch (allocation-free steady state)
    dec: codec::DecodedUpdate,
    acc: Vec<f32>,
    /// Free decode buffers for the recv/decode pipeline (recycled round
    /// over round; retention capped by `decode_buffers`).
    dec_pool: Vec<codec::DecodedUpdate>,
    /// Per-shard chunk accumulators for the sharded fold.
    chunks: Vec<Vec<f32>>,
}

impl Server {
    /// Server over `model` with seed-initialized global parameters.
    pub fn new(
        model: Arc<ModelRuntime>,
        test: Arc<data::Dataset>,
        seed: u32,
        opts: ServerOpts,
    ) -> Result<Self> {
        let params: Arc<[f32]> = model.init(seed)?.into();
        let budget = opts.round.budget;
        ensure!(
            budget.bit_budget == 0 || budget.bit_budget >= model.mm.d as u64,
            "--bit-budget {} is below the 1-bit/element floor for one client of model {} (d = {})",
            budget.bit_budget,
            model.mm.name,
            model.mm.d
        );
        let down = if (1..=16).contains(&budget.downlink_bits) {
            Some(Downlink {
                replica: Vec::new(),
                residual: vec![0.0; model.mm.d],
                rng: Rng::new(seed as u64).derive("server.downlink"),
                last: BTreeMap::new(),
            })
        } else {
            None
        };
        let budget_ctl = if budget.bit_budget > 0 {
            let sizes = model.mm.segment_sizes().iter().map(|&s| s as u64).collect();
            Some(BitBudgetController::new(budget.bit_budget, sizes))
        } else {
            None
        };
        Ok(Server {
            model,
            params,
            test,
            opts,
            initial_loss: None,
            prev_loss: None,
            cum_uplink_bits: 0,
            cum_downlink_bits: 0,
            down,
            budget_ctl,
            arena: Arc::new(Mutex::new(ClientArena::new())),
            cohort_hint: None,
            late_hint: None,
            tree_leaf_cohort: None,
            arrivals: Vec::new(),
            banked: BTreeMap::new(),
            dec: codec::DecodedUpdate::new(),
            acc: Vec::new(),
            dec_pool: Vec::new(),
            chunks: Vec::new(),
        })
    }

    /// The current global parameter vector.
    pub fn params(&self) -> &[f32] {
        &self.params
    }

    /// FNV-1a hash over the exact parameter bits (determinism checks).
    pub fn params_hash(&self) -> u64 {
        hash_f32_bits(&self.params)
    }

    /// Observed per-client round compute times of the last round
    /// (id, seconds) — the raw material for the scheduler's
    /// slowest-first EWMA ([`super::sched::RoundScheduler::observe`]).
    pub fn arrivals(&self) -> &[(u32, f64)] {
        &self.arrivals
    }

    /// The shared per-client state arena.  The scheduler reads and
    /// writes the same rows (dispatch EWMAs), so one allocation serves
    /// both sides — construct the scheduler with
    /// [`super::sched::RoundScheduler::from_config_with_arena`].
    pub fn arena(&self) -> Arc<Mutex<ClientArena>> {
        Arc::clone(&self.arena)
    }

    /// Set the leaf cohort the next broadcast carries (tree topology):
    /// aggregators intersect it with their span to relay the round to
    /// exactly the selected members.  Consumed by the next
    /// [`Self::run_round`]; flat-topology callers never set it and the
    /// broadcast frame stays byte-identical to the historical one.
    pub fn set_cohort_hint(&mut self, cohort: Option<Vec<u32>>) {
        self.cohort_hint = cohort;
    }

    /// Set the late-leaf plan the next broadcast carries (tree
    /// topology + semi-sync): aggregators relay the round to these
    /// leaves too, but forward their updates upstream raw so the root
    /// banks exactly what the in-process engine banks.  Consumed by the
    /// next [`Self::run_round`].
    pub fn set_late_hint(&mut self, late: Option<Vec<u32>>) {
        self.late_hint = late;
    }

    /// Declare the `(on_time, late)` *leaf* counts of the next tree
    /// round's cohort, so quorum (and the failed count) are judged over
    /// leaves rather than the root's composite handles.  Consumed by
    /// the next [`Self::run_round`].
    pub fn set_tree_leaf_cohort(&mut self, counts: Option<(usize, usize)>) {
        self.tree_leaf_cohort = counts;
    }

    /// Mutable view of the parameters.  Zero-copy when the server holds
    /// the only reference (the steady state: all per-round broadcast
    /// clones are dropped by aggregation time); falls back to
    /// copy-on-write otherwise.
    fn params_mut(&mut self) -> &mut [f32] {
        if Arc::get_mut(&mut self.params).is_none() {
            self.params = self.params.to_vec().into();
        }
        Arc::get_mut(&mut self.params).expect("unique after copy-on-write")
    }

    /// Aggregation weights in sorted-id order when every client's
    /// sample count is already known (and positive in total) — the
    /// precondition for fold overlap.
    fn fold_plan(&self, clients: &[Box<dyn ClientHandle + '_>]) -> Option<Vec<f32>> {
        let mut ids: Vec<u32> = clients.iter().map(|c| c.id()).collect();
        ids.sort_unstable();
        let mut counts = Vec::with_capacity(ids.len());
        let mut total: u64 = 0;
        let arena = self.arena.lock().expect("arena poisoned");
        for id in &ids {
            let s = arena.samples(*id)?;
            counts.push(s);
            total += s as u64;
        }
        if total == 0 {
            return None;
        }
        // Exactly the non-overlap path's arithmetic: u32 -> f32 over
        // u64 -> f32, so weights are bit-identical across paths.
        Some(counts.iter().map(|&s| s as f32 / total as f32).collect())
    }

    /// Drive one round across `clients` — the round's *cohort*, which
    /// may be any non-empty subset of the manifest's registry when the
    /// scheduler samples partial participation ([`super::sched`]).
    /// Aggregation weights, loss averaging, telemetry means and the
    /// `uplink_bits` ledger all range over exactly this cohort; clients
    /// not in the slice are untouched (their states, residuals and
    /// quantizer streams stay where they were).  Returns the round
    /// record; the caller fills in the plan-side fields (`dropped`,
    /// `sim_makespan_secs`, and the simulated share of `failed`).
    ///
    /// With the policy's quorum below 1.0 or a round timeout configured
    /// ([`RoundPolicy::is_tolerant`]), per-client send/recv failures no
    /// longer abort the round: the failing clients land in the record's
    /// `failed` count, and the round completes once `max(ceil(quorum *
    /// n), 1)` **on-time** updates arrived — aggregation weights, loss
    /// averaging and telemetry means renormalize over the fold set.  At
    /// quorum 1.0 with no timeout and no staleness, the strict
    /// historical behavior (and its fast receive paths) is preserved
    /// exactly.
    ///
    /// `late` is the scheduler's semi-sync plan for this round: members
    /// whose update answers `round` but is *banked* to fold at a later
    /// `due` round with a staleness discount (empty in strict mode and
    /// for plain callers).  Independently, banked updates whose due
    /// round is this one are harvested into this round's fold: each
    /// contributes discounted sample mass `n_samples / (1 + s)` (s =
    /// rounds late), renormalized over the whole fold set, applied in
    /// `(round, client id)` order — never arrival order — which keeps
    /// semi-sync runs bit-identical across thread counts and
    /// topologies.  Harvested folds are the record's `stale_folded`;
    /// updates staler than the policy bound are dropped and counted in
    /// `stale_dropped`.
    pub fn run_round(
        &mut self,
        round: u32,
        clients: &mut [Box<dyn ClientHandle + '_>],
        late: &[(u32, u32)],
        evaluate: bool,
    ) -> Result<RoundRecord> {
        let t0 = Instant::now();
        let n = clients.len();
        ensure!(
            n >= 1 && n <= self.model.mm.n_clients,
            "cohort of {n} clients outside 1..={} (manifest registry)",
            self.model.mm.n_clients
        );
        self.arrivals.clear();

        // Handles that know their dataset size up front seed the
        // fold-overlap weight plan before any update arrives (flat
        // topology; the tree path learns leaf counts from partial
        // metadata instead, and an aggregate handle's id would collide
        // with its subtree root's leaf row).
        let fanout = self.opts.round.topology.fanout;
        if fanout == 0 {
            let mut arena = self.arena.lock().expect("arena poisoned");
            for c in clients.iter() {
                if let Some(s) = c.num_samples() {
                    arena.set_samples(c.id(), s);
                }
            }
        }

        // Broadcast the global model (+ loss trajectory for AdaQuantFL):
        // one Arc clone per client, one encode per round.
        let losses = match (self.initial_loss, self.prev_loss) {
            (Some(f0), Some(fm)) => Some((f0, fm)),
            _ => None,
        };
        let cohort_ids = self.cohort_hint.take();
        let late_ids = self.late_hint.take();

        // The round's dispatched *leaves*, sorted: the cohort hint plus
        // the late plan on tree rounds (the composite handles in
        // `clients` span them), the non-aggregate handles otherwise.
        // Budget allocation and the downlink ledger/sync map range over
        // exactly this seed-pure set, never over transport outcomes —
        // the determinism contract's requirement.
        let dispatched: Vec<u32> = match &cohort_ids {
            Some(cohort) => {
                let mut ids: Vec<u32> = cohort
                    .iter()
                    .chain(late_ids.iter().flatten())
                    .copied()
                    .collect();
                ids.sort_unstable();
                ids.dedup();
                ids
            }
            None => {
                let mut ids: Vec<u32> = clients
                    .iter()
                    .filter(|c| !c.is_aggregate())
                    .map(|c| c.id())
                    .collect();
                ids.sort_unstable();
                ids
            }
        };

        // Closed-loop uplink budget: allocate this round's per-client
        // per-segment widths from the seeded outcome flags and the
        // controller's own allocation ledger (both bit-identical across
        // threads and topologies).
        let budgets: Option<Vec<(u32, Vec<u8>)>> = self.budget_ctl.as_mut().map(|ctl| {
            let cohort: Vec<(u32, bool)> = {
                let arena = self.arena.lock().expect("arena poisoned");
                dispatched.iter().map(|&id| (id, arena.is_flagged(id))).collect()
            };
            ctl.plan(&cohort)
        });

        // Quantized downlink: advance the delta chain (every round,
        // received or not — the replica stream must be a pure function
        // of the seed), charge the analytic per-leaf ledger against the
        // *old* sync map, then mark every dispatched leaf current.
        let down_bits_cfg = self.opts.round.budget.downlink_bits;
        let mm_d = self.model.mm.d as u64;
        let full_bcast_bits = mm_d * 32;
        let mut delta_msg: Option<messages::DownlinkDelta> = None;
        let mut init_round = false;
        if let Some(down) = self.down.as_mut() {
            if down.replica.is_empty() {
                init_round = true;
                down.replica = self.params.to_vec();
            } else {
                let seed = down.rng.next_u32();
                let dl = codec::encode_downlink(
                    &self.model.mm,
                    down_bits_cfg,
                    &self.params,
                    &down.replica,
                    &mut down.residual,
                    seed,
                )?;
                codec::apply_downlink(&self.model.mm, &dl, &mut down.replica)?;
                delta_msg = Some(dl);
            }
        }
        // Which dispatched leaves are in sync — judged against the map
        // *before* this round's update, per leaf, fanout-blind: the
        // ledger below and the per-handle routing both consult this
        // set, but the ledger never looks at handle grouping, so the
        // reported bits are identical across topologies.
        let synced: std::collections::BTreeSet<u32> = match &self.down {
            Some(down) if !init_round && round > 0 => dispatched
                .iter()
                .copied()
                .filter(|id| down.last.get(id) == Some(&(round - 1)))
                .collect(),
            _ => Default::default(),
        };
        let downlink_bits: u64 = match (&self.down, down_bits_cfg) {
            (None, 32) => dispatched.len() as u64 * full_bcast_bits,
            (None, _) => 0,
            (Some(_), _) => {
                let delta_bits = delta_msg.as_ref().map(|dl| {
                    dl.payload.len() as u64 * 8
                        + dl.segments.len() as u64 * math::SEGMENT_HEADER_BITS
                });
                dispatched
                    .iter()
                    .map(|&id| match (synced.contains(&id), delta_bits) {
                        (true, Some(b)) => b,
                        _ => full_bcast_bits,
                    })
                    .sum()
            }
        };
        self.cum_downlink_bits += downlink_bits;
        if let Some(down) = self.down.as_mut() {
            for &id in &dispatched {
                down.last.insert(id, round);
            }
        }

        // Clients train on the replica when the downlink is quantized
        // (full and delta both land receivers exactly on it), on the
        // true parameters otherwise.
        let bcast_params: Arc<[f32]> = match &self.down {
            Some(down) => down.replica.clone().into(),
            None => Arc::clone(&self.params),
        };
        let bcast = Message::Broadcast {
            round,
            params: bcast_params,
            losses,
            cohort: cohort_ids.clone(),
            late: late_ids.clone(),
            downlink: None,
            budgets: budgets.clone(),
        };
        let bcast_delta = delta_msg.map(|dl| Message::Broadcast {
            round,
            // Delta-base convention: the receiver advances its own
            // replica, so the full vector stays off this wire.
            params: Vec::new().into(),
            losses,
            cohort: cohort_ids.clone(),
            late: late_ids,
            downlink: Some(dl),
            budgets,
        });
        // Strict mode (full quorum, no timeout, no staleness) keeps the
        // historical any-failure-aborts semantics and the
        // pipelined/overlap fast paths; tolerant mode trades them for
        // per-client failure containment.
        let tolerant = self.opts.round.is_tolerant();
        let mut failed: Vec<u32> = Vec::new();
        let encoded = bcast.encode();
        let encoded_delta = bcast_delta.as_ref().map(Message::encode);
        for c in clients.iter_mut() {
            // Routing: only remote handles may take the delta (they
            // keep their own replica), and only when in sync — every
            // dispatched leaf of the handle's span got round m-1.
            // In-process handles share the replica Arc at full fidelity
            // for free, so quantizing their "wire" would only add
            // noise the ledger already accounts analytically.
            let use_delta = bcast_delta.is_some()
                && c.is_remote()
                && if c.is_aggregate() {
                    // A subtree relays the broadcast verbatim, so the
                    // delta is only safe when every dispatched leaf in
                    // its span can apply it.
                    let f = fanout.max(1);
                    let span = c.id()..c.id().saturating_add(f);
                    let mut any = false;
                    let all = dispatched
                        .iter()
                        .filter(|l| span.contains(l))
                        .all(|l| {
                            any = true;
                            synced.contains(l)
                        });
                    any && all
                } else {
                    synced.contains(&c.id())
                };
            let (msg, enc) = if use_delta {
                (
                    bcast_delta.as_ref().expect("checked above"),
                    encoded_delta.as_ref().expect("checked above").as_slice(),
                )
            } else {
                (&bcast, encoded.as_slice())
            };
            match c.send_broadcast(msg, enc) {
                Ok(()) => {}
                Err(e) if tolerant => {
                    crate::warn_!("server", "round {round}: broadcast to client {} failed: {e:#}", c.id());
                    failed.push(c.id());
                }
                Err(e) => return Err(e),
            }
        }
        // `bcast` is dropped now so the params Arc is unique again by
        // aggregation time; the *encoded* bytes stay alive through the
        // receive window — a composite handle that dies mid-round and
        // is revived from the rejoin map gets this round's broadcast
        // re-sent over the new transport ([`ClientHandle::retry_revive`]).
        drop(bcast);
        drop(bcast_delta);

        // Collect updates (blocking per client; pool clients overlap).
        // With a pool attached and the streaming fold selected, each
        // update's decode is dispatched to the priority lane as it
        // lands; with fold overlap additionally eligible, the sharded
        // fold itself runs inside this window (prefix folds).
        let t_recv = Instant::now();
        // Tree rounds take the plain serial (or tolerant) receive: the
        // pipelined/overlap fast paths key their bookkeeping by leaf
        // client id, which the grouping below replaces with subtree
        // roots.  Cohorts are tiny relative to the flat million-client
        // case (that is the point of the tree), so nothing is lost.
        let pipelined = !tolerant
            && fanout == 0
            && self.opts.tasks.is_some()
            && self.opts.aggregate == AggregateMode::Streaming;
        let overlap_plan = if pipelined && self.opts.round.pipeline.fold_overlap {
            self.fold_plan(clients)
        } else {
            None
        };
        let mut stale_dropped: u32 = 0;
        let mut subtree_failed: u32 = 0;
        let mut fold_ready: Option<(Vec<(usize, usize)>, Vec<Vec<f32>>)> = None;
        // Arrivals are partitioned by *handle kind* — composite partials
        // from aggregate handles vs raw leaf updates (flat handles,
        // in-process leaves, degraded direct-to-root leaves) — so the
        // partition, never the update's id, decides pseudo vs raw and a
        // subtree root's id cannot shadow its own leaf.
        let (agg_updates, leaf_updates, decoded) = if tolerant {
            let (agg, leaf) = self.recv_tolerant(
                round,
                clients,
                &mut failed,
                late,
                &mut stale_dropped,
                cohort_ids.as_deref(),
                &encoded,
                &mut subtree_failed,
            );
            (agg, leaf, Vec::new())
        } else if let Some(weights) = overlap_plan {
            let (ups, ranges, chunks) = self.recv_fold_overlapped(round, clients, &weights)?;
            fold_ready = Some((ranges, chunks));
            (Vec::new(), ups, Vec::new())
        } else if pipelined {
            let (ups, dec) = self.recv_decode_pipelined(round, clients)?;
            (Vec::new(), ups, dec)
        } else {
            let mut agg: Vec<Update> = Vec::new();
            let mut leaf: Vec<Update> = Vec::with_capacity(n);
            for c in clients.iter_mut() {
                let u = c.recv_update()?;
                ensure!(
                    u.round == round,
                    "client {} answered round {} for {round}",
                    c.id(),
                    u.round
                );
                if c.is_aggregate() {
                    agg.push(u);
                } else {
                    leaf.push(u);
                }
            }
            agg.sort_by_key(|u| u.client_id);
            leaf.sort_by_key(|u| u.client_id);
            (agg, leaf, Vec::new())
        };
        drop(encoded);
        let recv_decode_secs = t_recv.elapsed().as_secs_f64();

        // A real (socket-level) failure means the leaves never took
        // this round's broadcast after all: drop their sync entries so
        // the next round sends them full.  `failed` holds composite
        // ids on tree rounds, so clear the whole span.  Empty in
        // deterministic runs — the ledger above never sees this.
        if let Some(down) = self.down.as_mut() {
            let width = if fanout > 0 { fanout } else { 1 };
            for &id in &failed {
                for l in id..id.saturating_add(width) {
                    down.last.remove(&l);
                }
            }
        }

        // Harvest banked late updates whose fold is due this round:
        // `(staleness, update)` pairs in `(round, client id)` order
        // (the BTreeMap key order — the fold-determinism requirement).
        let mut stale: Vec<(u32, Update)> = Vec::new();
        if !self.banked.is_empty() {
            let due: Vec<(u32, u32)> = self
                .banked
                .iter()
                .filter(|(_, b)| b.due <= round)
                .map(|(&k, _)| k)
                .collect();
            let k_bound = self.opts.round.tolerance.staleness;
            for key in due {
                let b = self.banked.remove(&key).expect("key just listed");
                let s = round - b.update.round;
                if s >= 1 && s <= k_bound {
                    stale.push((s, b.update));
                } else {
                    // Defensive: a bank entry that slipped past the
                    // bound (cannot happen through the normal banking
                    // paths) is dropped, visibly.
                    stale_dropped += 1;
                }
            }
        }

        // Collect the cohort's observed round compute times (measured
        // by each client's own worker, so free of receive-queue skew)
        // for the scheduler's slowest-first EWMA.
        for c in clients.iter() {
            if let Some(s) = c.last_round_secs() {
                self.arrivals.push((c.id(), s));
            }
        }

        // Tree topology: every stage below consumes one pseudo-update
        // per subtree, keyed by the subtree root id.  Over TCP the
        // aggregate handles already delivered composite pseudo-updates
        // (harvest their partial metadata); any *raw* leaf updates —
        // the whole cohort in-process, or degraded direct-to-root
        // leaves over TCP — go through the identical
        // `codec::fold_partial` grouping virtually.  The grouping
        // defines the canonical fold order, so the two paths produce
        // bit-identical accumulators, records and `params_hash`
        // (ARCHITECTURE.md).
        let mut partial_metas: Vec<messages::PartialMeta> = Vec::new();
        let updates = if fanout == 0 {
            leaf_updates
        } else {
            let mut pseudo = agg_updates;
            for c in clients.iter_mut() {
                if let Some(m) = c.take_partial_meta() {
                    partial_metas.push(m);
                }
            }
            let mode = self.opts.round.pipeline.codec;
            let mut i = 0usize;
            while i < leaf_updates.len() {
                let root = leaf_updates[i].client_id / fanout * fanout;
                let mut j = i + 1;
                while j < leaf_updates.len() && leaf_updates[j].client_id / fanout * fanout == root
                {
                    j += 1;
                }
                let p =
                    codec::fold_partial(&self.model.mm, round, root, &leaf_updates[i..j], mode, 1)?;
                partial_metas.push(p.meta());
                pseudo.push(codec::partial_to_update(&self.model.mm, &p)?);
                i = j;
            }
            partial_metas.sort_by_key(|m| m.agg_id);
            pseudo.sort_by_key(|u| u.client_id);
            pseudo
        };

        // The quorum floor is *leaf-granular*: tree rounds count the
        // leaves carried in the partial metadata — never the composite
        // handles — against the leaf cohort the serve driver declared,
        // so a tree round meets (or misses) quorum exactly when the
        // same flat round would.  Flat rounds range over the dispatched
        // slice as before: at 1.0 the floor equals n (strict mode
        // already propagated any failure), below it the round completes
        // on the survivors.  Only *on-time* updates count toward quorum
        // — harvested stale folds are a bonus on top, never a
        // substitute for a live round.
        let tree_leaves = self.tree_leaf_cohort.take();
        let n_recv: usize = if fanout > 0 {
            partial_metas.iter().map(|m| m.members.len()).sum()
        } else {
            updates.len()
        };
        let n_quorum = tree_leaves.map_or(n, |(on_time, late_n)| on_time + late_n);
        let quorum_need = tolerance::quorum_floor(self.opts.round.tolerance.quorum, n_quorum);
        ensure!(
            n_recv >= quorum_need,
            "round {round}: quorum not met — {n_recv} of {n_quorum} updates arrived \
             (need {quorum_need}; failed clients: {failed:?})"
        );

        let total_samples: u64 = updates.iter().map(|u| u.num_samples as u64).sum();
        ensure!(total_samples > 0, "no samples reported");
        // Remember the counts so TCP cohorts become fold-overlap
        // eligible from the next round on; tree rounds record the
        // *leaf* counts carried in the partial metadata, never the
        // pseudo-update's subtree totals.
        {
            let mut arena = self.arena.lock().expect("arena poisoned");
            if fanout > 0 {
                for m in &partial_metas {
                    for (&id, &s) in m.members.iter().zip(&m.samples) {
                        arena.set_samples(id, s);
                    }
                }
            } else {
                for u in updates.iter().chain(stale.iter().map(|(_, u)| u)) {
                    arena.set_samples(u.client_id, u.num_samples);
                }
            }
        }

        // Decode + aggregate, then apply (Eq. 4).  Under fold overlap
        // the folds already happened inside the receive window; only
        // the chunk application remains here.  A round with harvested
        // stale folds takes the dedicated discounted-weight path; a
        // stale-free round keeps the exact historical arithmetic, so
        // staleness-0 runs stay bit-for-bit identical.
        let t_agg = Instant::now();
        if !stale.is_empty() {
            self.aggregate_with_stale(&updates, &stale)?;
        } else if let Some((ranges, chunks)) = fold_ready {
            self.apply_chunks(&ranges, &chunks);
            self.chunks = chunks;
        } else if pipelined {
            self.aggregate_decoded(&updates, decoded, total_samples)?;
        } else {
            match self.opts.aggregate {
                AggregateMode::Streaming => self.aggregate_streaming(&updates, total_samples)?,
                AggregateMode::Fused => self.aggregate_fused(&updates, total_samples)?,
            }
        }
        let agg_secs = t_agg.elapsed().as_secs_f64();

        // Loss bookkeeping for loss-driven policies: sample-mass
        // weighted, with stale folds contributing their discounted mass
        // (the same renormalized weights the aggregate used).
        let train_loss = if stale.is_empty() {
            updates
                .iter()
                .map(|u| u.train_loss as f64 * u.num_samples as f64 / total_samples as f64)
                .sum::<f64>()
        } else {
            let denom = discounted_denom(&updates, &stale);
            (stale
                .iter()
                .map(|(s, u)| u.train_loss as f64 * discounted_mass(u, *s))
                .sum::<f64>()
                + updates
                    .iter()
                    .map(|u| u.train_loss as f64 * u.num_samples as f64)
                    .sum::<f64>())
                / denom
        } as f32;
        if self.initial_loss.is_none() {
            self.initial_loss = Some(train_loss);
        }
        self.prev_loss = Some(train_loss);

        // Communication accounting: the paper counts uplink payloads.
        // A banked update's bits are charged to the round it *folds*
        // in (its simulated arrival), so strict and semi-sync runs
        // agree on the cumulative ledger once every bank drains.
        let mm = &self.model.mm;
        // Tree rounds charge the *leaf* wire bits carried in the
        // partial telemetry — the paper's volume metric counts client
        // uplinks, and a pseudo-update's fp32 frame is a topology
        // artifact, not client traffic.
        let uplink_bits: u64 = if fanout > 0 {
            // Leaf wire bits from the partial telemetry, plus harvested
            // banked updates charged at their fold round — the same
            // rule as flat, and the banked raws are identical objects
            // on both tree paths (aggregators forward late replies
            // upstream raw instead of folding them).
            partial_metas.iter().map(|m| m.wire_bits).sum::<u64>()
                + stale
                    .iter()
                    .map(|(_, u)| codec::update_wire_bits(mm, u))
                    .sum::<u64>()
        } else {
            updates
                .iter()
                .chain(stale.iter().map(|(_, u)| u))
                .map(|u| codec::update_wire_bits(mm, u))
                .sum()
        };
        self.cum_uplink_bits += uplink_bits;

        // Telemetry: mean bits/element and ranges (Figs. 1b, 5),
        // unweighted means over the whole fold set (on-time + stale).
        // Tree rounds mean over the pseudo-updates (32-bit headers,
        // zero telemetry range) — identical on both tree paths.
        let n_fold = updates.len() + stale.len();
        let l = mm.num_segments();
        let seg_sizes = mm.segment_sizes();
        let mut mean_bits_acc = 0.0f64;
        let mut mean_range_acc = 0.0f64;
        let mut seg_ranges = vec![0.0f32; l];
        for u in updates.iter().chain(stale.iter().map(|(_, u)| u)) {
            let bits_elem: u64 = u
                .segments
                .iter()
                .zip(&seg_sizes)
                .map(|(h, &sz)| h.bits as u64 * sz as u64)
                .sum();
            mean_bits_acc += bits_elem as f64 / mm.d as f64;
            let ranges: Vec<f32> = u.segments.iter().map(|h| h.range()).collect();
            mean_range_acc += stats::mean(&ranges.iter().map(|&x| x as f64).collect::<Vec<_>>());
            for (sr, r) in seg_ranges.iter_mut().zip(&ranges) {
                *sr += r / n_fold as f32;
            }
        }

        // Periodic server-side validation.
        let t_eval = Instant::now();
        let (test_loss, test_accuracy) = if evaluate {
            self.evaluate()?
        } else {
            (f32::NAN, f32::NAN)
        };
        let eval_secs = if evaluate { t_eval.elapsed().as_secs_f64() } else { 0.0 };

        // Tree depth this round: number of fold tiers above the leaves
        // (0 = flat, 2 = leaf -> aggregator -> server).  Identical on
        // the wire and virtual paths by construction.
        let agg_depth = if fanout > 0 {
            partial_metas.iter().map(|m| m.depth).max().unwrap_or(0) + 1
        } else {
            0
        };
        // Fold each handle's wire-volume deltas into the arena rows:
        // the per-client byte ledger lives with the rest of the client
        // state, so the root keeps no per-handle O(n) side maps.  A
        // composite handle's socket carries a whole span's traffic, not
        // one client's, so aggregate handles are drained but skipped
        // (their leaves' uplink volume is already accounted via the
        // partial telemetry).
        let client_state_bytes = {
            let mut arena = self.arena.lock().expect("arena poisoned");
            for c in clients.iter_mut() {
                let (up, down) = c.take_io_bytes();
                if !c.is_aggregate() {
                    arena.add_io_bytes(c.id(), up, down);
                }
            }
            arena.resident_bytes()
        };

        Ok(RoundRecord {
            round,
            train_loss,
            test_loss,
            test_accuracy,
            uplink_bits,
            cum_uplink_bits: self.cum_uplink_bits,
            mean_bits: (mean_bits_acc / n_fold as f64) as f32,
            mean_range: (mean_range_acc / n_fold as f64) as f32,
            seg_ranges,
            wall_secs: t0.elapsed().as_secs_f64(),
            recv_decode_secs,
            agg_secs,
            eval_secs,
            selected: n as u32,
            // Plan-side fields: the scheduler-owning caller overrides
            // these from its RoundPlan (serial/test callers have no
            // plan, so the zero defaults stand).
            dropped: 0,
            sim_makespan_secs: 0.0,
            // Real (socket-level) failures; the scheduler adds the
            // simulated fault count on top.  Tree rounds count in leaf
            // units — the on-time leaves that never made it into a
            // partial — matching the leaf-granular quorum above.
            failed: match tree_leaves {
                Some((on_time, _)) => (on_time as u32).saturating_sub(n_recv as u32),
                None => failed.len() as u32,
            },
            // Rejoins are observed by the TCP serve loop, not here.
            rejoined: 0,
            // Semi-sync staleness: banked folds harvested this round,
            // and updates too stale to ever fold (the scheduler adds
            // its simulated share of drops on top).
            stale_folded: stale.len() as u32,
            stale_dropped,
            agg_depth,
            client_state_bytes,
            // Aggregator subtrees whose composite handle died mid-round
            // (counted once per handle per round, revived or not);
            // degradation to direct-to-root attachment is observed by
            // the TCP serve driver, not here.
            subtree_failed,
            degraded: 0,
            downlink_bits,
            cum_downlink_bits: self.cum_downlink_bits,
        })
    }

    /// Add folded per-shard chunks onto the parameters.
    fn apply_chunks(&mut self, ranges: &[(usize, usize)], chunks: &[Vec<f32>]) {
        let params = self.params_mut();
        for (&(clo, chi), chunk) in ranges.iter().zip(chunks) {
            debug_assert_eq!(chunk.len(), chi - clo);
            for (p, a) in params[clo..chi].iter_mut().zip(chunk.iter()) {
                *p += *a;
            }
        }
    }

    /// Failure-tolerant receive, used when a quorum below 1.0, a round
    /// timeout or a staleness bound is configured: a client whose
    /// update cannot be obtained (dead socket, expired timeout,
    /// broadcast that already failed) lands in `failed` instead of
    /// aborting the round.  The shared timeout is one real-time budget
    /// for the whole receive window, apportioned as "whatever remains"
    /// to each blocking receive in turn.
    ///
    /// Two staleness hooks live here (the accept hook the semi-sync
    /// engine is built on):
    ///
    /// * a member of the scheduler's `late` plan answers *this* round,
    ///   but its update is banked for its due round instead of folding
    ///   now (the simulated-straggler path, identical on both
    ///   topologies);
    /// * a stale reply — a previously timed-out client answering an
    ///   older round over a real socket — is banked to fold this round
    ///   if it is within the staleness bound, counted in
    ///   `stale_dropped` if beyond it, and silently drained in strict
    ///   mode (the historical behavior) so a revived handle can
    ///   resynchronize.
    ///
    /// Arrivals are partitioned by handle kind and both halves return
    /// sorted by `client_id`: composite partials from aggregate handles
    /// (tree topology — these take the failover-aware
    /// [`Self::recv_from_aggregate`] path), then raw leaf updates.
    /// Decode happens downstream on the non-pipelined aggregation path
    /// (containment is worth more than overlap once clients are allowed
    /// to die mid-round).
    #[allow(clippy::too_many_arguments)]
    fn recv_tolerant(
        &mut self,
        round: u32,
        clients: &mut [Box<dyn ClientHandle + '_>],
        failed: &mut Vec<u32>,
        late: &[(u32, u32)],
        stale_dropped: &mut u32,
        cohort: Option<&[u32]>,
        encoded_bcast: &[u8],
        subtree_failed: &mut u32,
    ) -> (Vec<Update>, Vec<Update>) {
        let budget = RecvBudget::new(self.opts.round.tolerance.round_timeout);
        let k_bound = self.opts.round.tolerance.staleness;
        let mut agg_updates: Vec<Update> = Vec::new();
        let mut leaf_updates: Vec<Update> = Vec::with_capacity(clients.len());
        for c in clients.iter_mut() {
            let id = c.id();
            if c.is_aggregate() {
                self.recv_from_aggregate(
                    round,
                    c.as_mut(),
                    failed,
                    late,
                    stale_dropped,
                    cohort,
                    encoded_bcast,
                    &budget,
                    subtree_failed,
                    &mut agg_updates,
                    &mut leaf_updates,
                );
                continue;
            }
            if failed.contains(&id) {
                continue; // broadcast never reached this client
            }
            if let Some(remaining) = budget.remaining() {
                if remaining.is_zero() || c.set_recv_timeout(Some(remaining)).is_err() {
                    crate::warn_!("server", "round {round}: client {id} timed out");
                    failed.push(id);
                    continue;
                }
            }
            let got = loop {
                match c.recv_update() {
                    Ok(u) => match tolerance::classify(u.round, round) {
                        Arrival::OnTime => break Ok(u),
                        // stale reply from an older, timed-out round:
                        // the accept hook — bank it for this round's
                        // fold when the staleness bound allows, drop it
                        // visibly when not, drain it silently in strict
                        // mode
                        Arrival::Stale(s) => {
                            if k_bound > 0 {
                                if s <= k_bound {
                                    self.bank(u.round, u, round);
                                } else {
                                    *stale_dropped += 1;
                                }
                            }
                            continue;
                        }
                        Arrival::Future => {
                            break Err(anyhow!(
                                "client {id} answered round {} for {round}",
                                u.round
                            ))
                        }
                    },
                    Err(e) => break Err(e),
                }
            };
            match got {
                Ok(u) => {
                    if let Some(&(_, due)) = late.iter().find(|&&(l, _)| l == id) {
                        // Scheduler-planned late member: its update
                        // answers this round but folds (discounted) at
                        // `due`.
                        self.bank(round, u, due);
                    } else {
                        leaf_updates.push(u);
                    }
                }
                Err(e) => {
                    crate::warn_!("server", "round {round}: client {id} failed: {e:#}");
                    failed.push(id);
                }
            }
        }
        for c in clients.iter_mut() {
            let _ = c.set_recv_timeout(None);
        }
        agg_updates.sort_by_key(|u| u.client_id);
        leaf_updates.sort_by_key(|u| u.client_id);
        (agg_updates, leaf_updates)
    }

    /// Bank `update` (which answers round `answered`) to fold at `due`,
    /// materializing the leaf's arena row now so resident state evolves
    /// identically whether the update arrived flat, in-process, or as a
    /// raw relay through an aggregator.
    fn bank(&mut self, answered: u32, update: Update, due: u32) {
        self.arena
            .lock()
            .expect("arena poisoned")
            .set_samples(update.client_id, update.num_samples);
        self.banked
            .insert((answered, update.client_id), BankedUpdate { due, update });
    }

    /// Tolerant receive from one composite (aggregate) handle: collect
    /// the relayed raw updates of the span's late members plus the
    /// subtree's composite partial, in whatever order the aggregator
    /// sends them (protocol: raws first, partial last, so satisfying
    /// the expectations drains the socket).  A dead handle gets the
    /// failover path: wait — within the round budget, or a fixed grace
    /// window when unbounded — for the restarted aggregator to rejoin
    /// upstream ([`ClientHandle::retry_revive`]), re-send this round's
    /// broadcast over the adopted transport, and keep collecting.  The
    /// restarted aggregator re-runs the whole round, and the idempotent
    /// bank/got bookkeeping absorbs any duplicates, so a revived round
    /// folds exactly what an uninterrupted one would.
    #[allow(clippy::too_many_arguments)]
    fn recv_from_aggregate(
        &mut self,
        round: u32,
        c: &mut (dyn ClientHandle + '_),
        failed: &mut Vec<u32>,
        late: &[(u32, u32)],
        stale_dropped: &mut u32,
        cohort: Option<&[u32]>,
        encoded_bcast: &[u8],
        budget: &RecvBudget,
        subtree_failed: &mut u32,
        agg_updates: &mut Vec<Update>,
        leaf_updates: &mut Vec<Update>,
    ) {
        let id = c.id();
        let fanout = self.opts.round.topology.fanout.max(1);
        let span = id..id.saturating_add(fanout);
        // What this handle owes the round: one raw relay per late
        // member of its span, plus the composite partial whenever any
        // on-time member lives there.
        let want_raw: Vec<u32> = late
            .iter()
            .map(|&(l, _)| l)
            .filter(|l| span.contains(l))
            .collect();
        let want_partial = cohort.map_or(true, |ids| ids.iter().any(|i| span.contains(i)));
        let k_bound = self.opts.round.tolerance.staleness;
        let mut got_raw: std::collections::BTreeSet<u32> = Default::default();
        let mut got_partial: Option<Update> = None;
        let mut crashed = false; // `subtree_failed` once per round

        // A handle whose broadcast already failed goes straight to
        // failover; on success it leaves the failed set and owes the
        // full round like any live handle.
        if failed.contains(&id) {
            if !await_revive(c, round, encoded_bcast, budget, subtree_failed, &mut crashed) {
                return;
            }
            failed.retain(|&f| f != id);
        }

        while (want_partial && got_partial.is_none()) || got_raw.len() < want_raw.len() {
            if let Some(remaining) = budget.remaining() {
                if remaining.is_zero() || c.set_recv_timeout(Some(remaining)).is_err() {
                    crate::warn_!("server", "round {round}: aggregator {id} timed out");
                    break;
                }
            }
            match c.recv_update() {
                Ok(u) if c.last_recv_was_partial() => {
                    match tolerance::classify(u.round, round) {
                        Arrival::OnTime => got_partial = Some(u),
                        // a partial can only answer the round whose
                        // broadcast we (re-)sent; drain anything else
                        Arrival::Stale(_) | Arrival::Future => {
                            crate::warn_!(
                                "server",
                                "round {round}: aggregator {id} sent a partial for round {} — drained",
                                u.round
                            );
                        }
                    }
                }
                Ok(u) => match tolerance::classify(u.round, round) {
                    Arrival::OnTime => {
                        if let Some(&(_, due)) = late.iter().find(|&&(l, _)| l == u.client_id) {
                            got_raw.insert(u.client_id);
                            self.bank(round, u, due);
                        } else {
                            // defensive: an on-time relay outside the
                            // late plan folds like a direct leaf
                            leaf_updates.push(u);
                        }
                    }
                    Arrival::Stale(s) => {
                        if k_bound > 0 {
                            if s <= k_bound {
                                self.bank(u.round, u, round);
                            } else {
                                *stale_dropped += 1;
                            }
                        }
                    }
                    Arrival::Future => {
                        crate::warn_!(
                            "server",
                            "round {round}: aggregator {id} relayed round {} — drained",
                            u.round
                        );
                    }
                },
                Err(e) => {
                    // A read timeout is the budget expiring on a slow
                    // subtree — not a crash, no failover, no
                    // `subtree_failed`.  Anything else is a broken
                    // socket: the aggregator process died.
                    let timed_out = e
                        .downcast_ref::<std::io::Error>()
                        .map(|io| {
                            matches!(
                                io.kind(),
                                std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                            )
                        })
                        .unwrap_or(false);
                    if timed_out {
                        crate::warn_!("server", "round {round}: aggregator {id} timed out");
                        break;
                    }
                    crate::warn_!("server", "round {round}: aggregator {id} failed: {e:#}");
                    if !await_revive(c, round, encoded_bcast, budget, subtree_failed, &mut crashed)
                    {
                        break;
                    }
                    // revived: the restarted aggregator re-collects and
                    // re-sends the full round; duplicates are idempotent
                }
            }
        }
        if let Some(u) = got_partial {
            agg_updates.push(u);
        } else if want_partial && !failed.contains(&id) {
            // The span's on-time share never arrived: its leaves are
            // simply missing from the leaf-granular quorum count.
            failed.push(id);
        }
        let _ = c.set_recv_timeout(None);
    }

    /// Semi-sync aggregation for a round whose fold set includes
    /// harvested stale updates: every member contributes discounted
    /// sample mass `num_samples / (1 + s)` (`s = 0` for on-time
    /// members), renormalized over the whole set.  Folds walk the set
    /// in `(round, client id)` order — stale entries (strictly older
    /// rounds) first, then the on-time cohort — with the same serial
    /// streaming arithmetic on every topology and thread count, so
    /// semi-sync rounds are bit-identical everywhere.
    fn aggregate_with_stale(
        &mut self,
        updates: &[Update],
        stale: &[(u32, Update)],
    ) -> Result<()> {
        let d = self.model.mm.d;
        let denom = discounted_denom(updates, stale);
        ensure!(denom > 0.0, "no sample mass in the fold set");
        self.acc.clear();
        self.acc.resize(d, 0.0);
        let mode = self.opts.round.pipeline.codec;
        let stale_refs = stale.iter().map(|(s, u)| (*s, u));
        let ontime_refs = updates.iter().map(|u| (0u32, u));
        for (s, u) in stale_refs.chain(ontime_refs) {
            let mut dec = std::mem::take(&mut self.dec);
            codec::decode_update_into_mode(&self.model.mm, u, &mut dec, mode)
                .with_context(|| format!("decoding update from client {}", u.client_id))?;
            let w = (discounted_mass(u, s) / denom) as f32;
            codec::fold_range(&self.model.mm, &dec, w, 0, d, &mut self.acc);
            self.dec = dec;
        }
        // Borrow dance: take the accumulator, apply, put it back.
        let acc = std::mem::take(&mut self.acc);
        for (p, a) in self.params_mut().iter_mut().zip(&acc) {
            *p += a;
        }
        self.acc = acc;
        Ok(())
    }

    /// Receive every client's update, dispatching each one's decode to
    /// the pool the moment it arrives (decode overlaps the remaining
    /// receives and the still-running client rounds).  Returns updates
    /// and their decoded rows, both sorted by `client_id`.
    fn recv_decode_pipelined(
        &mut self,
        round: u32,
        clients: &mut [Box<dyn ClientHandle + '_>],
    ) -> Result<(Vec<Update>, Vec<codec::DecodedUpdate>)> {
        let tasks = self
            .opts
            .tasks
            .as_ref()
            .expect("pipelined path requires a pool")
            .clone();
        let n = clients.len();
        let mode = self.opts.round.pipeline.codec;
        let (tx, rx) = channel::<DecodeReply>();
        for c in clients.iter_mut() {
            let u = c.recv_update()?;
            ensure!(u.round == round, "client {} answered round {} for {round}", c.id(), u.round);
            let buf = self.dec_pool.pop().unwrap_or_default();
            let model = Arc::clone(&self.model);
            let tx = tx.clone();
            tasks.send(Task::Exec(Box::new(move || {
                let _ = tx.send(decode_task(&model, u, buf, mode));
            })))?;
        }
        drop(tx);
        let mut pairs: Vec<(Update, codec::DecodedUpdate)> = Vec::with_capacity(n);
        for _ in 0..n {
            let r = rx.recv().context("decode worker died")?;
            let (u, buf) = r.map_err(|m| anyhow!("{m}"))?;
            pairs.push((u, buf));
        }
        pairs.sort_by_key(|(u, _)| u.client_id);
        let mut updates = Vec::with_capacity(n);
        let mut decoded = Vec::with_capacity(n);
        for (u, d) in pairs {
            updates.push(u);
            decoded.push(d);
        }
        Ok((updates, decoded))
    }

    /// Receive updates while overlapping BOTH decode and the sharded
    /// fold with still-arriving replies (the fold-overlap path).
    ///
    /// Each arriving update's decode goes to the priority lane; as soon
    /// as the next client in sorted-id order is decoded, every idle
    /// shard folds it into its chunk ([`OverlapState::dispatch_folds`]).
    /// `weights` comes from [`Self::fold_plan`] and each update is
    /// checked against it.  Returns the sorted updates plus the folded
    /// `(ranges, chunks)` ready to apply.
    ///
    /// With `decode_buffers = k > 0` at most `k` decode buffers are
    /// ever allocated: the receive loop blocks for a recycled buffer
    /// while continuing to service decode/fold completion events, so
    /// every held buffer eventually frees and the loop cannot deadlock.
    fn recv_fold_overlapped(
        &mut self,
        round: u32,
        clients: &mut [Box<dyn ClientHandle + '_>],
        weights: &[f32],
    ) -> Result<OverlappedRound> {
        let tasks = self
            .opts
            .tasks
            .as_ref()
            .expect("fold overlap requires a pool")
            .clone();
        let n = clients.len();
        let d = self.model.mm.d;
        let shards = self.opts.agg_shards.clamp(1, d.max(1));
        let ranges = pool::chunk_ranges(d, shards);
        let cap = self.opts.round.pipeline.decode_buffers;

        // Receive in sorted-id order (not raw handle order): decode
        // dispatch then matches the fold order, so every buffer held
        // when the bounded acquire loop blocks belongs to an *earlier*
        // sorted position whose decode+fold chain completes without
        // further receives — the no-deadlock argument needs this even
        // for callers that pass handles unsorted.
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by_key(|&i| clients[i].id());

        // Recycled chunk buffers, one per shard.
        let mut chunk_bufs = std::mem::take(&mut self.chunks);
        while chunk_bufs.len() < ranges.len() {
            chunk_bufs.push(Vec::new());
        }
        chunk_bufs.truncate(ranges.len());
        let free = std::mem::take(&mut self.dec_pool);
        let allocated = free.len();

        let (tx, rx) = channel::<OverlapEv>();
        let mut st = OverlapState {
            tasks: &tasks,
            tx: &tx,
            model: &self.model,
            weights,
            ranges: &ranges,
            bufs: (0..n).map(|_| None).collect(),
            updates: (0..n).map(|_| None).collect(),
            decoded: vec![false; n],
            decoded_prefix: 0,
            folds_done: vec![0; n],
            shard_next: vec![0; ranges.len()],
            shard_chunk: chunk_bufs.into_iter().map(Some).collect(),
            free,
            allocated,
        };

        for (pos, &i) in order.iter().enumerate() {
            let id = clients[i].id();
            let u = clients[i].recv_update()?;
            ensure!(u.round == round, "client {id} answered round {} for {round}", u.round);
            ensure!(
                u.client_id == id,
                "handle {id} delivered an update for client {}",
                u.client_id
            );
            let expect = self
                .arena
                .lock()
                .expect("arena poisoned")
                .samples(id)
                .context("fold plan lost a client")?;
            ensure!(
                u.num_samples == expect,
                "client {id} reported {} samples but the fold plan used {expect}",
                u.num_samples
            );

            // Acquire a decode buffer under the bound, servicing
            // completions while we wait so held buffers can free.
            let buf = loop {
                if let Some(b) = st.free.pop() {
                    break b;
                }
                if cap == 0 || st.allocated < cap {
                    st.allocated += 1;
                    break codec::DecodedUpdate::new();
                }
                let ev = rx.recv().context("pool worker died mid-overlap")?;
                st.process(ev)?;
            };

            // Dispatch the decode on the priority lane.
            let mode = self.opts.round.pipeline.codec;
            let model = Arc::clone(&self.model);
            let tx2 = tx.clone();
            tasks.send(Task::Exec(Box::new(move || {
                let _ = tx2.send(OverlapEv::Decoded(pos, decode_task(&model, u, buf, mode)));
            })))?;

            // Opportunistically absorb completions between receives so
            // folds launch as early as possible.
            while let Ok(ev) = rx.try_recv() {
                st.process(ev)?;
            }
        }

        // Drain: every decode and every shard's full prefix fold.
        while !st.complete() {
            let ev = rx.recv().context("pool worker died mid-overlap")?;
            st.process(ev)?;
        }

        let updates: Vec<Update> = st
            .updates
            .into_iter()
            .map(|u| u.expect("all clients decoded"))
            .collect();
        let chunks: Vec<Vec<f32>> = st
            .shard_chunk
            .into_iter()
            .map(|c| c.expect("complete() checked"))
            .collect();
        let mut free = st.free;
        if cap > 0 {
            free.truncate(cap);
        }
        self.dec_pool = free;
        Ok((updates, ranges, chunks))
    }

    /// Fold pre-decoded updates into the parameters: sharded across the
    /// pool when `agg_shards > 1`, serial otherwise.  Client order and
    /// per-element arithmetic are identical in both cases (and identical
    /// to [`Self::aggregate_streaming`]), so every configuration
    /// produces bit-identical parameters.
    fn aggregate_decoded(
        &mut self,
        updates: &[Update],
        decoded: Vec<codec::DecodedUpdate>,
        total_samples: u64,
    ) -> Result<()> {
        let d = self.model.mm.d;
        let weights: Vec<f32> = updates
            .iter()
            .map(|u| u.num_samples as f32 / total_samples as f32)
            .collect();
        let shards = self.opts.agg_shards.clamp(1, d.max(1));
        if shards <= 1 || self.opts.tasks.is_none() {
            self.acc.clear();
            self.acc.resize(d, 0.0);
            for (dec, &w) in decoded.iter().zip(&weights) {
                codec::fold_range(&self.model.mm, dec, w, 0, d, &mut self.acc);
            }
            // Borrow dance: take the accumulator, apply, put it back.
            let acc = std::mem::take(&mut self.acc);
            for (p, a) in self.params_mut().iter_mut().zip(&acc) {
                *p += a;
            }
            self.acc = acc;
            self.recycle_decoded(decoded);
            return Ok(());
        }

        let tasks = self.opts.tasks.as_ref().expect("checked above").clone();
        let shared: Arc<Vec<codec::DecodedUpdate>> = Arc::new(decoded);
        let ws: Arc<Vec<f32>> = Arc::new(weights);
        let bufs = std::mem::take(&mut self.chunks);
        let (ranges, chunks) =
            pool::sharded_fold(&tasks, &self.model, &shared, &ws, shards, bufs)?;
        self.apply_chunks(&ranges, &chunks);
        self.chunks = chunks;
        // Every shard dropped its clone before replying, so this always
        // succeeds in practice; on a straggler we just reallocate next
        // round.
        if let Ok(bufs) = Arc::try_unwrap(shared) {
            self.recycle_decoded(bufs);
        }
        Ok(())
    }

    /// Return decode buffers to the free pool, respecting the retention
    /// cap (`decode_buffers`; 0 keeps everything — one per client).
    fn recycle_decoded(&mut self, bufs: Vec<codec::DecodedUpdate>) {
        self.dec_pool.extend(bufs);
        if self.opts.round.pipeline.decode_buffers > 0 {
            self.dec_pool.truncate(self.opts.round.pipeline.decode_buffers);
        }
    }

    /// Streaming decode-aggregate (serial, no pool): fold each update's
    /// weighted dequantized delta into one accumulator as it is decoded.
    /// Visits updates in sorted order with fixed-order f32 arithmetic,
    /// matching both the sharded fold and the fused kernel's
    /// client-major accumulation element for element.
    fn aggregate_streaming(&mut self, updates: &[Update], total_samples: u64) -> Result<()> {
        let d = self.model.mm.d;
        self.acc.clear();
        self.acc.resize(d, 0.0);
        for u in updates {
            let mut dec = std::mem::take(&mut self.dec);
            codec::decode_update_into_mode(&self.model.mm, u, &mut dec, self.opts.round.pipeline.codec)
                .with_context(|| format!("decoding update from client {}", u.client_id))?;
            let w = u.num_samples as f32 / total_samples as f32;
            codec::fold_range(&self.model.mm, &dec, w, 0, d, &mut self.acc);
            self.dec = dec;
        }
        // Borrow dance: take the accumulator, apply, put it back.
        let acc = std::mem::take(&mut self.acc);
        for (p, a) in self.params_mut().iter_mut().zip(&acc) {
            *p += a;
        }
        self.acc = acc;
        Ok(())
    }

    /// Fused path: materialize the `n x d` inputs and run the aggregate
    /// executable (XLA/Pallas kernel when built with `pjrt`).  The
    /// executable consumes f32 code rows, so the narrow `u16` rows are
    /// widened here ([`codec::DecodedUpdate::extend_codes_f32`] — the
    /// fused-mode shim; exact for codes below 2^16).
    fn aggregate_fused(&mut self, updates: &[Update], total_samples: u64) -> Result<()> {
        let n = updates.len();
        let l = self.model.mm.num_segments();
        let d = self.model.mm.d;
        let mut codes = Vec::with_capacity(n * d);
        let mut mins = Vec::with_capacity(n * l);
        let mut steps = Vec::with_capacity(n * l);
        let mut weights = Vec::with_capacity(n);
        for u in updates {
            let mut dec = std::mem::take(&mut self.dec);
            codec::decode_update_into_mode(&self.model.mm, u, &mut dec, self.opts.round.pipeline.codec)
                .with_context(|| format!("decoding update from client {}", u.client_id))?;
            dec.extend_codes_f32(&self.model.mm, &mut codes);
            mins.extend_from_slice(&dec.mins);
            steps.extend_from_slice(&dec.steps);
            self.dec = dec;
            weights.push(u.num_samples as f32 / total_samples as f32);
        }
        let delta = self.model.aggregate(&codes, &mins, &steps, &weights)?;
        for (p, dv) in self.params_mut().iter_mut().zip(&delta) {
            *p += dv;
        }
        Ok(())
    }

    /// Full-test-set evaluation in `eval_batch` chunks (the AOT executable
    /// has a static batch; a trailing partial chunk is dropped, which is
    /// deterministic and identical across policies).  With
    /// `eval_threads > 1` and a pool attached, contiguous batch slices
    /// run concurrently; the reduction always walks batches in order, so
    /// the result is bit-identical for any slice count.
    pub fn evaluate(&self) -> Result<(f32, f32)> {
        let mm = &self.model.mm;
        let e = mm.eval_batch;
        let fl = self.test.feature_len();
        let batches = self.test.len() / e;
        ensure!(batches > 0, "test set smaller than eval batch");
        let slices = self.opts.eval_threads.clamp(1, batches);
        let per_batch: Vec<(f32, i32)> = if slices > 1 && self.opts.tasks.is_some() {
            let tasks = self.opts.tasks.as_ref().expect("checked above").clone();
            type EvalSlice = Box<dyn FnOnce() -> Result<Vec<(f32, i32)>> + Send>;
            let mut fns: Vec<EvalSlice> = Vec::with_capacity(slices);
            for (b0, b1) in pool::chunk_ranges(batches, slices) {
                let model = Arc::clone(&self.model);
                let test = Arc::clone(&self.test);
                let params = Arc::clone(&self.params);
                fns.push(Box::new(move || {
                    let mut out = Vec::with_capacity(b1 - b0);
                    for b in b0..b1 {
                        let xs = &test.features[b * e * fl..(b + 1) * e * fl];
                        let ys = &test.labels[b * e..(b + 1) * e];
                        out.push(model.evaluate(&params, xs, ys)?);
                    }
                    // Drop the shared handles before replying so the
                    // server's params Arc is unique again by the time
                    // the next round applies its aggregate.
                    drop(params);
                    drop(test);
                    drop(model);
                    Ok(out)
                }));
            }
            let results = pool::scatter(&tasks, fns)?;
            let mut per_batch = Vec::with_capacity(batches);
            for r in results {
                per_batch.extend(r?);
            }
            per_batch
        } else {
            let mut out = Vec::with_capacity(batches);
            for b in 0..batches {
                let xs = &self.test.features[b * e * fl..(b + 1) * e * fl];
                let ys = &self.test.labels[b * e..(b + 1) * e];
                out.push(self.model.evaluate(&self.params, xs, ys)?);
            }
            out
        };
        // Fixed-order reduction over batches — identical for any
        // eval_threads value (and to the pre-parallel serial loop).
        let mut loss_sum = 0.0f64;
        let mut correct = 0i64;
        for &(ls, cc) in &per_batch {
            loss_sum += ls as f64;
            correct += cc as i64;
        }
        let seen = (batches * e) as f64;
        Ok(((loss_sum / seen) as f32, (correct as f64 / seen) as f32))
    }
}

/// How long a dead composite handle may wait for its restarted
/// aggregator to rejoin when no round timeout bounds the receive
/// window.
const AGG_FAILOVER_SECS: f64 = 20.0;
/// Poll cadence against the rejoin map during composite failover.
const REVIVE_POLL: Duration = Duration::from_millis(100);

/// Composite-handle failover loop: poll [`ClientHandle::retry_revive`]
/// until the restarted aggregator is adopted from the rejoin map
/// (`true`) or the window — the round budget when bounded, a fixed
/// grace otherwise — runs out (`false`).  Counts the crash into
/// `subtree_failed` exactly once per handle per round via `crashed`.
fn await_revive(
    c: &mut (dyn ClientHandle + '_),
    round: u32,
    encoded_bcast: &[u8],
    budget: &RecvBudget,
    subtree_failed: &mut u32,
    crashed: &mut bool,
) -> bool {
    if !*crashed {
        *subtree_failed += 1;
        *crashed = true;
    }
    let window = if budget.remaining().is_some() {
        *budget
    } else {
        RecvBudget::new(Some(AGG_FAILOVER_SECS))
    };
    loop {
        match c.retry_revive(encoded_bcast) {
            Ok(true) => {
                crate::warn_!(
                    "server",
                    "round {round}: aggregator {} rejoined mid-round — broadcast re-sent",
                    c.id()
                );
                return true;
            }
            Ok(false) => {}
            Err(_) => return false,
        }
        if window.expired() {
            return false;
        }
        let nap = window.remaining().map_or(REVIVE_POLL, |r| REVIVE_POLL.min(r));
        std::thread::sleep(nap);
    }
}

/// One fold-set member's staleness-discounted sample mass:
/// `num_samples / (1 + s)` where `s` is how many rounds late the update
/// folds (`0` for on-time members).
fn discounted_mass(u: &Update, s: u32) -> f64 {
    u.num_samples as f64 / (1.0 + s as f64)
}

/// Total discounted sample mass of a semi-sync fold set: stale members
/// at their discounted mass, on-time members at full mass.
fn discounted_denom(updates: &[Update], stale: &[(u32, Update)]) -> f64 {
    stale.iter().map(|(s, u)| discounted_mass(u, *s)).sum::<f64>()
        + updates.iter().map(|u| u.num_samples as f64).sum::<f64>()
}

/// FNV-1a over the bit patterns of an f32 slice.
pub fn hash_f32_bits(xs: &[f32]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &x in xs {
        for b in x.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    }
    h
}

// ---------------------------------------------------------------------------
// in-process session
// ---------------------------------------------------------------------------

/// In-process client handle backed by the worker pool: same `Message`
/// traffic as TCP, byte-accounted at framed size from exact encoded
/// lengths (nothing is serialized on this path except the shared
/// broadcast).  `send_broadcast` queues the round; `recv_update` blocks
/// for the result, so all clients compute concurrently between the two.
struct PoolClient {
    id: u32,
    state: Option<ClientState>,
    jobs: TaskSender,
    pending: Option<Receiver<Result<(ClientState, Update, f64)>>>,
    /// Shard size, known at construction (fold-overlap weight plan).
    samples: u32,
    /// Worker-measured compute seconds of the most recent round.
    last_secs: Option<f64>,
    up_bytes: u64,
    down_bytes: u64,
}

impl PoolClient {
    fn dispatch(&mut self, msg: &Message) -> Result<()> {
        if let Message::Broadcast { round, params, losses, budgets, .. } = msg {
            let state = self
                .state
                .take()
                .context("client already has a round in flight")?;
            let (tx, rx) = channel();
            // In-process handles always receive the full broadcast
            // (is_remote() = false), so `params` is the exact training
            // base; only the client's own budget entry rides along.
            let budget = budgets.as_ref().and_then(|b| {
                b.iter().find(|(id, _)| *id == self.id).map(|(_, ws)| ws.clone())
            });
            self.jobs.send(Task::Round(Job {
                state,
                round: *round,
                params: Arc::clone(params),
                losses: *losses,
                budget,
                reply: tx,
            }))?;
            self.pending = Some(rx);
        }
        Ok(())
    }
}

impl ClientHandle for PoolClient {
    fn id(&self) -> u32 {
        self.id
    }

    fn send(&mut self, msg: &Message) -> Result<()> {
        self.down_bytes += frame::framed_len(msg.encoded_len());
        self.dispatch(msg)
    }

    fn send_broadcast(&mut self, msg: &Message, encoded: &[u8]) -> Result<()> {
        self.down_bytes += frame::framed_len(encoded.len());
        self.dispatch(msg)
    }

    fn recv_update(&mut self) -> Result<Update> {
        let rx = self
            .pending
            .take()
            .context("no update pending (send a Broadcast first)")?;
        let (state, update, secs) = rx
            .recv()
            .context("round worker died")?
            .with_context(|| format!("client {} round failed", self.id))?;
        self.state = Some(state);
        self.last_secs = Some(secs);
        self.up_bytes += frame::framed_len(1 + messages::update_encoded_len(&update));
        Ok(update)
    }

    fn num_samples(&self) -> Option<u32> {
        Some(self.samples)
    }

    fn last_round_secs(&self) -> Option<f64> {
        self.last_secs
    }

    fn take_io_bytes(&mut self) -> (u64, u64) {
        (std::mem::take(&mut self.up_bytes), std::mem::take(&mut self.down_bytes))
    }
}

/// A complete single-process federated run.
pub struct Session {
    cfg: RunConfig,
    #[allow(dead_code)] // owns the backend (PJRT client) behind `model`
    runtime: Runtime,
    model: Arc<ModelRuntime>,
    train_shards: Vec<Arc<data::Dataset>>,
    test: Arc<data::Dataset>,
    /// Where the data came from (`"real"` / `"synthetic"`), for prints.
    pub data_source: &'static str,
}

impl Session {
    /// Materialize a session: runtime, model, datasets and shards.
    pub fn new(cfg: RunConfig) -> Result<Session> {
        cfg.validate()?;
        let runtime = Runtime::new(&cfg.artifacts_dir)?;
        let model = Arc::new(runtime.load_model(&cfg.model)?);
        let mm = &model.mm;
        ensure!(
            cfg.dataset.shape()
                == (mm.input_shape[0], mm.input_shape[1], mm.input_shape[2]),
            "dataset {:?} does not match model input {:?}",
            cfg.dataset,
            mm.input_shape
        );
        let (train, test, source) = data::load_or_synthesize(
            cfg.dataset,
            &cfg.data_dir,
            cfg.train_size,
            cfg.test_size,
            cfg.seed,
        )?;
        let shards = shard::shard_indices(&train, mm.n_clients, cfg.sharding, cfg.seed);
        let train_shards = shards
            .iter()
            .map(|idx| Arc::new(train.subset(idx)))
            .collect();
        Ok(Session {
            cfg,
            runtime,
            model,
            train_shards,
            test: Arc::new(test),
            data_source: source,
        })
    }

    /// The loaded model's manifest.
    pub fn manifest(&self) -> &crate::runtime::ModelManifest {
        &self.model.mm
    }

    /// The session's configuration.
    pub fn config(&self) -> &RunConfig {
        &self.cfg
    }

    /// Run the configured number of rounds; returns the report.
    pub fn run(&mut self) -> Result<RunReport> {
        self.run_with(|_r, _rec| {})
    }

    /// Run with a per-round observer (progress printing in examples).
    pub fn run_with(
        &mut self,
        mut observer: impl FnMut(u32, &RoundRecord),
    ) -> Result<RunReport> {
        let root = Rng::new(self.cfg.seed);
        let threads = self.cfg.resolved_threads(self.train_shards.len());
        // Declared before `server` and `clients` so both (holding task
        // senders) drop first and the pool's Drop can join its workers.
        let pool = WorkerPool::new(threads, Arc::clone(&self.model));
        let mut server = Server::new(
            Arc::clone(&self.model),
            Arc::clone(&self.test),
            self.cfg.seed as u32,
            ServerOpts {
                aggregate: self.cfg.aggregate,
                agg_shards: self.cfg.resolved_agg_shards(threads),
                eval_threads: self.cfg.resolved_eval_threads(threads),
                round: self.cfg.round,
                tasks: Some(pool.sender()),
            },
        )?;
        let mut clients: Vec<Box<dyn ClientHandle + '_>> = self
            .train_shards
            .iter()
            .enumerate()
            .map(|(i, shard)| {
                Box::new(PoolClient {
                    id: i as u32,
                    state: Some(
                        ClientState::with_options(
                            i as u32,
                            Arc::clone(shard),
                            self.cfg.policy.build(),
                            self.cfg.lr,
                            &self.model,
                            &root,
                            self.cfg.error_feedback,
                            self.cfg.round.pipeline.codec,
                        )
                        .with_ef_bits(self.cfg.ef_bits),
                    ),
                    jobs: pool.sender(),
                    pending: None,
                    samples: shard.len() as u32,
                    last_secs: None,
                    up_bytes: 0,
                    down_bytes: 0,
                }) as Box<dyn ClientHandle + '_>
            })
            .collect();

        // Round scheduler: samples each round's cohort (participation /
        // deadline knobs) and orders its dispatch slowest-first.  The
        // selection stream is seed-pure, so reports stay bit-identical
        // across every threading knob.
        let mut scheduler = RoundScheduler::from_config_with_arena(
            &self.cfg,
            self.train_shards.len(),
            server.arena(),
        )?;
        let mut rounds = Vec::with_capacity(self.cfg.rounds);
        for m in 0..self.cfg.rounds {
            let evaluate = m % self.cfg.eval_every == 0 || m + 1 == self.cfg.rounds;
            let rec = sched::run_scheduled_round(
                &mut scheduler,
                &mut server,
                &mut clients,
                m as u32,
                evaluate,
            )?;
            observer(m as u32, &rec);
            let done = self
                .cfg
                .target_accuracy
                .map(|t| rec.evaluated() && rec.test_accuracy >= t)
                .unwrap_or(false);
            rounds.push(rec);
            if done {
                break;
            }
        }
        let params_hash = server.params_hash();
        drop(clients);
        drop(server);
        Ok(RunReport {
            label: self.cfg.label(),
            model: self.cfg.model.clone(),
            rounds,
            params_hash,
        })
    }
}
