//! Server-side FL logic: the round loop, aggregation and evaluation —
//! plus [`Session`], the single-process driver that runs client rounds
//! on a persistent worker pool ([`super::pool`]) and talks to the server
//! through the same message types the TCP mode uses.
//!
//! ## Round data path
//!
//! * **Broadcast** is zero-copy: the global parameters live in an
//!   `Arc<[f32]>`, the `Broadcast` message is encoded **once** per round
//!   and every client handle receives the shared buffer / pre-encoded
//!   bytes ([`ClientHandle::send_broadcast`]).  After the round, the
//!   server updates the vector in place (`Arc::get_mut` — by then all
//!   clients have dropped their references).
//! * **Aggregation** streams by default
//!   ([`AggregateMode::Streaming`]): each update is decoded into a
//!   round-persistent scratch ([`codec::DecodedUpdate`]) and its
//!   weighted dequantized delta is folded directly into one `d`-length
//!   accumulator — no `n x d` codes matrix.  The fused
//!   dequantize-aggregate executable remains available as
//!   [`AggregateMode::Fused`].
//!
//! Both paths visit updates in ascending `client_id` order, so reports
//! are bit-identical across thread counts.  Across the two aggregation
//! *modes*, equality holds element-for-element on the native backend
//! (same fixed-order f32 arithmetic); a hardware-backed fused kernel
//! may reduce in a different order and is only guaranteed close, not
//! bit-equal (see `streaming_and_fused_aggregation_agree`).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{ensure, Context, Result};

use super::client::ClientState;
use super::codec;
use super::pool::{Job, WorkerPool};
use crate::config::{AggregateMode, RunConfig};
use crate::data::{self, shard};
use crate::metrics::{RoundRecord, RunReport};
use crate::runtime::{ModelRuntime, Runtime};
use crate::util::rng::Rng;
use crate::util::stats;
use crate::wire::frame;
use crate::wire::messages::{self, Message, Update};

/// A connected client as the server sees it.
pub trait ClientHandle {
    fn id(&self) -> u32;
    fn send(&mut self, msg: &Message) -> Result<()>;
    /// Broadcast fast path: `encoded` is `msg.encode()`, produced once
    /// by the server for the whole round.  Implementations must not
    /// re-encode; the default falls back to [`Self::send`].
    fn send_broadcast(&mut self, msg: &Message, encoded: &[u8]) -> Result<()> {
        let _ = encoded;
        self.send(msg)
    }
    fn recv_update(&mut self) -> Result<Update>;
    /// Cumulative uplink bytes (client -> server), framed size.
    fn uplink_bytes(&self) -> u64;
    /// Cumulative downlink bytes (server -> client), framed size.
    fn downlink_bytes(&self) -> u64;
}

/// The federated server: owns the global model and the round loop.
pub struct Server<'rt> {
    pub model: &'rt ModelRuntime,
    params: Arc<[f32]>,
    test: Arc<data::Dataset>,
    aggregate_mode: AggregateMode,
    initial_loss: Option<f32>,
    prev_loss: Option<f32>,
    cum_uplink_bits: u64,
    // round-persistent scratch (allocation-free steady state)
    dec: codec::DecodedUpdate,
    acc: Vec<f32>,
}

impl<'rt> Server<'rt> {
    pub fn new(
        model: &'rt ModelRuntime,
        test: Arc<data::Dataset>,
        seed: u32,
        aggregate_mode: AggregateMode,
    ) -> Result<Self> {
        let params: Arc<[f32]> = model.init(seed)?.into();
        Ok(Server {
            model,
            params,
            test,
            aggregate_mode,
            initial_loss: None,
            prev_loss: None,
            cum_uplink_bits: 0,
            dec: codec::DecodedUpdate::new(),
            acc: Vec::new(),
        })
    }

    /// The current global parameter vector.
    pub fn params(&self) -> &[f32] {
        &self.params
    }

    /// FNV-1a hash over the exact parameter bits (determinism checks).
    pub fn params_hash(&self) -> u64 {
        hash_f32_bits(&self.params)
    }

    /// Mutable view of the parameters.  Zero-copy when the server holds
    /// the only reference (the steady state: all per-round broadcast
    /// clones are dropped by aggregation time); falls back to
    /// copy-on-write otherwise.
    fn params_mut(&mut self) -> &mut [f32] {
        if Arc::get_mut(&mut self.params).is_none() {
            self.params = self.params.to_vec().into();
        }
        Arc::get_mut(&mut self.params).expect("unique after copy-on-write")
    }

    /// Drive one round across `clients`; returns the round record.
    pub fn run_round(
        &mut self,
        round: u32,
        clients: &mut [Box<dyn ClientHandle + '_>],
        evaluate: bool,
    ) -> Result<RoundRecord> {
        let t0 = Instant::now();
        let mm = &self.model.mm;
        let n = clients.len();
        ensure!(n == mm.n_clients, "manifest expects {} clients, got {n}", mm.n_clients);

        // Broadcast the global model (+ loss trajectory for AdaQuantFL):
        // one Arc clone per client, one encode per round.
        let losses = match (self.initial_loss, self.prev_loss) {
            (Some(f0), Some(fm)) => Some((f0, fm)),
            _ => None,
        };
        let bcast = Message::Broadcast {
            round,
            params: Arc::clone(&self.params),
            losses,
        };
        let encoded = bcast.encode();
        for c in clients.iter_mut() {
            c.send_broadcast(&bcast, &encoded)?;
        }
        drop(bcast);
        drop(encoded);

        // Collect updates (blocking per client; pool clients overlap).
        let mut updates: Vec<Update> = Vec::with_capacity(n);
        for c in clients.iter_mut() {
            let u = c.recv_update()?;
            ensure!(u.round == round, "client {} answered round {} for {round}", c.id(), u.round);
            updates.push(u);
        }
        updates.sort_by_key(|u| u.client_id);

        let total_samples: u64 = updates.iter().map(|u| u.num_samples as u64).sum();
        ensure!(total_samples > 0, "no samples reported");

        // Decode + aggregate, then apply (Eq. 4).
        match self.aggregate_mode {
            AggregateMode::Streaming => self.aggregate_streaming(&updates, total_samples)?,
            AggregateMode::Fused => self.aggregate_fused(&updates, total_samples)?,
        }

        // Loss bookkeeping for loss-driven policies.
        let train_loss = updates
            .iter()
            .map(|u| u.train_loss as f64 * u.num_samples as f64 / total_samples as f64)
            .sum::<f64>() as f32;
        if self.initial_loss.is_none() {
            self.initial_loss = Some(train_loss);
        }
        self.prev_loss = Some(train_loss);

        // Communication accounting: the paper counts uplink payloads.
        let uplink_bits: u64 = updates
            .iter()
            .map(|u| codec::update_wire_bits(mm, u))
            .sum();
        self.cum_uplink_bits += uplink_bits;

        // Telemetry: mean bits/element and ranges (Figs. 1b, 5).
        let l = mm.num_segments();
        let seg_sizes = mm.segment_sizes();
        let mut mean_bits_acc = 0.0f64;
        let mut mean_range_acc = 0.0f64;
        let mut seg_ranges = vec![0.0f32; l];
        for u in &updates {
            let bits_elem: u64 = u
                .segments
                .iter()
                .zip(&seg_sizes)
                .map(|(h, &sz)| h.bits as u64 * sz as u64)
                .sum();
            mean_bits_acc += bits_elem as f64 / mm.d as f64;
            let ranges: Vec<f32> = u.segments.iter().map(|h| h.range()).collect();
            mean_range_acc += stats::mean(&ranges.iter().map(|&x| x as f64).collect::<Vec<_>>());
            for (sr, r) in seg_ranges.iter_mut().zip(&ranges) {
                *sr += r / n as f32;
            }
        }

        // Periodic server-side validation.
        let (test_loss, test_accuracy) = if evaluate {
            self.evaluate()?
        } else {
            (f32::NAN, f32::NAN)
        };

        Ok(RoundRecord {
            round,
            train_loss,
            test_loss,
            test_accuracy,
            uplink_bits,
            cum_uplink_bits: self.cum_uplink_bits,
            mean_bits: (mean_bits_acc / n as f64) as f32,
            mean_range: (mean_range_acc / n as f64) as f32,
            seg_ranges,
            wall_secs: t0.elapsed().as_secs_f64(),
        })
    }

    /// Streaming decode-aggregate: fold each update's weighted
    /// dequantized delta into one accumulator as it is decoded.  Visits
    /// updates in sorted order with fixed-order f32 arithmetic, matching
    /// the fused kernel's client-major accumulation element for element.
    fn aggregate_streaming(&mut self, updates: &[Update], total_samples: u64) -> Result<()> {
        let mm = &self.model.mm;
        self.acc.clear();
        self.acc.resize(mm.d, 0.0);
        for u in updates {
            codec::decode_update_into(mm, u, &mut self.dec)
                .with_context(|| format!("decoding update from client {}", u.client_id))?;
            let w = u.num_samples as f32 / total_samples as f32;
            for (l, seg) in mm.segments.iter().enumerate() {
                let (mn, st) = (self.dec.mins[l], self.dec.steps[l]);
                let codes = &self.dec.codes[seg.offset..seg.offset + seg.size];
                let acc = &mut self.acc[seg.offset..seg.offset + seg.size];
                for (a, &c) in acc.iter_mut().zip(codes) {
                    *a += w * (c * st + mn);
                }
            }
        }
        // Borrow dance: take the accumulator, apply, put it back.
        let acc = std::mem::take(&mut self.acc);
        for (p, d) in self.params_mut().iter_mut().zip(&acc) {
            *p += d;
        }
        self.acc = acc;
        Ok(())
    }

    /// Fused path: materialize the `n x d` inputs and run the aggregate
    /// executable (XLA/Pallas kernel when built with `pjrt`).
    fn aggregate_fused(&mut self, updates: &[Update], total_samples: u64) -> Result<()> {
        let mm = &self.model.mm;
        let n = updates.len();
        let l = mm.num_segments();
        let mut codes = Vec::with_capacity(n * mm.d);
        let mut mins = Vec::with_capacity(n * l);
        let mut steps = Vec::with_capacity(n * l);
        let mut weights = Vec::with_capacity(n);
        for u in updates {
            codec::decode_update_into(mm, u, &mut self.dec)
                .with_context(|| format!("decoding update from client {}", u.client_id))?;
            codes.extend_from_slice(&self.dec.codes);
            mins.extend_from_slice(&self.dec.mins);
            steps.extend_from_slice(&self.dec.steps);
            weights.push(u.num_samples as f32 / total_samples as f32);
        }
        let delta = self.model.aggregate(&codes, &mins, &steps, &weights)?;
        for (p, d) in self.params_mut().iter_mut().zip(&delta) {
            *p += d;
        }
        Ok(())
    }

    /// Full-test-set evaluation in `eval_batch` chunks (the AOT executable
    /// has a static batch; a trailing partial chunk is dropped, which is
    /// deterministic and identical across policies).
    pub fn evaluate(&self) -> Result<(f32, f32)> {
        let mm = &self.model.mm;
        let e = mm.eval_batch;
        let fl = self.test.feature_len();
        let batches = self.test.len() / e;
        ensure!(batches > 0, "test set smaller than eval batch");
        let mut loss_sum = 0.0f64;
        let mut correct = 0i64;
        for b in 0..batches {
            let xs = &self.test.features[b * e * fl..(b + 1) * e * fl];
            let ys = &self.test.labels[b * e..(b + 1) * e];
            let (ls, cc) = self.model.evaluate(&self.params, xs, ys)?;
            loss_sum += ls as f64;
            correct += cc as i64;
        }
        let seen = (batches * e) as f64;
        Ok(((loss_sum / seen) as f32, (correct as f64 / seen) as f32))
    }
}

/// FNV-1a over the bit patterns of an f32 slice.
pub fn hash_f32_bits(xs: &[f32]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &x in xs {
        for b in x.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    }
    h
}

// ---------------------------------------------------------------------------
// in-process session
// ---------------------------------------------------------------------------

/// In-process client handle backed by the worker pool: same `Message`
/// traffic as TCP, byte-accounted at framed size from exact encoded
/// lengths (nothing is serialized on this path except the shared
/// broadcast).  `send_broadcast` queues the round; `recv_update` blocks
/// for the result, so all clients compute concurrently between the two.
struct PoolClient {
    id: u32,
    state: Option<ClientState>,
    jobs: Sender<Job>,
    pending: Option<Receiver<Result<(ClientState, Update)>>>,
    up_bytes: u64,
    down_bytes: u64,
}

impl PoolClient {
    fn dispatch(&mut self, msg: &Message) -> Result<()> {
        if let Message::Broadcast { round, params, losses } = msg {
            let state = self
                .state
                .take()
                .context("client already has a round in flight")?;
            let (tx, rx) = channel();
            self.jobs
                .send(Job {
                    state,
                    round: *round,
                    params: Arc::clone(params),
                    losses: *losses,
                    reply: tx,
                })
                .ok()
                .context("worker pool hung up")?;
            self.pending = Some(rx);
        }
        Ok(())
    }
}

impl ClientHandle for PoolClient {
    fn id(&self) -> u32 {
        self.id
    }

    fn send(&mut self, msg: &Message) -> Result<()> {
        self.down_bytes += frame::framed_len(msg.encoded_len());
        self.dispatch(msg)
    }

    fn send_broadcast(&mut self, msg: &Message, encoded: &[u8]) -> Result<()> {
        self.down_bytes += frame::framed_len(encoded.len());
        self.dispatch(msg)
    }

    fn recv_update(&mut self) -> Result<Update> {
        let rx = self
            .pending
            .take()
            .context("no update pending (send a Broadcast first)")?;
        let (state, update) = rx
            .recv()
            .context("round worker died (panicked?)")?
            .with_context(|| format!("client {} round failed", self.id))?;
        self.state = Some(state);
        self.up_bytes += frame::framed_len(1 + messages::update_encoded_len(&update));
        Ok(update)
    }

    fn uplink_bytes(&self) -> u64 {
        self.up_bytes
    }

    fn downlink_bytes(&self) -> u64 {
        self.down_bytes
    }
}

/// A complete single-process federated run.
pub struct Session {
    cfg: RunConfig,
    #[allow(dead_code)] // owns the backend (PJRT client) behind `model`
    runtime: Runtime,
    model: Arc<ModelRuntime>,
    train_shards: Vec<Arc<data::Dataset>>,
    test: Arc<data::Dataset>,
    pub data_source: &'static str,
}

impl Session {
    pub fn new(cfg: RunConfig) -> Result<Session> {
        cfg.validate()?;
        let runtime = Runtime::new(&cfg.artifacts_dir)?;
        let model = Arc::new(runtime.load_model(&cfg.model)?);
        let mm = &model.mm;
        ensure!(
            cfg.dataset.shape()
                == (mm.input_shape[0], mm.input_shape[1], mm.input_shape[2]),
            "dataset {:?} does not match model input {:?}",
            cfg.dataset,
            mm.input_shape
        );
        let (train, test, source) = data::load_or_synthesize(
            cfg.dataset,
            &cfg.data_dir,
            cfg.train_size,
            cfg.test_size,
            cfg.seed,
        )?;
        let shards = shard::shard_indices(&train, mm.n_clients, cfg.sharding, cfg.seed);
        let train_shards = shards
            .iter()
            .map(|idx| Arc::new(train.subset(idx)))
            .collect();
        Ok(Session {
            cfg,
            runtime,
            model,
            train_shards,
            test: Arc::new(test),
            data_source: source,
        })
    }

    pub fn manifest(&self) -> &crate::runtime::ModelManifest {
        &self.model.mm
    }

    pub fn config(&self) -> &RunConfig {
        &self.cfg
    }

    /// Run the configured number of rounds; returns the report.
    pub fn run(&mut self) -> Result<RunReport> {
        self.run_with(|_r, _rec| {})
    }

    /// Run with a per-round observer (progress printing in examples).
    pub fn run_with(
        &mut self,
        mut observer: impl FnMut(u32, &RoundRecord),
    ) -> Result<RunReport> {
        let root = Rng::new(self.cfg.seed);
        let threads = self.cfg.resolved_threads(self.train_shards.len());
        // Declared before `clients` so the clients (holding job senders)
        // drop first and the pool's Drop can join its workers.
        let pool = WorkerPool::new(threads, Arc::clone(&self.model));
        let mut server = Server::new(
            &self.model,
            Arc::clone(&self.test),
            self.cfg.seed as u32,
            self.cfg.aggregate,
        )?;
        let mut clients: Vec<Box<dyn ClientHandle + '_>> = self
            .train_shards
            .iter()
            .enumerate()
            .map(|(i, shard)| {
                Box::new(PoolClient {
                    id: i as u32,
                    state: Some(ClientState::with_options(
                        i as u32,
                        Arc::clone(shard),
                        self.cfg.policy.build(),
                        self.cfg.lr,
                        &self.model,
                        &root,
                        self.cfg.error_feedback,
                    )),
                    jobs: pool.sender(),
                    pending: None,
                    up_bytes: 0,
                    down_bytes: 0,
                }) as Box<dyn ClientHandle + '_>
            })
            .collect();

        let mut rounds = Vec::with_capacity(self.cfg.rounds);
        for m in 0..self.cfg.rounds {
            let evaluate = m % self.cfg.eval_every == 0 || m + 1 == self.cfg.rounds;
            let rec = server.run_round(m as u32, &mut clients, evaluate)?;
            observer(m as u32, &rec);
            let done = self
                .cfg
                .target_accuracy
                .map(|t| rec.evaluated() && rec.test_accuracy >= t)
                .unwrap_or(false);
            rounds.push(rec);
            if done {
                break;
            }
        }
        let params_hash = server.params_hash();
        drop(clients);
        Ok(RunReport {
            label: self.cfg.label(),
            model: self.cfg.model.clone(),
            rounds,
            params_hash,
        })
    }
}
