//! Server-side FL logic: the round loop, aggregation and evaluation —
//! plus [`Session`], the single-process driver that wires local clients
//! to the server through the same message types the TCP mode uses.

use std::time::Instant;

use anyhow::{ensure, Context, Result};

use super::client::ClientState;
use super::codec;
use crate::config::RunConfig;
use crate::data::{self, shard};
use crate::metrics::{RoundRecord, RunReport};
use crate::runtime::{ModelRuntime, Runtime};
use crate::util::rng::Rng;
use crate::util::stats;
use crate::wire::frame;
use crate::wire::messages::{Message, Update};

/// A connected client as the server sees it.
pub trait ClientHandle {
    fn id(&self) -> u32;
    fn send(&mut self, msg: &Message) -> Result<()>;
    fn recv_update(&mut self) -> Result<Update>;
    /// Cumulative uplink bytes (client -> server), framed size.
    fn uplink_bytes(&self) -> u64;
    /// Cumulative downlink bytes (server -> client), framed size.
    fn downlink_bytes(&self) -> u64;
}

/// The federated server: owns the global model and the round loop.
pub struct Server<'rt> {
    pub model: &'rt ModelRuntime,
    pub params: Vec<f32>,
    test: data::Dataset,
    initial_loss: Option<f32>,
    prev_loss: Option<f32>,
    cum_uplink_bits: u64,
}

impl<'rt> Server<'rt> {
    pub fn new(model: &'rt ModelRuntime, test: data::Dataset, seed: u32) -> Result<Self> {
        let params = model.init(seed)?;
        Ok(Server {
            model,
            params,
            test,
            initial_loss: None,
            prev_loss: None,
            cum_uplink_bits: 0,
        })
    }

    /// Drive one round across `clients`; returns the round record.
    pub fn run_round(
        &mut self,
        round: u32,
        clients: &mut [Box<dyn ClientHandle + '_>],
        evaluate: bool,
    ) -> Result<RoundRecord> {
        let t0 = Instant::now();
        let mm = &self.model.mm;
        let n = clients.len();
        ensure!(n == mm.n_clients, "manifest expects {} clients, got {n}", mm.n_clients);

        // Broadcast the global model (+ loss trajectory for AdaQuantFL).
        let losses = match (self.initial_loss, self.prev_loss) {
            (Some(f0), Some(fm)) => Some((f0, fm)),
            _ => None,
        };
        let bcast = Message::Broadcast {
            round,
            params: self.params.clone(),
            losses,
        };
        for c in clients.iter_mut() {
            c.send(&bcast)?;
        }

        // Collect updates.
        let mut updates: Vec<Update> = Vec::with_capacity(n);
        for c in clients.iter_mut() {
            let u = c.recv_update()?;
            ensure!(u.round == round, "client {} answered round {} for {round}", c.id(), u.round);
            updates.push(u);
        }
        updates.sort_by_key(|u| u.client_id);

        // Decode into the aggregate executable's inputs.
        let l = mm.num_segments();
        let mut codes = Vec::with_capacity(n * mm.d);
        let mut mins = Vec::with_capacity(n * l);
        let mut steps = Vec::with_capacity(n * l);
        let mut weights = Vec::with_capacity(n);
        let total_samples: u64 = updates.iter().map(|u| u.num_samples as u64).sum();
        ensure!(total_samples > 0, "no samples reported");
        for u in &updates {
            let dec = codec::decode_update(mm, u)
                .with_context(|| format!("decoding update from client {}", u.client_id))?;
            codes.extend_from_slice(&dec.codes);
            mins.extend_from_slice(&dec.mins);
            steps.extend_from_slice(&dec.steps);
            weights.push(u.num_samples as f32 / total_samples as f32);
        }

        // Fused dequantize + weighted aggregate, then apply (Eq. 4).
        let delta = self.model.aggregate(&codes, &mins, &steps, &weights)?;
        for (p, d) in self.params.iter_mut().zip(&delta) {
            *p += d;
        }

        // Loss bookkeeping for loss-driven policies.
        let train_loss = updates
            .iter()
            .map(|u| u.train_loss as f64 * u.num_samples as f64 / total_samples as f64)
            .sum::<f64>() as f32;
        if self.initial_loss.is_none() {
            self.initial_loss = Some(train_loss);
        }
        self.prev_loss = Some(train_loss);

        // Communication accounting: the paper counts uplink payloads.
        let uplink_bits: u64 = updates
            .iter()
            .map(|u| codec::update_wire_bits(mm, u))
            .sum();
        self.cum_uplink_bits += uplink_bits;

        // Telemetry: mean bits/element and ranges (Figs. 1b, 5).
        let seg_sizes = mm.segment_sizes();
        let mut mean_bits_acc = 0.0f64;
        let mut mean_range_acc = 0.0f64;
        let mut seg_ranges = vec![0.0f32; l];
        for u in &updates {
            let bits_elem: u64 = u
                .segments
                .iter()
                .zip(&seg_sizes)
                .map(|(h, &sz)| h.bits as u64 * sz as u64)
                .sum();
            mean_bits_acc += bits_elem as f64 / mm.d as f64;
            let ranges: Vec<f32> = u.segments.iter().map(|h| h.range()).collect();
            mean_range_acc += stats::mean(&ranges.iter().map(|&x| x as f64).collect::<Vec<_>>());
            for (sr, r) in seg_ranges.iter_mut().zip(&ranges) {
                *sr += r / n as f32;
            }
        }

        // Periodic server-side validation.
        let (test_loss, test_accuracy) = if evaluate {
            self.evaluate()?
        } else {
            (f32::NAN, f32::NAN)
        };

        Ok(RoundRecord {
            round,
            train_loss,
            test_loss,
            test_accuracy,
            uplink_bits,
            cum_uplink_bits: self.cum_uplink_bits,
            mean_bits: (mean_bits_acc / n as f64) as f32,
            mean_range: (mean_range_acc / n as f64) as f32,
            seg_ranges,
            wall_secs: t0.elapsed().as_secs_f64(),
        })
    }

    /// Full-test-set evaluation in `eval_batch` chunks (the AOT executable
    /// has a static batch; a trailing partial chunk is dropped, which is
    /// deterministic and identical across policies).
    pub fn evaluate(&self) -> Result<(f32, f32)> {
        let mm = &self.model.mm;
        let e = mm.eval_batch;
        let fl = self.test.feature_len();
        let batches = self.test.len() / e;
        ensure!(batches > 0, "test set smaller than eval batch");
        let mut loss_sum = 0.0f64;
        let mut correct = 0i64;
        for b in 0..batches {
            let xs = &self.test.features[b * e * fl..(b + 1) * e * fl];
            let ys = &self.test.labels[b * e..(b + 1) * e];
            let (ls, cc) = self.model.evaluate(&self.params, xs, ys)?;
            loss_sum += ls as f64;
            correct += cc as i64;
        }
        let seen = (batches * e) as f64;
        Ok(((loss_sum / seen) as f32, (correct as f64 / seen) as f32))
    }
}

// ---------------------------------------------------------------------------
// in-process session
// ---------------------------------------------------------------------------

/// In-process client handle: same `Message` traffic as TCP, byte-accounted
/// at framed size, executed synchronously on the session thread (the XLA
/// CPU client already parallelizes each execution across cores).
struct LocalClient<'rt> {
    state: ClientState,
    model: &'rt ModelRuntime,
    pending: Option<Update>,
    up_bytes: u64,
    down_bytes: u64,
}

impl<'rt> ClientHandle for LocalClient<'rt> {
    fn id(&self) -> u32 {
        self.state.id
    }

    fn send(&mut self, msg: &Message) -> Result<()> {
        self.down_bytes += frame::framed_len(msg.encode().len());
        if let Message::Broadcast { round, params, losses } = msg {
            let u = self.state.process_round(self.model, *round, params, *losses)?;
            self.pending = Some(u);
        }
        Ok(())
    }

    fn recv_update(&mut self) -> Result<Update> {
        let u = self
            .pending
            .take()
            .context("no update pending (send a Broadcast first)")?;
        self.up_bytes += frame::framed_len(Message::Update(u.clone()).encode().len());
        Ok(u)
    }

    fn uplink_bytes(&self) -> u64 {
        self.up_bytes
    }

    fn downlink_bytes(&self) -> u64 {
        self.down_bytes
    }
}

/// A complete single-process federated run.
pub struct Session {
    cfg: RunConfig,
    #[allow(dead_code)] // owns the PJRT client backing `model`
    runtime: Runtime,
    model: ModelRuntime,
    train_shards: Vec<data::Dataset>,
    test: data::Dataset,
    pub data_source: &'static str,
}

impl Session {
    pub fn new(cfg: RunConfig) -> Result<Session> {
        cfg.validate()?;
        let runtime = Runtime::new(&cfg.artifacts_dir)?;
        let model = runtime.load_model(&cfg.model)?;
        let mm = &model.mm;
        ensure!(
            cfg.dataset.shape()
                == (mm.input_shape[0], mm.input_shape[1], mm.input_shape[2]),
            "dataset {:?} does not match model input {:?}",
            cfg.dataset,
            mm.input_shape
        );
        let (train, test, source) = data::load_or_synthesize(
            cfg.dataset,
            &cfg.data_dir,
            cfg.train_size,
            cfg.test_size,
            cfg.seed,
        )?;
        let shards = shard::shard_indices(&train, mm.n_clients, cfg.sharding, cfg.seed);
        let train_shards = shards.iter().map(|idx| train.subset(idx)).collect();
        Ok(Session {
            cfg,
            runtime,
            model,
            train_shards,
            test,
            data_source: source,
        })
    }

    pub fn manifest(&self) -> &crate::runtime::ModelManifest {
        &self.model.mm
    }

    pub fn config(&self) -> &RunConfig {
        &self.cfg
    }

    /// Run the configured number of rounds; returns the report.
    pub fn run(&mut self) -> Result<RunReport> {
        self.run_with(|_r, _rec| {})
    }

    /// Run with a per-round observer (progress printing in examples).
    pub fn run_with(
        &mut self,
        mut observer: impl FnMut(u32, &RoundRecord),
    ) -> Result<RunReport> {
        let root = Rng::new(self.cfg.seed);
        let mut server = Server::new(&self.model, self.test.clone(), self.cfg.seed as u32)?;
        let mut clients: Vec<Box<dyn ClientHandle + '_>> = self
            .train_shards
            .iter()
            .enumerate()
            .map(|(i, shard)| {
                Box::new(LocalClient {
                    state: ClientState::with_options(
                        i as u32,
                        shard.clone(),
                        self.cfg.policy.build(),
                        self.cfg.lr,
                        &self.model,
                        &root,
                        self.cfg.error_feedback,
                    ),
                    model: &self.model,
                    pending: None,
                    up_bytes: 0,
                    down_bytes: 0,
                }) as Box<dyn ClientHandle + '_>
            })
            .collect();

        let mut rounds = Vec::with_capacity(self.cfg.rounds);
        for m in 0..self.cfg.rounds {
            let evaluate = m % self.cfg.eval_every == 0 || m + 1 == self.cfg.rounds;
            let rec = server.run_round(m as u32, &mut clients, evaluate)?;
            observer(m as u32, &rec);
            let done = self
                .cfg
                .target_accuracy
                .map(|t| rec.evaluated() && rec.test_accuracy >= t)
                .unwrap_or(false);
            rounds.push(rec);
            if done {
                break;
            }
        }
        Ok(RunReport {
            label: self.cfg.label(),
            model: self.cfg.model.clone(),
            rounds,
        })
    }
}
