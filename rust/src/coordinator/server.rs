//! Server-side FL logic: the round loop, aggregation and evaluation —
//! plus [`Session`], the single-process driver that runs client rounds
//! on a persistent worker pool ([`super::pool`]) and talks to the server
//! through the same message types the TCP mode uses.
//!
//! ## Round data path
//!
//! * **Broadcast** is zero-copy: the global parameters live in an
//!   `Arc<[f32]>`, the `Broadcast` message is encoded **once** per round
//!   and every client handle receives the shared buffer / pre-encoded
//!   bytes ([`ClientHandle::send_broadcast`]).  After the round, the
//!   server updates the vector in place (`Arc::get_mut` — by then all
//!   clients have dropped their references).
//! * **Receive and decode are pipelined** when a pool is attached
//!   ([`ServerOpts::tasks`]): each arriving `ClientUpdate` is handed to
//!   a worker the moment it lands, decoding into a round-persistent
//!   [`codec::DecodedUpdate`] buffer while the server blocks on the
//!   next client's reply.  Updates are then ordered by `client_id`.
//!   In TCP mode the pool has nothing else to do, so decode overlaps
//!   receive fully; in-process, decode tasks share one FIFO queue with
//!   the round jobs and so only overlap the *tail* of the round (a
//!   priority lane for server tasks is a noted future lever).
//! * **Aggregation** folds the decoded updates into the `d`-length
//!   accumulator.  With `agg_shards > 1` the accumulator is split into
//!   contiguous per-worker chunk ranges and the decode-free fold runs
//!   concurrently, each shard visiting clients in the same sorted
//!   order ([`codec::fold_range`]) — element-wise arithmetic never
//!   crosses a chunk boundary, so any shard count is bit-identical to
//!   the serial fold.  The fused dequantize-aggregate executable
//!   remains available as [`AggregateMode::Fused`].
//! * **Evaluation** splits the test set's eval batches into contiguous
//!   slices across the pool (`eval_threads`), then reduces the
//!   per-batch partials in batch order — bit-identical to the serial
//!   loop for any slice count.
//!
//! All paths visit updates in ascending `client_id` order, so reports
//! are bit-identical across thread counts, shard counts and eval slice
//! counts (enforced by `rust/tests/parallel_determinism.rs`).  Across
//! the two aggregation *modes*, equality holds element-for-element on
//! the native backend (same fixed-order f32 arithmetic); a
//! hardware-backed fused kernel may reduce in a different order and is
//! only guaranteed close, not bit-equal (see
//! `streaming_and_fused_aggregation_agree`).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{ensure, Context, Result};

use super::client::ClientState;
use super::codec;
use super::pool::{self, Job, Task, WorkerPool};
use crate::config::{AggregateMode, RunConfig};
use crate::data::{self, shard};
use crate::metrics::{RoundRecord, RunReport};
use crate::runtime::{ModelRuntime, Runtime};
use crate::util::rng::Rng;
use crate::util::stats;
use crate::wire::frame;
use crate::wire::messages::{self, Message, Update};

/// A connected client as the server sees it.
pub trait ClientHandle {
    fn id(&self) -> u32;
    fn send(&mut self, msg: &Message) -> Result<()>;
    /// Broadcast fast path: `encoded` is `msg.encode()`, produced once
    /// by the server for the whole round.  Implementations must not
    /// re-encode; the default falls back to [`Self::send`].
    fn send_broadcast(&mut self, msg: &Message, encoded: &[u8]) -> Result<()> {
        let _ = encoded;
        self.send(msg)
    }
    fn recv_update(&mut self) -> Result<Update>;
    /// Cumulative uplink bytes (client -> server), framed size.
    fn uplink_bytes(&self) -> u64;
    /// Cumulative downlink bytes (server -> client), framed size.
    fn downlink_bytes(&self) -> u64;
}

/// How the server schedules its own hot stages.
pub struct ServerOpts {
    /// Decode-fold strategy (streaming by default, fused executable on
    /// request).
    pub aggregate: AggregateMode,
    /// Accumulator shards for the parallel fold (>= 1); 1 = serial
    /// fold.  Bit-identical results for any value.
    pub agg_shards: usize,
    /// Worker slices for server-side eval batches (>= 1); 1 = serial.
    /// Bit-identical results for any value.
    pub eval_threads: usize,
    /// Pool handle for server-side stages (decode pipeline, shard fold,
    /// eval slices); `None` runs the server fully serial.
    pub tasks: Option<Sender<Task>>,
}

impl ServerOpts {
    /// Fully serial server (no pool): the pre-parallel behavior.
    pub fn serial(aggregate: AggregateMode) -> ServerOpts {
        ServerOpts { aggregate, agg_shards: 1, eval_threads: 1, tasks: None }
    }
}

/// The federated server: owns the global model and the round loop.
pub struct Server {
    pub model: Arc<ModelRuntime>,
    params: Arc<[f32]>,
    test: Arc<data::Dataset>,
    opts: ServerOpts,
    initial_loss: Option<f32>,
    prev_loss: Option<f32>,
    cum_uplink_bits: u64,
    // round-persistent scratch (allocation-free steady state)
    dec: codec::DecodedUpdate,
    acc: Vec<f32>,
    /// Free decode buffers for the recv/decode pipeline (grows to one
    /// per client, then recycles round over round).
    dec_pool: Vec<codec::DecodedUpdate>,
    /// Per-shard chunk accumulators for the sharded fold.
    chunks: Vec<Vec<f32>>,
}

impl Server {
    pub fn new(
        model: Arc<ModelRuntime>,
        test: Arc<data::Dataset>,
        seed: u32,
        opts: ServerOpts,
    ) -> Result<Self> {
        let params: Arc<[f32]> = model.init(seed)?.into();
        Ok(Server {
            model,
            params,
            test,
            opts,
            initial_loss: None,
            prev_loss: None,
            cum_uplink_bits: 0,
            dec: codec::DecodedUpdate::new(),
            acc: Vec::new(),
            dec_pool: Vec::new(),
            chunks: Vec::new(),
        })
    }

    /// The current global parameter vector.
    pub fn params(&self) -> &[f32] {
        &self.params
    }

    /// FNV-1a hash over the exact parameter bits (determinism checks).
    pub fn params_hash(&self) -> u64 {
        hash_f32_bits(&self.params)
    }

    /// Mutable view of the parameters.  Zero-copy when the server holds
    /// the only reference (the steady state: all per-round broadcast
    /// clones are dropped by aggregation time); falls back to
    /// copy-on-write otherwise.
    fn params_mut(&mut self) -> &mut [f32] {
        if Arc::get_mut(&mut self.params).is_none() {
            self.params = self.params.to_vec().into();
        }
        Arc::get_mut(&mut self.params).expect("unique after copy-on-write")
    }

    /// Drive one round across `clients`; returns the round record.
    pub fn run_round(
        &mut self,
        round: u32,
        clients: &mut [Box<dyn ClientHandle + '_>],
        evaluate: bool,
    ) -> Result<RoundRecord> {
        let t0 = Instant::now();
        let n = clients.len();
        ensure!(
            n == self.model.mm.n_clients,
            "manifest expects {} clients, got {n}",
            self.model.mm.n_clients
        );

        // Broadcast the global model (+ loss trajectory for AdaQuantFL):
        // one Arc clone per client, one encode per round.
        let losses = match (self.initial_loss, self.prev_loss) {
            (Some(f0), Some(fm)) => Some((f0, fm)),
            _ => None,
        };
        let bcast = Message::Broadcast {
            round,
            params: Arc::clone(&self.params),
            losses,
        };
        let encoded = bcast.encode();
        for c in clients.iter_mut() {
            c.send_broadcast(&bcast, &encoded)?;
        }
        drop(bcast);
        drop(encoded);

        // Collect updates (blocking per client; pool clients overlap).
        // With a pool attached and the streaming/sharded fold selected,
        // each update's decode is dispatched as it lands, overlapping
        // the remaining receives.
        let t_recv = Instant::now();
        let pipelined =
            self.opts.tasks.is_some() && self.opts.aggregate == AggregateMode::Streaming;
        let (updates, decoded) = if pipelined {
            self.recv_decode_pipelined(round, clients)?
        } else {
            let mut updates: Vec<Update> = Vec::with_capacity(n);
            for c in clients.iter_mut() {
                let u = c.recv_update()?;
                ensure!(u.round == round, "client {} answered round {} for {round}", c.id(), u.round);
                updates.push(u);
            }
            updates.sort_by_key(|u| u.client_id);
            (updates, Vec::new())
        };
        let recv_decode_secs = t_recv.elapsed().as_secs_f64();

        let total_samples: u64 = updates.iter().map(|u| u.num_samples as u64).sum();
        ensure!(total_samples > 0, "no samples reported");

        // Decode + aggregate, then apply (Eq. 4).
        let t_agg = Instant::now();
        if pipelined {
            self.aggregate_decoded(&updates, decoded, total_samples)?;
        } else {
            match self.opts.aggregate {
                AggregateMode::Streaming => self.aggregate_streaming(&updates, total_samples)?,
                AggregateMode::Fused => self.aggregate_fused(&updates, total_samples)?,
            }
        }
        let agg_secs = t_agg.elapsed().as_secs_f64();

        // Loss bookkeeping for loss-driven policies.
        let train_loss = updates
            .iter()
            .map(|u| u.train_loss as f64 * u.num_samples as f64 / total_samples as f64)
            .sum::<f64>() as f32;
        if self.initial_loss.is_none() {
            self.initial_loss = Some(train_loss);
        }
        self.prev_loss = Some(train_loss);

        // Communication accounting: the paper counts uplink payloads.
        let mm = &self.model.mm;
        let uplink_bits: u64 = updates
            .iter()
            .map(|u| codec::update_wire_bits(mm, u))
            .sum();
        self.cum_uplink_bits += uplink_bits;

        // Telemetry: mean bits/element and ranges (Figs. 1b, 5).
        let l = mm.num_segments();
        let seg_sizes = mm.segment_sizes();
        let mut mean_bits_acc = 0.0f64;
        let mut mean_range_acc = 0.0f64;
        let mut seg_ranges = vec![0.0f32; l];
        for u in &updates {
            let bits_elem: u64 = u
                .segments
                .iter()
                .zip(&seg_sizes)
                .map(|(h, &sz)| h.bits as u64 * sz as u64)
                .sum();
            mean_bits_acc += bits_elem as f64 / mm.d as f64;
            let ranges: Vec<f32> = u.segments.iter().map(|h| h.range()).collect();
            mean_range_acc += stats::mean(&ranges.iter().map(|&x| x as f64).collect::<Vec<_>>());
            for (sr, r) in seg_ranges.iter_mut().zip(&ranges) {
                *sr += r / n as f32;
            }
        }

        // Periodic server-side validation.
        let t_eval = Instant::now();
        let (test_loss, test_accuracy) = if evaluate {
            self.evaluate()?
        } else {
            (f32::NAN, f32::NAN)
        };
        let eval_secs = if evaluate { t_eval.elapsed().as_secs_f64() } else { 0.0 };

        Ok(RoundRecord {
            round,
            train_loss,
            test_loss,
            test_accuracy,
            uplink_bits,
            cum_uplink_bits: self.cum_uplink_bits,
            mean_bits: (mean_bits_acc / n as f64) as f32,
            mean_range: (mean_range_acc / n as f64) as f32,
            seg_ranges,
            wall_secs: t0.elapsed().as_secs_f64(),
            recv_decode_secs,
            agg_secs,
            eval_secs,
        })
    }

    /// Receive every client's update, dispatching each one's decode to
    /// the pool the moment it arrives (decode overlaps the remaining
    /// receives and the still-running client rounds).  Returns updates
    /// and their decoded rows, both sorted by `client_id`.
    fn recv_decode_pipelined(
        &mut self,
        round: u32,
        clients: &mut [Box<dyn ClientHandle + '_>],
    ) -> Result<(Vec<Update>, Vec<codec::DecodedUpdate>)> {
        let tasks = self
            .opts
            .tasks
            .as_ref()
            .expect("pipelined path requires a pool")
            .clone();
        let n = clients.len();
        type Reply = (Update, codec::DecodedUpdate, Result<()>);
        let (tx, rx) = channel::<Reply>();
        for c in clients.iter_mut() {
            let u = c.recv_update()?;
            ensure!(u.round == round, "client {} answered round {} for {round}", c.id(), u.round);
            let mut buf = self.dec_pool.pop().unwrap_or_default();
            let model = Arc::clone(&self.model);
            let tx = tx.clone();
            tasks
                .send(Task::Exec(Box::new(move || {
                    let res = codec::decode_update_into(&model.mm, &u, &mut buf);
                    drop(model);
                    let _ = tx.send((u, buf, res));
                })))
                .ok()
                .context("worker pool hung up")?;
        }
        drop(tx);
        let mut pairs: Vec<(Update, codec::DecodedUpdate)> = Vec::with_capacity(n);
        for _ in 0..n {
            let (u, buf, res) = rx.recv().context("decode worker died (panicked?)")?;
            res.with_context(|| format!("decoding update from client {}", u.client_id))?;
            pairs.push((u, buf));
        }
        pairs.sort_by_key(|(u, _)| u.client_id);
        let mut updates = Vec::with_capacity(n);
        let mut decoded = Vec::with_capacity(n);
        for (u, d) in pairs {
            updates.push(u);
            decoded.push(d);
        }
        Ok((updates, decoded))
    }

    /// Fold pre-decoded updates into the parameters: sharded across the
    /// pool when `agg_shards > 1`, serial otherwise.  Client order and
    /// per-element arithmetic are identical in both cases (and identical
    /// to [`Self::aggregate_streaming`]), so every configuration
    /// produces bit-identical parameters.
    fn aggregate_decoded(
        &mut self,
        updates: &[Update],
        decoded: Vec<codec::DecodedUpdate>,
        total_samples: u64,
    ) -> Result<()> {
        let d = self.model.mm.d;
        let weights: Vec<f32> = updates
            .iter()
            .map(|u| u.num_samples as f32 / total_samples as f32)
            .collect();
        let shards = self.opts.agg_shards.clamp(1, d.max(1));
        if shards <= 1 || self.opts.tasks.is_none() {
            self.acc.clear();
            self.acc.resize(d, 0.0);
            for (dec, &w) in decoded.iter().zip(&weights) {
                codec::fold_range(&self.model.mm, dec, w, 0, d, &mut self.acc);
            }
            // Borrow dance: take the accumulator, apply, put it back.
            let acc = std::mem::take(&mut self.acc);
            for (p, a) in self.params_mut().iter_mut().zip(&acc) {
                *p += a;
            }
            self.acc = acc;
            self.dec_pool.extend(decoded);
            return Ok(());
        }

        let tasks = self.opts.tasks.as_ref().expect("checked above").clone();
        let shared: Arc<Vec<codec::DecodedUpdate>> = Arc::new(decoded);
        let ws: Arc<Vec<f32>> = Arc::new(weights);
        let bufs = std::mem::take(&mut self.chunks);
        let (ranges, chunks) =
            pool::sharded_fold(&tasks, &self.model, &shared, &ws, shards, bufs)?;
        {
            let params = self.params_mut();
            for (&(clo, chi), chunk) in ranges.iter().zip(&chunks) {
                debug_assert_eq!(chunk.len(), chi - clo);
                for (p, a) in params[clo..chi].iter_mut().zip(chunk.iter()) {
                    *p += *a;
                }
            }
        }
        self.chunks = chunks;
        // Every shard dropped its clone before replying, so this always
        // succeeds in practice; on a straggler we just reallocate next
        // round.
        if let Ok(bufs) = Arc::try_unwrap(shared) {
            self.dec_pool.extend(bufs);
        }
        Ok(())
    }

    /// Streaming decode-aggregate (serial, no pool): fold each update's
    /// weighted dequantized delta into one accumulator as it is decoded.
    /// Visits updates in sorted order with fixed-order f32 arithmetic,
    /// matching both the sharded fold and the fused kernel's
    /// client-major accumulation element for element.
    fn aggregate_streaming(&mut self, updates: &[Update], total_samples: u64) -> Result<()> {
        let d = self.model.mm.d;
        self.acc.clear();
        self.acc.resize(d, 0.0);
        for u in updates {
            let mut dec = std::mem::take(&mut self.dec);
            codec::decode_update_into(&self.model.mm, u, &mut dec)
                .with_context(|| format!("decoding update from client {}", u.client_id))?;
            let w = u.num_samples as f32 / total_samples as f32;
            codec::fold_range(&self.model.mm, &dec, w, 0, d, &mut self.acc);
            self.dec = dec;
        }
        // Borrow dance: take the accumulator, apply, put it back.
        let acc = std::mem::take(&mut self.acc);
        for (p, a) in self.params_mut().iter_mut().zip(&acc) {
            *p += a;
        }
        self.acc = acc;
        Ok(())
    }

    /// Fused path: materialize the `n x d` inputs and run the aggregate
    /// executable (XLA/Pallas kernel when built with `pjrt`).
    fn aggregate_fused(&mut self, updates: &[Update], total_samples: u64) -> Result<()> {
        let n = updates.len();
        let l = self.model.mm.num_segments();
        let d = self.model.mm.d;
        let mut codes = Vec::with_capacity(n * d);
        let mut mins = Vec::with_capacity(n * l);
        let mut steps = Vec::with_capacity(n * l);
        let mut weights = Vec::with_capacity(n);
        for u in updates {
            let mut dec = std::mem::take(&mut self.dec);
            codec::decode_update_into(&self.model.mm, u, &mut dec)
                .with_context(|| format!("decoding update from client {}", u.client_id))?;
            codes.extend_from_slice(&dec.codes);
            mins.extend_from_slice(&dec.mins);
            steps.extend_from_slice(&dec.steps);
            self.dec = dec;
            weights.push(u.num_samples as f32 / total_samples as f32);
        }
        let delta = self.model.aggregate(&codes, &mins, &steps, &weights)?;
        for (p, dv) in self.params_mut().iter_mut().zip(&delta) {
            *p += dv;
        }
        Ok(())
    }

    /// Full-test-set evaluation in `eval_batch` chunks (the AOT executable
    /// has a static batch; a trailing partial chunk is dropped, which is
    /// deterministic and identical across policies).  With
    /// `eval_threads > 1` and a pool attached, contiguous batch slices
    /// run concurrently; the reduction always walks batches in order, so
    /// the result is bit-identical for any slice count.
    pub fn evaluate(&self) -> Result<(f32, f32)> {
        let mm = &self.model.mm;
        let e = mm.eval_batch;
        let fl = self.test.feature_len();
        let batches = self.test.len() / e;
        ensure!(batches > 0, "test set smaller than eval batch");
        let slices = self.opts.eval_threads.clamp(1, batches);
        let per_batch: Vec<(f32, i32)> = if slices > 1 && self.opts.tasks.is_some() {
            let tasks = self.opts.tasks.as_ref().expect("checked above").clone();
            type EvalSlice = Box<dyn FnOnce() -> Result<Vec<(f32, i32)>> + Send>;
            let mut fns: Vec<EvalSlice> = Vec::with_capacity(slices);
            for (b0, b1) in pool::chunk_ranges(batches, slices) {
                let model = Arc::clone(&self.model);
                let test = Arc::clone(&self.test);
                let params = Arc::clone(&self.params);
                fns.push(Box::new(move || {
                    let mut out = Vec::with_capacity(b1 - b0);
                    for b in b0..b1 {
                        let xs = &test.features[b * e * fl..(b + 1) * e * fl];
                        let ys = &test.labels[b * e..(b + 1) * e];
                        out.push(model.evaluate(&params, xs, ys)?);
                    }
                    // Drop the shared handles before replying so the
                    // server's params Arc is unique again by the time
                    // the next round applies its aggregate.
                    drop(params);
                    drop(test);
                    drop(model);
                    Ok(out)
                }));
            }
            let results = pool::scatter(&tasks, fns)?;
            let mut per_batch = Vec::with_capacity(batches);
            for r in results {
                per_batch.extend(r?);
            }
            per_batch
        } else {
            let mut out = Vec::with_capacity(batches);
            for b in 0..batches {
                let xs = &self.test.features[b * e * fl..(b + 1) * e * fl];
                let ys = &self.test.labels[b * e..(b + 1) * e];
                out.push(self.model.evaluate(&self.params, xs, ys)?);
            }
            out
        };
        // Fixed-order reduction over batches — identical for any
        // eval_threads value (and to the pre-parallel serial loop).
        let mut loss_sum = 0.0f64;
        let mut correct = 0i64;
        for &(ls, cc) in &per_batch {
            loss_sum += ls as f64;
            correct += cc as i64;
        }
        let seen = (batches * e) as f64;
        Ok(((loss_sum / seen) as f32, (correct as f64 / seen) as f32))
    }
}

/// FNV-1a over the bit patterns of an f32 slice.
pub fn hash_f32_bits(xs: &[f32]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &x in xs {
        for b in x.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    }
    h
}

// ---------------------------------------------------------------------------
// in-process session
// ---------------------------------------------------------------------------

/// In-process client handle backed by the worker pool: same `Message`
/// traffic as TCP, byte-accounted at framed size from exact encoded
/// lengths (nothing is serialized on this path except the shared
/// broadcast).  `send_broadcast` queues the round; `recv_update` blocks
/// for the result, so all clients compute concurrently between the two.
struct PoolClient {
    id: u32,
    state: Option<ClientState>,
    jobs: Sender<Task>,
    pending: Option<Receiver<Result<(ClientState, Update)>>>,
    up_bytes: u64,
    down_bytes: u64,
}

impl PoolClient {
    fn dispatch(&mut self, msg: &Message) -> Result<()> {
        if let Message::Broadcast { round, params, losses } = msg {
            let state = self
                .state
                .take()
                .context("client already has a round in flight")?;
            let (tx, rx) = channel();
            self.jobs
                .send(Task::Round(Job {
                    state,
                    round: *round,
                    params: Arc::clone(params),
                    losses: *losses,
                    reply: tx,
                }))
                .ok()
                .context("worker pool hung up")?;
            self.pending = Some(rx);
        }
        Ok(())
    }
}

impl ClientHandle for PoolClient {
    fn id(&self) -> u32 {
        self.id
    }

    fn send(&mut self, msg: &Message) -> Result<()> {
        self.down_bytes += frame::framed_len(msg.encoded_len());
        self.dispatch(msg)
    }

    fn send_broadcast(&mut self, msg: &Message, encoded: &[u8]) -> Result<()> {
        self.down_bytes += frame::framed_len(encoded.len());
        self.dispatch(msg)
    }

    fn recv_update(&mut self) -> Result<Update> {
        let rx = self
            .pending
            .take()
            .context("no update pending (send a Broadcast first)")?;
        let (state, update) = rx
            .recv()
            .context("round worker died (panicked?)")?
            .with_context(|| format!("client {} round failed", self.id))?;
        self.state = Some(state);
        self.up_bytes += frame::framed_len(1 + messages::update_encoded_len(&update));
        Ok(update)
    }

    fn uplink_bytes(&self) -> u64 {
        self.up_bytes
    }

    fn downlink_bytes(&self) -> u64 {
        self.down_bytes
    }
}

/// A complete single-process federated run.
pub struct Session {
    cfg: RunConfig,
    #[allow(dead_code)] // owns the backend (PJRT client) behind `model`
    runtime: Runtime,
    model: Arc<ModelRuntime>,
    train_shards: Vec<Arc<data::Dataset>>,
    test: Arc<data::Dataset>,
    pub data_source: &'static str,
}

impl Session {
    pub fn new(cfg: RunConfig) -> Result<Session> {
        cfg.validate()?;
        let runtime = Runtime::new(&cfg.artifacts_dir)?;
        let model = Arc::new(runtime.load_model(&cfg.model)?);
        let mm = &model.mm;
        ensure!(
            cfg.dataset.shape()
                == (mm.input_shape[0], mm.input_shape[1], mm.input_shape[2]),
            "dataset {:?} does not match model input {:?}",
            cfg.dataset,
            mm.input_shape
        );
        let (train, test, source) = data::load_or_synthesize(
            cfg.dataset,
            &cfg.data_dir,
            cfg.train_size,
            cfg.test_size,
            cfg.seed,
        )?;
        let shards = shard::shard_indices(&train, mm.n_clients, cfg.sharding, cfg.seed);
        let train_shards = shards
            .iter()
            .map(|idx| Arc::new(train.subset(idx)))
            .collect();
        Ok(Session {
            cfg,
            runtime,
            model,
            train_shards,
            test: Arc::new(test),
            data_source: source,
        })
    }

    pub fn manifest(&self) -> &crate::runtime::ModelManifest {
        &self.model.mm
    }

    pub fn config(&self) -> &RunConfig {
        &self.cfg
    }

    /// Run the configured number of rounds; returns the report.
    pub fn run(&mut self) -> Result<RunReport> {
        self.run_with(|_r, _rec| {})
    }

    /// Run with a per-round observer (progress printing in examples).
    pub fn run_with(
        &mut self,
        mut observer: impl FnMut(u32, &RoundRecord),
    ) -> Result<RunReport> {
        let root = Rng::new(self.cfg.seed);
        let threads = self.cfg.resolved_threads(self.train_shards.len());
        // Declared before `server` and `clients` so both (holding task
        // senders) drop first and the pool's Drop can join its workers.
        let pool = WorkerPool::new(threads, Arc::clone(&self.model));
        let mut server = Server::new(
            Arc::clone(&self.model),
            Arc::clone(&self.test),
            self.cfg.seed as u32,
            ServerOpts {
                aggregate: self.cfg.aggregate,
                agg_shards: self.cfg.resolved_agg_shards(threads),
                eval_threads: self.cfg.resolved_eval_threads(threads),
                tasks: Some(pool.sender()),
            },
        )?;
        let mut clients: Vec<Box<dyn ClientHandle + '_>> = self
            .train_shards
            .iter()
            .enumerate()
            .map(|(i, shard)| {
                Box::new(PoolClient {
                    id: i as u32,
                    state: Some(ClientState::with_options(
                        i as u32,
                        Arc::clone(shard),
                        self.cfg.policy.build(),
                        self.cfg.lr,
                        &self.model,
                        &root,
                        self.cfg.error_feedback,
                    )),
                    jobs: pool.sender(),
                    pending: None,
                    up_bytes: 0,
                    down_bytes: 0,
                }) as Box<dyn ClientHandle + '_>
            })
            .collect();

        let mut rounds = Vec::with_capacity(self.cfg.rounds);
        for m in 0..self.cfg.rounds {
            let evaluate = m % self.cfg.eval_every == 0 || m + 1 == self.cfg.rounds;
            let rec = server.run_round(m as u32, &mut clients, evaluate)?;
            observer(m as u32, &rec);
            let done = self
                .cfg
                .target_accuracy
                .map(|t| rec.evaluated() && rec.test_accuracy >= t)
                .unwrap_or(false);
            rounds.push(rec);
            if done {
                break;
            }
        }
        let params_hash = server.params_hash();
        drop(clients);
        drop(server);
        Ok(RunReport {
            label: self.cfg.label(),
            model: self.cfg.model.clone(),
            rounds,
            params_hash,
        })
    }
}
