//! Round scheduler: partial participation and straggler-aware dispatch.
//!
//! FedDQ's analysis assumes every client reports every round; at any
//! realistic scale a round runs over a *sampled cohort* and contends
//! with stragglers.  [`RoundScheduler`] owns that layer, one instance
//! per run, and produces one [`RoundPlan`] per round:
//!
//! * **Cohort selection** (`--participation f`): `ceil(f * n)` clients
//!   drawn by a seeded, **round-keyed** RNG — the stream for round `m`
//!   is derived as `Rng::new(seed).derive("sched").derive("round{m}")`,
//!   so the selected set is a pure function of `(seed, m, n, f)` and
//!   bit-reproducible regardless of thread count, knob settings or what
//!   any earlier round observed.
//! * **Deadline policy** (`--round-deadline T`, simulated seconds):
//!   over-samples `2 * ceil(f * n)` candidates (capped at `n`), prices
//!   each with the [`LatencyModel`], and keeps the deterministic
//!   first-`ceil(f * n)` by simulated completion time — ties broken by
//!   client id — dropping any of those that would finish after `T`.
//!   The cut candidates are the round's `dropped` count; if no
//!   candidate meets the deadline the single fastest one is kept so a
//!   round always has a cohort.  **Bias is the point**: a deadline
//!   policy deliberately prefers fast clients, so persistently slow
//!   clients are persistently under-selected — the same trade real
//!   deadline dropout makes (cf. DAdaQuant), visible per round in
//!   `dropped` and a named fairness follow-up in ROADMAP.md.  What is
//!   *not* acceptable is exclusion by identity rather than by cost:
//!   with a constant latency model every candidate ties and the id
//!   tie-break alone would decide who ever trains, so the constructor
//!   rejects deadlines combined with constant profiles
//!   ([`LatencyProfile::is_constant`](crate::sim::latency::LatencyProfile::is_constant)).
//! * **Straggler-aware dispatch**: [`RoundPlan::dispatch`] orders the
//!   cohort for minimum makespan (longest-processing-time-first).
//!   Clients with no observed history dispatch first — an unknown cost
//!   must be assumed long, and simulated latency orders them among
//!   themselves — followed by observed clients, slowest first by the
//!   EWMA of worker-measured round compute times
//!   ([`RoundScheduler::observe`]; the in-process session feeds it
//!   each round's actual `process_round` duration, free of
//!   receive-queue skew — TCP handles cannot separate compute from
//!   socket queueing and contribute nothing).  Observed and simulated
//!   seconds are never compared against each other: they live on
//!   different scales, and ranking them jointly would invert the
//!   heuristic.  Dispatch order is a pure performance heuristic:
//!   results fold in sorted client order regardless (see
//!   `ARCHITECTURE.md`), so the nondeterministic EWMA can never change
//!   a `RunReport`.
//!
//! **What the rest of the system owes absent clients:** a client that is
//! not selected runs nothing — its batch cursor, quantizer stream and
//! error-feedback residual stay exactly where they were, so its next
//! selected round continues the same per-client streams (enforced by
//! `rust/tests/parallel_determinism.rs`).  Server aggregation weights,
//! the fold-overlap weight plan and the `uplink_bits` ledger are all
//! computed over the cohort the server actually received, never over
//! the full registry.

use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::sync::{Arc, Mutex};

use anyhow::{ensure, Result};

use super::arena::ClientArena;
use super::server::{ClientHandle, Server};
use crate::config::RunConfig;
use crate::metrics::RoundRecord;
use crate::sim::faults::{FaultDraw, FaultModel, FaultProfile};
use crate::sim::latency::LatencyModel;
use crate::util::rng::Rng;

/// EWMA smoothing for observed per-client round times (higher = react
/// faster to the latest observation).
const EWMA_ALPHA: f64 = 0.3;

/// Candidate over-sampling factor of the deadline policy: sample this
/// many times the target cohort, then keep the fastest (see module
/// docs).  Fixed rather than a knob until a workload needs otherwise.
pub const DEADLINE_OVERSAMPLE: usize = 2;

/// One round's scheduling decision.
#[derive(Clone, Debug, PartialEq)]
pub struct RoundPlan {
    /// Round index this plan is for.
    pub round: u32,
    /// Participating client ids, ascending (the server's fold order).
    pub selected: Vec<u32>,
    /// The same ids in dispatch order: never-observed clients first
    /// (unknown cost = assume long; simulated latency ranks them),
    /// then observed clients slowest-first by EWMA.  Broadcast in this
    /// order so likely-long jobs start earliest.
    pub dispatch: Vec<u32>,
    /// Candidates sampled but cut by the deadline policy (0 without
    /// `--round-deadline`).  Unsampled clients are not "dropped" — they
    /// were never candidates.
    pub dropped: u32,
    /// Simulated completion time of the cohort's slowest member
    /// (seconds; 0 with the `off` latency profile).
    pub sim_makespan_secs: f64,
}

/// What the simulated fault model decided for one round's cohort
/// (returned by [`RoundScheduler::sim_churn`]; every field is seed-pure).
#[derive(Clone, Debug, PartialEq)]
pub struct ChurnOutcome {
    /// Members that fail the round outright (crash/flaky draws, and
    /// timeouts too stale to ever fold), ascending ids.  Excluded
    /// before dispatch.
    pub failed: Vec<u32>,
    /// Semi-sync late members as `(client id, due round)`: dispatched
    /// normally, but their update is banked by the server and folds at
    /// `due` with a staleness discount.  Empty unless `staleness > 0`.
    pub late: Vec<(u32, u32)>,
    /// Timed-out members whose overshoot exceeded the staleness bound
    /// `k` — they land in `failed` *and* in the round's
    /// `stale_dropped` column.  Always 0 in strict mode.
    pub stale_dropped: u32,
    /// Simulated completion time of the on-time survivors (late members
    /// cost the round nothing — the round closes at quorum without
    /// them).
    pub sim_makespan_secs: f64,
}

/// Per-run scheduler state: selection RNG root, the latency model and
/// the observed-cost EWMA.
pub struct RoundScheduler {
    n_clients: usize,
    /// Target cohort size: `ceil(participation * n_clients)`, in `1..=n`.
    k_target: usize,
    deadline: Option<f64>,
    latency: LatencyModel,
    /// Simulated churn (`--sim-faults`): per-`(client, round)` seeded
    /// crash/stall/drop draws, off by default.
    faults: FaultModel,
    /// The timeout stalled clients are judged against in sim mode (the
    /// server additionally enforces it in real time on the TCP path).
    round_timeout: Option<f64>,
    /// Bounded staleness `k` (semi-sync): a simulated straggler that
    /// overshoots the round timeout by up to `k` round-lengths is
    /// dispatched anyway and *banked* for a later fold instead of
    /// failed.  0 = strict (today's behavior).
    staleness: u32,
    /// Clients still mid-flight from an earlier round: id -> the round
    /// their banked update is due to fold.  A busy client is not
    /// eligible for selection while `round <= due` (it cannot compute
    /// two rounds at once).  Maintained by [`Self::note_late`].
    busy: BTreeMap<u32, u32>,
    /// Root of the per-round selection streams (see module docs).
    select_root: Rng,
    /// Per-client state rows (the dispatch EWMA lives in
    /// `ClientRow::ewma_secs`; 0.0 = never observed).  Shared with the
    /// server's arena when built through
    /// [`Self::from_config_with_arena`], so sample counts and EWMAs are
    /// one 24-byte row per client instead of parallel maps — and the
    /// rows materialize lazily, so a million-client registry costs
    /// nothing until a client is actually observed.
    arena: Arc<Mutex<ClientArena>>,
}

impl RoundScheduler {
    /// Build a scheduler from raw knobs.  `participation` must be in
    /// `(0, 1]`; a deadline, when given, must be positive and finite.
    pub fn new(
        n_clients: usize,
        participation: f32,
        deadline: Option<f64>,
        latency: LatencyModel,
        seed: u64,
    ) -> Result<RoundScheduler> {
        ensure!(n_clients >= 1, "scheduler needs at least one client");
        ensure!(
            participation > 0.0 && participation <= 1.0,
            "participation must be in (0, 1], got {participation}"
        );
        if let Some(d) = deadline {
            ensure!(d.is_finite() && d > 0.0, "round deadline must be positive, got {d}");
            // A deadline is *supposed* to favor fast clients (see the
            // module docs on bias); what it must never do is exclude by
            // identity: with every simulated cost identical (`off`, but
            // also the degenerate `lognormal:<m>:0` / `uniform:0:0`)
            // the (cost, id) tie-break alone would decide the cohort,
            // keeping the lowest ids round after round.
            ensure!(
                !latency.profile().is_constant(),
                "--round-deadline needs a spreading latency model (--sim-latency \
                 uniform:..|lognormal:.. with non-zero spread): with constant costs all \
                 candidates tie and the id tie-break alone would pick the cohort"
            );
        }
        // f32 arithmetic on purpose: the knob is an f32, and widening
        // first would turn e.g. 0.2 into 0.20000000298 and ceil a
        // 10-client cohort to 3 instead of the 2 the user asked for.
        let k_target = (participation * n_clients as f32).ceil() as usize;
        let k_target = k_target.clamp(1, n_clients);
        Ok(RoundScheduler {
            n_clients,
            k_target,
            deadline,
            latency,
            faults: FaultModel::new(FaultProfile::Off, seed),
            round_timeout: None,
            staleness: 0,
            busy: BTreeMap::new(),
            select_root: Rng::new(seed).derive("sched"),
            arena: Arc::new(Mutex::new(ClientArena::new())),
        })
    }

    /// Attach a fault model, plus the round timeout its stall draws are
    /// judged against in sim mode (`--sim-faults` / `--round-timeout`).
    /// Off by default.
    pub fn with_faults(
        mut self,
        faults: FaultModel,
        round_timeout: Option<f64>,
    ) -> RoundScheduler {
        self.faults = faults;
        self.round_timeout = round_timeout;
        self
    }

    /// Set the bounded staleness `k` for semi-synchronous rounds
    /// (`RoundPolicy::tolerance.staleness`).  0 (the default) keeps
    /// strict synchronous churn semantics.
    pub fn with_staleness(mut self, k: u32) -> RoundScheduler {
        self.staleness = k;
        self
    }

    /// Build from a run's config (the session and `feddq serve` path).
    pub fn from_config(cfg: &RunConfig, n_clients: usize) -> Result<RoundScheduler> {
        Ok(Self::new(
            n_clients,
            cfg.round.cohort.participation,
            cfg.round.cohort.deadline,
            LatencyModel::new(cfg.sim_latency, cfg.seed),
            cfg.seed,
        )?
        .with_faults(
            FaultModel::new(cfg.sim_faults, cfg.seed),
            cfg.round.tolerance.round_timeout,
        )
        .with_staleness(cfg.round.tolerance.staleness))
    }

    /// Build from a run's config, sharing the server's client arena so
    /// dispatch EWMAs and reported sample counts live in the same
    /// 24-byte rows (one resident-bytes ledger instead of two).
    pub fn from_config_with_arena(
        cfg: &RunConfig,
        n_clients: usize,
        arena: Arc<Mutex<ClientArena>>,
    ) -> Result<RoundScheduler> {
        Ok(Self::from_config(cfg, n_clients)?.with_arena(arena))
    }

    /// Replace the scheduler's (private) arena with a shared one.  Any
    /// EWMAs already written to the old arena are dropped — call before
    /// the first `observe`.
    pub fn with_arena(mut self, arena: Arc<Mutex<ClientArena>>) -> RoundScheduler {
        self.arena = arena;
        self
    }

    /// Target cohort size `ceil(participation * n)`.
    pub fn cohort_target(&self) -> usize {
        self.k_target
    }

    /// Draw `k` distinct client ids for `round` (partial Fisher–Yates
    /// over `0..n` on the round-keyed stream).  Pure in `(seed, round)`.
    ///
    /// Sparse in `k`, not `n`: instead of materializing the identity
    /// array `0..n` and swapping into it, only the *displacements* from
    /// identity are tracked in a map.  Iteration `i` of the dense
    /// algorithm reads positions `i` and `j >= i` and never revisits a
    /// position below `i`, so recording just the far swap ends
    /// reproduces the dense draw sequence exactly — the RNG stream and
    /// the returned ids are bit-identical to the historical O(n)
    /// version, at O(k) time and memory (the million-client scale-out
    /// requirement; asserted against a dense reference in tests).
    fn sample(&self, round: u32, k: usize) -> Vec<u32> {
        let mut rng = self.select_root.derive(&format!("round{round}"));
        let n = self.n_clients;
        let k = k.min(n);
        let mut displaced: HashMap<usize, u32> = HashMap::with_capacity(k);
        let mut out: Vec<u32> = Vec::with_capacity(k);
        for i in 0..k {
            let j = i + rng.below((n - i) as u64) as usize;
            // ids[p] = displaced[p] if a prior swap moved something
            // there, else the identity value p.
            let vi = displaced.get(&i).copied().unwrap_or(i as u32);
            let vj = displaced.get(&j).copied().unwrap_or(j as u32);
            out.push(vj);
            // Position i is never read again; position j now holds what
            // was at i.
            displaced.insert(j, vi);
        }
        out
    }

    /// Dispatch sort key for one cohort member: a `(tier, cost)` pair.
    /// Tier 0 = never observed (assume potentially slow, dispatch
    /// before all observed clients; simulated latency ranks them among
    /// themselves), tier 1 = observed (ranked by EWMA).  Observed and
    /// simulated seconds live on different scales, so they are ordered
    /// by tier instead of compared directly — jointly ranking them
    /// would put every unobserved client's ~1s *simulated* cost ahead
    /// of a true straggler's ~10ms *measured* cost and invert the
    /// longest-first heuristic.
    fn dispatch_key(&self, arena: &ClientArena, client_id: u32, round: u32) -> (u8, f64) {
        let e = arena.ewma(client_id);
        if e > 0.0 {
            (1, e)
        } else {
            (0, self.latency.round_secs(client_id, round))
        }
    }

    /// Plan round `round`.  Selection (and `dropped` / the simulated
    /// makespan) is a pure function of the seed and the scheduling
    /// knobs; only [`RoundPlan::dispatch`]'s order also reads the
    /// observed EWMA.
    pub fn plan_round(&self, round: u32) -> RoundPlan {
        // (sim_secs, id) pairs of the cohort.
        let (mut cohort, dropped) = match self.deadline {
            Some(deadline) => {
                let k_cand = (self.k_target * DEADLINE_OVERSAMPLE).min(self.n_clients);
                let mut timed: Vec<(f64, u32)> = self
                    .sample(round, k_cand)
                    .into_iter()
                    .map(|id| (self.latency.round_secs(id, round), id))
                    .collect();
                timed.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
                let mut keep: Vec<(f64, u32)> = timed
                    .iter()
                    .take(self.k_target)
                    .filter(|&&(t, _)| t <= deadline)
                    .copied()
                    .collect();
                if keep.is_empty() {
                    // Nobody makes the deadline: keep the fastest
                    // candidate so the round still has a cohort (its
                    // makespan will exceed the deadline — visible in
                    // the record).
                    keep.push(timed[0]);
                }
                let dropped = (k_cand - keep.len()) as u32;
                // Seed-pure slowness signal for the bit-budget
                // controller: every over-sampled candidate cut here
                // (too slow for the deadline, or the slow tail beyond
                // the k-target) is flagged dropped in the shared
                // arena.  The flag persists until the client's next
                // clean dispatch clears it (see [`Self::sim_churn`]),
                // so a budget planned rounds later still sees it.
                // `|=` writes are idempotent and the plan itself never
                // reads flags, so re-planning a round stays pure.
                let kept: BTreeSet<u32> = keep.iter().map(|&(_, id)| id).collect();
                let mut arena = self.arena.lock().expect("arena poisoned");
                for &(_, id) in &timed {
                    if !kept.contains(&id) {
                        arena.mark_dropped(id);
                    }
                }
                drop(arena);
                (keep, dropped)
            }
            None => {
                let cohort: Vec<(f64, u32)> = self
                    .sample(round, self.k_target)
                    .into_iter()
                    .map(|id| (self.latency.round_secs(id, round), id))
                    .collect();
                (cohort, 0)
            }
        };
        // Semi-sync: a client still mid-flight from an earlier round (its
        // banked update folds at `due`) cannot compute two rounds at
        // once — deterministically ineligible while `round <= due`.
        if !self.busy.is_empty() {
            let full = cohort.clone();
            cohort.retain(|&(_, id)| {
                self.busy.get(&id).map_or(true, |&due| round > due)
            });
            if cohort.is_empty() {
                // Every sampled member is mid-flight: keep the lowest id
                // so the round still has a cohort (degenerate guard,
                // mirroring the deadline/churn fallbacks).
                let lowest =
                    full.into_iter().min_by_key(|&(_, id)| id).expect("non-empty sample");
                cohort.push(lowest);
            }
        }
        let sim_makespan_secs = cohort.iter().map(|&(t, _)| t).fold(0.0f64, f64::max);
        let mut selected: Vec<u32> = cohort.iter().map(|&(_, id)| id).collect();
        selected.sort_unstable();
        // Longest-first dispatch: unobserved clients (tier 0) first,
        // ranked by simulated latency; then observed clients (tier 1)
        // by EWMA — see [`Self::dispatch_key`].  Ties (e.g. the `off`
        // profile with no observations yet) fall back to ascending id.
        // Keys are computed once per cohort member, not inside the
        // comparator.
        let arena = self.arena.lock().expect("arena poisoned");
        let mut keyed: Vec<(u8, f64, u32)> = selected
            .iter()
            .map(|&id| {
                let (tier, cost) = self.dispatch_key(&arena, id, round);
                (tier, cost, id)
            })
            .collect();
        drop(arena);
        keyed.sort_by(|a, b| {
            a.0.cmp(&b.0).then(b.1.total_cmp(&a.1)).then(a.2.cmp(&b.2))
        });
        let dispatch: Vec<u32> = keyed.into_iter().map(|(_, _, id)| id).collect();
        RoundPlan { round, selected, dispatch, dropped, sim_makespan_secs }
    }

    /// Decide what the simulated fault model does to round `plan.round`:
    /// which cohort members fail, which are merely *late* (semi-sync
    /// staleness), and what the on-time survivors' makespan is.
    ///
    /// Every field of the returned [`ChurnOutcome`] is a pure function
    /// of `(seed, profile, round, client id)` — never of arrival order
    /// or thread count — which is what keeps faulty runs
    /// bit-reproducible.  A failed client is excluded *before*
    /// dispatch, so (like an unselected client) its batch cursor,
    /// quantizer stream and error-feedback residual stay banked for its
    /// next surviving round.
    ///
    /// Fault/timeout/staleness interaction: a `Drop` draw fails
    /// outright; a `Stall(s)` draw adds `s` to the client's simulated
    /// completion time `t`.  With `--round-timeout T` and `t > T`, the
    /// member overshoots by `s = ceil((t - T) / T)` round-lengths:
    ///
    /// * strict mode (`staleness == 0`): the member fails, contributing
    ///   at most `T` to the makespan (the coordinator stops waiting) —
    ///   exactly the pre-semi-sync behavior;
    /// * semi-sync, `s <= k`: the member is **late** — still
    ///   dispatched, but its update is banked and folds at round
    ///   `plan.round + s` with a `1/(1+s)` discount.  It costs this
    ///   round *nothing* (the round closes at quorum without it — the
    ///   makespan win semi-sync exists for);
    /// * semi-sync, `s > k`: too stale to ever fold — failed, and
    ///   counted in [`ChurnOutcome::stale_dropped`].
    ///
    /// If no member would be on time, the lowest selected id is
    /// promoted back to on-time so the round can still meet its quorum
    /// floor of one update (mirroring the deadline policy's
    /// nobody-meets-it fallback).
    pub fn sim_churn(&self, plan: &RoundPlan) -> ChurnOutcome {
        if self.faults.is_off() {
            return ChurnOutcome {
                failed: Vec::new(),
                late: Vec::new(),
                stale_dropped: 0,
                sim_makespan_secs: plan.sim_makespan_secs,
            };
        }
        let stall_of = |id: u32| -> Option<f64> {
            // None = dropped; Some(s) = survives the draw with extra
            // stall s (0 for a clean FaultDraw::None).
            match self.faults.draw(id, plan.round) {
                FaultDraw::Drop => None,
                FaultDraw::Stall(s) => Some(s),
                FaultDraw::None => Some(0.0),
            }
        };
        let mut failed: Vec<u32> = Vec::new();
        let mut late: Vec<(u32, u32)> = Vec::new();
        let mut over_k: Vec<u32> = Vec::new();
        let mut makespan = 0.0f64;
        for &id in &plan.selected {
            let Some(stall) = stall_of(id) else {
                failed.push(id);
                continue;
            };
            let t = self.latency.round_secs(id, plan.round) + stall;
            match self.round_timeout {
                Some(timeout) if t > timeout => {
                    // Overshoot in round-lengths (>= 1 by construction).
                    let s = (((t - timeout) / timeout).ceil() as u32).max(1);
                    if self.staleness > 0 && s <= self.staleness {
                        // Late, not lost: banked to fold at `round + s`.
                        late.push((id, plan.round + s));
                    } else {
                        // Timed out for good: the coordinator gives up
                        // at `timeout`, so that is all it costs.
                        failed.push(id);
                        if self.staleness > 0 {
                            over_k.push(id);
                        }
                        makespan = makespan.max(timeout);
                    }
                }
                _ => makespan = makespan.max(t),
            }
        }
        if failed.len() + late.len() == plan.selected.len() {
            // No on-time member: promote the lowest selected id so the
            // round can still meet its quorum floor of one update.
            let id = plan.selected[0];
            if let Some(pos) = failed.iter().position(|&f| f == id) {
                failed.remove(pos);
            }
            if let Some(pos) = late.iter().position(|&(l, _)| l == id) {
                late.remove(pos);
            }
            if let Some(pos) = over_k.iter().position(|&f| f == id) {
                over_k.remove(pos);
            }
            let stall = stall_of(id).unwrap_or(0.0);
            makespan = makespan.max(self.latency.round_secs(id, plan.round) + stall);
        }
        // Publish the outcome as arena flags for the bit-budget
        // controller: a failed member is marked dropped, a banked-late
        // member late.  Derived only from (seed, profile, round, id) —
        // never from arrival order — so every thread count and
        // topology writes identical flags, and re-simulating a round
        // `|=`s the same bits again.  Forgiveness (clearing a flag
        // once the client answers a round cleanly) happens *after* the
        // round in [`run_scheduled_round`], so the budget planner
        // inside `Server::run_round` still sees last round's flag when
        // it allocates this round's bits.
        {
            let mut arena = self.arena.lock().expect("arena poisoned");
            for &id in &failed {
                arena.mark_dropped(id);
            }
            for &(id, _) in &late {
                arena.mark_late(id);
            }
        }
        ChurnOutcome {
            failed,
            late,
            stale_dropped: over_k.len() as u32,
            sim_makespan_secs: makespan,
        }
    }

    /// Forgiveness for the bit-budget controller's slowness flags: a
    /// dispatched member that answered its round on time (not in the
    /// late plan) sheds any flag left by an earlier deadline cut or
    /// fault draw.  Both round drivers call this *after*
    /// `Server::run_round` — the budget planner inside must read the
    /// pre-forgiveness flags when it allocates the round's bits.
    /// Dispatch and lateness are seed-pure, so the flag trajectory is
    /// bit-identical across threads and topologies.
    pub fn forgive_on_time(&self, dispatched: &[u32], late: &[(u32, u32)]) {
        let mut arena = self.arena.lock().expect("arena poisoned");
        for &id in dispatched {
            if !late.iter().any(|&(l, _)| l == id) {
                arena.clear_round_flags(id);
            }
        }
    }

    /// Record the round's late members as mid-flight: each is ineligible
    /// for selection until after its `due` round (see
    /// [`Self::plan_round`]), when its banked update folds.  Entries
    /// already past their due round are pruned.
    pub fn note_late(&mut self, round: u32, late: &[(u32, u32)]) {
        self.busy.retain(|_, &mut due| due > round);
        for &(id, due) in late {
            self.busy.insert(id, due);
        }
    }

    /// Feed one observed per-client round time (seconds) into the EWMA
    /// that drives slowest-first dispatch.  Non-finite or non-positive
    /// observations and unknown ids are ignored.
    pub fn observe(&mut self, client_id: u32, secs: f64) {
        if client_id as usize >= self.n_clients {
            return;
        }
        if !secs.is_finite() || secs <= 0.0 {
            return;
        }
        let mut arena = self.arena.lock().expect("arena poisoned");
        let e = arena.ewma(client_id);
        let blended =
            if e == 0.0 { secs } else { EWMA_ALPHA * secs + (1.0 - EWMA_ALPHA) * e };
        arena.set_ewma(client_id, blended);
    }
}

/// Drive one scheduled round end to end: plan, decide simulated churn,
/// reorder the registry so the *surviving* cohort is the slice prefix,
/// run that prefix through the server, patch the plan-side fields
/// (`selected`, `dropped`, `failed`, `sim_makespan_secs`) into the
/// record, and feed the cohort's observed compute times back into the
/// dispatch EWMA.  The in-process session and the TCP server both call
/// this, so the scheduling (and fault) protocol cannot diverge between
/// drivers — sim-failed clients never receive a broadcast on either
/// path, which is what keeps local and TCP runs bit-identical.
pub fn run_scheduled_round(
    scheduler: &mut RoundScheduler,
    server: &mut Server,
    clients: &mut [Box<dyn ClientHandle + '_>],
    round: u32,
    evaluate: bool,
) -> Result<RoundRecord> {
    let plan = scheduler.plan_round(round);
    let churn = scheduler.sim_churn(&plan);
    let dispatch: Vec<u32> = if churn.failed.is_empty() {
        plan.dispatch.clone()
    } else {
        // On-time survivors and *late* members keep their dispatch
        // (slowest-first) order — late members still compute, their
        // fold is just deferred.  Failed members are simply never
        // dispatched, exactly like unselected clients (their streams
        // stay banked — see module docs).
        plan.dispatch.iter().copied().filter(|id| !churn.failed.contains(id)).collect()
    };
    scheduler.note_late(round, &churn.late);
    let swaps = order_clients(clients, &dispatch);
    let rec =
        server.run_round(round, &mut clients[..dispatch.len()], &churn.late, evaluate);
    // Put the registry back in id order whether the round succeeded or
    // not — the O(k) ordering below depends on it next round.
    restore_clients(clients, swaps);
    let mut rec = rec?;
    scheduler.forgive_on_time(&dispatch, &churn.late);
    // Report over the *planned* cohort: `selected` counts everyone the
    // scheduler picked, `failed` adds the sim-failed members on top of
    // any real transport failures the server recorded, `stale_dropped`
    // adds sim members too stale to ever fold on top of real drains.
    rec.selected = plan.selected.len() as u32;
    rec.failed += churn.failed.len() as u32;
    rec.stale_dropped += churn.stale_dropped;
    rec.dropped = plan.dropped;
    rec.sim_makespan_secs = churn.sim_makespan_secs;
    for &(id, secs) in server.arrivals() {
        scheduler.observe(id, secs);
    }
    Ok(rec)
}

/// Reorder `clients` so `dispatch`'s ids form the slice prefix
/// `clients[..dispatch.len()]`, in dispatch (slowest-first) order.  The
/// session and the TCP server both call this (via
/// [`run_scheduled_round`]) before handing the prefix to
/// `Server::run_round`.
///
/// O(k) in the cohort size, not O(n log n) in the registry: the
/// registry is required to be in id order (`clients[p].id() == p`, how
/// both drivers construct it), each cohort member is swapped into its
/// prefix slot directly, and the returned swap log lets
/// [`restore_clients`] undo the permutation afterwards — so a
/// 1000-client cohort touches at most `2k` entries of a million-client
/// registry per round instead of re-sorting all of it.
pub fn order_clients(
    clients: &mut [Box<dyn ClientHandle + '_>],
    dispatch: &[u32],
) -> Vec<(usize, usize)> {
    // id -> current position, for the (at most k) handles a prior swap
    // displaced from their home slot `id as usize`.
    let mut pos_of: HashMap<u32, usize> = HashMap::with_capacity(dispatch.len());
    let mut swaps: Vec<(usize, usize)> = Vec::with_capacity(dispatch.len());
    for (i, &id) in dispatch.iter().enumerate() {
        let j = pos_of.get(&id).copied().unwrap_or(id as usize);
        debug_assert!(
            j < clients.len() && clients[j].id() == id,
            "client registry not in id order (cohort id {id} not at slot {j})"
        );
        if i == j {
            continue;
        }
        let displaced = clients[i].id();
        clients.swap(i, j);
        pos_of.insert(displaced, j);
        swaps.push((i, j));
    }
    debug_assert!(
        clients
            .iter()
            .take(dispatch.len())
            .zip(dispatch)
            .all(|(c, &id)| c.id() == id),
        "cohort ids missing from the client registry"
    );
    swaps
}

/// Undo an [`order_clients`] permutation (replay its swap log in
/// reverse), returning the registry to id order for the next round.
pub fn restore_clients(clients: &mut [Box<dyn ClientHandle + '_>], swaps: Vec<(usize, usize)>) {
    for &(i, j) in swaps.iter().rev() {
        clients.swap(i, j);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::latency::LatencyProfile;

    fn sched(n: usize, p: f32, deadline: Option<f64>, profile: LatencyProfile) -> RoundScheduler {
        RoundScheduler::new(n, p, deadline, LatencyModel::new(profile, 17), 17).unwrap()
    }

    #[test]
    fn cohort_size_is_ceil_of_fraction() {
        assert_eq!(sched(10, 1.0, None, LatencyProfile::Off).cohort_target(), 10);
        assert_eq!(sched(10, 0.5, None, LatencyProfile::Off).cohort_target(), 5);
        assert_eq!(sched(10, 0.21, None, LatencyProfile::Off).cohort_target(), 3);
        assert_eq!(sched(10, 0.01, None, LatencyProfile::Off).cohort_target(), 1);
        let off = || LatencyModel::new(LatencyProfile::Off, 1);
        assert!(RoundScheduler::new(10, 0.0, None, off(), 1).is_err());
        assert!(RoundScheduler::new(10, 1.5, None, off(), 1).is_err());
        assert!(RoundScheduler::new(10, 0.5, Some(0.0), off(), 1).is_err());
    }

    #[test]
    fn selection_is_seed_pure_and_observation_blind() {
        let a = sched(10, 0.5, None, LatencyProfile::Off);
        let mut b = sched(10, 0.5, None, LatencyProfile::Off);
        // feeding observations must not move selection (only dispatch)
        b.observe(3, 100.0);
        b.observe(7, 0.001);
        for m in 0..20u32 {
            let pa = a.plan_round(m);
            let pb = b.plan_round(m);
            assert_eq!(pa.selected, pb.selected, "round {m}");
            assert_eq!(pa.selected.len(), 5);
            // selected is sorted and duplicate-free
            assert!(pa.selected.windows(2).all(|w| w[0] < w[1]));
            // planning twice from the same state is identical
            assert_eq!(a.plan_round(m), a.plan_round(m));
        }
        // different seeds pick different cohorts somewhere
        let c = RoundScheduler::new(
            10, 0.5, None, LatencyModel::new(LatencyProfile::Off, 18), 18,
        )
        .unwrap();
        assert!((0..20u32).any(|m| c.plan_round(m).selected != a.plan_round(m).selected));
        // and cohorts rotate across rounds
        assert!((1..20u32).any(|m| a.plan_round(m).selected != a.plan_round(0).selected));
    }

    #[test]
    fn full_participation_selects_everyone() {
        let s = sched(7, 1.0, None, LatencyProfile::Off);
        let p = s.plan_round(3);
        assert_eq!(p.selected, (0..7u32).collect::<Vec<_>>());
        assert_eq!(p.dropped, 0);
        assert_eq!(p.sim_makespan_secs, 0.0);
        // off-profile, no observations: dispatch falls back to id order
        assert_eq!(p.dispatch, p.selected);
    }

    #[test]
    fn observed_ewma_drives_slowest_first_dispatch() {
        let mut s = sched(6, 1.0, None, LatencyProfile::Off);
        s.observe(2, 9.0);
        s.observe(4, 3.0);
        s.observe(0, 1.0);
        let p = s.plan_round(0);
        assert_eq!(p.selected, vec![0, 1, 2, 3, 4, 5]);
        // never-observed clients first (unknown = assume long; Off
        // profile ties, so id order), then observed slowest-first —
        // observed EWMA seconds are never ranked against simulated
        // seconds.
        assert_eq!(p.dispatch, vec![1, 3, 5, 2, 4, 0]);
        // EWMA blends rather than replaces
        s.observe(2, 1.0);
        let e = 0.3 * 1.0 + 0.7 * 9.0;
        let p2 = s.plan_round(0);
        assert_eq!(p2.dispatch[3], 2, "still slowest observed at ewma {e}");
        // once everyone is observed, dispatch is pure slowest-first:
        // ewma = {0: 1.0, 1: 5.0, 2: 6.6, 3: 0.5, 4: 3.0, 5: 7.0}
        s.observe(1, 5.0);
        s.observe(3, 0.5);
        s.observe(5, 7.0);
        assert_eq!(s.plan_round(0).dispatch, vec![5, 2, 1, 4, 0, 3]);
        // garbage observations are ignored
        s.observe(99, 1.0);
        s.observe(1, f64::NAN);
        s.observe(1, -3.0);
        assert_eq!(s.plan_round(0).selected, p.selected);
    }

    #[test]
    fn deadline_keeps_fastest_candidates_and_counts_drops() {
        // lognormal stragglers against a deadline barely above the
        // median: roughly half of all candidates miss it, so across 30
        // rounds some round must cut inside the first-k — and everyone
        // kept simulates in under the deadline.
        let deadline = 0.85;
        let profile = LatencyProfile::LogNormal { median: 0.8, sigma: 0.7 };
        let s = sched(20, 0.25, Some(deadline), profile);
        let k = s.cohort_target(); // 5
        let mut saw_drop_beyond_oversample_floor = false;
        for m in 0..30u32 {
            let p = s.plan_round(m);
            assert!(!p.selected.is_empty() && p.selected.len() <= k, "round {m}");
            // candidates = 2k; selected + dropped must account for all
            assert_eq!(p.selected.len() + p.dropped as usize, 2 * k, "round {m}");
            if p.selected.len() == 1 && p.sim_makespan_secs > deadline {
                // the nobody-meets-it fallback: single fastest kept
                continue;
            }
            assert!(
                p.sim_makespan_secs <= deadline,
                "round {m}: makespan {}",
                p.sim_makespan_secs
            );
            if p.dropped as usize > k {
                saw_drop_beyond_oversample_floor = true;
            }
        }
        assert!(
            saw_drop_beyond_oversample_floor,
            "a {deadline}s deadline under lognormal(0.8, 0.7) should cut inside the first-k somewhere"
        );
        // deterministic: same seed, same plans
        let s2 = sched(20, 0.25, Some(deadline), profile);
        for m in 0..30u32 {
            assert_eq!(s.plan_round(m), s2.plan_round(m));
        }
    }

    #[test]
    fn deadline_without_a_latency_model_is_rejected() {
        // With the `off` profile every candidate ties at 0 simulated
        // seconds and the id tie-break would keep the lowest ids every
        // round — permanently starving high-id clients.  The
        // combination must be rejected up front, not silently biased.
        for profile in [
            LatencyProfile::Off,
            LatencyProfile::LogNormal { median: 1.0, sigma: 0.0 },
            LatencyProfile::Uniform { lo: 0.0, hi: 0.0 },
        ] {
            let err =
                RoundScheduler::new(10, 0.3, Some(5.0), LatencyModel::new(profile, 17), 17)
                    .unwrap_err();
            assert!(format!("{err:#}").contains("latency model"), "{profile:?}: {err:#}");
        }
        // ...while a real model makes the same knobs valid.
        let s = sched(10, 0.3, Some(5.0), LatencyProfile::Uniform { lo: 0.5, hi: 1.5 });
        let p = s.plan_round(4);
        assert!(!p.selected.is_empty() && p.selected.len() <= 3);
        assert_eq!(p.selected.len() + p.dropped as usize, 6);
    }

    #[test]
    fn churn_is_off_by_default_and_a_pure_function_of_seed() {
        let s = sched(10, 1.0, None, LatencyProfile::Off);
        let p = s.plan_round(2);
        let quiet = s.sim_churn(&p);
        assert!(quiet.failed.is_empty() && quiet.late.is_empty());
        assert_eq!(quiet.stale_dropped, 0);
        assert_eq!(quiet.sim_makespan_secs, p.sim_makespan_secs);

        let faulty = |seed| {
            sched(10, 1.0, None, LatencyProfile::Off)
                .with_faults(FaultModel::new(FaultProfile::Crash { p: 0.4 }, seed), None)
        };
        let a = faulty(17);
        let b = faulty(17);
        let mut saw_failure = false;
        for m in 0..20u32 {
            let plan = a.plan_round(m);
            let ca = a.sim_churn(&plan);
            let cb = b.sim_churn(&plan);
            assert_eq!(ca, cb, "round {m}");
            // failed set is sorted, duplicate-free, within the cohort
            assert!(ca.failed.windows(2).all(|w| w[0] < w[1]), "round {m}");
            assert!(ca.failed.iter().all(|id| plan.selected.contains(id)), "round {m}");
            saw_failure |= !ca.failed.is_empty();
        }
        assert!(saw_failure, "crash:0.4 over 20 rounds of 10 clients must fail someone");
        // a different seed fails a different set somewhere
        let c = faulty(18);
        assert!((0..20u32).any(|m| {
            let plan = a.plan_round(m);
            c.sim_churn(&plan).failed != a.sim_churn(&plan).failed
        }));
    }

    #[test]
    fn certain_crash_keeps_one_survivor() {
        let s = sched(8, 1.0, None, LatencyProfile::Off)
            .with_faults(FaultModel::new(FaultProfile::Crash { p: 1.0 }, 17), None);
        let p = s.plan_round(0);
        let failed = s.sim_churn(&p).failed;
        // everyone draws Drop, but the lowest id is kept so the round
        // still has a cohort
        assert_eq!(failed, (1..8u32).collect::<Vec<_>>());
    }

    #[test]
    fn stalls_extend_the_makespan_and_timeouts_cut_them() {
        let profile = LatencyProfile::Uniform { lo: 0.5, hi: 1.0 };
        let base = sched(10, 1.0, None, profile);
        let stall = FaultModel::new(FaultProfile::Stall { p: 1.0, secs: 4.0 }, 17);
        // No timeout: every client stalls 4s on top of its latency, so
        // nobody fails and the makespan grows by exactly the stall.
        let s = sched(10, 1.0, None, profile).with_faults(stall.clone(), None);
        let p = base.plan_round(1);
        let c = s.sim_churn(&p);
        assert!(c.failed.is_empty() && c.late.is_empty());
        assert_eq!(c.sim_makespan_secs, p.sim_makespan_secs + 4.0);
        // A 2s timeout: latency + 4s > 2s for everyone, so all time out;
        // the lowest id is kept and the timeout caps what the rest cost.
        let st = sched(10, 1.0, None, profile).with_faults(stall, Some(2.0));
        let ct = st.sim_churn(&p);
        assert_eq!(ct.failed, (1..10u32).collect::<Vec<_>>());
        assert_eq!(ct.stale_dropped, 0, "strict mode never counts stale drops");
        assert!(
            ct.sim_makespan_secs > 4.0,
            "survivor's real completion dominates: {}",
            ct.sim_makespan_secs
        );
    }

    #[test]
    fn staleness_turns_timeouts_into_late_members() {
        // latency in [0.5, 1.2), stall 4s, timeout 2s: every member
        // overshoots by t - 2 in (2.5, 3.2) seconds = ceil(...) / 2 ->
        // s = 2 round-lengths for everyone, deterministically.
        let profile = LatencyProfile::Uniform { lo: 0.5, hi: 1.0 };
        let stall = FaultModel::new(FaultProfile::Stall { p: 1.0, secs: 4.0 }, 17);
        let plan = sched(10, 1.0, None, profile).plan_round(1);

        // k = 2: the overshoot fits the bound — everyone is late (except
        // the promoted quorum-floor member), nobody fails.
        let k2 = sched(10, 1.0, None, profile)
            .with_faults(stall.clone(), Some(2.0))
            .with_staleness(2);
        let c = k2.sim_churn(&plan);
        assert!(c.failed.is_empty(), "late members are not failures: {:?}", c.failed);
        assert_eq!(c.stale_dropped, 0);
        // the lowest id was promoted on-time (quorum floor); the other
        // nine are late with due = round + 2
        assert_eq!(c.late.len(), 9);
        assert!(c.late.iter().all(|&(id, due)| id != 0 && due == 3), "{:?}", c.late);
        // late members cost the round nothing; the promoted survivor's
        // full completion (latency + 4s stall) is the makespan
        assert!(c.sim_makespan_secs > 4.0 && c.sim_makespan_secs < 6.0);

        // k = 1: the same overshoot exceeds the bound — strict failure
        // semantics return, but now visibly counted as stale drops.
        let k1 = sched(10, 1.0, None, profile)
            .with_faults(stall.clone(), Some(2.0))
            .with_staleness(1);
        let c1 = k1.sim_churn(&plan);
        assert_eq!(c1.failed, (1..10u32).collect::<Vec<_>>());
        assert!(c1.late.is_empty());
        assert_eq!(c1.stale_dropped, 9);

        // k = 0 must be bit-identical to the pre-semi-sync outcome.
        let k0 = sched(10, 1.0, None, profile).with_faults(stall, Some(2.0));
        let c0 = k0.sim_churn(&plan);
        assert_eq!(c0.failed, (1..10u32).collect::<Vec<_>>());
        assert!(c0.late.is_empty());
        assert_eq!(c0.stale_dropped, 0);
    }

    #[test]
    fn late_members_are_ineligible_until_their_due_round() {
        let mut s = sched(6, 1.0, None, LatencyProfile::Off);
        // client 2 is mid-flight until round 3 (due = 3), client 4
        // until round 2
        s.note_late(1, &[(2, 3), (4, 2)]);
        assert_eq!(s.plan_round(2).selected, vec![0, 1, 3, 5]);
        assert_eq!(s.plan_round(3).selected, vec![0, 1, 3, 4, 5]);
        assert_eq!(s.plan_round(4).selected, vec![0, 1, 2, 3, 4, 5]);
        // pruning: noting later rounds drops expired entries
        s.note_late(4, &[]);
        assert_eq!(s.plan_round(2).selected, (0..6).collect::<Vec<u32>>());
        // degenerate guard: if every sampled member is mid-flight the
        // lowest id is kept so the round still has a cohort
        let mut all = sched(3, 1.0, None, LatencyProfile::Off);
        all.note_late(0, &[(0, 9), (1, 9), (2, 9)]);
        assert_eq!(all.plan_round(1).selected, vec![0]);
    }

    #[test]
    fn sparse_sampler_matches_dense_reference() {
        // The O(k) sampler must reproduce the historical O(n) partial
        // Fisher–Yates bit-for-bit: same RNG stream, same ids, same
        // order — otherwise every seeded run's cohorts would shift.
        for &n in &[1usize, 7, 100, 1000] {
            let s = sched(n, 1.0, None, LatencyProfile::Off);
            for round in 0..5u32 {
                for &k in &[1usize, 2, n / 2 + 1, n, n + 5] {
                    let mut rng =
                        Rng::new(17).derive("sched").derive(&format!("round{round}"));
                    let mut ids: Vec<u32> = (0..n as u32).collect();
                    for i in 0..k.min(n) {
                        let j = i + rng.below((n - i) as u64) as usize;
                        ids.swap(i, j);
                    }
                    ids.truncate(k.min(n));
                    assert_eq!(s.sample(round, k), ids, "n={n} k={k} round={round}");
                }
            }
        }
    }

    /// An inert handle for registry-permutation tests (never dispatched).
    struct NullHandle(u32);

    impl ClientHandle for NullHandle {
        fn id(&self) -> u32 {
            self.0
        }
        fn send(&mut self, _msg: &crate::wire::messages::Message) -> Result<()> {
            Ok(())
        }
        fn recv_update(&mut self) -> Result<crate::wire::messages::Update> {
            anyhow::bail!("inert test handle")
        }
    }

    fn registry(n: u32) -> Vec<Box<dyn ClientHandle + 'static>> {
        (0..n).map(|i| Box::new(NullHandle(i)) as Box<dyn ClientHandle>).collect()
    }

    #[test]
    fn ordering_touches_only_the_cohort_and_restores_id_order() {
        let n = 100_000u32;
        let mut clients = registry(n);
        let dispatch: Vec<u32> = vec![500, 3, 99_999, 42, 7];
        let swaps = order_clients(&mut clients, &dispatch);
        for (i, &id) in dispatch.iter().enumerate() {
            assert_eq!(clients[i].id(), id, "prefix slot {i}");
        }
        // Touched-entry regression: at most one swap (two touched slots)
        // per cohort member — never an O(n) re-sort of the registry.
        assert!(
            swaps.len() <= dispatch.len(),
            "{} swaps for a {}-member cohort",
            swaps.len(),
            dispatch.len()
        );
        restore_clients(&mut clients, swaps);
        assert!(clients.iter().enumerate().all(|(p, c)| c.id() == p as u32));
    }

    #[test]
    fn ordering_handles_cohorts_that_displace_each_other() {
        // Cohort members whose home slots overlap the prefix exercise
        // the displaced-position bookkeeping.
        for dispatch in
            [vec![2, 0, 1], vec![1, 0], vec![3, 2, 1, 0], vec![0, 1, 2], vec![5, 4, 0]]
        {
            let mut clients = registry(6);
            let swaps = order_clients(&mut clients, &dispatch);
            for (i, &id) in dispatch.iter().enumerate() {
                assert_eq!(clients[i].id(), id, "{dispatch:?} slot {i}");
            }
            assert!(swaps.len() <= dispatch.len());
            restore_clients(&mut clients, swaps);
            assert!(clients.iter().enumerate().all(|(p, c)| c.id() == p as u32));
        }
    }

    #[test]
    fn ewma_lives_in_the_shared_arena() {
        let arena = Arc::new(Mutex::new(ClientArena::new()));
        let mut s = sched(6, 1.0, None, LatencyProfile::Off).with_arena(arena.clone());
        s.observe(2, 9.0);
        assert_eq!(arena.lock().unwrap().ewma(2), 9.0);
        // Dispatch reads straight from the shared rows: a value written
        // by the other owner (the server side) drives ordering too.
        arena.lock().unwrap().set_ewma(5, 50.0);
        let p = s.plan_round(0);
        assert_eq!(p.dispatch, vec![0, 1, 3, 4, 5, 2]);
        // Out-of-registry observations must not materialize rows.
        s.observe(99, 1.0);
        assert!(arena.lock().unwrap().len() <= 6);
    }
}
