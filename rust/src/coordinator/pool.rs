//! Persistent worker pool shared by the round engine's two sides.
//!
//! The single-process `Session` used to run every client's local round
//! sequentially on the session thread; with tau SGD steps per client
//! this serialized the entire compute of a round.  The pool runs
//! [`ClientState::process_round`] for many clients concurrently on a
//! fixed set of `std::thread` workers (the `threads` knob in
//! [`RunConfig`](crate::config::RunConfig); default min(n_clients,
//! cores)).
//!
//! The same workers also execute the **server's** hot stages as generic
//! [`Task::Exec`] closures: update decoding pipelined with receive,
//! the sharded accumulator fold, and evaluation batch slices (see
//! [`super::server`]).  One pool, two kinds of work — server tasks are
//! only submitted at points where no client job can be waiting on them
//! (decode after a client replied, fold/eval after all replies), so the
//! shared queue cannot deadlock.
//!
//! ## Determinism contract
//!
//! Scheduling is work-stealing (a shared job queue), so *which* worker
//! runs a client or server task, and in what order tasks complete, is
//! nondeterministic — but the results are not:
//!
//! * each round job owns its `ClientState` (moved in, moved back out),
//!   so no client state is ever shared between threads;
//! * every stochastic stream (batch cursor, quantizer seeds) is derived
//!   per client at construction, not from a shared generator;
//! * the server collects replies per client and sorts updates by
//!   `client_id` before folding, and [`scatter`] returns results in
//!   submission order so sharded reductions reassemble deterministically.
//!
//! A round therefore produces a bit-identical `RunReport` for any
//! thread count, shard count or eval slice count, which
//! `rust/tests/parallel_determinism.rs` asserts.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use super::client::ClientState;
use super::codec::{self, DecodedUpdate};
use crate::runtime::ModelRuntime;
use crate::wire::messages::Update;

/// One client-round job: state in, (state, update) out.
pub struct Job {
    pub state: ClientState,
    pub round: u32,
    pub params: Arc<[f32]>,
    pub losses: Option<(f32, f32)>,
    pub reply: Sender<Result<(ClientState, Update)>>,
}

/// A unit of pool work: a client local round, or an arbitrary
/// server-side closure (update decode, shard fold, eval slice).
pub enum Task {
    Round(Job),
    Exec(Box<dyn FnOnce() + Send + 'static>),
}

/// Fixed-size pool of workers sharing one [`ModelRuntime`].
pub struct WorkerPool {
    tasks: Option<Sender<Task>>,
    workers: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `threads` workers (>= 1) over a shared task queue.
    pub fn new(threads: usize, model: Arc<ModelRuntime>) -> WorkerPool {
        let threads = threads.max(1);
        let (tx, rx) = channel::<Task>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let model = Arc::clone(&model);
                std::thread::Builder::new()
                    .name(format!("feddq-round-{i}"))
                    .spawn(move || worker_loop(&rx, &model))
                    .expect("spawn round worker")
            })
            .collect();
        WorkerPool { tasks: Some(tx), workers }
    }

    /// A submission handle callers keep without borrowing the pool;
    /// tasks queue on it and round results arrive on each job's `reply`.
    pub fn sender(&self) -> Sender<Task> {
        self.tasks.as_ref().expect("pool alive").clone()
    }
}

/// Split `[0, total)` into `parts` contiguous `(lo, hi)` ranges, the
/// first `total % parts` ranges one element longer.  The single source
/// of the chunk layout used by the sharded accumulator fold, the eval
/// slicer and the perf benches — covers `[0, total)` exactly, no
/// overlaps, `parts.min(total).max(1)` non-empty ranges.
pub fn chunk_ranges(total: usize, parts: usize) -> Vec<(usize, usize)> {
    let parts = parts.clamp(1, total.max(1));
    let per = total / parts;
    let rem = total % parts;
    let mut ranges = Vec::with_capacity(parts);
    let mut lo = 0usize;
    for s in 0..parts {
        let hi = lo + per + usize::from(s < rem);
        ranges.push((lo, hi));
        lo = hi;
    }
    ranges
}

/// The sharded weighted fold — THE production aggregation kernel, also
/// driven directly by the perf benches so they measure this exact code
/// path.  Splits `[0, d)` into `shards` chunk ranges ([`chunk_ranges`])
/// and folds every decoded update into each chunk concurrently on the
/// pool; within a chunk, updates fold in the caller's (sorted-client)
/// order, so any shard count is bit-identical to a serial
/// [`codec::fold_range`] pass.
///
/// `chunks` supplies reusable per-shard buffers (missing ones are
/// allocated); returns `(ranges, folded_chunks)` in range order.  Each
/// shard drops its `Arc` clones before replying, so once this returns
/// the caller holds the only reference to `decoded`/`weights`.
pub fn sharded_fold(
    tasks: &Sender<Task>,
    model: &Arc<ModelRuntime>,
    decoded: &Arc<Vec<DecodedUpdate>>,
    weights: &Arc<Vec<f32>>,
    shards: usize,
    mut chunks: Vec<Vec<f32>>,
) -> Result<(Vec<(usize, usize)>, Vec<Vec<f32>>)> {
    let d = model.mm.d;
    let ranges = chunk_ranges(d, shards);
    while chunks.len() < ranges.len() {
        chunks.push(Vec::new());
    }
    chunks.truncate(ranges.len());
    type FoldShard = Box<dyn FnOnce() -> Vec<f32> + Send>;
    let mut fns: Vec<FoldShard> = Vec::with_capacity(ranges.len());
    for (&(clo, chi), mut chunk) in ranges.iter().zip(chunks.into_iter()) {
        let model = Arc::clone(model);
        let decoded = Arc::clone(decoded);
        let ws = Arc::clone(weights);
        fns.push(Box::new(move || {
            chunk.clear();
            chunk.resize(chi - clo, 0.0);
            for (dec, &w) in decoded.iter().zip(ws.iter()) {
                codec::fold_range(&model.mm, dec, w, clo, chi, &mut chunk);
            }
            // Release the shared handles *before* replying so the
            // caller can deterministically reclaim the decode buffers.
            drop(decoded);
            drop(ws);
            drop(model);
            chunk
        }));
    }
    let folded = scatter(tasks, fns)?;
    Ok((ranges, folded))
}

/// Run `fns` on the pool and return their results **in submission
/// order** (the caller's reduction order stays deterministic however
/// the workers interleave).  Blocks the calling thread, which
/// contributes no work of its own — the pool executes everything.
pub fn scatter<T, F>(tasks: &Sender<Task>, fns: Vec<F>) -> Result<Vec<T>>
where
    T: Send + 'static,
    F: FnOnce() -> T + Send + 'static,
{
    let n = fns.len();
    let (tx, rx) = channel::<(usize, T)>();
    for (i, f) in fns.into_iter().enumerate() {
        let tx = tx.clone();
        tasks
            .send(Task::Exec(Box::new(move || {
                let v = f();
                let _ = tx.send((i, v));
            })))
            .ok()
            .context("worker pool hung up")?;
    }
    drop(tx);
    let mut out: Vec<Option<T>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    for _ in 0..n {
        let (i, v) = rx.recv().context("pool worker died (panicked?)")?;
        out[i] = Some(v);
    }
    Ok(out
        .into_iter()
        .map(|v| v.expect("each index replies exactly once"))
        .collect())
}

fn worker_loop(rx: &Mutex<Receiver<Task>>, model: &ModelRuntime) {
    loop {
        // Hold the lock only for the dequeue, never across a task.
        let task = match rx.lock() {
            Ok(guard) => guard.recv(),
            Err(_) => return, // a sibling panicked mid-dequeue
        };
        let task = match task {
            Ok(t) => t,
            Err(_) => return, // all senders dropped: shut down
        };
        match task {
            Task::Round(job) => {
                let Job { mut state, round, params, losses, reply } = job;
                let result = state
                    .process_round(model, round, &params, losses)
                    .map(|update| (state, update));
                // A dropped receiver just means the session gave up on
                // the round.
                let _ = reply.send(result);
            }
            Task::Exec(f) => f(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::chunk_ranges;

    #[test]
    fn chunk_ranges_partition_exactly() {
        for total in [0usize, 1, 7, 100, 101_770] {
            for parts in [1usize, 2, 3, 5, 64, 300] {
                let ranges = chunk_ranges(total, parts);
                assert!(!ranges.is_empty());
                assert_eq!(ranges.first().unwrap().0, 0);
                assert_eq!(ranges.last().unwrap().1, total);
                for w in ranges.windows(2) {
                    assert_eq!(w[0].1, w[1].0, "gap/overlap at {w:?}");
                }
                if total > 0 {
                    assert!(ranges.iter().all(|&(lo, hi)| hi > lo));
                    assert_eq!(ranges.len(), parts.min(total));
                }
            }
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Close the queue, then wait for in-flight tasks to finish.
        // (Anyone holding `sender()` clones — pool clients, the server —
        // must be dropped first or the workers keep serving them; the
        // session and the TCP server both declare the pool before those
        // holders, so the holders drop first.)
        self.tasks.take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}
