//! Persistent worker pool shared by the round engine's two sides — now a
//! **two-lane scheduler**.
//!
//! The single-process `Session` used to run every client's local round
//! sequentially on the session thread; with tau SGD steps per client
//! this serialized the entire compute of a round.  The pool runs
//! [`ClientState::process_round`] for many clients concurrently on a
//! fixed set of `std::thread` workers (the `threads` knob in
//! [`RunConfig`](crate::config::RunConfig); default min(n_clients,
//! cores)).
//!
//! ## Two lanes
//!
//! The same workers also execute the **server's** hot stages: update
//! decoding pipelined with receive, the sharded accumulator fold
//! (including the per-client prefix folds of the fold-overlap path),
//! and evaluation batch slices (see [`super::server`]).  Those server
//! tasks land in a **priority lane** that every worker drains before
//! pulling the next client round job from the **round lane**:
//!
//! * [`Task::Exec`] → priority lane (server work: decode, fold, eval);
//! * [`Task::Round`] / [`Task::RoundExec`] → round lane (client work).
//!
//! Queue-jumping is what lets an in-process decode overlap the
//! *remaining* receives of a round instead of sitting FIFO behind
//! not-yet-started round jobs (TCP mode always overlapped fully because
//! its pool has no round jobs; in-process mode now matches it).
//!
//! The lanes cannot deadlock or starve each other: a running task is
//! never preempted, priority tasks are self-contained compute (they
//! never block on round results or submit round jobs), and the server
//! only produces priority work in response to *completed* round work —
//! each client reply spawns at most one decode plus a bounded number of
//! fold/eval tasks — so the priority lane drains between arrivals and
//! round jobs always get workers back.
//!
//! ## Worker survival
//!
//! Task execution is wrapped in `catch_unwind`: a panicking task no
//! longer kills its worker thread (which silently shrank the pool and
//! surfaced as a generic "pool worker died" at the collector).  The
//! worker survives and the panic payload is reported to the submitter
//! as a task-level `Err` — [`scatter`] callers get it in their result,
//! round jobs get it on their reply channel.
//!
//! ## Determinism contract
//!
//! Scheduling is work-stealing (two shared queues), so *which* worker
//! runs a client or server task, and in what order tasks complete, is
//! nondeterministic — but the results are not:
//!
//! * each round job owns its `ClientState` (moved in, moved back out),
//!   so no client state is ever shared between threads;
//! * every stochastic stream (batch cursor, quantizer seeds) is derived
//!   per client at construction, not from a shared generator;
//! * the server collects replies per client and sorts updates by
//!   `client_id` before folding, and [`scatter`] returns results in
//!   submission order so sharded reductions reassemble deterministically.
//!
//! A round therefore produces a bit-identical `RunReport` for any
//! thread count, shard count, eval slice count, decode-buffer bound or
//! fold-overlap setting, which `rust/tests/parallel_determinism.rs`
//! asserts.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;

use anyhow::{anyhow, Context, Result};

use super::client::ClientState;
use super::codec::{self, DecodedUpdate};
use crate::runtime::ModelRuntime;
use crate::wire::messages::Update;

/// One client-round job: state in, (state, update, compute seconds) out.
pub struct Job {
    /// The client's state, moved into the worker for the round.
    pub state: ClientState,
    /// Round index being processed.
    pub round: u32,
    /// Shared global parameters (zero-copy broadcast).
    pub params: Arc<[f32]>,
    /// Global (initial, previous) loss pair for loss-driven policies.
    pub losses: Option<(f32, f32)>,
    /// Per-segment bit-width allocation from the server's budget
    /// controller (`--bit-budget`), `None` when the budget is off.
    pub budget: Option<Vec<u8>>,
    /// Where the worker sends the state, the update and the round's
    /// measured compute seconds back (or the error).  The timing is
    /// taken *inside* the worker, so it reflects the client's actual
    /// local-round cost — not its position in any receive queue — and
    /// feeds the scheduler's slowest-first EWMA.
    pub reply: Sender<Result<(ClientState, Update, f64)>>,
}

/// A boxed pool closure.
pub type TaskFn = Box<dyn FnOnce() + Send + 'static>;

/// A unit of pool work.  The variant selects the lane: `Exec` is server
/// work and goes to the priority lane; `Round` (a client local round)
/// and `RoundExec` (an arbitrary closure standing in for client-side
/// work — benches and tests) go to the round lane.
pub enum Task {
    /// A client local round (round lane).
    Round(Job),
    /// Server-side work — decode, fold, eval slice (priority lane).
    Exec(TaskFn),
    /// An arbitrary closure on the round lane (benches and tests).
    RoundExec(TaskFn),
}

/// The two task lanes plus the live-sender count used for shutdown.
struct Lanes {
    /// Priority lane: server tasks (decode, folds, eval slices).
    server: VecDeque<Task>,
    /// Round lane: client round jobs.
    rounds: VecDeque<Task>,
    /// Live [`TaskSender`] handles; workers exit once this hits zero
    /// *and* both lanes are drained (in-flight work always finishes).
    senders: usize,
}

/// The shared two-lane queue.
struct TwoLaneQueue {
    state: Mutex<Lanes>,
    available: Condvar,
}

impl TwoLaneQueue {
    fn lock(&self) -> MutexGuard<'_, Lanes> {
        // Tasks never run under the lock and panics never escape
        // `run_task`, so poisoning is unreachable; recover anyway.
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// A cloneable submission handle onto the pool's two lanes.  Dropping
/// the last handle shuts the pool down once the lanes drain.
pub struct TaskSender {
    q: Arc<TwoLaneQueue>,
}

impl Clone for TaskSender {
    fn clone(&self) -> TaskSender {
        self.q.lock().senders += 1;
        TaskSender { q: Arc::clone(&self.q) }
    }
}

impl Drop for TaskSender {
    fn drop(&mut self) {
        let mut st = self.q.lock();
        st.senders -= 1;
        if st.senders == 0 {
            drop(st);
            // Wake every worker so they can observe the shutdown.
            self.q.available.notify_all();
        }
    }
}

impl TaskSender {
    /// Enqueue a task on its lane.  Never blocks; the queue is unbounded
    /// (back-pressure comes from the submitters' own reply channels).
    pub fn send(&self, task: Task) -> Result<()> {
        {
            let mut st = self.q.lock();
            match task {
                Task::Exec(_) => st.server.push_back(task),
                Task::Round(_) | Task::RoundExec(_) => st.rounds.push_back(task),
            }
        }
        self.q.available.notify_one();
        Ok(())
    }
}

/// Fixed-size pool of workers sharing one [`ModelRuntime`].
pub struct WorkerPool {
    tasks: Option<TaskSender>,
    workers: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `threads` workers (>= 1) over the shared two-lane queue.
    pub fn new(threads: usize, model: Arc<ModelRuntime>) -> WorkerPool {
        let threads = threads.max(1);
        let q = Arc::new(TwoLaneQueue {
            state: Mutex::new(Lanes {
                server: VecDeque::new(),
                rounds: VecDeque::new(),
                senders: 1, // the pool's own handle below
            }),
            available: Condvar::new(),
        });
        let workers = (0..threads)
            .map(|i| {
                let q = Arc::clone(&q);
                let model = Arc::clone(&model);
                std::thread::Builder::new()
                    .name(format!("feddq-round-{i}"))
                    .spawn(move || worker_loop(&q, &model))
                    .expect("spawn round worker")
            })
            .collect();
        WorkerPool { tasks: Some(TaskSender { q }), workers }
    }

    /// A submission handle callers keep without borrowing the pool;
    /// tasks queue on it and round results arrive on each job's `reply`.
    pub fn sender(&self) -> TaskSender {
        self.tasks.as_ref().expect("pool alive").clone()
    }
}

/// Split `[0, total)` into `parts` contiguous `(lo, hi)` ranges, the
/// first `total % parts` ranges one element longer.  The single source
/// of the chunk layout used by the sharded accumulator fold, the eval
/// slicer and the perf benches — covers `[0, total)` exactly, no
/// overlaps, `parts.min(total).max(1)` non-empty ranges.
pub fn chunk_ranges(total: usize, parts: usize) -> Vec<(usize, usize)> {
    let parts = parts.clamp(1, total.max(1));
    let per = total / parts;
    let rem = total % parts;
    let mut ranges = Vec::with_capacity(parts);
    let mut lo = 0usize;
    for s in 0..parts {
        let hi = lo + per + usize::from(s < rem);
        ranges.push((lo, hi));
        lo = hi;
    }
    ranges
}

/// The sharded weighted fold — THE production aggregation kernel, also
/// driven directly by the perf benches so they measure this exact code
/// path.  Splits `[0, d)` into `shards` chunk ranges ([`chunk_ranges`])
/// and folds every decoded update into each chunk concurrently on the
/// pool; within a chunk, updates fold in the caller's (sorted-client)
/// order, so any shard count is bit-identical to a serial
/// [`codec::fold_range`] pass.
///
/// `chunks` supplies reusable per-shard buffers (missing ones are
/// allocated); returns `(ranges, folded_chunks)` in range order.  Each
/// shard drops its `Arc` clones before replying, so once this returns
/// the caller holds the only reference to `decoded`/`weights`.
pub fn sharded_fold(
    tasks: &TaskSender,
    model: &Arc<ModelRuntime>,
    decoded: &Arc<Vec<DecodedUpdate>>,
    weights: &Arc<Vec<f32>>,
    shards: usize,
    mut chunks: Vec<Vec<f32>>,
) -> Result<(Vec<(usize, usize)>, Vec<Vec<f32>>)> {
    let d = model.mm.d;
    let ranges = chunk_ranges(d, shards);
    while chunks.len() < ranges.len() {
        chunks.push(Vec::new());
    }
    chunks.truncate(ranges.len());
    type FoldShard = Box<dyn FnOnce() -> Vec<f32> + Send>;
    let mut fns: Vec<FoldShard> = Vec::with_capacity(ranges.len());
    for (&(clo, chi), mut chunk) in ranges.iter().zip(chunks.into_iter()) {
        let model = Arc::clone(model);
        let decoded = Arc::clone(decoded);
        let ws = Arc::clone(weights);
        fns.push(Box::new(move || {
            chunk.clear();
            chunk.resize(chi - clo, 0.0);
            for (dec, &w) in decoded.iter().zip(ws.iter()) {
                codec::fold_range(&model.mm, dec, w, clo, chi, &mut chunk);
            }
            // Release the shared handles *before* replying so the
            // caller can deterministically reclaim the decode buffers.
            drop(decoded);
            drop(ws);
            drop(model);
            chunk
        }));
    }
    let folded = scatter(tasks, fns)?;
    Ok((ranges, folded))
}

/// Render a panic payload's message (the common `&str`/`String` cases).
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Run `fns` on the pool's priority lane and return their results **in
/// submission order** (the caller's reduction order stays deterministic
/// however the workers interleave).  Blocks the calling thread, which
/// contributes no work of its own — the pool executes everything.
///
/// A panicking closure does not kill its worker; it surfaces here as an
/// `Err` carrying the panic payload's message.
pub fn scatter<T, F>(tasks: &TaskSender, fns: Vec<F>) -> Result<Vec<T>>
where
    T: Send + 'static,
    F: FnOnce() -> T + Send + 'static,
{
    let n = fns.len();
    let (tx, rx) = channel::<(usize, std::result::Result<T, String>)>();
    for (i, f) in fns.into_iter().enumerate() {
        let tx = tx.clone();
        tasks.send(Task::Exec(Box::new(move || {
            let v = catch_unwind(AssertUnwindSafe(f)).map_err(|p| panic_message(&*p));
            let _ = tx.send((i, v));
        })))?;
    }
    drop(tx);
    let mut out: Vec<Option<T>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    for _ in 0..n {
        let (i, v) = rx.recv().context("pool worker died")?;
        out[i] = Some(v.map_err(|msg| anyhow!("pool task panicked: {msg}"))?);
    }
    Ok(out
        .into_iter()
        .map(|v| v.expect("each index replies exactly once"))
        .collect())
}

fn worker_loop(q: &TwoLaneQueue, model: &ModelRuntime) {
    loop {
        // Hold the lock only for the dequeue, never across a task.
        let task = {
            let mut st = q.lock();
            loop {
                // Priority lane first: server tasks jump the queue so
                // decode/fold/eval never wait behind unstarted rounds.
                if let Some(t) = st.server.pop_front() {
                    break t;
                }
                if let Some(t) = st.rounds.pop_front() {
                    break t;
                }
                if st.senders == 0 {
                    return; // all senders gone and lanes drained
                }
                st = q.available.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        };
        run_task(task, model);
    }
}

/// Execute one task, containing any panic to a task-level error so the
/// worker thread survives.
fn run_task(task: Task, model: &ModelRuntime) {
    match task {
        Task::Round(job) => {
            let Job { state, round, params, losses, budget, reply } = job;
            let result = catch_unwind(AssertUnwindSafe(move || {
                let mut state = state;
                let t0 = std::time::Instant::now();
                state
                    .process_round(model, round, &params, losses, budget.as_deref())
                    .map(|update| (state, update, t0.elapsed().as_secs_f64()))
            }))
            .unwrap_or_else(|p| Err(anyhow!("client round panicked: {}", panic_message(&*p))));
            // A dropped receiver just means the session gave up on the
            // round.
            let _ = reply.send(result);
        }
        // Exec closures that need to report a panic payload wrap
        // themselves (see `scatter` and the server's decode/fold
        // tasks); this outer catch is the backstop that keeps the
        // worker alive either way.
        Task::Exec(f) | Task::RoundExec(f) => {
            let _ = catch_unwind(AssertUnwindSafe(f));
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Drop the pool's own sender, then wait for in-flight tasks to
        // finish.  (Anyone holding `sender()` clones — pool clients,
        // the server — must be dropped first or the workers keep
        // serving them; the session and the TCP server both declare the
        // pool before those holders, so the holders drop first.)
        self.tasks.take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{Manifest, ModelRuntime};

    fn test_pool(threads: usize) -> WorkerPool {
        let mm = Manifest::builtin().models.get("mlp").unwrap().clone();
        let model = Arc::new(ModelRuntime::load_native(mm).unwrap());
        WorkerPool::new(threads, model)
    }

    #[test]
    fn chunk_ranges_partition_exactly() {
        for total in [0usize, 1, 7, 100, 101_770] {
            for parts in [1usize, 2, 3, 5, 64, 300] {
                let ranges = chunk_ranges(total, parts);
                assert!(!ranges.is_empty());
                assert_eq!(ranges.first().unwrap().0, 0);
                assert_eq!(ranges.last().unwrap().1, total);
                for w in ranges.windows(2) {
                    assert_eq!(w[0].1, w[1].0, "gap/overlap at {w:?}");
                }
                if total > 0 {
                    assert!(ranges.iter().all(|&(lo, hi)| hi > lo));
                    assert_eq!(ranges.len(), parts.min(total));
                }
            }
        }
    }

    #[test]
    fn panicking_task_reports_err_and_worker_survives() {
        let pool = test_pool(1);
        let tasks = pool.sender();
        // One good closure, one that panics: the panic must come back
        // as a task-level Err carrying the payload message...
        let boom: Box<dyn FnOnce() -> i32 + Send> = Box::new(|| panic!("boom in task"));
        let err = scatter(&tasks, vec![boom]).unwrap_err();
        assert!(format!("{err:#}").contains("boom in task"), "{err:#}");
        // ...and the single worker must still be alive to run new work.
        let ok = scatter(&tasks, vec![|| 41 + 1]).unwrap();
        assert_eq!(ok, vec![42]);
    }

    #[test]
    fn round_lane_panic_reports_on_reply_channel() {
        let pool = test_pool(1);
        let tasks = pool.sender();
        let (tx, rx) = channel::<&'static str>();
        tasks
            .send(Task::RoundExec(Box::new(|| panic!("round-side boom"))))
            .unwrap();
        // Worker survived the round-lane panic: this closure still runs.
        tasks
            .send(Task::RoundExec(Box::new(move || {
                let _ = tx.send("alive");
            })))
            .unwrap();
        assert_eq!(rx.recv().unwrap(), "alive");
    }

    #[test]
    fn priority_lane_jumps_ahead_of_queued_round_work() {
        let pool = test_pool(1);
        let tasks = pool.sender();
        let order = Arc::new(Mutex::new(Vec::<&'static str>::new()));

        // Occupy the single worker until released, so the next two
        // submissions are both *queued* (not running).
        let (started_tx, started_rx) = channel::<()>();
        let (release_tx, release_rx) = channel::<()>();
        tasks
            .send(Task::RoundExec(Box::new(move || {
                let _ = started_tx.send(());
                let _ = release_rx.recv();
            })))
            .unwrap();
        started_rx.recv().unwrap();

        // Round-lane work enqueued FIRST, priority work SECOND ...
        let o1 = Arc::clone(&order);
        tasks
            .send(Task::RoundExec(Box::new(move || {
                o1.lock().unwrap().push("round");
            })))
            .unwrap();
        let o2 = Arc::clone(&order);
        let (done_tx, done_rx) = channel::<()>();
        tasks
            .send(Task::Exec(Box::new(move || {
                o2.lock().unwrap().push("server");
                let _ = done_tx.send(());
            })))
            .unwrap();

        release_tx.send(()).unwrap();
        done_rx.recv().unwrap();
        // ... yet the priority task ran first.
        let got = order.lock().unwrap().clone();
        assert_eq!(got[0], "server", "priority lane must jump the round queue: {got:?}");
        // Let the round task finish before the pool drops.
        drop(tasks);
    }

    #[test]
    fn scatter_preserves_submission_order() {
        let pool = test_pool(3);
        let tasks = pool.sender();
        let fns: Vec<_> = (0..20).map(|i| move || i * 2).collect();
        let out = scatter(&tasks, fns).unwrap();
        assert_eq!(out, (0..20).map(|i| i * 2).collect::<Vec<_>>());
    }
}
