//! Persistent worker pool for in-process client rounds.
//!
//! The single-process `Session` used to run every client's local round
//! sequentially on the session thread; with tau SGD steps per client
//! this serialized the entire compute of a round.  The pool runs
//! [`ClientState::process_round`] for many clients concurrently on a
//! fixed set of `std::thread` workers (the `threads` knob in
//! [`RunConfig`](crate::config::RunConfig); default min(n_clients,
//! cores)).
//!
//! ## Determinism contract
//!
//! Scheduling is work-stealing (a shared job queue), so *which* worker
//! runs a client, and in what order rounds complete, is nondeterministic
//! — but the results are not:
//!
//! * each job owns its `ClientState` (moved in, moved back out), so no
//!   client state is ever shared between threads;
//! * every stochastic stream (batch cursor, quantizer seeds) is derived
//!   per client at construction, not from a shared generator;
//! * the server collects replies per client and sorts updates by
//!   `client_id` before aggregating.
//!
//! A round therefore produces a bit-identical `RunReport` for any
//! thread count, which `rust/tests/parallel_determinism.rs` asserts.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::Result;

use super::client::ClientState;
use crate::runtime::ModelRuntime;
use crate::wire::messages::Update;

/// One client-round job: state in, (state, update) out.
pub struct Job {
    pub state: ClientState,
    pub round: u32,
    pub params: Arc<[f32]>,
    pub losses: Option<(f32, f32)>,
    pub reply: Sender<Result<(ClientState, Update)>>,
}

/// Fixed-size pool of round workers sharing one [`ModelRuntime`].
pub struct WorkerPool {
    jobs: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `threads` workers (>= 1) over a shared job queue.
    pub fn new(threads: usize, model: Arc<ModelRuntime>) -> WorkerPool {
        let threads = threads.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let model = Arc::clone(&model);
                std::thread::Builder::new()
                    .name(format!("feddq-round-{i}"))
                    .spawn(move || worker_loop(&rx, &model))
                    .expect("spawn round worker")
            })
            .collect();
        WorkerPool { jobs: Some(tx), workers }
    }

    /// A submission handle clients keep without borrowing the pool;
    /// jobs queue on it and results arrive on each job's `reply`.
    pub fn sender(&self) -> Sender<Job> {
        self.jobs.as_ref().expect("pool alive").clone()
    }
}

fn worker_loop(rx: &Mutex<Receiver<Job>>, model: &ModelRuntime) {
    loop {
        // Hold the lock only for the dequeue, never across a round.
        let job = match rx.lock() {
            Ok(guard) => guard.recv(),
            Err(_) => return, // a sibling panicked mid-dequeue
        };
        let job = match job {
            Ok(j) => j,
            Err(_) => return, // all senders dropped: shut down
        };
        let Job { mut state, round, params, losses, reply } = job;
        let result = state
            .process_round(model, round, &params, losses)
            .map(|update| (state, update));
        // A dropped receiver just means the session gave up on the round.
        let _ = reply.send(result);
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Close the queue, then wait for in-flight rounds to finish.
        // (Clients holding `sender()` clones must be dropped first or
        // the workers keep serving them — the session drops its clients
        // before the pool by declaration order.)
        self.jobs.take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}
