//! Compact per-client server state: one flat arena keyed by client id.
//!
//! Before this module the server scattered per-client metadata across
//! several growable maps (`samples_by_id: BTreeMap`, the scheduler's
//! dense `ewma: Vec<f64>`, ad-hoc arrival lists).  At the ROADMAP's
//! million-client scale those structures dominate resident memory and
//! cache behavior, so everything the server must remember about a
//! client between rounds now lives in one dense [`ClientRow`] — 24
//! bytes per client, lazily grown, shared between the [`Server`] fold
//! path and the [`RoundScheduler`] dispatch path behind an
//! `Arc<Mutex<..>>`.  That includes the per-client uplink/downlink
//! byte ledger, which used to live in O(n) per-handle counters at the
//! root.
//!
//! The arena stores *metadata only* (sample counts, latency EWMAs);
//! model-sized state (EF residuals) lives client-side and is banked
//! quantized — see `client::ResidualBank`.
//!
//! [`Server`]: super::server::Server
//! [`RoundScheduler`]: super::sched::RoundScheduler

/// One client's resident server-side state.  Kept to 24 bytes so a
/// million clients cost 24 MB — vs. ~48+ bytes per entry for the old
/// `BTreeMap<u32, u32>` + `Vec<f64>` + allocator overhead spread.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ClientRow {
    /// Local dataset size, once reported (see `FLAG_SAMPLES`).
    pub samples: u32,
    /// Bit flags; see the `FLAG_*` constants.
    pub flags: u32,
    /// EWMA of observed round latency in seconds (scheduler dispatch
    /// tiering).  f64 so the blend arithmetic is bit-identical to the
    /// scheduler's historical `Vec<f64>` field.
    pub ewma_secs: f64,
    /// Cumulative uplink bytes received from this client (saturating).
    pub up_bytes: u32,
    /// Cumulative downlink bytes sent to this client (saturating).
    pub down_bytes: u32,
}

/// `flags` bit: the client has reported its sample count.
pub const FLAG_SAMPLES: u32 = 1 << 0;

/// `flags` bit: the client answered its last dispatched round late
/// (banked under bounded staleness).  Set by the scheduler's seeded
/// churn simulation, so the bit is identical across threads and
/// topologies — the bit-budget controller conditions on it.
pub const FLAG_LATE: u32 = 1 << 1;

/// `flags` bit: the client was dropped from its last planned round
/// (deadline cut or simulated fault).  Seed-pure, like [`FLAG_LATE`].
pub const FLAG_DROPPED: u32 = 1 << 2;

/// Dense, lazily-grown arena of [`ClientRow`]s indexed by client id.
///
/// Rows materialize on first write (`set_samples` / `set_ewma`); reads
/// of never-written ids return defaults (0 samples unknown, 0.0 EWMA)
/// without growing the arena, so sampling a 1000-client cohort out of a
/// million-client id space touches only the cohort's rows.
#[derive(Clone, Debug, Default)]
pub struct ClientArena {
    rows: Vec<ClientRow>,
}

impl ClientArena {
    /// An empty arena.
    pub fn new() -> ClientArena {
        ClientArena { rows: Vec::new() }
    }

    /// Number of materialized rows (ids `0..len` are resident).
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when no row has been written yet.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    fn row_mut(&mut self, id: u32) -> &mut ClientRow {
        let i = id as usize;
        if i >= self.rows.len() {
            self.rows.resize(i + 1, ClientRow::default());
        }
        &mut self.rows[i]
    }

    /// The row for `id`, default-valued if never written.
    pub fn row(&self, id: u32) -> ClientRow {
        self.rows.get(id as usize).copied().unwrap_or_default()
    }

    /// Record the client's reported sample count.
    pub fn set_samples(&mut self, id: u32, samples: u32) {
        let r = self.row_mut(id);
        r.samples = samples;
        r.flags |= FLAG_SAMPLES;
    }

    /// The client's sample count, if it has reported one.
    pub fn samples(&self, id: u32) -> Option<u32> {
        let r = self.row(id);
        if r.flags & FLAG_SAMPLES != 0 {
            Some(r.samples)
        } else {
            None
        }
    }

    /// Iterate `(id, samples)` over every client with a known count, in
    /// ascending id order (the fold path's canonical order).
    pub fn known_samples(&self) -> impl Iterator<Item = (u32, u32)> + '_ {
        self.rows
            .iter()
            .enumerate()
            .filter(|(_, r)| r.flags & FLAG_SAMPLES != 0)
            .map(|(i, r)| (i as u32, r.samples))
    }

    /// The client's latency EWMA (0.0 until first observation).
    pub fn ewma(&self, id: u32) -> f64 {
        self.row(id).ewma_secs
    }

    /// Overwrite the client's latency EWMA.
    pub fn set_ewma(&mut self, id: u32, secs: f64) {
        self.row_mut(id).ewma_secs = secs;
    }

    /// Accumulate observed wire volume for this client (saturating: the
    /// ledger is telemetry, and 4 GB per client outlives any run we
    /// model).
    pub fn add_io_bytes(&mut self, id: u32, up: u64, down: u64) {
        if up == 0 && down == 0 {
            return;
        }
        let r = self.row_mut(id);
        r.up_bytes = r.up_bytes.saturating_add(up.min(u32::MAX as u64) as u32);
        r.down_bytes = r.down_bytes.saturating_add(down.min(u32::MAX as u64) as u32);
    }

    /// Cumulative `(uplink, downlink)` bytes observed for this client.
    pub fn io_bytes(&self, id: u32) -> (u64, u64) {
        let r = self.row(id);
        (r.up_bytes as u64, r.down_bytes as u64)
    }

    /// Flag the client as late on its last dispatched round.
    /// Idempotent (a pure bit-set), so re-planning a round is safe.
    pub fn mark_late(&mut self, id: u32) {
        self.row_mut(id).flags |= FLAG_LATE;
    }

    /// Flag the client as dropped from its last planned round.
    /// Idempotent (a pure bit-set), so re-planning a round is safe.
    pub fn mark_dropped(&mut self, id: u32) {
        self.row_mut(id).flags |= FLAG_DROPPED;
    }

    /// Clear the per-round outcome flags after a clean on-time round.
    pub fn clear_round_flags(&mut self, id: u32) {
        self.row_mut(id).flags &= !(FLAG_LATE | FLAG_DROPPED);
    }

    /// Did this client's last planned round end late or dropped?  The
    /// bit-budget controller's only arena input: unlike the EWMA and
    /// byte ledgers (wall-clock / real sockets), the outcome flags are
    /// written from seeded simulation state and so are bit-identical
    /// across threads and topologies.
    pub fn is_flagged(&self, id: u32) -> bool {
        self.row(id).flags & (FLAG_LATE | FLAG_DROPPED) != 0
    }

    /// Resident bytes of per-client state: materialized rows times the
    /// row size.  Reported per round as `RoundRecord::client_state_bytes`
    /// and asserted sub-fp32-baseline by the scale-smoke test.
    pub fn resident_bytes(&self) -> u64 {
        (self.rows.len() * std::mem::size_of::<ClientRow>()) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_are_24_bytes() {
        // The million-client budget is 24 MB; a silent row growth would
        // change the scale-smoke math.
        assert_eq!(std::mem::size_of::<ClientRow>(), 24);
    }

    #[test]
    fn reads_of_unwritten_ids_do_not_grow() {
        let a = ClientArena::new();
        assert_eq!(a.samples(1_000_000), None);
        assert_eq!(a.ewma(1_000_000), 0.0);
        assert_eq!(a.len(), 0);
        assert_eq!(a.resident_bytes(), 0);
    }

    #[test]
    fn samples_round_trip_and_flag() {
        let mut a = ClientArena::new();
        assert_eq!(a.samples(3), None);
        a.set_samples(3, 120);
        assert_eq!(a.samples(3), Some(120));
        // id 0..=2 materialized as padding but report unknown
        assert_eq!(a.samples(0), None);
        assert_eq!(a.len(), 4);
        // a zero count is still "known" (the flag, not the value, decides)
        a.set_samples(5, 0);
        assert_eq!(a.samples(5), Some(0));
    }

    #[test]
    fn known_samples_walks_ascending_ids() {
        let mut a = ClientArena::new();
        a.set_samples(7, 70);
        a.set_samples(2, 20);
        a.set_samples(4, 40);
        let got: Vec<(u32, u32)> = a.known_samples().collect();
        assert_eq!(got, vec![(2, 20), (4, 40), (7, 70)]);
    }

    #[test]
    fn ewma_read_write() {
        let mut a = ClientArena::new();
        a.set_ewma(9, 1.5);
        assert_eq!(a.ewma(9), 1.5);
        a.set_ewma(9, 0.25);
        assert_eq!(a.ewma(9), 0.25);
        assert_eq!(a.resident_bytes(), 10 * 24);
    }

    #[test]
    fn round_flags_set_clear_and_compose_with_samples() {
        let mut a = ClientArena::new();
        assert!(!a.is_flagged(4));
        a.set_samples(4, 10);
        a.mark_late(4);
        assert!(a.is_flagged(4));
        // idempotent: marking again changes nothing
        a.mark_late(4);
        a.mark_dropped(4);
        assert!(a.is_flagged(4));
        a.clear_round_flags(4);
        assert!(!a.is_flagged(4));
        // clearing must not erase the samples flag
        assert_eq!(a.samples(4), Some(10));
        // dropped alone also flags
        a.mark_dropped(7);
        assert!(a.is_flagged(7));
    }

    #[test]
    fn io_bytes_accumulate_and_saturate() {
        let mut a = ClientArena::new();
        assert_eq!(a.io_bytes(2), (0, 0));
        a.add_io_bytes(2, 100, 40);
        a.add_io_bytes(2, 3, 0);
        assert_eq!(a.io_bytes(2), (103, 40));
        // a zero-delta add on an unseen id must not materialize a row
        a.add_io_bytes(999, 0, 0);
        assert_eq!(a.len(), 3);
        // overflow pins at u32::MAX instead of wrapping
        a.add_io_bytes(2, u64::MAX, u32::MAX as u64);
        a.add_io_bytes(2, 1, 1);
        assert_eq!(a.io_bytes(2), (u32::MAX as u64, u32::MAX as u64));
    }
}
