//! The FL coordinator — L3's contribution: round orchestration, the
//! client uplink path (local round → range → policy → quantize → pack) and
//! the server downlink/aggregation path, over pluggable client handles
//! (in-process pool workers or TCP workers).  In-process client rounds
//! run concurrently on a persistent thread pool ([`pool`]) with
//! bit-deterministic results for any thread count.

pub mod client;
pub mod codec;
pub mod pool;
pub mod server;
pub mod topology;

pub use client::ClientState;
pub use server::{Server, Session};
