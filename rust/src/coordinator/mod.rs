//! The FL coordinator — L3's contribution: round orchestration, the
//! client uplink path (local round → range → policy → quantize → pack) and
//! the server downlink/aggregation path, over pluggable client handles
//! (in-process pool workers or TCP workers).
//!
//! Both sides of a round are parallel on one persistent thread pool
//! ([`pool`]): in-process client rounds run concurrently, and the
//! server's three hot stages scale on the same workers — update decode
//! is **pipelined with receive** (each `Update` is handed to a worker
//! as it lands), the streaming accumulator is **sharded** into
//! contiguous per-worker chunk ranges ([`codec::fold_range`]), and
//! evaluation batches split into per-worker slices.  On top sits the
//! **round scheduler** ([`sched`]): per-round cohort sampling
//! (`--participation`), a simulated-time deadline policy
//! (`--round-deadline`) and straggler-aware slowest-first dispatch.
//! Every configuration (thread count, `agg_shards`, `eval_threads`,
//! participation knobs) is bit-deterministic: cohorts come from a
//! seed-pure round-keyed RNG, folds visit clients in sorted order
//! inside each shard, and reductions walk batches in a fixed order.
//! `ARCHITECTURE.md` at the repo root walks the whole life of a round.

pub mod arena;
pub mod client;
pub mod codec;
pub mod pool;
pub mod sched;
pub mod server;
pub mod tolerance;
pub mod topology;

pub use arena::ClientArena;
pub use client::{ClientState, ResidualBank};
pub use sched::{RoundPlan, RoundScheduler};
pub use server::{Server, ServerOpts, Session};
