//! The FL coordinator — L3's contribution: round orchestration, the
//! client uplink path (local round → range → policy → quantize → pack) and
//! the server downlink/aggregation path, over pluggable client handles
//! (in-process or TCP workers).

pub mod client;
pub mod codec;
pub mod server;
pub mod topology;

pub use client::ClientState;
pub use server::{Server, Session};
