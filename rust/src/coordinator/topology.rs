//! Multi-process topology: `feddq serve` runs the server and accepts TCP
//! workers; `feddq worker` runs one client in its own process with its own
//! PJRT runtime.  The wire traffic is byte-identical to the in-process
//! session (same `Message` encoding, same framing), so measured volumes
//! agree across modes.
//!
//! # Churn
//!
//! Workers connect with bounded retry (so a worker racing the server's
//! `bind()` does not die on the first refusal), and the server keeps
//! accepting connections *after* the initial handshake: a `Join` from an
//! already-registered id whose socket has since died re-attaches that
//! worker mid-run.  The rejoin `Welcome` carries the next round index, so
//! a restarted worker knows the run is in progress.  Together with quorum
//! aggregation (`--quorum`, `--round-timeout` — see
//! [`super::server::ServerOpts`]) this lets a run survive workers that
//! crash and come back, at the cost the real world charges for it: a
//! restarted worker's optimizer-adjacent state (error-feedback residual,
//! batch cursor) restarts from scratch, exactly as a crashed process's
//! memory would.  The *deterministic* churn story (`--sim-faults`) never
//! uses this machinery — there the scheduler pre-excludes the failed set
//! server-side (see [`super::sched::RoundScheduler::sim_churn`]) so local
//! and TCP runs stay bit-identical.  Simulated faults compose with the
//! tree topology: the draws are pure in `(seed, client, round)` over
//! *leaf* ids, the excluded leaves simply vanish from the broadcast's
//! `cohort`/`late` routing fields, and the in-process engine applies the
//! identical exclusion before its virtual grouping — so `--fanout` ×
//! `--sim-faults` runs stay bit-identical across topologies too.
//!
//! # Tree failures
//!
//! An aggregator socket is a fat pipe carrying a whole subtree, so it
//! gets more machinery than a leaf (see ARCHITECTURE.md's failure state
//! machine): a killed-and-restarted `feddq aggregate` process re-`Join`s
//! upstream mid-run (the accept thread parks it in a rejoin map; the
//! server's composite handle adopts it *mid-round* and re-sends the
//! round's broadcast), quorum and `--staleness` banking are judged over
//! the *leaves* carried in partial metadata — never subtree composites —
//! and an orphaned leaf that cannot reach its aggregator degrades to
//! direct-to-root attachment at the `fallback_addr` its aggregator
//! stamped into the relayed run config.

use std::collections::{HashMap, HashSet};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, ensure, Context, Result};

use super::client::ClientState;
use super::codec;
use super::pool::WorkerPool;
use super::sched::{self, RoundScheduler};
use super::server::{ClientHandle, Server, ServerOpts};
use super::tolerance::{self, Arrival, RecvBudget};
use crate::config::RunConfig;
use crate::data::{self, shard, Dataset};
use crate::metrics::{RoundRecord, RunReport};
use crate::runtime::{ModelRuntime, Runtime};
use crate::sim::faults::{FaultModel, FaultProfile};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::wire::messages::{Message, PartialMeta, Update};
use crate::wire::transport::{FaultTransport, TcpTransport, Transport};

/// How many connect attempts a worker makes before giving up, and the
/// initial backoff between them (doubling, capped — see
/// [`TcpTransport::connect_retry`]).  40 attempts at 50ms initial
/// backoff spans roughly a minute, enough for a coordinator restart.
const WORKER_CONNECT_ATTEMPTS: u32 = 40;
const WORKER_CONNECT_BACKOFF: Duration = Duration::from_millis(50);

/// How many reconnect attempts an orphaned *leaf* spends on its dead
/// aggregator before degrading to direct-to-root attachment (when the
/// relayed config carries a `fallback_addr`).  10 doubling attempts at
/// 50ms span a few seconds — long enough for an aggregator restart the
/// supervisor performs promptly, short enough that a permanently lost
/// subtree does not stall its leaves for the whole run.
const DEGRADE_CONNECT_ATTEMPTS: u32 = 10;

/// Sockets re-attached by the accept thread, keyed by client id; a dead
/// [`RemoteClient`] picks its replacement up here at its next send.
/// Tree mode keys a second map of the same shape by subtree *root* id
/// for restarted aggregators ([`AggregateClient::retry_revive`]).
type RejoinMap = Arc<Mutex<HashMap<u32, (TcpTransport, Option<u32>)>>>;

/// Degraded leaves parked by the tree accept thread (one-step
/// handshake): `(leaf id, transport, samples)`, drained into
/// direct-to-root [`RemoteClient`] handles between rounds.
type DirectJoins = Arc<Mutex<Vec<(u32, TcpTransport, Option<u32>)>>>;

/// Server-side handle for one remote worker.
struct RemoteClient {
    id: u32,
    t: TcpTransport,
    /// Shard size learned from the worker's ready `Join` during the
    /// handshake (None for pre-`num_samples` workers) — lets the
    /// fold-overlap weight plan exist at round 0 instead of round 1.
    samples: Option<u32>,
    /// Set when the socket errored; cleared when a rejoined socket is
    /// picked up from the rejoin map.
    dead: bool,
    /// Shared with the accept thread (see [`RejoinMap`]).
    rejoins: RejoinMap,
    /// Wire-volume deltas not yet drained by the server's
    /// [`ClientHandle::take_io_bytes`], flushed here from a dead
    /// socket's totals at revive time so no bytes are lost across a
    /// re-attach.
    pending_up: u64,
    pending_down: u64,
    /// Current socket's totals already drained by `take_io_bytes`.
    mark_up: u64,
    mark_down: u64,
}

impl RemoteClient {
    /// If this handle is dead and the accept thread has re-attached the
    /// worker, swap the fresh socket in (carrying the byte counters
    /// over) and come back to life.
    fn revive_if_rejoined(&mut self) {
        if !self.dead {
            return;
        }
        let Some((t, samples)) = self.rejoins.lock().unwrap().remove(&self.id) else {
            return;
        };
        // Flush the dead socket's undrained volume, then start the
        // fresh socket's ledger from zero (its handshake bytes count).
        self.pending_up += self.t.bytes_received().saturating_sub(self.mark_up);
        self.pending_down += self.t.bytes_sent().saturating_sub(self.mark_down);
        self.mark_up = 0;
        self.mark_down = 0;
        self.t = t;
        // A rejoining worker re-materializes the same deterministic
        // shard, so a differing `num_samples` is a misconfigured or
        // confused worker — trusting it would silently skew the
        // aggregation weights.  Keep the original count and log;
        // only adopt the rejoiner's count when we never had one.
        match (self.samples, samples) {
            (Some(orig), Some(new)) if orig != new => {
                crate::warn_!(
                    "serve",
                    "worker {} rejoined claiming {new} samples but registered {orig}; keeping {orig}",
                    self.id
                );
            }
            (None, Some(_)) => self.samples = samples,
            _ => {}
        }
        self.dead = false;
        crate::info!("serve", "worker {} re-attached", self.id);
    }
}

impl ClientHandle for RemoteClient {
    fn id(&self) -> u32 {
        self.id
    }

    fn send(&mut self, msg: &Message) -> Result<()> {
        self.revive_if_rejoined();
        ensure!(!self.dead, "worker {} socket is dead (no rejoin yet)", self.id);
        let r = self.t.send(msg);
        if r.is_err() {
            self.dead = true;
        }
        r
    }

    fn send_broadcast(&mut self, _msg: &Message, encoded: &[u8]) -> Result<()> {
        // one encode per round (done by the server), n transmissions
        self.revive_if_rejoined();
        ensure!(!self.dead, "worker {} socket is dead (no rejoin yet)", self.id);
        let r = self.t.send_encoded(encoded);
        if r.is_err() {
            self.dead = true;
        }
        r
    }

    fn recv_update(&mut self) -> Result<Update> {
        let r = match self.t.recv() {
            Ok(Message::Update(u)) => Ok(u),
            Ok(other) => Err(anyhow::anyhow!("expected Update, got {other:?}")),
            Err(e) => Err(e),
        };
        if let Err(e) = &r {
            // A read *timeout* is the quorum path giving up on a slow
            // worker whose socket may be fine — its late update is
            // drained next round (and, with `--staleness k > 0`, banked
            // for a discounted fold).  Anything else means the
            // socket (or protocol) is broken: only a rejoin revives it.
            let timed_out = e
                .downcast_ref::<std::io::Error>()
                .map(|io| {
                    matches!(
                        io.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    )
                })
                .unwrap_or(false);
            if !timed_out {
                self.dead = true;
            }
        }
        r
    }

    fn set_recv_timeout(&mut self, timeout: Option<Duration>) -> Result<()> {
        if self.dead {
            // recv will fail fast anyway; nothing to configure.
            return Ok(());
        }
        self.t.set_read_timeout(timeout)
    }

    fn num_samples(&self) -> Option<u32> {
        self.samples
    }

    fn is_remote(&self) -> bool {
        true
    }

    fn take_io_bytes(&mut self) -> (u64, u64) {
        let up = self.t.bytes_received();
        let down = self.t.bytes_sent();
        let d_up = self.pending_up + up.saturating_sub(self.mark_up);
        let d_down = self.pending_down + down.saturating_sub(self.mark_down);
        self.pending_up = 0;
        self.pending_down = 0;
        self.mark_up = up;
        self.mark_down = down;
        (d_up, d_down)
    }
}

/// Server-side handle for one intermediate aggregator (tree topology).
///
/// The child process folds its whole subtree into one
/// [`Message::Partial`]; this handle re-shapes that partial into a
/// weight-exact fp32 *pseudo-update* ([`codec::partial_to_update`]) so
/// the server's shared fold path — sorted-key order, quorum, staleness
/// banking — treats a subtree exactly like one big client, keyed by the
/// subtree's root id.
struct AggregateClient {
    /// Lowest leaf id of the subtree — doubles as the handle's registry
    /// id, so pseudo-updates land in the canonical grouped fold order.
    lo: u32,
    t: TcpTransport,
    /// Total samples over the subtree's leaves (ready handshake).
    samples: Option<u32>,
    /// Metadata of the most recently received partial (leaf members,
    /// per-leaf samples, leaf wire bits, depth) for the server's ledger.
    meta: Option<PartialMeta>,
    model: Arc<ModelRuntime>,
    /// Set when the socket errored; cleared when a restarted aggregator
    /// is picked up from the rejoin map (keyed by subtree root id).
    dead: bool,
    /// Shared with the tree accept thread (see [`RejoinMap`]).
    rejoins: RejoinMap,
    /// Whether the most recent successful `recv_update` decoded a
    /// `Partial` (subtree composite) rather than a raw late/stale leaf
    /// `Update` the aggregator forwarded verbatim — the server's
    /// tolerant receive routes on this, never on update ids.
    last_was_partial: bool,
    /// Same byte-ledger scheme as [`RemoteClient`]: deltas pending
    /// across socket swaps + drained marks on the current socket.
    pending_up: u64,
    pending_down: u64,
    mark_up: u64,
    mark_down: u64,
}

impl AggregateClient {
    /// If this handle is dead and the accept thread has parked a
    /// restarted aggregator for this subtree root, adopt its socket.
    fn revive_if_rejoined(&mut self) {
        if !self.dead {
            return;
        }
        let Some((t, samples)) = self.rejoins.lock().unwrap().remove(&self.lo) else {
            return;
        };
        self.pending_up += self.t.bytes_received().saturating_sub(self.mark_up);
        self.pending_down += self.t.bytes_sent().saturating_sub(self.mark_down);
        self.mark_up = 0;
        self.mark_down = 0;
        self.t = t;
        // Same trust rule as a rejoining leaf: the subtree's leaves
        // re-materialize deterministic shards, so a differing total is
        // a confused aggregator — keep the registered count.
        match (self.samples, samples) {
            (Some(orig), Some(new)) if orig != new => {
                crate::warn_!(
                    "serve",
                    "aggregator {} rejoined claiming {new} samples but registered {orig}; keeping {orig}",
                    self.lo
                );
            }
            (None, Some(_)) => self.samples = samples,
            _ => {}
        }
        self.dead = false;
        crate::info!("serve", "aggregator {} re-attached", self.lo);
    }
}

impl ClientHandle for AggregateClient {
    fn id(&self) -> u32 {
        self.lo
    }

    fn send(&mut self, msg: &Message) -> Result<()> {
        self.revive_if_rejoined();
        ensure!(!self.dead, "aggregator {} socket is dead (no rejoin yet)", self.lo);
        let r = self.t.send(msg);
        if r.is_err() {
            self.dead = true;
        }
        r
    }

    fn send_broadcast(&mut self, _msg: &Message, encoded: &[u8]) -> Result<()> {
        self.revive_if_rejoined();
        ensure!(!self.dead, "aggregator {} socket is dead (no rejoin yet)", self.lo);
        let r = self.t.send_encoded(encoded);
        if r.is_err() {
            self.dead = true;
        }
        r
    }

    fn recv_update(&mut self) -> Result<Update> {
        let r = match self.t.recv() {
            Ok(Message::Partial(p)) => {
                self.meta = Some(p.meta());
                self.last_was_partial = true;
                codec::partial_to_update(&self.model.mm, &p)
            }
            // A raw late/stale leaf update the aggregator forwards
            // verbatim so the root banks the identical object the flat
            // topology would have received.
            Ok(Message::Update(u)) => {
                self.last_was_partial = false;
                Ok(u)
            }
            Ok(other) => Err(anyhow!("unexpected {other:?} from aggregator {}", self.lo)),
            Err(e) => Err(e),
        };
        if let Err(e) = &r {
            // Same discrimination as RemoteClient: a read timeout is
            // the budget expiring on a slow subtree; a broken socket
            // means the aggregator process died and only the failover
            // path ([`ClientHandle::retry_revive`]) brings it back.
            let timed_out = e
                .downcast_ref::<std::io::Error>()
                .map(|io| {
                    matches!(
                        io.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    )
                })
                .unwrap_or(false);
            if !timed_out {
                self.dead = true;
            }
        }
        r
    }

    fn set_recv_timeout(&mut self, timeout: Option<Duration>) -> Result<()> {
        if self.dead {
            return Ok(());
        }
        self.t.set_read_timeout(timeout)
    }

    fn num_samples(&self) -> Option<u32> {
        self.samples
    }

    fn take_io_bytes(&mut self) -> (u64, u64) {
        let up = self.t.bytes_received();
        let down = self.t.bytes_sent();
        let d_up = self.pending_up + up.saturating_sub(self.mark_up);
        let d_down = self.pending_down + down.saturating_sub(self.mark_down);
        self.pending_up = 0;
        self.pending_down = 0;
        self.mark_up = up;
        self.mark_down = down;
        (d_up, d_down)
    }

    fn is_aggregate(&self) -> bool {
        true
    }

    fn is_remote(&self) -> bool {
        true
    }

    fn take_partial_meta(&mut self) -> Option<PartialMeta> {
        self.meta.take()
    }

    fn last_recv_was_partial(&self) -> bool {
        self.last_was_partial
    }

    fn retry_revive(&mut self, encoded_broadcast: &[u8]) -> Result<bool> {
        ensure!(
            self.dead,
            "aggregator {} is alive — retry_revive is the failover path, not a resend",
            self.lo
        );
        self.revive_if_rejoined();
        if self.dead {
            return Ok(false);
        }
        // Re-send the round's broadcast on the fresh socket so the
        // restarted aggregator (and the leaves it re-accepted) can
        // compute the round it missed the first transmission of.
        match self.t.send_encoded(encoded_broadcast) {
            Ok(()) => Ok(true),
            Err(e) => {
                crate::warn_!(
                    "serve",
                    "aggregator {} rejoined but broadcast re-send failed: {e:#}",
                    self.lo
                );
                self.dead = true;
                Ok(false) // keep polling; another rejoin may land
            }
        }
    }
}

/// The post-handshake accept loop, run on its own thread so late joins
/// and rejoins are absorbed *while rounds run*.  Every accepted
/// connection performs the same two-step handshake as an initial join
/// (`Join` -> `Welcome` -> ready `Join`), except the `Welcome` now
/// carries the next round index; the finished socket is parked in the
/// rejoin map for the round loop's [`RemoteClient`] to pick up.  Each
/// handshake read runs under a short timeout so one wedged connection
/// cannot block later rejoins.
fn accept_rejoins(
    listener: TcpListener,
    n: usize,
    config_json: String,
    round_now: Arc<AtomicU32>,
    rejoins: RejoinMap,
    rejoined_total: Arc<AtomicU32>,
    stop: Arc<AtomicBool>,
) {
    const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(10);
    while !stop.load(Ordering::Acquire) {
        let (stream, peer) = match listener.accept() {
            Ok(s) => s,
            Err(e) => {
                crate::warn_!("serve", "accept failed: {e:#}");
                continue;
            }
        };
        if stop.load(Ordering::Acquire) {
            break; // the shutdown wake-up connection
        }
        let handshake = || -> Result<(u32, TcpTransport, Option<u32>)> {
            let mut t = TcpTransport::new(stream)?;
            t.set_read_timeout(Some(HANDSHAKE_TIMEOUT))?;
            let id = match t.recv()? {
                Message::Join { client_id, .. } => client_id,
                other => anyhow::bail!("expected Join, got {other:?}"),
            };
            ensure!((id as usize) < n, "rejoin id {id} out of range 0..{n}");
            t.send(&Message::Welcome {
                client_id: id,
                config_json: config_json.clone(),
                round: Some(round_now.load(Ordering::Acquire)),
            })?;
            let samples = match t.recv()? {
                Message::Join { client_id, num_samples } => {
                    ensure!(client_id == id, "ready Join for {client_id}, expected {id}");
                    num_samples
                }
                other => anyhow::bail!("expected ready Join, got {other:?}"),
            };
            t.set_read_timeout(None)?;
            Ok((id, t, samples))
        };
        match handshake() {
            Ok((id, t, samples)) => {
                crate::info!("serve", "worker {id} rejoined from {peer}");
                rejoins.lock().unwrap().insert(id, (t, samples));
                rejoined_total.fetch_add(1, Ordering::AcqRel);
            }
            Err(e) => crate::warn_!("serve", "rejoin handshake from {peer} failed: {e:#}"),
        }
    }
}

/// Tree-mode post-handshake accept loop: two kinds of connection land
/// here while rounds run.  A restarted `feddq aggregate` re-`Join`s
/// with `num_samples: None` (it cannot know its subtree total until its
/// leaves re-attach) and runs the two-step handshake; the ready socket
/// is parked in `agg_rejoins` keyed by subtree root id for
/// [`AggregateClient::retry_revive`] to adopt mid-round.  An orphaned
/// *leaf* that gave up on its aggregator sends a one-step `Join` that
/// already carries its shard size (its state survived — only its
/// aggregator died); it is parked in `direct_joins` for the round loop
/// to absorb as a direct-to-root [`RemoteClient`] (graceful
/// degradation).  The aggregator handshake window is generous: between
/// `Welcome` and the ready `Join` the restarted process reloads its
/// model and re-accepts its whole subtree.
#[allow(clippy::too_many_arguments)]
fn accept_tree_rejoins(
    listener: TcpListener,
    n: usize,
    fanout: usize,
    config_json: String,
    round_now: Arc<AtomicU32>,
    agg_rejoins: RejoinMap,
    direct_joins: DirectJoins,
    rejoined_total: Arc<AtomicU32>,
    stop: Arc<AtomicBool>,
) {
    const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(30);
    enum Attach {
        Aggregator(u32, TcpTransport, Option<u32>),
        Leaf(u32, TcpTransport, Option<u32>),
    }
    while !stop.load(Ordering::Acquire) {
        let (stream, peer) = match listener.accept() {
            Ok(s) => s,
            Err(e) => {
                crate::warn_!("serve", "accept failed: {e:#}");
                continue;
            }
        };
        if stop.load(Ordering::Acquire) {
            break; // the shutdown wake-up connection
        }
        let handshake = || -> Result<Attach> {
            let mut t = TcpTransport::new(stream)?;
            t.set_read_timeout(Some(HANDSHAKE_TIMEOUT))?;
            let (id, first_samples) = match t.recv()? {
                Message::Join { client_id, num_samples } => (client_id, num_samples),
                other => anyhow::bail!("expected Join, got {other:?}"),
            };
            ensure!((id as usize) < n, "rejoin id {id} out of range 0..{n}");
            t.send(&Message::Welcome {
                client_id: id,
                config_json: config_json.clone(),
                round: Some(round_now.load(Ordering::Acquire)),
            })?;
            if first_samples.is_some() {
                // One-step degraded-leaf attach.
                t.set_read_timeout(None)?;
                return Ok(Attach::Leaf(id, t, first_samples));
            }
            ensure!(
                (id as usize) % fanout == 0,
                "mid-run aggregator Join id {id} is not a subtree root for fanout {fanout}"
            );
            let samples = match t.recv()? {
                Message::Join { client_id, num_samples } => {
                    ensure!(client_id == id, "ready Join for {client_id}, expected {id}");
                    num_samples
                }
                other => anyhow::bail!("expected ready Join, got {other:?}"),
            };
            t.set_read_timeout(None)?;
            Ok(Attach::Aggregator(id, t, samples))
        };
        match handshake() {
            Ok(Attach::Aggregator(id, t, samples)) => {
                crate::info!("serve", "aggregator {id} rejoined from {peer}");
                agg_rejoins.lock().unwrap().insert(id, (t, samples));
                rejoined_total.fetch_add(1, Ordering::AcqRel);
            }
            Ok(Attach::Leaf(id, t, samples)) => {
                crate::info!("serve", "leaf {id} attached directly from {peer} (degraded)");
                direct_joins.lock().unwrap().push((id, t, samples));
            }
            Err(e) => {
                crate::warn_!("serve", "tree rejoin handshake from {peer} failed: {e:#}")
            }
        }
    }
}

/// Run the federated server: listen on `addr`, wait for `n_clients`
/// workers to join, then drive the configured rounds.  The listener
/// stays open for the whole run (on a background thread) so crashed
/// workers can rejoin; with `--quorum < 1` and/or `--round-timeout` the
/// round loop survives the gap in between.
pub fn serve(
    cfg: &RunConfig,
    addr: &str,
    mut observer: impl FnMut(u32, &RoundRecord),
) -> Result<RunReport> {
    let runtime = Runtime::new(&cfg.artifacts_dir)?;
    let model = Arc::new(runtime.load_model(&cfg.model)?);
    let n = model.mm.n_clients;
    // Server-side pool: the remote workers own their round compute, so
    // these threads only serve the server's stages (the recv/decode
    // pipeline, the sharded accumulator fold, eval slices) — sized by
    // cores, not cohort.  Declared before `server` so the server's
    // task sender drops first and the pool can join its workers.
    let server_threads = cfg.resolved_server_threads();
    let pool = WorkerPool::new(server_threads, Arc::clone(&model));
    let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
    crate::info!("serve", "listening on {addr}, waiting for {n} workers");

    let (_, test, _) = data::load_or_synthesize(
        cfg.dataset,
        &cfg.data_dir,
        cfg.train_size,
        cfg.test_size,
        cfg.seed,
    )?;

    let config_json = cfg.to_json().to_string_compact();
    if cfg.round.topology.fanout > 0 {
        // Tree topology: the sockets that join are intermediate
        // aggregators (one per subtree), not leaves — a different
        // handshake, round driver and handle type, but the same model,
        // data and server fold underneath.
        return serve_tree(
            cfg,
            listener,
            cfg.round.topology.fanout as usize,
            model,
            &pool,
            test,
            config_json,
            observer,
        );
    }
    let mut remotes: Vec<RemoteClient> = Vec::with_capacity(n);
    let rejoins: RejoinMap = Arc::new(Mutex::new(HashMap::new()));
    let mut seen = vec![false; n];
    for _ in 0..n {
        let (stream, peer) = listener.accept().context("accept")?;
        let mut t = TcpTransport::new(stream)?;
        let (id, samples) = match t.recv()? {
            Message::Join { client_id, num_samples } => (client_id, num_samples),
            other => anyhow::bail!("expected Join, got {other:?}"),
        };
        ensure!((id as usize) < n, "client id {id} out of range 0..{n} (from {peer})");
        ensure!(
            !seen[id as usize],
            "duplicate Join for client id {id} (second connection from {peer})"
        );
        seen[id as usize] = true;
        t.send(&Message::Welcome {
            client_id: id,
            config_json: config_json.clone(),
            round: None,
        })?;
        crate::info!("serve", "worker {id} joined from {peer}");
        remotes.push(RemoteClient {
            id,
            t,
            samples,
            dead: false,
            rejoins: Arc::clone(&rejoins),
            pending_up: 0,
            pending_down: 0,
            mark_up: 0,
            mark_down: 0,
        });
    }
    remotes.sort_by_key(|c| c.id);
    debug_assert!(remotes.iter().enumerate().all(|(i, c)| c.id == i as u32));

    // Ready phase: each worker re-sends `Join` once it has materialized
    // its shard, now carrying `num_samples` — the aggregation weight
    // plan the fold-overlap path needs *before* round 0's updates
    // arrive (previously the server only learned the counts from the
    // first round's updates, so TCP fold overlap started at round 1).
    // Version tolerance is at the *frame* level (`num_samples` is
    // optional on the wire, and a ready frame without it merely
    // downgrades that worker to the learn-at-round-1 behavior); the
    // handshake itself requires a same-revision worker that sends the
    // ready message — server and workers have always had to ship from
    // the same build (the run config crosses the wire in `Welcome`),
    // so a pre-ready worker would block here rather than degrade.  The
    // log line makes a stuck handshake diagnosable (workers load their
    // datasets before acking, which can legitimately take a while).
    crate::info!("serve", "waiting for {n} ready handshakes");
    for c in remotes.iter_mut() {
        match c.t.recv()? {
            Message::Join { client_id, num_samples } => {
                ensure!(
                    client_id == c.id,
                    "worker {} sent a ready Join for client {client_id}",
                    c.id
                );
                if let Some(s) = num_samples {
                    crate::info!("serve", "worker {} ready ({s} samples)", c.id);
                }
                c.samples = num_samples.or(c.samples);
            }
            other => anyhow::bail!("expected ready Join from worker {}, got {other:?}", c.id),
        }
    }
    let mut clients: Vec<Box<dyn ClientHandle + '_>> = remotes
        .into_iter()
        .map(|c| Box::new(c) as Box<dyn ClientHandle + '_>)
        .collect();

    // Hand the listener to the rejoin accept thread for the rest of the
    // run; `stop` + a self-connect wake it out of `accept()` at the end.
    let round_now = Arc::new(AtomicU32::new(0));
    let rejoined_total = Arc::new(AtomicU32::new(0));
    let stop = Arc::new(AtomicBool::new(false));
    let accept_thread = std::thread::spawn({
        let (config_json, round_now, rejoins, rejoined_total, stop) = (
            config_json.clone(),
            Arc::clone(&round_now),
            Arc::clone(&rejoins),
            Arc::clone(&rejoined_total),
            Arc::clone(&stop),
        );
        move || accept_rejoins(listener, n, config_json, round_now, rejoins, rejoined_total, stop)
    });

    let mut server = Server::new(
        Arc::clone(&model),
        Arc::new(test),
        cfg.seed as u32,
        ServerOpts {
            aggregate: cfg.aggregate,
            agg_shards: cfg.resolved_agg_shards(server_threads),
            eval_threads: cfg.resolved_eval_threads(server_threads),
            // The round policy travels whole: tolerance (quorum /
            // timeout / staleness) and pipeline shape.  Remote handles
            // carry their shard size from the ready handshake, so fold
            // overlap is active from round 0 (legacy workers without
            // `num_samples` degrade to round 1).
            round: cfg.round,
            tasks: Some(pool.sender()),
        },
    )?;
    // Same scheduler as the in-process session: sampled cohorts and
    // slowest-first dispatch.  A worker outside the round's cohort
    // simply receives no Broadcast and keeps blocking on its socket
    // until a later round selects it (or Shutdown arrives) — no wire
    // change needed, and its client-side state is untouched.
    let mut scheduler = RoundScheduler::from_config_with_arena(cfg, n, server.arena())?;
    let run = (|| -> Result<Vec<RoundRecord>> {
        let mut rounds = Vec::with_capacity(cfg.rounds);
        for m in 0..cfg.rounds {
            round_now.store(m as u32, Ordering::Release);
            let rejoined_before = rejoined_total.load(Ordering::Acquire);
            let evaluate = m % cfg.eval_every == 0 || m + 1 == cfg.rounds;
            let mut rec = sched::run_scheduled_round(
                &mut scheduler,
                &mut server,
                &mut clients,
                m as u32,
                evaluate,
            )?;
            rec.rejoined = rejoined_total.load(Ordering::Acquire) - rejoined_before;
            observer(m as u32, &rec);
            let done = cfg
                .target_accuracy
                .map(|t| rec.evaluated() && rec.test_accuracy >= t)
                .unwrap_or(false);
            rounds.push(rec);
            if done {
                break;
            }
        }
        Ok(rounds)
    })();
    // Stop the accept thread whether the run finished or aborted: set
    // the flag, then self-connect to knock it out of `accept()`.
    stop.store(true, Ordering::Release);
    let _ = TcpStream::connect(addr);
    let _ = accept_thread.join();
    let rounds = run?;
    for c in clients.iter_mut() {
        let _ = c.send(&Message::Shutdown);
    }
    Ok(RunReport {
        label: format!("{}-tcp", cfg.label()),
        model: cfg.model.clone(),
        rounds,
        params_hash: server.params_hash(),
    })
}

/// Tree-mode half of [`serve`]: accept `ceil(n / fanout)` intermediate
/// aggregators (subtree roots `0, f, 2f, ...`), then drive rounds by
/// broadcasting the leaf cohort (as the `Broadcast` frame's `cohort`
/// routing field) to exactly the subtrees that own selected leaves.
///
/// Determinism: the canonical fold order is *defined by the grouping* —
/// when `fanout > 0` the in-process engine applies the same virtual
/// grouping via [`codec::fold_partial`], so a TCP tree run is
/// bit-identical (params hash included) to the in-process run with the
/// same config.  Simulated faults compose: the scheduler's churn draws
/// run over *leaf* ids exactly as in-process, the excluded leaves
/// vanish from the broadcast's `cohort`/`late` routing fields, and the
/// leaf-granular quorum (`Server::run_round` counts partial-metadata
/// members) judges the survivors identically.
///
/// Real failures get the machinery the module docs describe: restarted
/// aggregators re-attach through [`accept_tree_rejoins`] (adopted
/// mid-round by [`AggregateClient::retry_revive`]), and orphaned leaves
/// degrade to direct-to-root handles — the first degraded leaf of a
/// subtree *retires* that subtree's aggregate handle permanently, since
/// the root id doubles as a leaf id and two live handles may not share
/// one id.
#[allow(clippy::too_many_arguments)]
fn serve_tree(
    cfg: &RunConfig,
    listener: TcpListener,
    fanout: usize,
    model: Arc<ModelRuntime>,
    pool: &WorkerPool,
    test: Dataset,
    config_json: String,
    mut observer: impl FnMut(u32, &RoundRecord),
) -> Result<RunReport> {
    let n = model.mm.n_clients;
    let g = n.div_ceil(fanout);
    crate::info!("serve", "tree topology: fanout {fanout}, {g} aggregators over {n} leaves");
    let local_addr = listener.local_addr().context("listener local addr")?;
    let agg_rejoins: RejoinMap = Arc::new(Mutex::new(HashMap::new()));
    let mut aggs: Vec<AggregateClient> = Vec::with_capacity(g);
    let mut seen = vec![false; g];
    for _ in 0..g {
        let (stream, peer) = listener.accept().context("accept")?;
        let mut t = TcpTransport::new(stream)?;
        let lo = match t.recv()? {
            Message::Join { client_id, .. } => client_id,
            other => anyhow::bail!("expected Join, got {other:?}"),
        };
        ensure!(
            (lo as usize) < n && (lo as usize) % fanout == 0,
            "aggregator id {lo} is not a subtree root for fanout {fanout} over {n} leaves \
             (from {peer})"
        );
        ensure!(
            !seen[lo as usize / fanout],
            "duplicate Join for aggregator {lo} (second connection from {peer})"
        );
        seen[lo as usize / fanout] = true;
        t.send(&Message::Welcome {
            client_id: lo,
            config_json: config_json.clone(),
            round: None,
        })?;
        crate::info!("serve", "aggregator {lo} joined from {peer}");
        aggs.push(AggregateClient {
            lo,
            t,
            samples: None,
            meta: None,
            model: Arc::clone(&model),
            dead: false,
            rejoins: Arc::clone(&agg_rejoins),
            last_was_partial: true,
            pending_up: 0,
            pending_down: 0,
            mark_up: 0,
            mark_down: 0,
        });
    }
    aggs.sort_by_key(|a| a.lo);
    // Ready phase: an aggregator acks once all of its leaves have joined
    // *it*, reporting the subtree's total samples.
    crate::info!("serve", "waiting for {g} aggregator ready handshakes");
    for a in aggs.iter_mut() {
        match a.t.recv()? {
            Message::Join { client_id, num_samples } => {
                ensure!(
                    client_id == a.lo,
                    "aggregator {} sent a ready Join for {client_id}",
                    a.lo
                );
                a.samples = num_samples;
                if let Some(s) = num_samples {
                    crate::info!("serve", "aggregator {} ready ({s} subtree samples)", a.lo);
                }
            }
            other => {
                anyhow::bail!("expected ready Join from aggregator {}, got {other:?}", a.lo)
            }
        }
    }
    let mut clients: Vec<Box<dyn ClientHandle + '_>> =
        aggs.into_iter().map(|a| Box::new(a) as Box<dyn ClientHandle + '_>).collect();

    // Hand the listener to the tree accept thread: restarted
    // aggregators and degrading leaves land there for the rest of the
    // run; `stop` + a self-connect wake it out of `accept()` at the end.
    let round_now = Arc::new(AtomicU32::new(0));
    let rejoined_total = Arc::new(AtomicU32::new(0));
    let stop = Arc::new(AtomicBool::new(false));
    let direct_joins: DirectJoins = Arc::new(Mutex::new(Vec::new()));
    let accept_thread = std::thread::spawn({
        let (config_json, round_now, agg_rejoins, direct_joins, rejoined_total, stop) = (
            config_json.clone(),
            Arc::clone(&round_now),
            Arc::clone(&agg_rejoins),
            Arc::clone(&direct_joins),
            Arc::clone(&rejoined_total),
            Arc::clone(&stop),
        );
        move || {
            accept_tree_rejoins(
                listener,
                n,
                fanout,
                config_json,
                round_now,
                agg_rejoins,
                direct_joins,
                rejoined_total,
                stop,
            )
        }
    });

    let server_threads = cfg.resolved_server_threads();
    let mut server = Server::new(
        Arc::clone(&model),
        Arc::new(test),
        cfg.seed as u32,
        ServerOpts {
            aggregate: cfg.aggregate,
            agg_shards: cfg.resolved_agg_shards(server_threads),
            eval_threads: cfg.resolved_eval_threads(server_threads),
            round: cfg.round,
            tasks: Some(pool.sender()),
        },
    )?;
    // The scheduler samples *leaves* (the same seed-pure cohorts and
    // fault/late draws as the flat topology); the tree only changes how
    // their updates travel.
    let mut scheduler = RoundScheduler::from_config_with_arena(cfg, n, server.arena())?;
    let f = fanout as u32;
    // Subtrees whose aggregate handle was retired because a leaf
    // degraded to direct attachment, and the leaf ids holding direct
    // handles (their rejoins go through `direct_rejoins`, keyed by leaf
    // id, disjoint from `agg_rejoins`' root keys by construction).
    let direct_rejoins: RejoinMap = Arc::new(Mutex::new(HashMap::new()));
    let mut retired: HashSet<u32> = HashSet::new();
    let mut direct_ids: HashSet<u32> = HashSet::new();
    let run = (|| -> Result<Vec<RoundRecord>> {
        let mut rounds = Vec::with_capacity(cfg.rounds);
        for m in 0..cfg.rounds {
            round_now.store(m as u32, Ordering::Release);
            let rejoined_before = rejoined_total.load(Ordering::Acquire);
            let evaluate = m % cfg.eval_every == 0 || m + 1 == cfg.rounds;

            // Absorb leaves that degraded to direct attachment since
            // last round.  The first degraded leaf of a subtree retires
            // that subtree's aggregate handle for good: the root id
            // doubles as a leaf id, and two live handles sharing one id
            // would corrupt the fold routing.
            let fresh: Vec<(u32, TcpTransport, Option<u32>)> =
                direct_joins.lock().unwrap().drain(..).collect();
            for (id, t, samples) in fresh {
                if direct_ids.contains(&id) {
                    // Already-degraded leaf crashed and came back: a
                    // plain rejoin of its direct handle.
                    direct_rejoins.lock().unwrap().insert(id, (t, samples));
                    continue;
                }
                let root = id / f * f;
                if retired.insert(root) {
                    if let Some(pos) =
                        clients.iter().position(|c| c.is_aggregate() && c.id() == root)
                    {
                        // Dropping the handle closes the socket; a
                        // still-running aggregator exits on the dead
                        // pipe rather than feeding a forked subtree.
                        clients.swap_remove(pos);
                    }
                    crate::warn_!(
                        "serve",
                        "leaf {id} degraded to direct attachment — retiring subtree {root} \
                         (its remaining leaves must degrade too or count as failed)"
                    );
                }
                direct_ids.insert(id);
                clients.push(Box::new(RemoteClient {
                    id,
                    t,
                    samples,
                    dead: false,
                    rejoins: Arc::clone(&direct_rejoins),
                    pending_up: 0,
                    pending_down: 0,
                    mark_up: 0,
                    mark_down: 0,
                }));
            }

            let plan = scheduler.plan_round(m as u32);
            let churn = scheduler.sim_churn(&plan);
            scheduler.note_late(m as u32, &churn.late);
            // Dispatched leaves: the cohort minus the sim-failed set —
            // the identical pre-dispatch exclusion the in-process
            // engine applies, so the broadcast's routing fields (and
            // the fold) never see a failed leaf.
            let dispatched: Vec<u32> = plan
                .selected
                .iter()
                .copied()
                .filter(|id| !churn.failed.contains(id))
                .collect();
            let late_ids: Vec<u32> = churn.late.iter().map(|&(id, _)| id).collect();
            let on_time: Vec<u32> =
                dispatched.iter().copied().filter(|id| !late_ids.contains(id)).collect();

            // The handles to drive this round: one aggregate handle per
            // live subtree owning a dispatched leaf, plus the direct
            // handles of a retired subtree's dispatched leaves.  A
            // dispatched leaf of a retired subtree that has not
            // re-attached is stranded — it counts against the
            // leaf-granular quorum like any other failure.
            let mut want: Vec<u32> = Vec::new();
            let mut degraded_now: u32 = 0;
            let mut i = 0;
            while i < dispatched.len() {
                let root = dispatched[i] / f * f;
                let mut j = i;
                while j < dispatched.len() && dispatched[j] / f * f == root {
                    j += 1;
                }
                if retired.contains(&root) {
                    for &id in &dispatched[i..j] {
                        if direct_ids.contains(&id) {
                            want.push(id);
                            degraded_now += 1;
                        } else {
                            crate::warn_!(
                                "serve",
                                "round {m}: leaf {id} of retired subtree {root} has not \
                                 re-attached — it will count as failed"
                            );
                        }
                    }
                } else {
                    want.push(root);
                }
                i = j;
            }
            let rank: HashMap<u32, usize> =
                want.iter().enumerate().map(|(i, &r)| (r, i)).collect();
            clients.sort_by_key(|c| rank.get(&c.id()).copied().unwrap_or(usize::MAX));
            server.set_cohort_hint(Some(on_time.clone()));
            server.set_late_hint(if late_ids.is_empty() {
                None
            } else {
                Some(late_ids.clone())
            });
            server.set_tree_leaf_cohort(Some((on_time.len(), churn.late.len())));
            let mut rec =
                server.run_round(m as u32, &mut clients[..want.len()], &churn.late, evaluate)?;
            // Same post-round flag forgiveness as the in-process
            // driver (`run_scheduled_round`): the budget controller's
            // flag trajectory must not depend on the topology.
            scheduler.forgive_on_time(&dispatched, &churn.late);
            // The record counts leaves, not subtree handles: a tree
            // round selects (and fails, banks, drops) the exact leaf
            // cohort the flat run would.
            rec.selected = plan.selected.len() as u32;
            rec.failed += churn.failed.len() as u32;
            rec.stale_dropped += churn.stale_dropped;
            rec.dropped = plan.dropped;
            rec.sim_makespan_secs = churn.sim_makespan_secs;
            rec.rejoined = rejoined_total.load(Ordering::Acquire) - rejoined_before;
            rec.degraded = degraded_now;
            for &(id, secs) in server.arrivals() {
                scheduler.observe(id, secs);
            }
            observer(m as u32, &rec);
            let done = cfg
                .target_accuracy
                .map(|t| rec.evaluated() && rec.test_accuracy >= t)
                .unwrap_or(false);
            rounds.push(rec);
            if done {
                break;
            }
        }
        Ok(rounds)
    })();
    // Stop the accept thread whether the run finished or aborted: set
    // the flag, then self-connect to knock it out of `accept()`.
    stop.store(true, Ordering::Release);
    let _ = TcpStream::connect(local_addr);
    let _ = accept_thread.join();
    let rounds = run?;
    for c in clients.iter_mut() {
        let _ = c.send(&Message::Shutdown);
    }
    Ok(RunReport {
        label: format!("{}-tcp-tree", cfg.label()),
        model: cfg.model.clone(),
        rounds,
        params_hash: server.params_hash(),
    })
}

/// Run one worker process: join `addr` as client `id`, then serve rounds
/// until Shutdown.  The run config arrives in the Welcome message so the
/// worker materializes exactly the same shard it would own in-process.
///
/// The connect retries (bounded, backing off), so start order does not
/// matter; a worker started *after* a crash rejoins the run in progress
/// (the `Welcome` then carries the next round index) with fresh local
/// state.  A worker whose *socket* dies mid-run keeps its state and
/// reconnects itself: first to `addr` (the flat server, or this leaf's
/// aggregator — either may have restarted), and, when the relayed
/// config carries a `fallback_addr` (stamped by `feddq aggregate`),
/// degrading to a direct root attachment after
/// [`DEGRADE_CONNECT_ATTEMPTS`] failures.  Because a rejoined subtree
/// gets its round broadcast re-sent, the worker caches its last answer
/// and replays it by round index — at-least-once delivery, exactly-once
/// compute, so local state (residual, batch cursor) advances once per
/// round no matter how often the broadcast arrives.
///
/// Setting `FEDDQ_WORKER_FAULTS` to a fault profile (e.g.
/// `crash:0.1`, `flaky:0.2` — see
/// [`FaultProfile::parse`](crate::sim::faults::FaultProfile::parse))
/// wraps the wire in a [`FaultTransport`] that injects those faults into
/// *real* sends — a chaos harness for the server's quorum/rejoin path,
/// not part of the deterministic simulation.
pub fn worker(addr: &str, id: u32, artifacts_dir: &str) -> Result<()> {
    let mut t: Box<dyn Transport> = Box::new(TcpTransport::connect_retry(
        addr,
        WORKER_CONNECT_ATTEMPTS,
        WORKER_CONNECT_BACKOFF,
    )?);
    // The initial Join can't carry the shard size yet — the run config
    // (which determines the sharding) only arrives in the Welcome.
    t.send(&Message::Join { client_id: id, num_samples: None })?;
    let (cfg, fallback) = match t.recv()? {
        Message::Welcome { client_id, config_json, round } => {
            ensure!(client_id == id, "server assigned a different id");
            if let Some(m) = round {
                crate::info!("worker", "client {id} joining a run in progress (round {m})");
            }
            let mut cfg = RunConfig::from_json_str(&config_json)?;
            cfg.artifacts_dir = artifacts_dir.to_string();
            // An aggregator stamps the root's address into the config
            // it relays, so its leaves can outlive it (see `aggregate`).
            let fallback = Json::parse(&config_json)
                .ok()
                .and_then(|j| j.get("fallback_addr").and_then(Json::as_str).map(String::from));
            (cfg, fallback)
        }
        other => anyhow::bail!("expected Welcome, got {other:?}"),
    };

    let runtime = Runtime::new(&cfg.artifacts_dir)?;
    let model = runtime.load_model(&cfg.model)?;
    let mm = &model.mm;
    ensure!((id as usize) < mm.n_clients, "worker id out of range");

    // Deterministic data pipeline: same seed -> same shards as the server
    // (and as in-process mode) without shipping data over the wire.
    let (train, _, _) = data::load_or_synthesize(
        cfg.dataset,
        &cfg.data_dir,
        cfg.train_size,
        cfg.test_size,
        cfg.seed,
    )?;
    let shards = shard::shard_indices(&train, mm.n_clients, cfg.sharding, cfg.seed);
    let my_shard = Arc::new(train.subset(&shards[id as usize]));
    let root = Rng::new(cfg.seed);
    let mut state = ClientState::with_options(
        id,
        my_shard,
        cfg.policy.build(),
        cfg.lr,
        &model,
        &root,
        cfg.error_feedback,
        cfg.round.pipeline.codec,
    )
    // The banking knob travels in the run config, so a TCP worker
    // banks its residual exactly like its in-process twin would.
    .with_ef_bits(cfg.ef_bits);
    // Chaos injection (tests/CI only): wrap the wire so this worker's
    // updates crash/stall/drop per the profile in FEDDQ_WORKER_FAULTS.
    // Parsed once and kept — a reconnected socket is re-wrapped so the
    // chaos survives the worker's own resilience.
    let fault_profile: Option<FaultProfile> = match std::env::var("FEDDQ_WORKER_FAULTS") {
        Ok(spec) if !spec.is_empty() => {
            let profile = FaultProfile::parse(&spec)
                .with_context(|| format!("FEDDQ_WORKER_FAULTS={spec:?}"))?;
            (!profile.is_off()).then_some(profile)
        }
        _ => None,
    };
    if let Some(profile) = fault_profile {
        crate::warn_!("worker", "client {id} injecting faults: {}", profile.label());
        t = Box::new(FaultTransport::new(t, FaultModel::new(profile, cfg.seed), id));
    }
    // Ready handshake: re-send Join carrying the shard size so the
    // server's fold-overlap weight plan exists before round 0.
    let samples = state.num_samples();
    t.send(&Message::Join { client_id: id, num_samples: Some(samples) })?;
    crate::info!("worker", "client {id} ready ({samples} samples)");

    let rewrap = |raw: TcpTransport| -> Box<dyn Transport> {
        match fault_profile {
            Some(profile) => Box::new(FaultTransport::new(
                Box::new(raw) as Box<dyn Transport>,
                FaultModel::new(profile, cfg.seed),
                id,
            )),
            None => Box::new(raw),
        }
    };
    // Reconnect policy: retry the upstream we joined through (it may
    // have restarted — a full two-step rejoin handshake); a leaf under
    // an aggregator that stays dead degrades to the fallback root with
    // a one-step attach (its state, and so its shard size, survived).
    let mut degraded = false;
    let reconnect = |degraded: &mut bool| -> Result<Box<dyn Transport>> {
        if *degraded {
            let fb = fallback.as_deref().expect("degraded leaf without a fallback addr");
            return Ok(rewrap(reattach(fb, WORKER_CONNECT_ATTEMPTS, false, id, samples)?));
        }
        let budget = if fallback.is_some() {
            DEGRADE_CONNECT_ATTEMPTS
        } else {
            WORKER_CONNECT_ATTEMPTS
        };
        match reattach(addr, budget, true, id, samples) {
            Ok(t) => Ok(rewrap(t)),
            Err(e) => match &fallback {
                Some(fb) => {
                    crate::warn_!(
                        "worker",
                        "client {id} giving up on aggregator {addr} ({e:#}); degrading to \
                         direct attachment at {fb}"
                    );
                    let t = reattach(fb, WORKER_CONNECT_ATTEMPTS, false, id, samples)?;
                    *degraded = true;
                    Ok(rewrap(t))
                }
                None => Err(e),
            },
        }
    };

    // Exactly-once compute under at-least-once delivery: a broadcast
    // re-sent to a rejoined subtree must not advance this leaf's
    // residual/cursor state twice, so the last answer is cached and
    // replayed by round index.
    let mut cache: Option<(u32, Update)> = None;
    // Quantized downlink (`--downlink-bits` 1..=16): this worker keeps
    // its own replica of the broadcast parameters.  A full broadcast
    // (round 0, an out-of-sync catch-up, a rejoin re-send) resets it; a
    // delta advances it from the previous round's replica with the
    // server's exact dequant arithmetic, so both land bit-identically
    // on the server-side replica.  Applying is idempotent by round — a
    // re-delivered frame of the current round is skipped.
    let down_on = (1..=16).contains(&cfg.round.budget.downlink_bits);
    let mut replica: Vec<f32> = Vec::new();
    let mut down_round: Option<u32> = None;
    loop {
        match t.recv() {
            Ok(Message::Broadcast { round, params, losses, downlink, budgets, .. }) => {
                // `cohort`/`late` are routing metadata for intermediate
                // aggregators; a leaf was sent this broadcast *because*
                // it is in one of them.
                let train_params: &[f32] = if down_on {
                    match &downlink {
                        Some(dl) => {
                            ensure!(
                                down_round == round.checked_sub(1)
                                    || down_round == Some(round),
                                "client {id} got a round-{round} delta on a \
                                 round-{down_round:?} replica"
                            );
                            if down_round != Some(round) {
                                codec::apply_downlink(&model.mm, dl, &mut replica)?;
                                down_round = Some(round);
                            }
                            &replica
                        }
                        None => {
                            ensure!(
                                params.len() == model.mm.d,
                                "full broadcast of {} params, model d = {}",
                                params.len(),
                                model.mm.d
                            );
                            replica.clear();
                            replica.extend_from_slice(&params);
                            down_round = Some(round);
                            &replica
                        }
                    }
                } else {
                    &params
                };
                let my_budget: Option<Vec<u8>> = budgets.as_ref().and_then(|b| {
                    b.iter().find(|(bid, _)| *bid == id).map(|(_, ws)| ws.clone())
                });
                let u = match &cache {
                    Some((r, u)) if *r == round => {
                        crate::info!("worker", "client {id} replaying round {round} from cache");
                        u.clone()
                    }
                    _ => {
                        let u = state.process_round(
                            &model,
                            round,
                            train_params,
                            losses,
                            my_budget.as_deref(),
                        )?;
                        cache = Some((round, u.clone()));
                        u
                    }
                };
                if let Err(e) = t.send(&Message::Update(u)) {
                    crate::warn_!("worker", "client {id} failed to send round {round}: {e:#}");
                    t = reconnect(&mut degraded)?;
                }
            }
            Ok(Message::Shutdown) => break,
            Ok(other) => anyhow::bail!("unexpected message {other:?}"),
            Err(e) => {
                crate::warn_!("worker", "client {id} lost its upstream: {e:#}; reconnecting");
                t = reconnect(&mut degraded)?;
            }
        }
    }
    crate::info!("worker", "client {id} done");
    Ok(())
}

/// Re-establish a worker's upstream connection after a socket failure.
/// `two_step` runs the full rejoin handshake (`Join(None)` → `Welcome` →
/// ready `Join`) the flat server, the tree root and a restarted
/// aggregator all expect from a leaf; a degraded direct attach is
/// one-step (the first `Join` already carries the shard size, which is
/// how the tree accept loop tells the two apart).  Handshake reads run
/// under a timeout so a listener that accepts but never answers (e.g. a
/// live aggregator past its setup phase) fails over instead of wedging.
fn reattach(
    target: &str,
    attempts: u32,
    two_step: bool,
    id: u32,
    samples: u32,
) -> Result<TcpTransport> {
    const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(10);
    let mut t = TcpTransport::connect_retry(target, attempts, WORKER_CONNECT_BACKOFF)?;
    t.set_read_timeout(Some(HANDSHAKE_TIMEOUT))?;
    if two_step {
        t.send(&Message::Join { client_id: id, num_samples: None })?;
        match t.recv()? {
            Message::Welcome { client_id, round, .. } => {
                ensure!(client_id == id, "upstream assigned a different id");
                if let Some(m) = round {
                    crate::info!("worker", "client {id} rejoined a run in progress (round {m})");
                }
            }
            other => anyhow::bail!("expected Welcome, got {other:?}"),
        }
        t.send(&Message::Join { client_id: id, num_samples: Some(samples) })?;
    } else {
        t.send(&Message::Join { client_id: id, num_samples: Some(samples) })?;
        match t.recv()? {
            Message::Welcome { client_id, .. } => {
                ensure!(client_id == id, "root assigned a different id");
            }
            other => anyhow::bail!("expected Welcome, got {other:?}"),
        }
    }
    t.set_read_timeout(None)?;
    Ok(t)
}

/// Run one intermediate aggregator: join `upstream` as subtree root
/// `lo`, accept the subtree's leaf workers on `addr` (relaying the run
/// config verbatim, so leaves cannot diverge from the server), and per
/// round relay the broadcast to the cohort members in the subtree's
/// span, fold their updates with the server's own fold kernel
/// ([`codec::fold_partial`] — weight-exact, sorted order) and uplink a
/// single [`Message::Partial`].  `fanout` must match the run's
/// `--fanout`; the subtree's leaves are `lo .. min(lo + fanout, n)`.
pub fn aggregate(
    upstream: &str,
    addr: &str,
    lo: u32,
    fanout: u32,
    artifacts_dir: &str,
) -> Result<()> {
    ensure!(fanout >= 2, "aggregator fanout must be >= 2, got {fanout}");
    let mut up = TcpTransport::connect_retry(
        upstream,
        WORKER_CONNECT_ATTEMPTS,
        WORKER_CONNECT_BACKOFF,
    )?;
    up.send(&Message::Join { client_id: lo, num_samples: None })?;
    let (cfg, config_json) = match up.recv()? {
        Message::Welcome { client_id, config_json, round } => {
            ensure!(client_id == lo, "upstream assigned a different id");
            if let Some(m) = round {
                // A restarted aggregator rejoining mid-run: the root's
                // accept thread parked this socket and the composite
                // handle will re-send the current round's broadcast
                // once the ready handshake below completes.
                crate::info!(
                    "aggregate",
                    "subtree root {lo} rejoining a run in progress (round {m})"
                );
            }
            let mut cfg = RunConfig::from_json_str(&config_json)?;
            cfg.artifacts_dir = artifacts_dir.to_string();
            (cfg, config_json)
        }
        other => anyhow::bail!("expected Welcome, got {other:?}"),
    };
    ensure!(
        cfg.round.topology.fanout == fanout,
        "--fanout {fanout} disagrees with the run's topology (fanout {})",
        cfg.round.topology.fanout
    );
    let runtime = Runtime::new(&cfg.artifacts_dir)?;
    let model = runtime.load_model(&cfg.model)?;
    let n = model.mm.n_clients;
    ensure!(
        (lo as usize) < n && (lo as usize) % fanout as usize == 0,
        "aggregator id {lo} is not a subtree root for fanout {fanout} over {n} leaves"
    );
    let span_lo = lo as usize;
    let span_hi = (span_lo + fanout as usize).min(n);
    let members: Vec<u32> = (span_lo as u32..span_hi as u32).collect();
    let mode = cfg.round.pipeline.codec;

    // Accept this subtree's leaves: the exact two-step handshake the
    // flat server runs.  The relayed config gains one key — the root's
    // address — so an orphaned leaf can degrade to a direct root
    // attachment if this process dies and never comes back.
    let leaf_config = with_fallback_addr(&config_json, upstream)?;
    let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
    crate::info!(
        "aggregate",
        "subtree {span_lo}..{span_hi} listening on {addr}, upstream {upstream}"
    );
    let mut children: Vec<(u32, TcpTransport)> = Vec::with_capacity(members.len());
    for _ in 0..members.len() {
        let (stream, peer) = listener.accept().context("accept")?;
        let mut t = TcpTransport::new(stream)?;
        let id = match t.recv()? {
            Message::Join { client_id, .. } => client_id,
            other => anyhow::bail!("expected Join, got {other:?}"),
        };
        ensure!(
            (span_lo..span_hi).contains(&(id as usize)),
            "leaf id {id} outside subtree {span_lo}..{span_hi} (from {peer})"
        );
        ensure!(
            children.iter().all(|&(c, _)| c != id),
            "duplicate Join for leaf {id} (second connection from {peer})"
        );
        t.send(&Message::Welcome {
            client_id: id,
            config_json: leaf_config.clone(),
            round: None,
        })?;
        children.push((id, t));
    }
    children.sort_by_key(|&(id, _)| id);
    // Ready phase: collect each leaf's shard size; their sum is the
    // subtree's aggregation weight numerator upstream.
    let mut total: u64 = 0;
    for (id, t) in children.iter_mut() {
        match t.recv()? {
            Message::Join { client_id, num_samples } => {
                ensure!(client_id == *id, "leaf {id} sent a ready Join for {client_id}");
                let s = num_samples
                    .with_context(|| format!("leaf {id} did not report its shard size"))?;
                total += s as u64;
            }
            other => anyhow::bail!("expected ready Join from leaf {id}, got {other:?}"),
        }
    }
    ensure!(total > 0 && total <= u32::MAX as u64, "subtree sample total {total} out of range");
    up.send(&Message::Join { client_id: lo, num_samples: Some(total as u32) })?;
    crate::info!("aggregate", "subtree {span_lo}..{span_hi} ready ({total} samples)");

    let tolerant = cfg.round.is_tolerant();
    loop {
        match up.recv()? {
            Message::Broadcast { round, params, losses, cohort, late, downlink, budgets } => {
                // Our members this round: the broadcast's on-time leaf
                // cohort and late plan intersected with the span (a
                // missing cohort field — a legacy flat server — means
                // every leaf, all on time).
                let sel: Vec<u32> = match &cohort {
                    Some(c) => {
                        c.iter().copied().filter(|&id| members.contains(&id)).collect()
                    }
                    None => members.clone(),
                };
                let late_sel: Vec<u32> = late
                    .as_deref()
                    .unwrap_or(&[])
                    .iter()
                    .copied()
                    .filter(|&id| members.contains(&id))
                    .collect();
                ensure!(
                    !sel.is_empty() || !late_sel.is_empty(),
                    "round {round} broadcast reached subtree {span_lo}..{span_hi} with no \
                     cohort member in its span"
                );
                // Downlink deltas and budget tables relay verbatim: the
                // aggregator holds no replica of its own, leaves apply
                // the delta against theirs.
                let relay =
                    Message::Broadcast { round, params, losses, cohort, late, downlink, budgets };
                let encoded = relay.encode();
                // Relay to on-time and late members alike (a late leaf
                // computes now; the root banks its forwarded update for
                // the due round), then collect: members compute in
                // parallel.  A dead child is tolerable in quorum mode —
                // the leaf-granular quorum absorbs its absence, and the
                // leaf reconnects (or degrades) on its own.
                let mut live: Vec<u32> = Vec::with_capacity(sel.len() + late_sel.len());
                for &id in sel.iter().chain(late_sel.iter()) {
                    match children[(id - lo) as usize].1.send_encoded(&encoded) {
                        Ok(()) => live.push(id),
                        Err(e) if tolerant => crate::warn_!(
                            "aggregate",
                            "round {round}: leaf {id} unreachable ({e:#}); leaving it to quorum"
                        ),
                        Err(e) => {
                            return Err(e).with_context(|| format!("broadcast to leaf {id}"))
                        }
                    }
                }
                // Tolerant collect mirrors the root's receive loop via
                // the shared tolerance core: one budget apportioned
                // across the span, arrivals classified identically.
                let budget = RecvBudget::new(cfg.round.tolerance.round_timeout);
                let mut on_time: Vec<Update> = Vec::new();
                let mut raws: Vec<Update> = Vec::new();
                for &id in &live {
                    let child = &mut children[(id - lo) as usize].1;
                    if tolerant {
                        child.set_read_timeout(budget.remaining())?;
                    }
                    // Drain until this leaf yields its answer for the
                    // round; stale backlog goes upstream raw, so the
                    // *root* makes every bank-or-drop decision and the
                    // staleness ledger matches the flat topology's.
                    loop {
                        match child.recv() {
                            Ok(Message::Update(u)) => {
                                ensure!(
                                    u.client_id == id,
                                    "leaf {id} sent an update for client {}",
                                    u.client_id
                                );
                                match tolerance::classify(u.round, round) {
                                    Arrival::OnTime => {
                                        if late_sel.contains(&id) {
                                            raws.push(u);
                                        } else {
                                            on_time.push(u);
                                        }
                                        break;
                                    }
                                    Arrival::Stale(_) => {
                                        raws.push(u);
                                        continue;
                                    }
                                    Arrival::Future => {
                                        crate::warn_!(
                                            "aggregate",
                                            "leaf {id} answered future round {} during \
                                             {round}; dropping",
                                            u.round
                                        );
                                        continue;
                                    }
                                }
                            }
                            Ok(other) if tolerant => {
                                crate::warn_!(
                                    "aggregate",
                                    "unexpected {other:?} from leaf {id}; skipping it"
                                );
                                break;
                            }
                            Ok(other) => {
                                anyhow::bail!("expected Update from leaf {id}, got {other:?}")
                            }
                            Err(e) if tolerant => {
                                crate::warn_!(
                                    "aggregate",
                                    "round {round}: leaf {id} failed ({e:#}); leaving it \
                                     to quorum"
                                );
                                break;
                            }
                            Err(e) => {
                                return Err(e)
                                    .with_context(|| format!("receive from leaf {id}"))
                            }
                        }
                    }
                }
                if tolerant {
                    for (_, t) in children.iter_mut() {
                        let _ = t.set_read_timeout(None);
                    }
                }
                // Raw forwards go upstream FIRST (ascending leaf id),
                // the subtree partial LAST — the order the root's
                // composite receive expects.
                raws.sort_by_key(|u| u.client_id);
                for u in &raws {
                    up.send(&Message::Update(u.clone()))?;
                }
                if !on_time.is_empty() {
                    let p = codec::fold_partial(&model.mm, round, lo, &on_time, mode, 1)?;
                    up.send(&Message::Partial(p))?;
                } else if !sel.is_empty() {
                    crate::warn_!(
                        "aggregate",
                        "round {round}: no on-time survivor in subtree {span_lo}..{span_hi}; \
                         nothing to uplink (the root counts the span failed)"
                    );
                }
            }
            Message::Shutdown => {
                for (_, t) in children.iter_mut() {
                    let _ = t.send(&Message::Shutdown);
                }
                break;
            }
            other => anyhow::bail!("unexpected message {other:?}"),
        }
    }
    crate::info!("aggregate", "subtree {span_lo}..{span_hi} done");
    Ok(())
}

/// Stamp `fallback_addr` (the tree root's address, i.e. this
/// aggregator's `--upstream`) into a run-config JSON string, preserving
/// every other key.  [`RunConfig::from_json_str`] ignores unknown keys,
/// so the stamped config parses identically on the leaf; only the
/// degradation path in [`worker`] reads the extra key.
fn with_fallback_addr(config_json: &str, upstream: &str) -> Result<String> {
    let mut j = Json::parse(config_json)?;
    match &mut j {
        Json::Obj(o) => {
            o.insert("fallback_addr".to_string(), Json::Str(upstream.to_string()));
        }
        _ => anyhow::bail!("run config JSON is not an object"),
    }
    Ok(j.to_string_compact())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loopback() -> TcpTransport {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        drop(client); // revive never touches the socket
        TcpTransport::new(server).unwrap()
    }

    fn dead_handle(id: u32, samples: Option<u32>, rejoins: &RejoinMap) -> RemoteClient {
        RemoteClient {
            id,
            t: loopback(),
            samples,
            dead: true,
            rejoins: Arc::clone(rejoins),
            pending_up: 0,
            pending_down: 0,
            mark_up: 0,
            mark_down: 0,
        }
    }

    #[test]
    fn rejoin_with_mismatched_num_samples_keeps_the_registered_count() {
        // A rejoining worker re-materializes the same deterministic
        // shard, so a differing claim is a confused worker — adopting it
        // would silently skew the aggregation weights mid-run.
        let rejoins: RejoinMap = Arc::new(Mutex::new(HashMap::new()));
        let mut c = dead_handle(7, Some(60), &rejoins);
        rejoins.lock().unwrap().insert(7, (loopback(), Some(9999)));
        c.revive_if_rejoined();
        assert!(!c.dead, "rejoin must revive the handle");
        assert_eq!(c.num_samples(), Some(60), "registered sample count must win");
    }

    #[test]
    fn rejoin_supplies_num_samples_when_none_was_registered() {
        // Pre-`num_samples` handshakes leave the server without a
        // count: the rejoiner's claim is the only one there is.
        let rejoins: RejoinMap = Arc::new(Mutex::new(HashMap::new()));
        let mut c = dead_handle(8, None, &rejoins);
        rejoins.lock().unwrap().insert(8, (loopback(), Some(42)));
        c.revive_if_rejoined();
        assert!(!c.dead);
        assert_eq!(c.num_samples(), Some(42), "absent count adopts the rejoiner's");
    }
}
