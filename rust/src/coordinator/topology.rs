//! Multi-process topology: `feddq serve` runs the server and accepts TCP
//! workers; `feddq worker` runs one client in its own process with its own
//! PJRT runtime.  The wire traffic is byte-identical to the in-process
//! session (same `Message` encoding, same framing), so measured volumes
//! agree across modes.

use std::net::TcpListener;
use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use super::client::ClientState;
use super::pool::WorkerPool;
use super::sched::{self, RoundScheduler};
use super::server::{ClientHandle, Server, ServerOpts};
use crate::config::RunConfig;
use crate::data::{self, shard};
use crate::metrics::{RoundRecord, RunReport};
use crate::runtime::Runtime;
use crate::util::rng::Rng;
use crate::wire::messages::{Message, Update};
use crate::wire::transport::{TcpTransport, Transport};

/// Server-side handle for one remote worker.
struct RemoteClient {
    id: u32,
    t: TcpTransport,
    /// Shard size learned from the worker's ready `Join` during the
    /// handshake (None for pre-`num_samples` workers) — lets the
    /// fold-overlap weight plan exist at round 0 instead of round 1.
    samples: Option<u32>,
}

impl ClientHandle for RemoteClient {
    fn id(&self) -> u32 {
        self.id
    }

    fn send(&mut self, msg: &Message) -> Result<()> {
        self.t.send(msg)
    }

    fn send_broadcast(&mut self, _msg: &Message, encoded: &[u8]) -> Result<()> {
        // one encode per round (done by the server), n transmissions
        self.t.send_encoded(encoded)
    }

    fn recv_update(&mut self) -> Result<Update> {
        match self.t.recv()? {
            Message::Update(u) => Ok(u),
            other => anyhow::bail!("expected Update, got {other:?}"),
        }
    }

    fn num_samples(&self) -> Option<u32> {
        self.samples
    }

    fn uplink_bytes(&self) -> u64 {
        self.t.bytes_received()
    }

    fn downlink_bytes(&self) -> u64 {
        self.t.bytes_sent()
    }
}

/// Run the federated server: listen on `addr`, wait for `n_clients`
/// workers to join, then drive the configured rounds.
pub fn serve(
    cfg: &RunConfig,
    addr: &str,
    mut observer: impl FnMut(u32, &RoundRecord),
) -> Result<RunReport> {
    let runtime = Runtime::new(&cfg.artifacts_dir)?;
    let model = Arc::new(runtime.load_model(&cfg.model)?);
    let n = model.mm.n_clients;
    // Server-side pool: the remote workers own their round compute, so
    // these threads only serve the server's stages (the recv/decode
    // pipeline, the sharded accumulator fold, eval slices) — sized by
    // cores, not cohort.  Declared before `server` so the server's
    // task sender drops first and the pool can join its workers.
    let server_threads = cfg.resolved_server_threads();
    let pool = WorkerPool::new(server_threads, Arc::clone(&model));
    let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
    crate::info!("serve", "listening on {addr}, waiting for {n} workers");

    let (_, test, _) = data::load_or_synthesize(
        cfg.dataset,
        &cfg.data_dir,
        cfg.train_size,
        cfg.test_size,
        cfg.seed,
    )?;

    let config_json = cfg.to_json().to_string_compact();
    let mut remotes: Vec<RemoteClient> = Vec::with_capacity(n);
    for _ in 0..n {
        let (stream, peer) = listener.accept().context("accept")?;
        let mut t = TcpTransport::new(stream)?;
        let (id, samples) = match t.recv()? {
            Message::Join { client_id, num_samples } => (client_id, num_samples),
            other => anyhow::bail!("expected Join, got {other:?}"),
        };
        ensure!((id as usize) < n, "client id {id} out of range");
        t.send(&Message::Welcome { client_id: id, config_json: config_json.clone() })?;
        crate::info!("serve", "worker {id} joined from {peer}");
        remotes.push(RemoteClient { id, t, samples });
    }
    remotes.sort_by_key(|c| c.id);
    for (i, c) in remotes.iter().enumerate() {
        ensure!(c.id == i as u32, "duplicate or missing client ids");
    }

    // Ready phase: each worker re-sends `Join` once it has materialized
    // its shard, now carrying `num_samples` — the aggregation weight
    // plan the fold-overlap path needs *before* round 0's updates
    // arrive (previously the server only learned the counts from the
    // first round's updates, so TCP fold overlap started at round 1).
    // Version tolerance is at the *frame* level (`num_samples` is
    // optional on the wire, and a ready frame without it merely
    // downgrades that worker to the learn-at-round-1 behavior); the
    // handshake itself requires a same-revision worker that sends the
    // ready message — server and workers have always had to ship from
    // the same build (the run config crosses the wire in `Welcome`),
    // so a pre-ready worker would block here rather than degrade.  The
    // log line makes a stuck handshake diagnosable (workers load their
    // datasets before acking, which can legitimately take a while).
    crate::info!("serve", "waiting for {n} ready handshakes");
    for c in remotes.iter_mut() {
        match c.t.recv()? {
            Message::Join { client_id, num_samples } => {
                ensure!(
                    client_id == c.id,
                    "worker {} sent a ready Join for client {client_id}",
                    c.id
                );
                if let Some(s) = num_samples {
                    crate::info!("serve", "worker {} ready ({s} samples)", c.id);
                }
                c.samples = num_samples.or(c.samples);
            }
            other => anyhow::bail!("expected ready Join from worker {}, got {other:?}", c.id),
        }
    }
    let mut clients: Vec<Box<dyn ClientHandle + '_>> = remotes
        .into_iter()
        .map(|c| Box::new(c) as Box<dyn ClientHandle + '_>)
        .collect();

    let mut server = Server::new(
        Arc::clone(&model),
        Arc::new(test),
        cfg.seed as u32,
        ServerOpts {
            aggregate: cfg.aggregate,
            agg_shards: cfg.resolved_agg_shards(server_threads),
            eval_threads: cfg.resolved_eval_threads(server_threads),
            // Remote handles carry their shard size from the ready
            // handshake, so fold overlap is active from round 0 (legacy
            // workers without `num_samples` degrade to round 1).
            fold_overlap: cfg.fold_overlap,
            decode_buffers: cfg.decode_buffers,
            codec: cfg.codec,
            tasks: Some(pool.sender()),
        },
    )?;
    // Same scheduler as the in-process session: sampled cohorts and
    // slowest-first dispatch.  A worker outside the round's cohort
    // simply receives no Broadcast and keeps blocking on its socket
    // until a later round selects it (or Shutdown arrives) — no wire
    // change needed, and its client-side state is untouched.
    let mut scheduler = RoundScheduler::from_config(cfg, n)?;
    let mut rounds = Vec::with_capacity(cfg.rounds);
    for m in 0..cfg.rounds {
        let evaluate = m % cfg.eval_every == 0 || m + 1 == cfg.rounds;
        let rec = sched::run_scheduled_round(
            &mut scheduler,
            &mut server,
            &mut clients,
            m as u32,
            evaluate,
        )?;
        observer(m as u32, &rec);
        let done = cfg
            .target_accuracy
            .map(|t| rec.evaluated() && rec.test_accuracy >= t)
            .unwrap_or(false);
        rounds.push(rec);
        if done {
            break;
        }
    }
    for c in clients.iter_mut() {
        let _ = c.send(&Message::Shutdown);
    }
    Ok(RunReport {
        label: format!("{}-tcp", cfg.label()),
        model: cfg.model.clone(),
        rounds,
        params_hash: server.params_hash(),
    })
}

/// Run one worker process: join `addr` as client `id`, then serve rounds
/// until Shutdown.  The run config arrives in the Welcome message so the
/// worker materializes exactly the same shard it would own in-process.
pub fn worker(addr: &str, id: u32, artifacts_dir: &str) -> Result<()> {
    let mut t = TcpTransport::connect(addr)?;
    // The initial Join can't carry the shard size yet — the run config
    // (which determines the sharding) only arrives in the Welcome.
    t.send(&Message::Join { client_id: id, num_samples: None })?;
    let cfg = match t.recv()? {
        Message::Welcome { client_id, config_json } => {
            ensure!(client_id == id, "server assigned a different id");
            let mut cfg = RunConfig::from_json_str(&config_json)?;
            cfg.artifacts_dir = artifacts_dir.to_string();
            cfg
        }
        other => anyhow::bail!("expected Welcome, got {other:?}"),
    };

    let runtime = Runtime::new(&cfg.artifacts_dir)?;
    let model = runtime.load_model(&cfg.model)?;
    let mm = &model.mm;
    ensure!((id as usize) < mm.n_clients, "worker id out of range");

    // Deterministic data pipeline: same seed -> same shards as the server
    // (and as in-process mode) without shipping data over the wire.
    let (train, _, _) = data::load_or_synthesize(
        cfg.dataset,
        &cfg.data_dir,
        cfg.train_size,
        cfg.test_size,
        cfg.seed,
    )?;
    let shards = shard::shard_indices(&train, mm.n_clients, cfg.sharding, cfg.seed);
    let my_shard = Arc::new(train.subset(&shards[id as usize]));
    let root = Rng::new(cfg.seed);
    let mut state = ClientState::with_options(
        id, my_shard, cfg.policy.build(), cfg.lr, &model, &root, cfg.error_feedback, cfg.codec,
    );
    // Ready handshake: re-send Join carrying the shard size so the
    // server's fold-overlap weight plan exists before round 0.
    t.send(&Message::Join { client_id: id, num_samples: Some(state.num_samples()) })?;
    crate::info!("worker", "client {id} ready ({} samples)", state.num_samples());

    loop {
        match t.recv()? {
            Message::Broadcast { round, params, losses } => {
                let u = state.process_round(&model, round, &params, losses)?;
                t.send(&Message::Update(u))?;
            }
            Message::Shutdown => break,
            other => anyhow::bail!("unexpected message {other:?}"),
        }
    }
    crate::info!("worker", "client {id} done");
    Ok(())
}
