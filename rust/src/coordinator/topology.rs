//! Multi-process topology: `feddq serve` runs the server and accepts TCP
//! workers; `feddq worker` runs one client in its own process with its own
//! PJRT runtime.  The wire traffic is byte-identical to the in-process
//! session (same `Message` encoding, same framing), so measured volumes
//! agree across modes.

use std::net::TcpListener;
use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use super::client::ClientState;
use super::pool::WorkerPool;
use super::server::{ClientHandle, Server, ServerOpts};
use crate::config::RunConfig;
use crate::data::{self, shard};
use crate::metrics::{RoundRecord, RunReport};
use crate::runtime::Runtime;
use crate::util::rng::Rng;
use crate::wire::messages::{Message, Update};
use crate::wire::transport::{TcpTransport, Transport};

/// Server-side handle for one remote worker.
struct RemoteClient {
    id: u32,
    t: TcpTransport,
}

impl ClientHandle for RemoteClient {
    fn id(&self) -> u32 {
        self.id
    }

    fn send(&mut self, msg: &Message) -> Result<()> {
        self.t.send(msg)
    }

    fn send_broadcast(&mut self, _msg: &Message, encoded: &[u8]) -> Result<()> {
        // one encode per round (done by the server), n transmissions
        self.t.send_encoded(encoded)
    }

    fn recv_update(&mut self) -> Result<Update> {
        match self.t.recv()? {
            Message::Update(u) => Ok(u),
            other => anyhow::bail!("expected Update, got {other:?}"),
        }
    }

    fn uplink_bytes(&self) -> u64 {
        self.t.bytes_received()
    }

    fn downlink_bytes(&self) -> u64 {
        self.t.bytes_sent()
    }
}

/// Run the federated server: listen on `addr`, wait for `n_clients`
/// workers to join, then drive the configured rounds.
pub fn serve(
    cfg: &RunConfig,
    addr: &str,
    mut observer: impl FnMut(u32, &RoundRecord),
) -> Result<RunReport> {
    let runtime = Runtime::new(&cfg.artifacts_dir)?;
    let model = Arc::new(runtime.load_model(&cfg.model)?);
    let n = model.mm.n_clients;
    // Server-side pool: the remote workers own their round compute, so
    // these threads only serve the server's stages (the recv/decode
    // pipeline, the sharded accumulator fold, eval slices) — sized by
    // cores, not cohort.  Declared before `server` so the server's
    // task sender drops first and the pool can join its workers.
    let server_threads = cfg.resolved_server_threads();
    let pool = WorkerPool::new(server_threads, Arc::clone(&model));
    let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
    crate::info!("serve", "listening on {addr}, waiting for {n} workers");

    let (_, test, _) = data::load_or_synthesize(
        cfg.dataset,
        &cfg.data_dir,
        cfg.train_size,
        cfg.test_size,
        cfg.seed,
    )?;

    let config_json = cfg.to_json().to_string_compact();
    let mut clients: Vec<Box<dyn ClientHandle + '_>> = Vec::with_capacity(n);
    for _ in 0..n {
        let (stream, peer) = listener.accept().context("accept")?;
        let mut t = TcpTransport::new(stream)?;
        let id = match t.recv()? {
            Message::Join { client_id } => client_id,
            other => anyhow::bail!("expected Join, got {other:?}"),
        };
        ensure!((id as usize) < n, "client id {id} out of range");
        t.send(&Message::Welcome { client_id: id, config_json: config_json.clone() })?;
        crate::info!("serve", "worker {id} joined from {peer}");
        clients.push(Box::new(RemoteClient { id, t }));
    }
    clients.sort_by_key(|c| c.id());
    for (i, c) in clients.iter().enumerate() {
        ensure!(c.id() == i as u32, "duplicate or missing client ids");
    }

    let mut server = Server::new(
        Arc::clone(&model),
        Arc::new(test),
        cfg.seed as u32,
        ServerOpts {
            aggregate: cfg.aggregate,
            agg_shards: cfg.resolved_agg_shards(server_threads),
            eval_threads: cfg.resolved_eval_threads(server_threads),
            // Remote handles don't know their shard size up front, so
            // fold overlap kicks in from round 1 (the server learns the
            // counts from round 0's updates).
            fold_overlap: cfg.fold_overlap,
            decode_buffers: cfg.decode_buffers,
            tasks: Some(pool.sender()),
        },
    )?;
    let mut rounds = Vec::with_capacity(cfg.rounds);
    for m in 0..cfg.rounds {
        let evaluate = m % cfg.eval_every == 0 || m + 1 == cfg.rounds;
        let rec = server.run_round(m as u32, &mut clients, evaluate)?;
        observer(m as u32, &rec);
        let done = cfg
            .target_accuracy
            .map(|t| rec.evaluated() && rec.test_accuracy >= t)
            .unwrap_or(false);
        rounds.push(rec);
        if done {
            break;
        }
    }
    for c in clients.iter_mut() {
        let _ = c.send(&Message::Shutdown);
    }
    Ok(RunReport {
        label: format!("{}-tcp", cfg.label()),
        model: cfg.model.clone(),
        rounds,
        params_hash: server.params_hash(),
    })
}

/// Run one worker process: join `addr` as client `id`, then serve rounds
/// until Shutdown.  The run config arrives in the Welcome message so the
/// worker materializes exactly the same shard it would own in-process.
pub fn worker(addr: &str, id: u32, artifacts_dir: &str) -> Result<()> {
    let mut t = TcpTransport::connect(addr)?;
    t.send(&Message::Join { client_id: id })?;
    let cfg = match t.recv()? {
        Message::Welcome { client_id, config_json } => {
            ensure!(client_id == id, "server assigned a different id");
            let mut cfg = RunConfig::from_json_str(&config_json)?;
            cfg.artifacts_dir = artifacts_dir.to_string();
            cfg
        }
        other => anyhow::bail!("expected Welcome, got {other:?}"),
    };

    let runtime = Runtime::new(&cfg.artifacts_dir)?;
    let model = runtime.load_model(&cfg.model)?;
    let mm = &model.mm;
    ensure!((id as usize) < mm.n_clients, "worker id out of range");

    // Deterministic data pipeline: same seed -> same shards as the server
    // (and as in-process mode) without shipping data over the wire.
    let (train, _, _) = data::load_or_synthesize(
        cfg.dataset,
        &cfg.data_dir,
        cfg.train_size,
        cfg.test_size,
        cfg.seed,
    )?;
    let shards = shard::shard_indices(&train, mm.n_clients, cfg.sharding, cfg.seed);
    let my_shard = Arc::new(train.subset(&shards[id as usize]));
    let root = Rng::new(cfg.seed);
    let mut state = ClientState::with_options(
        id, my_shard, cfg.policy.build(), cfg.lr, &model, &root, cfg.error_feedback,
    );
    crate::info!("worker", "client {id} ready ({} samples)", state.num_samples());

    loop {
        match t.recv()? {
            Message::Broadcast { round, params, losses } => {
                let u = state.process_round(&model, round, &params, losses)?;
                t.send(&Message::Update(u))?;
            }
            Message::Shutdown => break,
            other => anyhow::bail!("unexpected message {other:?}"),
        }
    }
    crate::info!("worker", "client {id} done");
    Ok(())
}
