//! Closed-loop bit-budget control: the server-side half of doubly
//! adaptive quantization (DAdaQuant-style cross-*client* adaptation on
//! top of the policies' cross-*time* adaptation).
//!
//! [`BitBudgetController`] splits a round-level uplink payload budget
//! (`--bit-budget <bits>`) across the dispatched cohort, FedFQ-style:
//! per client *and* per segment, so an expensive client is throttled to
//! fewer bits instead of being dropped.  The resulting per-segment
//! widths ride the `Broadcast` to each client, where they clamp the
//! policy's own decision (`min(policy_level, max_level_for_bits(w))`)
//! before the existing `QuantPlan` encode path runs — the controller
//! never invents a new encoder.
//!
//! **Determinism.**  The controller's inputs are restricted to state
//! that is bit-identical across threads, shard counts and topologies:
//! the arena's seeded per-round outcome flags
//! ([`FLAG_LATE`]/[`FLAG_DROPPED`], written from the scheduler's seeded
//! churn simulation) and the controller's *own* cumulative
//! allocated-bits ledger.  Wall-clock EWMAs and real socket byte
//! counts are deliberately excluded: they differ run-to-run and
//! topology-to-topology, and one divergent input would break the
//! repo-wide contract that any (threads, shards, fanout) combination
//! yields an identical `RunReport`.  For the same reason the ledger
//! tracks bits the controller *allocated*, not bits that actually hit
//! the wire — at a tree root only subtree totals are observable, so
//! observed bits are not per-leaf reconstructible.
//!
//! **Accounting.**  The cap covers *payload* bits only (code bits,
//! `Σ_l seg_size_l * width_l` per client).  Segment headers are a
//! fixed small tax (`SEGMENT_HEADER_BITS` per segment) independent of
//! the controller's choices, so including them would only shift every
//! allocation by a constant.
//!
//! [`FLAG_LATE`]: crate::coordinator::arena::FLAG_LATE
//! [`FLAG_DROPPED`]: crate::coordinator::arena::FLAG_DROPPED

/// Widest per-segment width the controller will allocate, matching the
/// narrow-codec ceiling (`u16` code rows).
pub const MAX_WIDTH: u8 = 16;

/// Splits a round-level uplink payload budget across the dispatched
/// cohort, per client per segment.  See the module docs for the
/// determinism and accounting rules.
#[derive(Clone, Debug)]
pub struct BitBudgetController {
    /// Round-level payload budget in bits (never 0 — a zero budget
    /// means the controller is not constructed at all).
    cap: u64,
    /// Element count per model segment.
    seg_sizes: Vec<u64>,
    /// `Σ seg_sizes`: one client's floor cost (1 bit/element).
    d: u64,
    /// Per-client total payload bits allocated last time the client was
    /// in a cohort; `u64::MAX` = never budgeted (unconstrained).
    /// Flagged (late/dropped) clients may never exceed this.
    prev_bits: Vec<u64>,
    /// Per-client cumulative allocated payload bits — the controller's
    /// fairness ledger (cheapest-so-far clients are raised first).
    cum_bits: Vec<u64>,
}

impl BitBudgetController {
    /// A controller for `cap` payload bits per round over a model with
    /// the given per-segment element counts.
    pub fn new(cap: u64, seg_sizes: Vec<u64>) -> BitBudgetController {
        let d = seg_sizes.iter().sum();
        debug_assert!(cap > 0, "a zero budget should not construct a controller");
        debug_assert!(d > 0, "budgeting an empty model");
        BitBudgetController { cap, seg_sizes, d, prev_bits: Vec::new(), cum_bits: Vec::new() }
    }

    fn slot(v: &mut Vec<u64>, id: u32, fill: u64) -> &mut u64 {
        let i = id as usize;
        if i >= v.len() {
            v.resize(i + 1, fill);
        }
        &mut v[i]
    }

    /// Cumulative payload bits allocated to `id` so far.
    pub fn cum_allocated(&self, id: u32) -> u64 {
        self.cum_bits.get(id as usize).copied().unwrap_or(0)
    }

    /// Allocate this round's budget over the dispatched cohort, given
    /// each member's seeded outcome flag (late/dropped last round).
    /// Returns `(client_id, per-segment widths in bits)` sorted by id.
    ///
    /// Every member gets the 1 bit/segment floor unconditionally — a
    /// cap below `cohort * d` is allowed to overshoot rather than send
    /// a 0-bit (empty) update.  Above the floor, a deterministic
    /// greedy raises one segment of one client at a time: unflagged
    /// before flagged, then lowest cumulative allocation, then lowest
    /// id; within a client, the narrowest segment first (ties to the
    /// lowest index).  A flagged client's total may never exceed its
    /// previous allocation, so a slow client's budget is monotonically
    /// non-increasing until it completes a round cleanly.
    pub fn plan(&mut self, cohort: &[(u32, bool)]) -> Vec<(u32, Vec<u8>)> {
        let nseg = self.seg_sizes.len();
        let mut members: Vec<(u32, bool)> = cohort.to_vec();
        members.sort_by_key(|&(id, _)| id);
        members.dedup_by_key(|&mut (id, _)| id);
        if members.is_empty() {
            return Vec::new();
        }

        // Floor: 1 bit per element for everyone.
        let mut widths: Vec<Vec<u8>> = vec![vec![1u8; nseg]; members.len()];
        let mut totals: Vec<u64> = vec![self.d; members.len()];
        let mut spent: u64 = self.d * members.len() as u64;

        // Greedy raises while the cap has room.  Each raise picks the
        // eligible member with (unflagged, lowest cum ledger, lowest
        // id) and widens its narrowest segment by one bit.
        let mut order: Vec<usize> = (0..members.len()).collect();
        order.sort_by_key(|&i| {
            let (id, flagged) = members[i];
            (flagged as u8, self.cum_allocated(id), id)
        });
        loop {
            let mut raised = false;
            for &i in &order {
                let (id, flagged) = members[i];
                // narrowest raisable segment, ties to the lowest index
                let Some(l) = (0..nseg)
                    .filter(|&l| widths[i][l] < MAX_WIDTH)
                    .min_by_key(|&l| (widths[i][l], l))
                else {
                    continue;
                };
                let cost = self.seg_sizes[l];
                if spent + cost > self.cap {
                    continue;
                }
                if flagged {
                    let prev = self.prev_bits.get(id as usize).copied().unwrap_or(u64::MAX);
                    if totals[i] + cost > prev {
                        continue;
                    }
                }
                widths[i][l] += 1;
                totals[i] += cost;
                spent += cost;
                raised = true;
            }
            if !raised {
                break;
            }
        }

        for (i, &(id, _)) in members.iter().enumerate() {
            *Self::slot(&mut self.prev_bits, id, u64::MAX) = totals[i];
            *Self::slot(&mut self.cum_bits, id, 0) += totals[i];
        }
        members
            .iter()
            .enumerate()
            .map(|(i, &(id, _))| (id, std::mem::take(&mut widths[i])))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn total(seg_sizes: &[u64], widths: &[u8]) -> u64 {
        seg_sizes.iter().zip(widths).map(|(&s, &w)| s * w as u64).sum()
    }

    const SEGS: [u64; 3] = [5, 4, 3]; // d = 12

    #[test]
    fn conservation_when_cap_covers_the_floor() {
        let mut c = BitBudgetController::new(200, SEGS.to_vec());
        let plan = c.plan(&[(0, false), (1, false), (2, false)]);
        assert_eq!(plan.len(), 3);
        let spent: u64 = plan.iter().map(|(_, w)| total(&SEGS, w)).sum();
        assert!(spent <= 200, "allocated {spent} > cap 200");
        // and the greedy actually uses the room: within one raise of the cap
        assert!(spent + SEGS.iter().min().unwrap() > 200 - SEGS.iter().max().unwrap());
        for (_, w) in &plan {
            assert!(w.iter().all(|&b| (1..=MAX_WIDTH).contains(&b)));
        }
    }

    #[test]
    fn starved_cohort_still_gets_the_one_bit_floor() {
        // cap 20 < 3 clients * d 12: floor wins over conservation
        let mut c = BitBudgetController::new(20, SEGS.to_vec());
        let plan = c.plan(&[(5, true), (6, false), (7, true)]);
        assert_eq!(plan.len(), 3);
        for (_, w) in &plan {
            assert_eq!(w, &vec![1u8; 3], "starved clients still send 1 bit/segment");
        }
    }

    #[test]
    fn flagged_client_budget_never_grows() {
        let mut c = BitBudgetController::new(300, SEGS.to_vec());
        // round 0: clean, client 1 gets some allocation
        let p0 = c.plan(&[(0, false), (1, false)]);
        let t0 = total(&SEGS, &p0.iter().find(|(id, _)| *id == 1).unwrap().1);
        // rounds 1..: client 1 flagged — its total must never exceed t0,
        // even when the round cap would allow more
        let mut prev = t0;
        for _ in 0..4 {
            let p = c.plan(&[(0, false), (1, true)]);
            let t = total(&SEGS, &p.iter().find(|(id, _)| *id == 1).unwrap().1);
            assert!(t <= prev, "flagged client grew {prev} -> {t}");
            prev = t;
        }
        // after a clean round the constraint lifts
        let p = c.plan(&[(1, false)]);
        let t = total(&SEGS, &p.iter().find(|(id, _)| *id == 1).unwrap().1);
        assert!(t >= prev, "a clean round may restore the budget");
    }

    #[test]
    fn unflagged_clients_are_raised_before_flagged() {
        let mut c = BitBudgetController::new(50, SEGS.to_vec());
        // prior round so client 9 has a prev ceiling
        c.plan(&[(8, false), (9, false)]);
        let p = c.plan(&[(8, false), (9, true)]);
        let t8 = total(&SEGS, &p.iter().find(|(id, _)| *id == 8).unwrap().1);
        let t9 = total(&SEGS, &p.iter().find(|(id, _)| *id == 9).unwrap().1);
        assert!(t8 >= t9, "clean client {t8} must not trail flagged client {t9}");
    }

    #[test]
    fn allocations_replay_from_inputs_alone() {
        // Identical input sequences → identical plans: no hidden clock,
        // RNG, or wire feedback.  This is what lets a report reader
        // re-derive every budget from the report's own telemetry.
        let rounds: Vec<Vec<(u32, bool)>> = vec![
            vec![(0, false), (1, false), (2, false)],
            vec![(0, true), (2, false)],
            vec![(0, true), (1, false), (2, true)],
            vec![(1, false)],
        ];
        let mut a = BitBudgetController::new(160, SEGS.to_vec());
        let mut b = BitBudgetController::new(160, SEGS.to_vec());
        for cohort in &rounds {
            assert_eq!(a.plan(cohort), b.plan(cohort));
        }
        assert_eq!(a.cum_allocated(0), b.cum_allocated(0));
    }

    #[test]
    fn plan_output_is_sorted_and_deduped() {
        let mut c = BitBudgetController::new(100, SEGS.to_vec());
        let p = c.plan(&[(3, false), (1, true), (3, false), (2, false)]);
        let ids: Vec<u32> = p.iter().map(|(id, _)| *id).collect();
        assert_eq!(ids, vec![1, 2, 3]);
    }

    #[test]
    fn widths_cap_at_sixteen_with_a_huge_budget() {
        let mut c = BitBudgetController::new(u64::MAX / 2, SEGS.to_vec());
        let p = c.plan(&[(0, false)]);
        assert_eq!(p[0].1, vec![MAX_WIDTH; 3]);
    }

    #[test]
    fn empty_cohort_is_a_no_op() {
        let mut c = BitBudgetController::new(100, SEGS.to_vec());
        assert!(c.plan(&[]).is_empty());
        assert_eq!(c.cum_allocated(0), 0);
    }

    #[test]
    fn fairness_ledger_prefers_the_cheaper_history() {
        // Client 0 was budgeted alone for a round; when 0 and 4 later
        // share a tight cap, 4 (lower cumulative ledger) is raised first.
        let mut c = BitBudgetController::new(40, SEGS.to_vec());
        c.plan(&[(0, false)]);
        let p = c.plan(&[(0, false), (4, false)]);
        let t0 = total(&SEGS, &p.iter().find(|(id, _)| *id == 0).unwrap().1);
        let t4 = total(&SEGS, &p.iter().find(|(id, _)| *id == 4).unwrap().1);
        assert!(t4 >= t0, "ledger-cheap client {t4} must not trail {t0}");
    }
}
